"""AOT compile path: lower L2 graphs (which embed the L1 Pallas kernels)
to HLO **text** artifacts for the rust PJRT runtime.

HLO text — NOT ``lowered.compile().serialize()`` and NOT the proto bytes:
jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids, which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`).  The HLO *text* parser reassigns ids, so text round-trips
cleanly (see /opt/xla-example/README.md).

Lowered with ``return_tuple=True``; the rust side unwraps the tuple.

Usage: ``python -m compile.aot --out ../artifacts`` (the Makefile runs this
once; python never executes on the estimation path).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all() -> dict:
    """Lower every artifact; returns name -> (hlo_text, spec dict)."""
    arts = {}

    for dim in (1, 2):
        lowered = jax.jit(model.gp_posterior_fn).lower(*model.example_args_posterior(dim))
        arts[f"gp_posterior_d{dim}"] = (
            to_hlo_text(lowered),
            {
                "kind": "gp_posterior",
                "dim": dim,
                "n_inducing": model.N_INDUCING,
                "n_queries": model.N_QUERIES,
                "inputs": ["xq", "xi", "alpha", "kinv", "lengthscale", "variance"],
                "outputs": ["mean", "variance"],
            },
        )

    lowered = jax.jit(model.cnn_train_step).lower(*model.example_args_train())
    arts["cnn_train_step"] = (
        to_hlo_text(lowered),
        {
            "kind": "train_step",
            "batch": model.BATCH,
            "img": model.IMG,
            "c1": model.C1,
            "c2": model.C2,
            "n_classes": model.N_CLASSES,
            "inputs": ["x", "y", "w1", "b1", "w2", "b2", "wf", "bf", "m1", "m2", "lr"],
            "outputs": ["w1", "b1", "w2", "b2", "wf", "bf", "loss", "acc"],
        },
    )

    lowered = jax.jit(model.cnn_eval).lower(*model.example_args_eval())
    arts["cnn_eval"] = (
        to_hlo_text(lowered),
        {
            "kind": "eval",
            "batch": model.BATCH,
            "img": model.IMG,
            "c1": model.C1,
            "c2": model.C2,
            "inputs": ["x", "y", "w1", "b1", "w2", "b2", "wf", "bf", "m1", "m2"],
            "outputs": ["loss", "acc"],
        },
    )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    for name, (text, spec) in lower_all().items():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {**spec, "file": f"{name}.hlo.txt", "bytes": len(text)}
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
