"""L2: JAX compute graphs, AOT-lowered to HLO text by aot.py.

Two families:

* ``gp_posterior_fn`` — the estimation hot path: batched GP posterior
  (mean, variance) over a padded query block, backed by the fused L1
  Pallas kernel (`kernels.gp_posterior`).  The rust coordinator calls the
  compiled artifact for every layer-family prediction during estimation,
  acquisition, and the pruning search.

* ``cnn_train_step`` / ``cnn_eval`` — a real training workload: a masked
  two-conv CNN (im2col + the L1 Pallas matmul kernel, so fwd AND bwd run
  through Pallas) with inline SGD.  Used by the end-to-end example, the
  Fig-6 time/energy experiment and the Fig-13 pruning case study; channel
  masks let one artifact serve every pruned sub-network.

Shapes are fixed at AOT time (PJRT executables are shape-specialized);
`aot.py` records them in artifacts/manifest.json.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import matmul as pk_matmul
from .kernels import gp_posterior as pk_posterior

# ---------------------------------------------------------------------------
# GP posterior (estimation hot path)
# ---------------------------------------------------------------------------

# Padded artifact shapes: N inducing points, Q queries per call.  Padded
# inducing rows carry zero alpha and zero K⁻¹ rows/cols (exactness proven in
# tests/test_posterior.py::test_padding_invariance).
N_INDUCING = 64
N_QUERIES = 256


def gp_posterior_fn(xq, xi, alpha, kinv, lengthscale, variance):
    """(Q, D) queries -> ((Q,) mean, (Q,) variance), via the fused L1 kernel."""
    mean, var = pk_posterior.gp_posterior(xq, xi, alpha, kinv, lengthscale, variance)
    return mean, var


# ---------------------------------------------------------------------------
# CNN train step (real workload)
# ---------------------------------------------------------------------------

BATCH = 16
IMG = 16          # 16x16 single-channel synthetic images
C1, C2 = 8, 16    # full (unpruned) channel counts
N_CLASSES = 2     # CelebA-gender-like binary task


def _im2col_conv(x, w, b):
    """3x3 SAME conv as im2col + Pallas matmul.  x: (B, H, W, Cin),
    w: (3, 3, Cin, Cout), b: (Cout,)."""
    bsz, h, wd, cin = x.shape
    cout = w.shape[-1]
    patches = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(3, 3), window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, H, W, Cin*9) with feature order (Cin, 3, 3)
    cols = patches.reshape(bsz * h * wd, cin * 9)
    # conv_general_dilated_patches emits features as (Cin, KH, KW); reorder
    # the weight to match.
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * 9, cout)
    out = pk_matmul.matmul(cols, wmat).reshape(bsz, h, wd, cout)
    return out + b


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _forward(params, x, m1, m2):
    w1, b1, w2, b2, wf, bf = params
    h = jax.nn.relu(_im2col_conv(x, w1, b1)) * m1          # (B,16,16,C1)
    h = _maxpool2(h)                                       # (B,8,8,C1)
    h = jax.nn.relu(_im2col_conv(h, w2, b2)) * m2          # (B,8,8,C2)
    h = _maxpool2(h)                                       # (B,4,4,C2)
    h = h.reshape(h.shape[0], -1)                          # (B, 4*4*C2)
    logits = pk_matmul.matmul(h, wf) + bf                  # (B, N_CLASSES)
    return logits


def _loss_acc(params, x, y, m1, m2):
    logits = _forward(params, x, m1, m2)
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(y, N_CLASSES)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc


def cnn_train_step(x, y, w1, b1, w2, b2, wf, bf, m1, m2, lr):
    """One SGD step.  Returns (w1', b1', w2', b2', wf', bf', loss, acc).

    `m1`/`m2` are {0,1} channel masks (pruning); gradients flow only to
    surviving channels because masked activations are exactly zero.
    """
    params = (w1, b1, w2, b2, wf, bf)
    (loss, acc), grads = jax.value_and_grad(_loss_acc, has_aux=True)(
        params, x, y, m1, m2
    )
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new, loss, acc)


def cnn_eval(x, y, w1, b1, w2, b2, wf, bf, m1, m2):
    """Forward-only loss/accuracy on a batch (held-out evaluation)."""
    loss, acc = _loss_acc((w1, b1, w2, b2, wf, bf), x, y, m1, m2)
    return loss, acc


def init_params(key):
    """He-initialized full-width parameters (the rust trainer re-implements
    this exactly; fixture parity is tested)."""
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jax.random.normal(k1, (3, 3, 1, C1)) * (2.0 / 9.0) ** 0.5
    b1 = jnp.zeros((C1,))
    w2 = jax.random.normal(k2, (3, 3, C1, C2)) * (2.0 / (9.0 * C1)) ** 0.5
    b2 = jnp.zeros((C2,))
    wf = jax.random.normal(k3, (4 * 4 * C2, N_CLASSES)) * (2.0 / (4 * 4 * C2)) ** 0.5
    bf = jnp.zeros((N_CLASSES,))
    return w1, b1, w2, b2, wf, bf


def example_args_train():
    """ShapeDtypeStructs for AOT lowering of cnn_train_step."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((BATCH, IMG, IMG, 1), f32),
        jax.ShapeDtypeStruct((BATCH,), jnp.int32),
        jax.ShapeDtypeStruct((3, 3, 1, C1), f32),
        jax.ShapeDtypeStruct((C1,), f32),
        jax.ShapeDtypeStruct((3, 3, C1, C2), f32),
        jax.ShapeDtypeStruct((C2,), f32),
        jax.ShapeDtypeStruct((4 * 4 * C2, N_CLASSES), f32),
        jax.ShapeDtypeStruct((N_CLASSES,), f32),
        jax.ShapeDtypeStruct((C1,), f32),
        jax.ShapeDtypeStruct((C2,), f32),
        jax.ShapeDtypeStruct((), f32),
    )


def example_args_eval():
    return example_args_train()[:10]


def example_args_posterior(dim: int):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((N_QUERIES, dim), f32),
        jax.ShapeDtypeStruct((N_INDUCING, dim), f32),
        jax.ShapeDtypeStruct((N_INDUCING,), f32),
        jax.ShapeDtypeStruct((N_INDUCING, N_INDUCING), f32),
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((), f32),
    )
