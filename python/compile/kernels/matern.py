"""L1 Pallas kernel: tiled Matérn-5/2 cross-covariance matrix.

TPU mental model (see DESIGN.md §Hardware-Adaptation): each grid step keeps
one (TILE_M, D) block of queries and one (TILE_N, D) block of inducing
points in VMEM, forms the (TILE_M, TILE_N) squared-distance tile through an
MXU-shaped `x @ z.T` plus rank-1 row/col corrections, and applies the
closed-form Matérn-5/2 response elementwise on the VPU.  The BlockSpec grid
is the HBM↔VMEM schedule a CUDA implementation would express with
threadblocks.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust runtime
runs unmodified.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 5.0 ** 0.5

# Default tile sizes.  D (feature dim) is always tiny (1 or 2 here), so the
# VMEM footprint per grid step is TILE_M*D + TILE_N*D + TILE_M*TILE_N f32
# ≈ 64*64*4 B = 16 KiB for the default tiles — far below the ~16 MiB VMEM
# budget, leaving room for double buffering (see EXPERIMENTS.md §Perf for
# the sweep).
TILE_M = 64
TILE_N = 64


def _matern_kernel(x_ref, z_ref, ls_ref, var_ref, o_ref):
    x = x_ref[...]                                   # (TM, D)
    z = z_ref[...]                                   # (TN, D)
    ls = ls_ref[0]
    var = var_ref[0]
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)      # (TM, 1)
    z2 = jnp.sum(z * z, axis=-1, keepdims=True).T    # (1, TN)
    # MXU-shaped cross term; accumulate in f32 regardless of input dtype.
    cross = jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(x2 + z2 - 2.0 * cross, 0.0)
    r = jnp.sqrt(d2 + 1e-12)
    s = SQRT5 * r / ls
    o_ref[...] = (var * (1.0 + s + s * s / 3.0) * jnp.exp(-s)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n"))
def matern52(x, z, lengthscale, variance, *, tile_m: int = TILE_M, tile_n: int = TILE_N):
    """Matérn-5/2 cross-covariance k(x, z), shapes (M, D), (N, D) -> (M, N).

    M and N must be multiples of the tile sizes (aot.py pads; the pytest
    sweep covers exact and padded shapes through the public wrapper).
    """
    m, d = x.shape
    n, _ = z.shape
    assert m % tile_m == 0 and n % tile_n == 0, (m, n, tile_m, tile_n)
    ls = jnp.asarray(lengthscale, jnp.float32).reshape(1)
    var = jnp.asarray(variance, jnp.float32).reshape(1)
    grid = (m // tile_m, n // tile_n)
    return pl.pallas_call(
        _matern_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), z.astype(jnp.float32), ls, var)


def matern52_padded(x, z, lengthscale, variance):
    """Convenience wrapper that pads M/N up to tile multiples and slices back."""
    m, n = x.shape[0], z.shape[0]
    mp = -(-m // TILE_M) * TILE_M
    np_ = -(-n // TILE_N) * TILE_N
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    zp = jnp.pad(z, ((0, np_ - n), (0, 0)))
    return matern52(xp, zp, lengthscale, variance)[:m, :n]
