"""L1 Pallas kernel: tiled matmul with a custom VJP.

This is the compute hot-spot of the L2 train step (conv layers are lowered
to im2col matmuls, FC layers are matmuls).  The backward pass reuses the
same kernel on transposed operands (dA = dY @ Bᵀ, dB = Aᵀ @ dY), so the
whole train step — forward AND backward — runs through Pallas.

The kernel keeps an f32 accumulator tile in VMEM scratch across the K
grid dimension (classic MXU schedule: output-stationary, K-innermost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 128
TILE_K = 128


def _pad_dim(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# The f32 accumulator lives in the output ref (output-stationary: the same
# (TM, TN) output tile is revisited across the K grid dimension, which
# Pallas keeps resident in VMEM between consecutive grid steps).
def _matmul_accum_out(a, b, tm, tn, tk):
    m, k = a.shape
    _, n = b.shape
    grid = (m // tm, n // tn, k // tk)
    k_steps = grid[2]

    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, l: (i, l)),
            pl.BlockSpec((tk, tn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """`a @ b` through the Pallas tile kernel, any (M, K) x (K, N) f32."""
    return _matmul_impl(a, b)


def _matmul_impl(a, b):
    m, k = a.shape
    _, n = b.shape
    tm = min(TILE_M, -(-m // 8) * 8 if m < TILE_M else TILE_M)
    tn = min(TILE_N, -(-n // 8) * 8 if n < TILE_N else TILE_N)
    tk = min(TILE_K, -(-k // 8) * 8 if k < TILE_K else TILE_K)
    ap = _pad_dim(_pad_dim(a.astype(jnp.float32), tm, 0), tk, 1)
    bp = _pad_dim(_pad_dim(b.astype(jnp.float32), tk, 0), tn, 1)
    out = _matmul_accum_out(ap, bp, tm, tn, tk)
    return out[:m, :n]


def _matmul_fwd(a, b):
    return _matmul_impl(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    da = _matmul_impl(g, b.T)
    db = _matmul_impl(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
