"""L1 Pallas kernel: fused GP posterior (mean + variance) — the estimation
hot path.

One grid step handles a (TILE_Q, D) block of query points against the FULL
(padded) inducing set: the whole (N, D) inducing matrix, the (N,) alpha
vector and the (N, N) precision matrix stay resident in VMEM across the
grid (N ≤ 128 → K⁻¹ is ≤ 64 KiB f32), so the kernel is a single pass over
HBM for the queries:

    kstar = matern52(q_tile, Xi)              (TILE_Q, N)   VPU + MXU
    mean  = kstar @ alpha                     (TILE_Q,)     MXU
    tmp   = kstar @ Kinv                      (TILE_Q, N)   MXU
    var   = sigma2 - rowsum(tmp * kstar)      (TILE_Q,)     VPU

Fusing mean and variance into one kernel means kstar is computed once and
never round-trips to HBM — this is the paper-relevant hot spot because the
pruning search (Fig 13) and the end-to-end sweeps (Fig 8) evaluate 10⁴-10⁵
candidate layer configurations per run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 5.0 ** 0.5

TILE_Q = 128


def _posterior_kernel(xq_ref, xi_ref, alpha_ref, kinv_ref, ls_ref, var_ref,
                      mean_ref, varo_ref):
    xq = xq_ref[...]                                 # (TQ, D)
    xi = xi_ref[...]                                 # (N, D)
    ls = ls_ref[0]
    sigma2 = var_ref[0]
    # -- Matérn-5/2 cross-covariance tile (same closed form as matern.py) --
    q2 = jnp.sum(xq * xq, axis=-1, keepdims=True)
    i2 = jnp.sum(xi * xi, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(
        xq, xi, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(q2 + i2 - 2.0 * cross, 0.0)
    r = jnp.sqrt(d2 + 1e-12)
    s = SQRT5 * r / ls
    kstar = sigma2 * (1.0 + s + s * s / 3.0) * jnp.exp(-s)   # (TQ, N)
    # -- fused posterior --
    mean_ref[...] = jax.lax.dot_general(
        kstar, alpha_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    tmp = jax.lax.dot_general(
        kstar, kinv_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    varo_ref[...] = sigma2 - jnp.sum(tmp * kstar, axis=-1)


@functools.partial(jax.jit, static_argnames=("tile_q",))
def gp_posterior(xq, xi, alpha, kinv, lengthscale, variance, *, tile_q: int = TILE_Q):
    """Posterior mean/var at `xq` (Q, D) given inducing set `xi` (N, D),
    `alpha = K⁻¹y` (N,) and `kinv = K⁻¹` (N, N).  Q must be a multiple of
    tile_q.  Padded inducing rows must carry zero alpha and zero kinv
    rows/cols (see ref.gp_posterior)."""
    q, d = xq.shape
    n, _ = xi.shape
    assert q % tile_q == 0, (q, tile_q)
    ls = jnp.asarray(lengthscale, jnp.float32).reshape(1)
    var = jnp.asarray(variance, jnp.float32).reshape(1)
    grid = (q // tile_q,)
    return pl.pallas_call(
        _posterior_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),      # resident
            pl.BlockSpec((n,), lambda i: (0,)),          # resident
            pl.BlockSpec((n, n), lambda i: (0, 0)),      # resident
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q,), lambda i: (i,)),
            pl.BlockSpec((tile_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.float32),
            jax.ShapeDtypeStruct((q,), jnp.float32),
        ],
        interpret=True,
    )(xq.astype(jnp.float32), xi.astype(jnp.float32),
      alpha.astype(jnp.float32), kinv.astype(jnp.float32), ls, var)
