"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact counterpart here; pytest
asserts `assert_allclose(pallas(...), ref(...))` across hypothesis-driven
shape/parameter sweeps.  These oracles are also what `rust/src/gp` is
validated against (the rust integration tests reproduce the same closed
forms and the runtime cross-check compares artifact outputs to them).
"""
from __future__ import annotations

import jax.numpy as jnp

SQRT5 = 5.0 ** 0.5


def sq_dists(x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared Euclidean distances, (M, D) x (N, D) -> (M, N)."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (M, 1)
    z2 = jnp.sum(z * z, axis=-1, keepdims=True).T        # (1, N)
    d2 = x2 + z2 - 2.0 * (x @ z.T)
    return jnp.maximum(d2, 0.0)


def matern52(x: jnp.ndarray, z: jnp.ndarray, lengthscale, variance) -> jnp.ndarray:
    """Matérn ν=5/2 cross-covariance (closed form, no Bessel needed).

    k(r) = σ² (1 + √5 r/ℓ + 5 r²/(3ℓ²)) exp(−√5 r/ℓ)
    """
    r = jnp.sqrt(sq_dists(x, z) + 1e-12)
    s = SQRT5 * r / lengthscale
    return variance * (1.0 + s + s * s / 3.0) * jnp.exp(-s)


def rbf(x: jnp.ndarray, z: jnp.ndarray, lengthscale, variance) -> jnp.ndarray:
    """Squared-exponential kernel — used in the A15 kernel ablation."""
    return variance * jnp.exp(-0.5 * sq_dists(x, z) / (lengthscale * lengthscale))


def gp_posterior(xq, xi, alpha, kinv, lengthscale, variance):
    """GP posterior mean and variance at query points.

    mean(q) = k(q, Xi) @ alpha,   alpha = K⁻¹ y
    var(q)  = σ² − k(q, Xi) @ K⁻¹ @ k(q, Xi)ᵀ   (diagonal only)

    Padding convention: rows of `xi` beyond the real inducing set must come
    with zero `alpha` entries and zero `kinv` rows/columns, which leaves
    both mean and variance untouched.
    """
    kstar = matern52(xq, xi, lengthscale, variance)      # (Q, N)
    mean = kstar @ alpha                                 # (Q,)
    tmp = kstar @ kinv                                   # (Q, N)
    var = variance - jnp.sum(tmp * kstar, axis=-1)       # (Q,)
    return mean, var


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a @ b
