"""L1 correctness: Pallas Matérn-5/2 kernel vs the pure-jnp oracle.

hypothesis sweeps shapes, dims and hyper-parameters per the repro spec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matern, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _data(key, m, n, d, scale):
    kx, kz = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(kx, (m, d)) * scale
    z = jax.random.normal(kz, (n, d)) * scale
    return x, z


@given(
    key=st.integers(0, 2**31 - 1),
    m=st.integers(1, 150),
    n=st.integers(1, 150),
    d=st.sampled_from([1, 2, 3]),
    ls=st.floats(0.05, 10.0),
    var=st.floats(0.1, 50.0),
)
def test_matern_matches_ref(key, m, n, d, ls, var):
    x, z = _data(key, m, n, d, 2.0)
    got = matern.matern52_padded(x, z, ls, var)
    want = ref.matern52(x, z, ls, var)
    # f32: tiny lengthscales make exp(-√5 r/ℓ) extremely steep, so a
    # one-ulp distance difference moves the result by ~1e-4 relative.
    tol = 2e-4 if ls < 0.1 else 2e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_exact_tile_shapes():
    """Shapes that are exact tile multiples skip the padding path."""
    x, z = _data(7, 128, 64, 2, 1.0)
    got = matern.matern52(x, z, 1.0, 1.0)
    want = ref.matern52(x, z, 1.0, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_diagonal_is_variance():
    """k(x, x) == sigma^2 (up to the 1e-12 distance-jitter)."""
    x, _ = _data(3, 64, 1, 2, 1.0)
    k = matern.matern52(x, x, 0.5, 3.0)
    np.testing.assert_allclose(np.asarray(jnp.diag(k)), 3.0, rtol=1e-3)


def test_symmetry():
    x, _ = _data(11, 64, 1, 2, 1.0)
    k = np.asarray(matern.matern52(x, x, 0.8, 2.0))
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)


def test_psd():
    """Gram matrix must be positive semi-definite (kernel validity)."""
    x, _ = _data(13, 64, 1, 2, 1.5)
    k = np.asarray(matern.matern52(x, x, 0.8, 2.0))
    eig = np.linalg.eigvalsh(k)
    assert eig.min() > -1e-4, eig.min()


def test_decay_with_distance():
    """Covariance is monotonically non-increasing in distance."""
    x = jnp.zeros((1, 1))
    z = jnp.linspace(0.0, 10.0, 64).reshape(64, 1)
    k = np.asarray(matern.matern52_padded(x, z, 1.0, 1.0))[0]
    assert np.all(np.diff(k) <= 1e-7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    """Inputs in bf16 still accumulate in f32 (preferred_element_type)."""
    x, z = _data(5, 64, 64, 2, 1.0)
    got = matern.matern52(x.astype(dtype), z.astype(dtype), 1.0, 1.0)
    want = ref.matern52(x, z, 1.0, 1.0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)
