"""L1 correctness: fused GP posterior kernel vs the jnp oracle and vs the
textbook GP formulas; padding-invariance (the property the rust runtime
relies on when it pads inducing sets to N_INDUCING)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import gp_posterior as pk, ref
from compile import model

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _gp_problem(key, n, d, ls=0.8, var=2.0, noise=0.05, smooth_y=False):
    kx, ky = jax.random.split(jax.random.PRNGKey(key))
    xi = jax.random.normal(kx, (n, d))
    if smooth_y:
        # A function the GP prior can actually represent — required for the
        # interpolation sanity test (random y at closely-spaced points is
        # not smooth and the posterior rightly refuses to interpolate it).
        y = jnp.sin(2.0 * jnp.sum(xi, axis=-1))
    else:
        y = jax.random.normal(ky, (n,))
    kmat = ref.matern52(xi, xi, ls, var) + noise * jnp.eye(n)
    kinv = jnp.linalg.inv(kmat)
    alpha = kinv @ y
    return xi, y, kinv, alpha, ls, var


@given(
    key=st.integers(0, 2**31 - 1),
    n=st.sampled_from([8, 16, 32, 64, 128]),
    d=st.sampled_from([1, 2]),
    q_tiles=st.integers(1, 3),
)
def test_posterior_matches_ref(key, n, d, q_tiles):
    xi, _, kinv, alpha, ls, var = _gp_problem(key, n, d)
    xq = jax.random.normal(jax.random.PRNGKey(key + 1), (128 * q_tiles, d))
    m1, v1 = pk.gp_posterior(xq, xi, alpha, kinv, ls, var)
    m2, v2 = ref.gp_posterior(xq, xi, alpha, kinv, ls, var)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4, atol=1e-4)


def test_posterior_interpolates_training_targets():
    """With small noise the posterior mean at inducing points ≈ y and the
    variance there is far below the prior variance (textbook GP sanity).
    Noise is kept at 1e-2: the whole pipeline is f32, and a 32-point Matérn
    gram with 1e-4 jitter is too ill-conditioned to invert in f32."""
    xi, y, kinv, alpha, ls, var = _gp_problem(3, 32, 1, noise=1e-2, smooth_y=True)
    xq = jnp.pad(xi, ((0, 128 - 32), (0, 0)))
    mean, varo = pk.gp_posterior(xq, xi, alpha, kinv, ls, var)
    resid = np.abs(np.asarray(mean[:32]) - np.asarray(y))
    assert resid.max() < 0.15, resid.max()
    assert float(jnp.max(varo[:32])) < 0.1 * var


def test_variance_positive_and_bounded():
    xi, _, kinv, alpha, ls, var = _gp_problem(5, 64, 2)
    xq = jax.random.normal(jax.random.PRNGKey(9), (256, 2)) * 3.0
    _, v = pk.gp_posterior(xq, xi, alpha, kinv, ls, var)
    v = np.asarray(v)
    assert v.min() > -1e-4         # numerically non-negative
    assert v.max() <= var + 1e-4   # never exceeds the prior variance


@given(key=st.integers(0, 2**31 - 1), n_real=st.integers(2, 60))
def test_padding_invariance(key, n_real):
    """Zero-padded inducing rows (zero alpha, zero K⁻¹ rows/cols) must not
    change the posterior — this is the contract the AOT artifact exposes to
    the rust runtime for variable-size inducing sets."""
    d = 2
    xi, _, kinv, alpha, ls, var = _gp_problem(key, n_real, d)
    xq = jax.random.normal(jax.random.PRNGKey(key + 7), (128, d))

    n_pad = model.N_INDUCING
    xi_p = jnp.pad(xi, ((0, n_pad - n_real), (0, 0)))
    alpha_p = jnp.pad(alpha, (0, n_pad - n_real))
    kinv_p = jnp.pad(kinv, ((0, n_pad - n_real), (0, n_pad - n_real)))

    m_ref, v_ref = ref.gp_posterior(xq, xi, alpha, kinv, ls, var)
    m_pad, v_pad = pk.gp_posterior(xq, xi_p, alpha_p, kinv_p, ls, var)
    np.testing.assert_allclose(np.asarray(m_pad), np.asarray(m_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(v_pad), np.asarray(v_ref), rtol=1e-4, atol=1e-4)
