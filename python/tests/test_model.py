"""L2 correctness: the CNN train step (learning, masking, shape contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _toy_batch(key=0):
    """A linearly separable synthetic 'gender' task: class = sign of the
    mean of the top half minus the bottom half of the image."""
    kx = jax.random.PRNGKey(key)
    x = jax.random.normal(kx, (model.BATCH, model.IMG, model.IMG, 1))
    top = jnp.mean(x[:, : model.IMG // 2], axis=(1, 2, 3))
    bot = jnp.mean(x[:, model.IMG // 2 :], axis=(1, 2, 3))
    y = (top > bot).astype(jnp.int32)
    return x, y


def test_loss_decreases():
    params = model.init_params(jax.random.PRNGKey(0))
    x, y = _toy_batch()
    m1, m2 = jnp.ones((model.C1,)), jnp.ones((model.C2,))
    losses = []
    for _ in range(8):
        out = model.cnn_train_step(x, y, *params, m1, m2, jnp.float32(0.1))
        params = out[:6]
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_masked_channels_receive_no_gradient():
    """Pruned (masked) conv-2 channels must stay bit-identical after a step."""
    params = model.init_params(jax.random.PRNGKey(1))
    x, y = _toy_batch(1)
    m1 = jnp.ones((model.C1,))
    m2 = jnp.ones((model.C2,)).at[3].set(0.0).at[7].set(0.0)
    out = model.cnn_train_step(x, y, *params, m1, m2, jnp.float32(0.5))
    w2_new, b2_new = out[2], out[3]
    np.testing.assert_array_equal(np.asarray(w2_new[..., 3]), np.asarray(params[2][..., 3]))
    np.testing.assert_array_equal(np.asarray(b2_new[7]), np.asarray(params[3][7]))


def test_full_mask_equals_no_mask_fc_grad():
    """All-ones masks are a no-op (masking is multiplicative identity)."""
    params = model.init_params(jax.random.PRNGKey(2))
    x, y = _toy_batch(2)
    ones1, ones2 = jnp.ones((model.C1,)), jnp.ones((model.C2,))
    out = model.cnn_train_step(x, y, *params, ones1, ones2, jnp.float32(0.1))
    loss_eval, acc_eval = model.cnn_eval(x, y, *params, ones1, ones2)
    # eval loss on the pre-step params equals the train-step's reported loss
    np.testing.assert_allclose(float(out[-2]), float(loss_eval), rtol=1e-5)
    assert 0.0 <= float(acc_eval) <= 1.0


def test_output_shapes():
    params = model.init_params(jax.random.PRNGKey(3))
    x, y = _toy_batch(3)
    m1, m2 = jnp.ones((model.C1,)), jnp.ones((model.C2,))
    out = model.cnn_train_step(x, y, *params, m1, m2, jnp.float32(0.1))
    assert len(out) == 8
    for new, old in zip(out[:6], params):
        assert new.shape == old.shape and new.dtype == old.dtype
    assert out[-2].shape == () and out[-1].shape == ()


@pytest.mark.parametrize("lr", [0.0, 0.05, 0.5])
def test_lr_zero_is_identity(lr):
    params = model.init_params(jax.random.PRNGKey(4))
    x, y = _toy_batch(4)
    m1, m2 = jnp.ones((model.C1,)), jnp.ones((model.C2,))
    out = model.cnn_train_step(x, y, *params, m1, m2, jnp.float32(lr))
    if lr == 0.0:
        for new, old in zip(out[:6], params):
            np.testing.assert_array_equal(np.asarray(new), np.asarray(old))
    else:
        assert any(
            not np.array_equal(np.asarray(new), np.asarray(old))
            for new, old in zip(out[:6], params)
        )
