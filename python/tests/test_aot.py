"""AOT path validation: the lowering used by `make artifacts` emits
parseable HLO text with the expected entry signatures — the contract the
rust runtime (HloModuleProto::from_text_file) depends on."""
import jax

from compile import aot, model


def test_lower_all_produces_hlo_text():
    arts = aot.lower_all()
    assert set(arts) == {"gp_posterior_d1", "gp_posterior_d2", "cnn_train_step", "cnn_eval"}
    for name, (text, spec) in arts.items():
        assert "HloModule" in text.splitlines()[0], name
        assert "ENTRY" in text, name
        assert spec["inputs"] and spec["outputs"], name


def test_posterior_entry_shapes_match_manifest():
    arts = aot.lower_all()
    text, spec = arts["gp_posterior_d1"]
    n, q = spec["n_inducing"], spec["n_queries"]
    # the entry computation layout names the padded shapes
    assert f"f32[{q},1]" in text
    assert f"f32[{n},{n}]" in text


def test_train_step_output_arity():
    text, spec = aot.lower_all()["cnn_train_step"]
    assert len(spec["outputs"]) == 8  # 6 params + loss + acc
    # lowered with return_tuple=True: a tuple root exists
    assert "tuple(" in text


def test_lowering_is_deterministic():
    a = aot.to_hlo_text(jax.jit(model.cnn_eval).lower(*model.example_args_eval()))
    b = aot.to_hlo_text(jax.jit(model.cnn_eval).lower(*model.example_args_eval()))
    assert a == b
