"""L1 correctness: Pallas tiled matmul + its custom VJP vs jnp."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pk

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(
    key=st.integers(0, 2**31 - 1),
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
)
def test_matmul_matches_jnp(key, m, k, n):
    ka, kb = jax.random.split(jax.random.PRNGKey(key))
    a = jax.random.normal(ka, (m, k))
    b = jax.random.normal(kb, (k, n))
    np.testing.assert_allclose(
        np.asarray(pk.matmul(a, b)), np.asarray(a @ b), rtol=1e-4, atol=1e-4
    )


@given(key=st.integers(0, 2**31 - 1), m=st.integers(2, 96), k=st.integers(2, 96), n=st.integers(2, 96))
def test_matmul_vjp(key, m, k, n):
    """The backward pass (dA = g Bᵀ, dB = Aᵀ g) also runs through Pallas."""
    ka, kb = jax.random.split(jax.random.PRNGKey(key))
    a = jax.random.normal(ka, (m, k))
    b = jax.random.normal(kb, (k, n))

    def f_pk(a, b):
        return jnp.sum(jnp.tanh(pk.matmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.tanh(a @ b))

    ga = jax.grad(f_pk, argnums=(0, 1))(a, b)
    gr = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga[0]), np.asarray(gr[0]), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(ga[1]), np.asarray(gr[1]), rtol=1e-3, atol=1e-3)


def test_large_k_accumulation():
    """K > TILE_K exercises the output-stationary accumulator across grid
    steps — the case where a wrong @pl.when(init) would silently corrupt."""
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (64, 500))
    b = jax.random.normal(kb, (500, 32))
    np.testing.assert_allclose(
        np.asarray(pk.matmul(a, b)), np.asarray(a @ b), rtol=1e-4, atol=1e-3
    )


def test_identity():
    a = jnp.eye(64)
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    np.testing.assert_allclose(np.asarray(pk.matmul(a, b)), np.asarray(b), rtol=1e-5, atol=1e-5)
