//! End-to-end driver: proves all three layers compose.
//!
//! Loads the AOT artifacts (L1 Pallas kernels inside L2 JAX graphs),
//! trains the masked CNN on a synthetic gender-like task through PJRT
//! from rust (L3), logs the loss curve, cross-checks the artifact-backed
//! GP posterior against the native rust GP, and correlates real
//! wall-clock with simulated energy (the Fig-6 claim).
//!
//!     make artifacts && cargo run --release --example end_to_end_training

use thor::gp::{GpModel, KernelKind};
use thor::model::zoo;
use thor::runtime::{GpExecutor, Runtime, TrainStep};
use thor::simdevice::{devices, Device};
use thor::trainer::{train, GenderLikeData};
use thor::util::stats::pearson;
use thor::workload::{fusion::fuse, lower::lower};

fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::open(&Runtime::default_dir())?;

    // ---- real training through the PJRT artifact --------------------------
    let mut ts = TrainStep::new(7);
    let mut data = GenderLikeData::new(11, 0.7);
    let steps = 300;
    let report = train(&mut rt, &mut ts, &mut data, steps, 0.08, 25)?;
    println!("# loss curve (real PJRT execution of the Pallas-backed train step)");
    for (s, l) in &report.losses {
        println!("step {s:4}  loss {l:.4}");
    }
    let eval = report.eval.unwrap();
    println!(
        "eval: loss {:.4} acc {:.3}  ({} steps in {:.2}s = {:.2} ms/step)",
        eval.loss,
        eval.acc,
        steps,
        report.step_seconds,
        1e3 * report.step_seconds / steps as f64
    );
    assert!(eval.acc > 0.8, "training failed to learn the synthetic task");

    // ---- artifact-backed GP posterior == native rust GP --------------------
    let xs: Vec<Vec<f64>> = (0..32).map(|i| vec![i as f64 / 31.0]).collect();
    let ys: Vec<f64> = xs.iter().map(|x| 1.0 + (5.0 * x[0]).sin()).collect();
    let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
    let queries: Vec<Vec<f64>> = (0..256).map(|i| vec![i as f64 / 255.0]).collect();
    let (m_native, _) = gp.predict_batch(&queries);
    let (m_art, _) = GpExecutor::posterior(&mut rt, &gp.export(), &queries)?;
    let max_diff = m_native
        .iter()
        .zip(&m_art)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("artifact GP vs native GP: max |Δmean| = {max_diff:.2e} (256 queries)");
    assert!(max_diff < 1e-3, "artifact path diverged from native GP");

    // ---- Fig-6 style: real wall-clock vs simulated energy ------------------
    let dev_p = devices::xavier();
    let mut dev = Device::new(dev_p.clone(), 3);
    let mut times = Vec::new();
    let mut energies = Vec::new();
    for ch in [[4usize, 8, 16, 32], [8, 16, 32, 64], [16, 32, 64, 128], [32, 64, 128, 256]] {
        let g = zoo::cnn5(&ch, 16, 10);
        let m = dev.run(&fuse(&lower(&g)), 100);
        times.push(m.time_per_iter());
        energies.push(m.energy_per_iter());
    }
    println!(
        "simulated time↔energy correlation over widths: r = {:.3}",
        pearson(&times, &energies)
    );
    println!("end_to_end_training OK");
    Ok(())
}
