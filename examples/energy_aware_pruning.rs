//! Fig 13 — energy-aware pruning case study, now a first-class registry
//! experiment: this example is a thin wrapper over `thor exp fig13`.
//!
//! Random channel pruning on Xavier under 30/50/70 % energy budgets,
//! guided by THOR's GP estimates vs the FLOPs-ratio heuristic (which
//! overshoots).  Actually training a channel-masked network through the
//! PJRT artifact is covered by `rust/tests/integration.rs`
//! (`artifact_pruned_training_freezes_masked_channels`); plain artifact
//! training by `examples/end_to_end_training.rs`.
//!
//!     cargo run --release --example energy_aware_pruning

use thor::exp::{by_id, Experiment as _, ExpConfig};

fn main() -> anyhow::Result<()> {
    let exp = by_id("fig13").expect("fig13 registered");
    let rep = exp.run(&ExpConfig::for_experiment(2025, true, exp.id()));
    print!("{}", rep.render());
    println!("energy_aware_pruning OK (same output as `thor exp fig13 --quick`)");
    Ok(())
}
