//! Fig 13 — energy-aware pruning case study.
//!
//! A CelebA-gender-like task under a 50 % energy budget on Xavier:
//! random channel pruning guided by (a) THOR's GP estimates and (b) the
//! FLOPs-ratio heuristic.  The pruned network is then actually trained
//! through the PJRT artifact (channel masks) to show accuracy holds,
//! while the device simulator accounts the energy.
//!
//!     make artifacts && cargo run --release --example energy_aware_pruning

use thor::model::zoo;
use thor::pruning::{prune_cnn5, Guidance};
use thor::runtime::{Runtime, TrainStep};
use thor::simdevice::{devices, Device};
use thor::thor::{Thor, ThorConfig};
use thor::trainer::{train, GenderLikeData};

fn main() -> anyhow::Result<()> {
    let original = [16usize, 32, 64, 128];
    let budget = 0.5;
    let iterations = 2000usize; // paper: ~2000 iterations, ~20 kJ original

    // --- profile THOR on the device --------------------------------------
    let mut dev = Device::new(devices::xavier(), 9);
    let mut thor = Thor::new(ThorConfig::quick());
    thor.profile(&mut dev, &zoo::cnn5(&original, 16, 10));

    // --- search under the 50% budget with both guidances ------------------
    let meas_iters = 200;
    let t = prune_cnn5(&mut dev, &original, 16, 10, budget, Guidance::Thor(&thor, "xavier"), 80, meas_iters, 5);
    let f = prune_cnn5(
        &mut dev,
        &original,
        16,
        10,
        budget,
        Guidance::FlopsRatio { original_actual: t.original_actual },
        80,
        meas_iters,
        5,
    );
    println!("original energy: {:.4e} J/iter ({:.1} J per {iterations} iterations)", t.original_actual, t.original_actual * iterations as f64);
    println!(
        "THOR-guided : channels {:?} predicted {:.1}% actual {:.1}% of original {}",
        t.channels,
        100.0 * t.predicted / t.original_actual,
        100.0 * t.actual_ratio(),
        if t.actual_ratio() <= budget + 0.02 { "✓ within budget" } else { "✗ OVER budget" },
    );
    println!(
        "FLOPs-guided: channels {:?} predicted {:.1}% actual {:.1}% of original {}",
        f.channels,
        100.0 * f.predicted / f.original_actual,
        100.0 * f.actual_ratio(),
        if f.actual_ratio() <= budget + 0.02 { "✓ within budget" } else { "✗ OVER budget" },
    );

    // --- train pruned networks for real (masks through the artifact) ------
    let mut rt = Runtime::open(&Runtime::default_dir())?;
    for (label, ch) in [("dense", vec![8usize, 16]), ("THOR-pruned", vec![
        (t.channels[0] / 2).clamp(1, 8),
        (t.channels[1] / 2).clamp(1, 16),
    ])] {
        let mut ts = TrainStep::with_pruned(7, ch[0], ch[1]);
        let mut data = GenderLikeData::new(11, 0.7);
        let r = train(&mut rt, &mut ts, &mut data, 250, 0.08, 50)?;
        let e = r.eval.unwrap();
        println!("{label:12} (keep {ch:?}): final loss {:.4} eval acc {:.3}", e.loss, e.acc);
    }
    println!("energy_aware_pruning OK");
    Ok(())
}
