//! Quickstart: profile the 5-layer CNN family on a simulated Jetson
//! Xavier, then estimate the training energy of unseen variants and
//! compare against the device's measured consumption.
//!
//!     cargo run --release --example quickstart

use thor::exp::measured_energy;
use thor::model::zoo;
use thor::simdevice::{devices, Device};
use thor::thor::{Thor, ThorConfig};
use thor::util::stats::mape;

fn main() -> anyhow::Result<()> {
    // 1. a simulated device (stand-in for the paper's physical Jetson)
    let mut dev = Device::new(devices::xavier(), 42);

    // 2. profile the model family once (active-learning GP fitting) —
    //    paper-scale budgets; ThorConfig::quick() exists for smoke tests
    let mut thor = Thor::new(ThorConfig { iterations: 200, ..ThorConfig::default() });
    let reference = zoo::cnn5(&[32, 64, 128, 256], 28, 10);
    let report = thor.profile_local(&mut dev, &reference);
    println!(
        "profiled {} layer families with {} measurements ({:.0} simulated device-seconds)",
        report.families.len(),
        report.total_points(),
        report.device_seconds()
    );

    // 3. estimate unseen architectures — no further device access needed
    let mut actual = Vec::new();
    let mut est = Vec::new();
    for ch in [[16usize, 32, 64, 128], [5, 50, 100, 20], [30, 60, 120, 250], [2, 4, 8, 16]] {
        let g = zoo::cnn5(&ch, 28, 10);
        let e = thor.estimate("xavier", &g)?;
        let a = measured_energy(&mut dev, &g, 200, 1);
        println!(
            "cnn5{ch:?}: estimated {:.4e} J/iter, measured {:.4e} J/iter ({:+.1}%)",
            e.energy_per_iter,
            a,
            100.0 * (e.energy_per_iter - a) / a
        );
        actual.push(a);
        est.push(e.energy_per_iter);
    }
    println!("MAPE over the 4 unseen variants: {:.1}%", mape(&actual, &est));
    Ok(())
}
