//! Fleet profiling through the decoupled client/server architecture
//! (paper Appendix A5.2), now a first-class registry experiment: this
//! example is a thin wrapper over `thor exp fleet1`.
//!
//! A fitting leader on an ephemeral loopback TCP port, three device
//! workers streaming measurements, GPs fitted server-side — all in one
//! process (the `thor serve` / `thor worker` CLI runs them as separate
//! processes/hosts).
//!
//!     cargo run --release --example fleet_profiling

use thor::exp::{by_id, Experiment as _, ExpConfig};

fn main() -> anyhow::Result<()> {
    let exp = by_id("fleet1").expect("fleet1 registered");
    let rep = exp.run(&ExpConfig::for_experiment(2025, true, exp.id()));
    print!("{}", rep.render());
    println!("fleet_profiling OK (same output as `thor exp fleet1 --quick`)");
    Ok(())
}
