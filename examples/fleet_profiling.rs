//! Fleet profiling through the decoupled client/server architecture
//! (paper Appendix A5.2): a fitting leader on a TCP socket, device
//! workers streaming measurements, GPs fitted server-side — all in one
//! process here for demonstration (the `thor serve` / `thor worker` CLI
//! runs them as separate processes/hosts).
//!
//!     cargo run --release --example fleet_profiling

use thor::coordinator::{DeviceWorker, FleetServer};
use thor::exp::measured_energy;
use thor::model::zoo;
use thor::simdevice::{devices, Device};
use thor::thor::estimator::estimate;
use thor::thor::ThorConfig;
use thor::util::stats::mape;

fn main() -> anyhow::Result<()> {
    let reference = zoo::cnn5(&[32, 64, 128, 256], 16, 10);
    let addr = "127.0.0.1:7731";
    let n_workers = 2;

    // workers (each owns a simulated Xavier; a real deployment points
    // these at physical devices)
    let mut handles = Vec::new();
    for w in 0..n_workers {
        let reference = reference.clone();
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || {
            // small delay so the leader binds first
            std::thread::sleep(std::time::Duration::from_millis(150 + 50 * w as u64));
            let mut worker = DeviceWorker::new(Device::new(devices::xavier(), 100 + w as u64), &reference);
            worker.run(&addr).map_err(|e| format!("worker: {e}"))
        }));
    }

    // leader
    let server = FleetServer::new(ThorConfig::quick());
    let store = server.run(addr, &reference, n_workers)?;
    println!("leader fitted {} family GPs from the fleet", store.len());

    for h in handles {
        match h.join() {
            Ok(Ok(jobs)) => println!("worker finished {jobs} jobs"),
            Ok(Err(e)) => println!("worker error: {e}"),
            Err(_) => println!("worker panicked"),
        }
    }

    // estimate with the fleet-fitted store
    let mut dev = Device::new(devices::xavier(), 5);
    let (mut actual, mut est) = (vec![], vec![]);
    for ch in [[8usize, 16, 32, 64], [3, 30, 60, 100], [16, 8, 4, 2]] {
        let g = zoo::cnn5(&ch, 16, 10);
        actual.push(measured_energy(&mut dev, &g, 150, 1));
        est.push(estimate(&store, "xavier", &g)?.energy_per_iter);
    }
    println!("fleet-store MAPE on 3 unseen variants: {:.1}%", mape(&actual, &est));
    println!("fleet_profiling OK");
    Ok(())
}
