//! GP regression: NLML hyper-parameter fitting, posterior prediction.

use crate::gp::kernel::{Kernel, KernelKind};
use crate::util::linalg::{chol_inverse, chol_logdet, chol_solve, cholesky, Mat};

/// Hyper-parameters under optimization (log-space internally).
#[derive(Clone, Copy, Debug)]
pub struct GpHyper {
    pub lengthscale: f64,
    pub variance: f64,
    pub noise: f64,
}

impl Default for GpHyper {
    fn default() -> Self {
        Self { lengthscale: 0.3, variance: 1.0, noise: 1e-3 }
    }
}

/// A fitted GP over normalized inputs (dimension 1 or 2) with
/// standardized targets (the model stores the de-standardization).
#[derive(Clone, Debug)]
pub struct GpModel {
    pub kind: KernelKind,
    pub hyper: GpHyper,
    pub xs: Vec<Vec<f64>>,
    /// Standardized targets.
    ys: Vec<f64>,
    /// Target standardization: y_std = (y − y_mean) / y_scale.
    pub y_mean: f64,
    pub y_scale: f64,
    /// α = K⁻¹ y (standardized).
    alpha: Vec<f64>,
    /// K⁻¹ (needed for predictive variance and for export to the Pallas
    /// posterior artifact).
    kinv: Mat,
}

impl GpModel {
    /// Fit with fixed hyper-parameters.
    pub fn fit_fixed(kind: KernelKind, hyper: GpHyper, xs: Vec<Vec<f64>>, ys_raw: &[f64]) -> Option<Self> {
        assert_eq!(xs.len(), ys_raw.len());
        assert!(!xs.is_empty());
        let y_mean = crate::util::stats::mean(ys_raw);
        let y_scale = crate::util::stats::std_dev(ys_raw).max(1e-12 * y_mean.abs()).max(1e-12);
        let ys: Vec<f64> = ys_raw.iter().map(|y| (y - y_mean) / y_scale).collect();
        let kern = Kernel { kind, lengthscale: hyper.lengthscale, variance: hyper.variance };
        let mut k = kern.gram(&xs);
        for i in 0..xs.len() {
            k[(i, i)] += hyper.noise + 1e-10;
        }
        let l = cholesky(&k)?;
        let alpha = chol_solve(&l, &ys);
        let kinv = chol_inverse(&l);
        Some(Self { kind, hyper, xs, ys, y_mean, y_scale, alpha, kinv })
    }

    /// Fit hyper-parameters by maximizing the log marginal likelihood with
    /// multi-start coordinate descent over (log ℓ, log σ², log σ_n²).
    pub fn fit(kind: KernelKind, xs: Vec<Vec<f64>>, ys_raw: &[f64]) -> Option<Self> {
        let starts: &[GpHyper] = &[
            GpHyper { lengthscale: 0.1, variance: 1.0, noise: 1e-3 },
            GpHyper { lengthscale: 0.3, variance: 1.0, noise: 1e-2 },
            GpHyper { lengthscale: 1.0, variance: 1.0, noise: 1e-3 },
        ];
        let y_mean = crate::util::stats::mean(ys_raw);
        let y_scale = crate::util::stats::std_dev(ys_raw).max(1e-12 * y_mean.abs()).max(1e-12);
        let ys: Vec<f64> = ys_raw.iter().map(|y| (y - y_mean) / y_scale).collect();

        let mut best: Option<(f64, GpHyper)> = None;
        for &start in starts {
            let h = coord_descent(kind, &xs, &ys, start);
            if let Some(nlml) = nlml(kind, &xs, &ys, h) {
                if best.map_or(true, |(b, _)| nlml < b) {
                    best = Some((nlml, h));
                }
            }
        }
        let (_, hyper) = best?;
        Self::fit_fixed(kind, hyper, xs, ys_raw)
    }

    pub fn n_points(&self) -> usize {
        self.xs.len()
    }

    fn kernel(&self) -> Kernel {
        Kernel { kind: self.kind, lengthscale: self.hyper.lengthscale, variance: self.hyper.variance }
    }

    /// Posterior (mean, variance) at one point, de-standardized.
    /// Variance is in *standardized* units scaled back by y_scale² (so it
    /// is comparable across refits of the same family).
    pub fn predict(&self, q: &[f64]) -> (f64, f64) {
        let kern = self.kernel();
        let kstar = kern.cross(q, &self.xs);
        let mean_std: f64 = kstar.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
        let tmp = self.kinv.matvec(&kstar);
        let var_std = (self.hyper.variance
            - kstar.iter().zip(&tmp).map(|(a, b)| a * b).sum::<f64>())
        .max(0.0);
        (self.y_mean + self.y_scale * mean_std, self.y_scale * self.y_scale * var_std)
    }

    /// Batch prediction through the native path (the artifact-backed path
    /// lives in `runtime::GpExecutor` and is cross-checked against this).
    ///
    /// §Perf: reuses one kstar/tmp scratch pair across the batch instead
    /// of allocating per query, and walks `kinv` row-major in a single
    /// fused pass that accumulates both `kstar·α` and `kstarᵀK⁻¹kstar`
    /// (see EXPERIMENTS.md §Perf for the before/after).
    pub fn predict_batch(&self, qs: &[Vec<f64>]) -> (Vec<f64>, Vec<f64>) {
        let n = self.xs.len();
        let kern = self.kernel();
        let mut means = Vec::with_capacity(qs.len());
        let mut vars = Vec::with_capacity(qs.len());
        let mut kstar = vec![0.0f64; n];
        for q in qs {
            let mut mean_std = 0.0;
            for (i, x) in self.xs.iter().enumerate() {
                let k = kern.eval(q, x);
                kstar[i] = k;
                mean_std += k * self.alpha[i];
            }
            // quad = kstarᵀ K⁻¹ kstar, fused over rows of K⁻¹
            let mut quad = 0.0;
            for (i, &ki) in kstar.iter().enumerate() {
                if ki == 0.0 {
                    continue;
                }
                let row = self.kinv.row(i);
                let mut dot = 0.0;
                for (r, &kj) in row.iter().zip(kstar.iter()) {
                    dot += r * kj;
                }
                quad += ki * dot;
            }
            let var_std = (self.hyper.variance - quad).max(0.0);
            means.push(self.y_mean + self.y_scale * mean_std);
            vars.push(self.y_scale * self.y_scale * var_std);
        }
        (means, vars)
    }

    /// Export (xs, alpha, kinv, hyper) for the AOT Pallas posterior
    /// artifact (padding handled by the runtime).
    pub fn export(&self) -> GpExport<'_> {
        GpExport {
            xs: &self.xs,
            alpha: &self.alpha,
            kinv: &self.kinv,
            lengthscale: self.hyper.lengthscale,
            variance: self.hyper.variance,
            y_mean: self.y_mean,
            y_scale: self.y_scale,
        }
    }

    /// Serialize to JSON (the store + the coordinator protocol).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let ys_raw: Vec<f64> = self.ys.iter().map(|y| self.y_mean + self.y_scale * y).collect();
        Json::obj(vec![
            ("kind", Json::str(match self.kind {
                KernelKind::Matern52 => "matern52",
                KernelKind::Rbf => "rbf",
                KernelKind::DotProduct => "dot",
            })),
            ("lengthscale", Json::Num(self.hyper.lengthscale)),
            ("variance", Json::Num(self.hyper.variance)),
            ("noise", Json::Num(self.hyper.noise)),
            ("xs", Json::Arr(self.xs.iter().map(|x| Json::arr_f64(x)).collect())),
            ("ys", Json::arr_f64(&ys_raw)),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> Option<Self> {
        let kind = match j.get("kind")?.as_str()? {
            "matern52" => KernelKind::Matern52,
            "rbf" => KernelKind::Rbf,
            "dot" => KernelKind::DotProduct,
            _ => return None,
        };
        let hyper = GpHyper {
            lengthscale: j.get("lengthscale")?.as_f64()?,
            variance: j.get("variance")?.as_f64()?,
            noise: j.get("noise")?.as_f64()?,
        };
        let xs: Option<Vec<Vec<f64>>> = j.get("xs")?.as_arr()?.iter().map(|x| x.as_f64_vec()).collect();
        let ys = j.get("ys")?.as_f64_vec()?;
        Self::fit_fixed(kind, hyper, xs?, &ys)
    }
}

/// Borrowed view of the fitted state, consumed by the runtime executor.
pub struct GpExport<'a> {
    pub xs: &'a [Vec<f64>],
    pub alpha: &'a [f64],
    pub kinv: &'a Mat,
    pub lengthscale: f64,
    pub variance: f64,
    pub y_mean: f64,
    pub y_scale: f64,
}

/// Negative log marginal likelihood (standardized targets).
pub fn nlml(kind: KernelKind, xs: &[Vec<f64>], ys: &[f64], h: GpHyper) -> Option<f64> {
    let kern = Kernel { kind, lengthscale: h.lengthscale, variance: h.variance };
    let mut k = kern.gram(xs);
    for i in 0..xs.len() {
        k[(i, i)] += h.noise + 1e-10;
    }
    let l = cholesky(&k)?;
    let alpha = chol_solve(&l, ys);
    let fit: f64 = ys.iter().zip(&alpha).map(|(y, a)| y * a).sum();
    Some(0.5 * fit + 0.5 * chol_logdet(&l) + 0.5 * xs.len() as f64 * (2.0 * std::f64::consts::PI).ln())
}

/// Coordinate descent in log-space with shrinking step, 3 sweeps.
fn coord_descent(kind: KernelKind, xs: &[Vec<f64>], ys: &[f64], start: GpHyper) -> GpHyper {
    let mut logs = [start.lengthscale.ln(), start.variance.ln(), start.noise.ln()];
    let bounds = [(-4.0, 2.0), (-4.0, 4.0), (-9.0, 0.0)];
    let mut best = nlml(kind, xs, ys, from_logs(logs)).unwrap_or(f64::INFINITY);
    let mut step = 0.8;
    for _sweep in 0..6 {
        for d in 0..3 {
            for dir in [-1.0, 1.0] {
                let mut cand = logs;
                cand[d] = (cand[d] + dir * step).clamp(bounds[d].0, bounds[d].1);
                if let Some(v) = nlml(kind, xs, ys, from_logs(cand)) {
                    if v < best {
                        best = v;
                        logs = cand;
                    }
                }
            }
        }
        step *= 0.6;
    }
    from_logs(logs)
}

fn from_logs(l: [f64; 3]) -> GpHyper {
    GpHyper { lengthscale: l[0].exp(), variance: l[1].exp(), noise: l[2].exp() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn toy_1d(n: usize, noise: f64, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let xs: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 50.0 + 30.0 * (6.0 * x[0]).sin() + noise * rng.normal())
            .collect();
        (xs, ys)
    }

    #[test]
    fn interpolates_smooth_function() {
        let (xs, ys) = toy_1d(15, 0.0, 1);
        let gp = GpModel::fit(KernelKind::Matern52, xs.clone(), &ys).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let (m, _) = gp.predict(x);
            assert!((m - y).abs() < 2.0, "{m} vs {y}");
        }
        // interpolation between points is sane
        let (m, v) = gp.predict(&[0.5 / 14.0 + 1.0 / 14.0]);
        assert!(m.is_finite() && v >= 0.0);
    }

    #[test]
    fn variance_shrinks_near_data_grows_far() {
        let (xs, ys) = toy_1d(10, 0.5, 2);
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
        let (_, v_near) = gp.predict(&[0.0]);
        let (_, v_far) = gp.predict(&[3.0]);
        assert!(v_far > 5.0 * v_near.max(1e-12), "near {v_near} far {v_far}");
    }

    #[test]
    fn fit_beats_bad_fixed_hypers_on_nlml() {
        let (xs, ys) = toy_1d(20, 1.0, 3);
        let y_mean = crate::util::stats::mean(&ys);
        let y_scale = crate::util::stats::std_dev(&ys);
        let ys_std: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_scale).collect();
        let fitted = GpModel::fit(KernelKind::Matern52, xs.clone(), &ys).unwrap();
        let bad = GpHyper { lengthscale: 10.0, variance: 0.01, noise: 0.9 };
        let n_fit = nlml(KernelKind::Matern52, &xs, &ys_std, fitted.hyper).unwrap();
        let n_bad = nlml(KernelKind::Matern52, &xs, &ys_std, bad).unwrap();
        assert!(n_fit < n_bad, "{n_fit} vs {n_bad}");
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let (xs, ys) = toy_1d(12, 0.3, 4);
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
        let j = gp.to_json();
        let back = GpModel::from_json(&crate::util::json::Json::parse(&j.to_string()).unwrap()).unwrap();
        for q in [[0.1], [0.45], [0.99]] {
            let (m1, v1) = gp.predict(&q);
            let (m2, v2) = back.predict(&q);
            assert!((m1 - m2).abs() < 1e-6 * m1.abs().max(1.0), "{m1} {m2}");
            assert!((v1 - v2).abs() < 1e-6 * v1.abs().max(1e-9));
        }
    }

    #[test]
    fn predict_batch_matches_scalar() {
        let (xs, ys) = toy_1d(10, 0.2, 5);
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
        let qs: Vec<Vec<f64>> = (0..7).map(|i| vec![i as f64 / 6.0]).collect();
        let (ms, vs) = gp.predict_batch(&qs);
        for (i, q) in qs.iter().enumerate() {
            let (m, v) = gp.predict(q);
            assert_eq!(ms[i], m);
            assert_eq!(vs[i], v);
        }
    }

    #[test]
    fn handles_2d_inputs() {
        let mut rng = Pcg64::new(6);
        let xs: Vec<Vec<f64>> = (0..25).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 10.0 * x[0] + 5.0 * (4.0 * x[1]).cos()).collect();
        let gp = GpModel::fit(KernelKind::Matern52, xs.clone(), &ys).unwrap();
        let mut err = 0.0;
        for (x, y) in xs.iter().zip(&ys) {
            err += (gp.predict(x).0 - y).abs();
        }
        assert!(err / 25.0 < 1.0, "mean abs err {}", err / 25.0);
    }

    #[test]
    fn dotproduct_fits_linear_data_well() {
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64 / 9.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 4.0 + 2.0 * x[0]).collect();
        let gp = GpModel::fit(KernelKind::DotProduct, xs, &ys).unwrap();
        let (m, _) = gp.predict(&[0.55]);
        assert!((m - 5.1).abs() < 0.1, "{m}");
    }

    #[test]
    fn singular_inputs_do_not_panic() {
        // duplicate points with different noise-free targets: noise floor
        // keeps the gram invertible
        let xs = vec![vec![0.5], vec![0.5], vec![0.5]];
        let ys = [1.0, 2.0, 3.0];
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys);
        assert!(gp.is_some());
    }
}
