//! GP regression: NLML hyper-parameter fitting, posterior prediction.
//!
//! §Perf: the fit path is workspace-backed ([`FitWorkspace`]) — pairwise
//! distances are computed once per point set ([`DistGram`]), every NLML
//! evaluation reuses the same K/L/solve buffers (`cholesky_into` /
//! `chol_solve_into`), noise-only candidate moves rewrite only the gram
//! diagonal, and a point appended at unchanged hypers extends the cached
//! Cholesky factor by one bordered row instead of refactoring
//! (`cholesky_append_row`).  [`GpModel::fit_warm`] runs a single-start
//! descent seeded from the previous fit's hypers, which turns the
//! acquisition loop's per-point refit from 3 starts × ~37 evals × O(n²)
//! gram rebuilds into one warm descent over cached distances (see
//! EXPERIMENTS.md §Perf for the before/after).
//!
//! Scale: past a few hundred points the exact O(n³) fit dominates, so
//! [`GpBackend`] adds a sparse inducing-point backend (SoR mean, DTC
//! variance): `m` inducing points are chosen from the training set by
//! deterministic farthest-point selection ([`select_inducing`]), the
//! Nyström-factored gram runs through the same cached [`DistGram`]
//! statistics, and the hyper-fit is the same coordinate descent over the
//! sparse NLML — O(n·m²) per evaluation instead of O(n³), O(m) per
//! prediction instead of O(n).  The default [`GpBackend::Auto`] keeps
//! every fit below its n-threshold on the exact path, so small-n fits
//! (all of today's per-family stores) stay bit-identical to before.

use crate::gp::kernel::{sq_dist, DistGram, Kernel, KernelKind};
use crate::util::linalg::{
    chol_inverse, chol_inverse_into, chol_logdet, chol_solve, chol_solve_into, cholesky,
    cholesky_append_row, cholesky_into, Mat,
};

/// Hyper-parameters under optimization (log-space internally).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpHyper {
    pub lengthscale: f64,
    pub variance: f64,
    pub noise: f64,
}

impl Default for GpHyper {
    fn default() -> Self {
        Self { lengthscale: 0.3, variance: 1.0, noise: 1e-3 }
    }
}

/// Default inducing-set size for the sparse backend.
pub const DEFAULT_SPARSE_M: usize = 64;
/// Default exact→sparse crossover: fits below this point count stay on
/// the exact path.  Every store the pipeline builds today holds ≤
/// [`crate::gp::MAX_POINTS`] = 64 points, so the default backend resolves
/// to `Exact` everywhere — sparse only engages on fleet-scale stores.
pub const DEFAULT_SPARSE_THRESHOLD: usize = 256;

/// Which posterior the fit engine builds.
///
/// `Exact` is the original O(n³) path, bit-for-bit unchanged.  `Sparse`
/// forces `m` inducing points (clamped to the exact path when `m ≥ n`,
/// where the "approximation" would just be a permuted exact model).
/// `Auto` — the default — crosses over from exact to sparse at
/// `n_threshold` points.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GpBackend {
    Exact,
    Sparse { m: usize },
    Auto { m: usize, n_threshold: usize },
}

impl Default for GpBackend {
    fn default() -> Self {
        Self::Auto { m: DEFAULT_SPARSE_M, n_threshold: DEFAULT_SPARSE_THRESHOLD }
    }
}

impl GpBackend {
    /// Resolve against a concrete point count: `Some(m)` = fit sparse
    /// with `m` inducing points, `None` = fit exact.
    pub fn resolve(self, n: usize) -> Option<usize> {
        match self {
            GpBackend::Exact => None,
            GpBackend::Sparse { m } => (m < n).then_some(m),
            GpBackend::Auto { m, n_threshold } => (n >= n_threshold && m < n).then_some(m),
        }
    }

    /// Parse a CLI spelling: `exact`, `auto`, `sparse:<m>`, or
    /// `auto:<m>:<n_threshold>`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "exact" => return Ok(Self::Exact),
            "auto" => return Ok(Self::default()),
            _ => {}
        }
        let err = || format!("bad --gp '{s}' (want exact | auto | sparse:<m> | auto:<m>:<n>)");
        if let Some(m) = s.strip_prefix("sparse:") {
            let m: usize = m.parse().map_err(|_| err())?;
            if m == 0 {
                return Err(err());
            }
            return Ok(Self::Sparse { m });
        }
        if let Some(rest) = s.strip_prefix("auto:") {
            let (m, t) = rest.split_once(':').ok_or_else(err)?;
            let m: usize = m.parse().map_err(|_| err())?;
            let t: usize = t.parse().map_err(|_| err())?;
            if m == 0 {
                return Err(err());
            }
            return Ok(Self::Auto { m, n_threshold: t });
        }
        Err(err())
    }
}

/// Deterministic farthest-point (max–min) inducing selection: a pure
/// function of `(xs, m)` — no RNG state, no wall clock — so checkpoint
/// replay and a JSON reload reproduce the same inducing set bit-for-bit.
///
/// The start index is derived from FNV-1a over (n, m); each subsequent
/// pick maximizes the min squared distance to the chosen set (ties →
/// lowest index).  Selection stops early when only duplicates of chosen
/// points remain (max min-distance 0), so the effective set can be
/// smaller than `m`.  Returned indices are sorted ascending.
pub fn select_inducing(xs: &[Vec<f64>], m: usize) -> Vec<usize> {
    let n = xs.len();
    if m >= n {
        return (0..n).collect();
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for word in [n as u64, m as u64] {
        for byte in word.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    let start = (h % n as u64) as usize;
    let mut chosen = vec![start];
    let mut mind2: Vec<f64> = xs.iter().map(|x| sq_dist(x, &xs[start])).collect();
    while chosen.len() < m {
        let (mut bi, mut bd) = (0usize, -1.0f64);
        for (i, &d) in mind2.iter().enumerate() {
            if d > bd {
                bd = d;
                bi = i;
            }
        }
        if bd <= 0.0 {
            break; // only duplicates of chosen points remain
        }
        chosen.push(bi);
        for (i, d) in mind2.iter_mut().enumerate() {
            let nd = sq_dist(&xs[i], &xs[bi]);
            if nd < *d {
                *d = nd;
            }
        }
    }
    chosen.sort_unstable();
    chosen
}

/// A fitted GP over normalized inputs (dimension 1 or 2) with
/// standardized targets (the model stores the de-standardization).
#[derive(Clone, Debug)]
pub struct GpModel {
    pub kind: KernelKind,
    pub hyper: GpHyper,
    pub xs: Vec<Vec<f64>>,
    /// Standardized targets.
    ys: Vec<f64>,
    /// Raw targets as passed to the fit.  Kept so serialization is
    /// bit-exact: re-deriving `y_mean + y_scale * y_std` rounds
    /// differently than the original values, which would make a JSON
    /// roundtrip perturb the refit posterior by ULPs — fatal for the
    /// checkpoint/resume byte-identity contract (see thor::checkpoint).
    ys_raw: Vec<f64>,
    /// Target standardization: y_std = (y − y_mean) / y_scale.
    pub y_mean: f64,
    pub y_scale: f64,
    /// α = K⁻¹ y (standardized).  Sparse backend: the m-vector
    /// σ⁻²·A⁻¹K_mn·y over the inducing basis — the posterior mean is
    /// `k(q, basis)·α` either way.
    alpha: Vec<f64>,
    /// K⁻¹ (needed for predictive variance and for export to the Pallas
    /// posterior artifact).  Sparse backend: the m×m matrix
    /// K_mm⁻¹ − A⁻¹, so `σ² − k_qᵀ·kinv·k_q` is the DTC predictive
    /// variance through the same quadratic-form code path.
    kinv: Mat,
    /// Sorted training-set indices of the inducing points; empty = exact
    /// backend (the basis is the full training set).
    inducing: Vec<usize>,
    /// The inducing points themselves (`xs[inducing[..]]`), cached so
    /// prediction never re-gathers.
    zs: Vec<Vec<f64>>,
}

impl GpModel {
    /// Fit with fixed hyper-parameters.
    pub fn fit_fixed(kind: KernelKind, hyper: GpHyper, xs: Vec<Vec<f64>>, ys_raw: &[f64]) -> Option<Self> {
        assert_eq!(xs.len(), ys_raw.len());
        assert!(!xs.is_empty());
        let (ys, y_mean, y_scale) = standardized(ys_raw);
        let kern = Kernel { kind, lengthscale: hyper.lengthscale, variance: hyper.variance };
        let mut k = kern.gram(&xs);
        for i in 0..xs.len() {
            k[(i, i)] += hyper.noise + 1e-10;
        }
        let l = cholesky(&k)?;
        let alpha = chol_solve(&l, &ys);
        let kinv = chol_inverse(&l);
        Some(Self {
            kind,
            hyper,
            xs,
            ys,
            ys_raw: ys_raw.to_vec(),
            y_mean,
            y_scale,
            alpha,
            kinv,
            inducing: Vec::new(),
            zs: Vec::new(),
        })
    }

    /// Fit with fixed hyper-parameters through a reusable [`FitWorkspace`]
    /// — bit-identical to [`GpModel::fit_fixed`] (asserted by a property
    /// test), but allocation-free on the gram/factorization path, and
    /// scratch-free on the posterior (α, K⁻¹) construction: the only
    /// allocations left are the model-owned α/K⁻¹ buffers themselves
    /// (`chol_inverse_into` replaces the 2n-vector scratch churn of
    /// [`chol_inverse`]).
    pub fn fit_fixed_with(
        ws: &mut FitWorkspace,
        kind: KernelKind,
        hyper: GpHyper,
        xs: Vec<Vec<f64>>,
        ys_raw: &[f64],
    ) -> Option<Self> {
        assert_eq!(xs.len(), ys_raw.len());
        assert!(!xs.is_empty());
        let n = xs.len();
        let (ys, y_mean, y_scale) = standardized(ys_raw);
        ws.sync(&xs);
        if !ws.factor(kind, hyper) {
            return None;
        }
        let mut alpha = vec![0.0; n];
        ws.tmp.resize(n, 0.0);
        chol_solve_into(&ws.l, &ys, &mut ws.tmp, &mut alpha);
        let mut kinv = Mat::zeros(n, n);
        chol_inverse_into(&ws.l, &mut kinv, &mut ws.tmp);
        Some(Self {
            kind,
            hyper,
            xs,
            ys,
            ys_raw: ys_raw.to_vec(),
            y_mean,
            y_scale,
            alpha,
            kinv,
            inducing: Vec::new(),
            zs: Vec::new(),
        })
    }

    /// Sparse fit at fixed hypers: SoR/DTC posterior over the inducing
    /// basis.  `forced` (the deserialization path) pins the inducing
    /// indices stored in the artifact instead of re-running selection, so
    /// old artifacts stay loadable even if the selection heuristic ever
    /// changes.
    fn fit_fixed_sparse(
        ws: &mut FitWorkspace,
        kind: KernelKind,
        hyper: GpHyper,
        xs: Vec<Vec<f64>>,
        ys_raw: &[f64],
        m_req: usize,
        forced: Option<&[usize]>,
    ) -> Option<Self> {
        assert_eq!(xs.len(), ys_raw.len());
        assert!(!xs.is_empty());
        let (ys, y_mean, y_scale) = standardized(ys_raw);
        ws.sync(&xs);
        if let Some(idx) = forced {
            ws.force_inducing(idx, m_req);
        }
        if !ws.prepare_sparse(kind, hyper, m_req) {
            return None;
        }
        let sn2 = hyper.noise + DIAG_JITTER;
        let mi = ws.sp.idx.len();
        // b = K_mn y, c = A⁻¹ b  (same arithmetic as the sparse NLML)
        ws.sparse_information(&ys);
        // posterior mean factor over the basis: α = σ⁻² c
        let alpha: Vec<f64> = ws.sp.c.iter().map(|&c| c / sn2).collect();
        // posterior variance factor: K_mm⁻¹ − A⁻¹ (DTC)
        let mut kinv = Mat::zeros(mi, mi);
        chol_inverse_into(&ws.sp.lmm, &mut kinv, &mut ws.sp.tmp);
        chol_inverse_into(&ws.sp.la, &mut ws.sp.ainv, &mut ws.sp.tmp);
        for (k, a) in kinv.data.iter_mut().zip(&ws.sp.ainv.data) {
            *k -= a;
        }
        let inducing = ws.sp.idx.clone();
        let zs: Vec<Vec<f64>> = inducing.iter().map(|&i| xs[i].clone()).collect();
        Some(Self {
            kind,
            hyper,
            xs,
            ys,
            ys_raw: ys_raw.to_vec(),
            y_mean,
            y_scale,
            alpha,
            kinv,
            inducing,
            zs,
        })
    }

    /// Backend-dispatching [`GpModel::fit_fixed_with`]: resolves the
    /// backend at this point count, delegating verbatim to the exact path
    /// (bit-identical) or fitting the sparse posterior.
    pub fn fit_fixed_b(
        ws: &mut FitWorkspace,
        kind: KernelKind,
        hyper: GpHyper,
        xs: Vec<Vec<f64>>,
        ys_raw: &[f64],
        backend: GpBackend,
    ) -> Option<Self> {
        match backend.resolve(xs.len()) {
            None => Self::fit_fixed_with(ws, kind, hyper, xs, ys_raw),
            Some(m) => Self::fit_fixed_sparse(ws, kind, hyper, xs, ys_raw, m, None),
        }
    }

    /// Backend-dispatching [`GpModel::fit_with`]: the same multi-start
    /// coordinate descent, over the sparse NLML when the backend resolves
    /// sparse at this n.
    pub fn fit_b(
        ws: &mut FitWorkspace,
        kind: KernelKind,
        xs: Vec<Vec<f64>>,
        ys_raw: &[f64],
        backend: GpBackend,
    ) -> Option<Self> {
        let m = match backend.resolve(xs.len()) {
            None => return Self::fit_with(ws, kind, xs, ys_raw),
            Some(m) => m,
        };
        let (ys, _, _) = standardized(ys_raw);
        ws.sync(&xs);
        let mut best: Option<(f64, GpHyper)> = None;
        for &start in MULTI_STARTS {
            let (h, score) = coord_descent_ws(ws, kind, &ys, start, Some(m));
            if score.is_finite() && best.map_or(true, |(b, _)| score < b) {
                best = Some((score, h));
            }
        }
        let (_, hyper) = best?;
        Self::fit_fixed_sparse(ws, kind, hyper, xs, ys_raw, m, None)
    }

    /// Backend-dispatching [`GpModel::fit_warm`]: warm single-start
    /// descent over the backend's NLML, with the same stuck-detector
    /// fallback to the full multi-start search.
    pub fn fit_warm_b(
        ws: &mut FitWorkspace,
        kind: KernelKind,
        xs: Vec<Vec<f64>>,
        ys_raw: &[f64],
        start: GpHyper,
        backend: GpBackend,
    ) -> Option<Self> {
        let m = match backend.resolve(xs.len()) {
            None => return Self::fit_warm(ws, kind, xs, ys_raw, start),
            Some(m) => m,
        };
        let (ys, _, _) = standardized(ys_raw);
        ws.sync(&xs);
        let (h, score) = coord_descent_ws(ws, kind, &ys, start, Some(m));
        let stuck = !score.is_finite()
            || MULTI_STARTS
                .iter()
                .any(|&s| ws.nlml_b(kind, &ys, s, Some(m)).is_some_and(|v| v < score));
        if stuck {
            return Self::fit_b(ws, kind, xs, ys_raw, backend);
        }
        Self::fit_fixed_sparse(ws, kind, h, xs, ys_raw, m, None)
    }

    /// Fit hyper-parameters by maximizing the log marginal likelihood with
    /// multi-start coordinate descent over (log ℓ, log σ², log σ_n²).
    pub fn fit(kind: KernelKind, xs: Vec<Vec<f64>>, ys_raw: &[f64]) -> Option<Self> {
        Self::fit_with(&mut FitWorkspace::new(), kind, xs, ys_raw)
    }

    /// [`GpModel::fit`] through a caller-owned workspace: the pairwise
    /// distances, gram/Cholesky buffers and (when the point set merely
    /// grew) the cached factorization all carry over between calls.
    pub fn fit_with(
        ws: &mut FitWorkspace,
        kind: KernelKind,
        xs: Vec<Vec<f64>>,
        ys_raw: &[f64],
    ) -> Option<Self> {
        let (ys, _, _) = standardized(ys_raw);
        ws.sync(&xs);
        let mut best: Option<(f64, GpHyper)> = None;
        for &start in MULTI_STARTS {
            let (h, score) = coord_descent_ws(ws, kind, &ys, start, None);
            if score.is_finite() && best.map_or(true, |(b, _)| score < b) {
                best = Some((score, h));
            }
        }
        let (_, hyper) = best?;
        Self::fit_fixed_with(ws, kind, hyper, xs, ys_raw)
    }

    /// Warm refit: a single-start coordinate descent seeded from the
    /// previous fit's hypers (the acquisition loop adds one point per
    /// round, so the NLML optimum barely moves).  Falls back to the full
    /// multi-start search when the warm descent diverges or is beaten by
    /// a canonical start point (a cheap stuck-detector: 3 extra NLML
    /// evaluations against ~37 saved per skipped start).
    pub fn fit_warm(
        ws: &mut FitWorkspace,
        kind: KernelKind,
        xs: Vec<Vec<f64>>,
        ys_raw: &[f64],
        start: GpHyper,
    ) -> Option<Self> {
        let (ys, _, _) = standardized(ys_raw);
        ws.sync(&xs);
        let (h, score) = coord_descent_ws(ws, kind, &ys, start, None);
        let stuck = !score.is_finite()
            || MULTI_STARTS
                .iter()
                .any(|&s| ws.nlml(kind, &ys, s).is_some_and(|v| v < score));
        if stuck {
            return Self::fit_with(ws, kind, xs, ys_raw);
        }
        Self::fit_fixed_with(ws, kind, h, xs, ys_raw)
    }

    pub fn n_points(&self) -> usize {
        self.xs.len()
    }

    /// The backend this model was fit with (derived from the stored
    /// inducing set, so it survives serialization).
    pub fn backend(&self) -> GpBackend {
        if self.inducing.is_empty() {
            GpBackend::Exact
        } else {
            GpBackend::Sparse { m: self.inducing.len() }
        }
    }

    /// Training-set indices of the inducing points (empty for exact).
    pub fn inducing(&self) -> &[usize] {
        &self.inducing
    }

    /// The prediction basis: the full training set for the exact backend
    /// (the original code path, untouched), the inducing points for the
    /// sparse backend.  `alpha`/`kinv` are always sized to this basis.
    fn basis(&self) -> &[Vec<f64>] {
        if self.inducing.is_empty() {
            &self.xs
        } else {
            &self.zs
        }
    }

    fn kernel(&self) -> Kernel {
        Kernel { kind: self.kind, lengthscale: self.hyper.lengthscale, variance: self.hyper.variance }
    }

    /// Posterior (mean, variance) at one point, de-standardized.
    /// Variance is in *standardized* units scaled back by y_scale² (so it
    /// is comparable across refits of the same family).
    pub fn predict(&self, q: &[f64]) -> (f64, f64) {
        let kern = self.kernel();
        let kstar = kern.cross(q, self.basis());
        let mean_std: f64 = kstar.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
        let tmp = self.kinv.matvec(&kstar);
        let var_std = (self.hyper.variance
            - kstar.iter().zip(&tmp).map(|(a, b)| a * b).sum::<f64>())
        .max(0.0);
        (self.y_mean + self.y_scale * mean_std, self.y_scale * self.y_scale * var_std)
    }

    /// Batch prediction through the native path (the artifact-backed path
    /// lives in `runtime::GpExecutor` and is cross-checked against this).
    ///
    /// §Perf: reuses one kstar/tmp scratch pair across the batch instead
    /// of allocating per query, and walks `kinv` row-major in a single
    /// fused pass that accumulates both `kstar·α` and `kstarᵀK⁻¹kstar`
    /// (see EXPERIMENTS.md §Perf for the before/after).
    pub fn predict_batch(&self, qs: &[Vec<f64>]) -> (Vec<f64>, Vec<f64>) {
        let basis = self.basis();
        let n = basis.len();
        let kern = self.kernel();
        let mut means = Vec::with_capacity(qs.len());
        let mut vars = Vec::with_capacity(qs.len());
        let mut kstar = vec![0.0f64; n];
        for q in qs {
            let mut mean_std = 0.0;
            for (i, x) in basis.iter().enumerate() {
                let k = kern.eval(q, x);
                kstar[i] = k;
                mean_std += k * self.alpha[i];
            }
            // quad = kstarᵀ K⁻¹ kstar, fused over rows of K⁻¹
            let mut quad = 0.0;
            for (i, &ki) in kstar.iter().enumerate() {
                if ki == 0.0 {
                    continue;
                }
                let row = self.kinv.row(i);
                let mut dot = 0.0;
                for (r, &kj) in row.iter().zip(kstar.iter()) {
                    dot += r * kj;
                }
                quad += ki * dot;
            }
            let var_std = (self.hyper.variance - quad).max(0.0);
            means.push(self.y_mean + self.y_scale * mean_std);
            vars.push(self.y_scale * self.y_scale * var_std);
        }
        (means, vars)
    }

    /// Export (basis, alpha, kinv, hyper) for the AOT Pallas posterior
    /// artifact (padding handled by the runtime).  For the sparse backend
    /// the exported point set is the inducing basis — the artifact's
    /// posterior formula is identical either way.
    pub fn export(&self) -> GpExport<'_> {
        GpExport {
            xs: self.basis(),
            alpha: &self.alpha,
            kinv: &self.kinv,
            lengthscale: self.hyper.lengthscale,
            variance: self.hyper.variance,
            y_mean: self.y_mean,
            y_scale: self.y_scale,
        }
    }

    /// Serialize to JSON (the store + the coordinator protocol).
    ///
    /// Emits the *raw* targets the model was fit on (not a
    /// de-standardization of the internal targets), so that
    /// `to_json → from_json → to_json` is byte-idempotent and the refit
    /// posterior — rebuilt from bit-identical (hyper, xs, ys) — predicts
    /// bit-identically to the original model.  Pinned below.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut fields = vec![
            ("kind", Json::str(match self.kind {
                KernelKind::Matern52 => "matern52",
                KernelKind::Rbf => "rbf",
                KernelKind::DotProduct => "dot",
            })),
            ("lengthscale", Json::Num(self.hyper.lengthscale)),
            ("variance", Json::Num(self.hyper.variance)),
            ("noise", Json::Num(self.hyper.noise)),
            ("xs", Json::Arr(self.xs.iter().map(|x| Json::arr_f64(x)).collect())),
            ("ys", Json::arr_f64(&self.ys_raw)),
        ];
        // Sparse models additionally record their inducing set — the
        // artifact stays self-describing (a reload pins these indices
        // instead of re-running selection), and exact models keep the
        // exact byte layout older stores were written with.
        if !self.inducing.is_empty() {
            fields.push(("backend", Json::str("sparse")));
            fields.push((
                "inducing",
                Json::Arr(self.inducing.iter().map(|&i| Json::Num(i as f64)).collect()),
            ));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &crate::util::json::Json) -> Option<Self> {
        Self::from_json_with(&mut FitWorkspace::new(), j)
    }

    /// [`GpModel::from_json`] through a caller-owned workspace.  The
    /// artifact stores (xs, ys, hyper) but not the posterior, so loading
    /// rebuilds α and K⁻¹ — through [`GpModel::fit_fixed_with`]'s
    /// scratch-free `chol_inverse_into` path here, so a store load
    /// precomputes every family's posterior factors exactly once with
    /// one shared scratch (bit-identical to the naive path; pinned).
    pub fn from_json_with(ws: &mut FitWorkspace, j: &crate::util::json::Json) -> Option<Self> {
        let kind = match j.get("kind")?.as_str()? {
            "matern52" => KernelKind::Matern52,
            "rbf" => KernelKind::Rbf,
            "dot" => KernelKind::DotProduct,
            _ => return None,
        };
        let hyper = GpHyper {
            lengthscale: j.get("lengthscale")?.as_f64()?,
            variance: j.get("variance")?.as_f64()?,
            noise: j.get("noise")?.as_f64()?,
        };
        let xs: Option<Vec<Vec<f64>>> = j.get("xs")?.as_arr()?.iter().map(|x| x.as_f64_vec()).collect();
        let ys = j.get("ys")?.as_f64_vec()?;
        let xs = xs?;
        if j.get("backend").and_then(|b| b.as_str()) == Some("sparse") {
            let idx: Option<Vec<usize>> = j
                .get("inducing")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().map(|f| f as usize))
                .collect();
            let idx = idx?;
            let valid = !idx.is_empty()
                && idx.windows(2).all(|w| w[0] < w[1])
                && *idx.last().unwrap() < xs.len();
            if !valid {
                return None;
            }
            return Self::fit_fixed_sparse(ws, kind, hyper, xs, &ys, idx.len(), Some(&idx));
        }
        Self::fit_fixed_with(ws, kind, hyper, xs, &ys)
    }
}

/// Borrowed view of the fitted state, consumed by the runtime executor.
pub struct GpExport<'a> {
    pub xs: &'a [Vec<f64>],
    pub alpha: &'a [f64],
    pub kinv: &'a Mat,
    pub lengthscale: f64,
    pub variance: f64,
    pub y_mean: f64,
    pub y_scale: f64,
}

/// The canonical multi-start grid of [`GpModel::fit`].
const MULTI_STARTS: &[GpHyper] = &[
    GpHyper { lengthscale: 0.1, variance: 1.0, noise: 1e-3 },
    GpHyper { lengthscale: 0.3, variance: 1.0, noise: 1e-2 },
    GpHyper { lengthscale: 1.0, variance: 1.0, noise: 1e-3 },
];

/// Additive diagonal jitter on top of the fitted noise.
const DIAG_JITTER: f64 = 1e-10;

/// Jitter on the inducing gram K_mm's diagonal (it carries no noise
/// term, so it needs its own regularization to stay factorizable when
/// inducing points crowd together).
const SPARSE_JITTER: f64 = 1e-8;

/// Target standardization shared by every fit path: returns
/// (standardized targets, y_mean, y_scale).
fn standardized(ys_raw: &[f64]) -> (Vec<f64>, f64, f64) {
    let y_mean = crate::util::stats::mean(ys_raw);
    let y_scale = crate::util::stats::std_dev(ys_raw).max(1e-12 * y_mean.abs()).max(1e-12);
    (ys_raw.iter().map(|y| (y - y_mean) / y_scale).collect(), y_mean, y_scale)
}

/// Reusable state of the GP fit engine: pairwise distances of the point
/// set (`DistGram`), the gram/Cholesky/solve buffers shared by every
/// NLML evaluation, and the cache keys that enable the two incremental
/// fast paths (diagonal-only noise moves, bordered Cholesky append).
///
/// One workspace serves one acquisition loop: `sync` recognizes when the
/// point set merely grew (the per-round append) and extends the distance
/// rows instead of rebuilding them.
#[derive(Default)]
pub struct FitWorkspace {
    /// Points currently covered by `gram` (prefix-compared by `sync`).
    xs: Vec<Vec<f64>>,
    gram: DistGram,
    k: Mat,
    l: Mat,
    alpha: Vec<f64>,
    tmp: Vec<f64>,
    row_buf: Vec<f64>,
    /// (kind, ℓ, σ²) profile currently applied into `k` — noise-only
    /// moves then rewrite just the diagonal.
    last_profile: Option<(KernelKind, f64, f64)>,
    /// (kind, hypers, n) of the factorization currently held in `l`.
    last_chol: Option<(KernelKind, GpHyper, usize)>,
    /// Sparse-backend state (inducing selection + Nyström factors).
    sp: SparseState,
}

/// Cached state of the sparse (inducing-point) fit path.  The inducing
/// selection is keyed on (n, m_req) and the noise-independent factors
/// (K_nm, K_mm, G = K_mn·K_nm, chol(K_mm)) on the scalar kernel profile,
/// so the ~100 NLML evaluations of a coordinate descent rebuild the
/// O(n·m²) part only when (ℓ, σ²) move — noise-only candidate moves cost
/// O(m²) to reassemble A = K_mm + σ⁻²G plus one O(m³) factorization.
#[derive(Default)]
struct SparseState {
    /// Sorted inducing indices into the synced point set.
    idx: Vec<usize>,
    /// (n, m_req) the selection in `idx` was computed for.
    sel_key: Option<(usize, usize)>,
    /// (kind, ℓ, σ², n, m) profile the Nyström factors below were built
    /// at — noise excluded on purpose (it only enters through A).
    profile: Option<(KernelKind, f64, f64, usize, usize)>,
    /// K_nm: training × inducing cross-covariance.
    knm: Mat,
    /// K_mm + SPARSE_JITTER·I.
    kmm: Mat,
    /// G = K_mn·K_nm.
    g: Mat,
    /// chol(K_mm).
    lmm: Mat,
    /// A = K_mm + σ⁻²·G (rebuilt per noise value).
    a: Mat,
    /// chol(A).
    la: Mat,
    /// A⁻¹ scratch for the posterior assembly.
    ainv: Mat,
    /// b = K_mn·y.
    b: Vec<f64>,
    /// c = A⁻¹·b.
    c: Vec<f64>,
    tmp: Vec<f64>,
}

impl FitWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Point the workspace at `xs`, reusing the pairwise distances when
    /// `xs` extends the previously synced set.
    pub fn sync(&mut self, xs: &[Vec<f64>]) {
        let extends =
            xs.len() >= self.xs.len() && self.xs.iter().zip(xs).all(|(a, b)| a == b);
        if !extends {
            self.xs.clear();
            self.gram.clear();
            self.last_chol = None;
            // a replaced point set at the same length would otherwise
            // alias the sparse selection/factor keys
            self.sp.sel_key = None;
            self.sp.profile = None;
        }
        if xs.len() != self.xs.len() {
            self.last_profile = None;
        }
        for i in self.xs.len()..xs.len() {
            self.gram.push(&xs[..=i]);
            self.xs.push(xs[i].clone());
        }
    }

    /// Number of points currently synced.
    pub fn n_points(&self) -> usize {
        self.gram.len()
    }

    /// Apply (kind, h) into the gram buffer `k`; when only the noise
    /// differs from the last applied profile, rewrite just the diagonal.
    fn apply(&mut self, kind: KernelKind, h: GpHyper) {
        let kern = Kernel { kind, lengthscale: h.lengthscale, variance: h.variance };
        let diag_add = h.noise + DIAG_JITTER;
        match self.last_profile {
            Some((k0, l0, v0)) if k0 == kind && l0 == h.lengthscale && v0 == h.variance => {
                self.gram.apply_diag(&kern, diag_add, &mut self.k);
            }
            _ => {
                self.gram.apply_into(&kern, diag_add, &mut self.k);
                self.last_profile = Some((kind, h.lengthscale, h.variance));
            }
        }
    }

    /// Factor K(kind, h) into the workspace's `l`.  Fast path: when `l`
    /// already holds the factor at identical hypers for exactly one
    /// point fewer, extend it with one bordered row (bit-identical to a
    /// from-scratch factorization, see `cholesky_append_row`).
    fn factor(&mut self, kind: KernelKind, h: GpHyper) -> bool {
        let n = self.gram.len();
        if let Some((k0, h0, n0)) = self.last_chol {
            if k0 == kind && h0 == h && n == n0 + 1 && self.l.rows == n0 {
                self.apply(kind, h);
                self.row_buf.clear();
                self.row_buf.extend((0..n).map(|j| self.k[(n - 1, j)]));
                if cholesky_append_row(&mut self.l, &self.row_buf) {
                    self.last_chol = Some((kind, h, n));
                    return true;
                }
                // bordered matrix not PD at these hypers: refactor below
            }
        }
        self.apply(kind, h);
        let ok = cholesky_into(&self.k, &mut self.l);
        self.last_chol = if ok { Some((kind, h, n)) } else { None };
        ok
    }

    /// Negative log marginal likelihood through the reusable buffers —
    /// bit-identical to the standalone [`nlml`] (asserted by a property
    /// test), with zero allocations at steady state.
    pub fn nlml(&mut self, kind: KernelKind, ys: &[f64], h: GpHyper) -> Option<f64> {
        let n = self.gram.len();
        assert_eq!(ys.len(), n, "workspace not synced to the target vector");
        if !self.factor(kind, h) {
            return None;
        }
        self.alpha.resize(n, 0.0);
        self.tmp.resize(n, 0.0);
        chol_solve_into(&self.l, ys, &mut self.tmp, &mut self.alpha);
        let fit: f64 = ys.iter().zip(&self.alpha).map(|(y, a)| y * a).sum();
        Some(
            0.5 * fit
                + 0.5 * chol_logdet(&self.l)
                + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln(),
        )
    }

    /// Backend-dispatching NLML: `m = None` is the exact path, `Some(m)`
    /// the sparse one.
    fn nlml_b(&mut self, kind: KernelKind, ys: &[f64], h: GpHyper, m: Option<usize>) -> Option<f64> {
        match m {
            None => self.nlml(kind, ys, h),
            Some(m) => self.nlml_sparse(kind, ys, h, m),
        }
    }

    /// Pin the inducing selection (deserialization path): subsequent
    /// sparse calls at the same (n, m_req) reuse exactly these indices.
    fn force_inducing(&mut self, idx: &[usize], m_req: usize) {
        if self.sp.idx != idx {
            self.sp.idx = idx.to_vec();
            self.sp.profile = None;
        }
        self.sp.sel_key = Some((self.gram.len(), m_req));
    }

    /// Select inducing points (cached on (n, m_req)) and build every
    /// noise-independent sparse factor at (kind, ℓ, σ²); then assemble
    /// and factor A = K_mm + σ⁻²·G for this noise.  All kernel entries
    /// come from the cached [`DistGram`] statistics via
    /// [`DistGram::kern_at`], so no distance is ever recomputed.
    fn prepare_sparse(&mut self, kind: KernelKind, h: GpHyper, m_req: usize) -> bool {
        let n = self.gram.len();
        if self.sp.sel_key != Some((n, m_req)) {
            self.sp.idx = select_inducing(&self.xs, m_req);
            self.sp.sel_key = Some((n, m_req));
            self.sp.profile = None;
        }
        let gram = &self.gram;
        let sp = &mut self.sp;
        let mi = sp.idx.len();
        let kern = Kernel { kind, lengthscale: h.lengthscale, variance: h.variance };
        if sp.profile != Some((kind, h.lengthscale, h.variance, n, mi)) {
            sp.knm.resize(n, mi);
            for i in 0..n {
                for jj in 0..mi {
                    sp.knm[(i, jj)] = gram.kern_at(&kern, i, sp.idx[jj]);
                }
            }
            sp.kmm.resize(mi, mi);
            for a in 0..mi {
                for b in 0..=a {
                    let v = gram.kern_at(&kern, sp.idx[a], sp.idx[b]);
                    sp.kmm[(a, b)] = v;
                    sp.kmm[(b, a)] = v;
                }
                sp.kmm[(a, a)] += SPARSE_JITTER;
            }
            sp.g.resize(mi, mi);
            for a in 0..mi {
                for b in 0..=a {
                    let mut s = 0.0;
                    for i in 0..n {
                        s += sp.knm[(i, a)] * sp.knm[(i, b)];
                    }
                    sp.g[(a, b)] = s;
                    sp.g[(b, a)] = s;
                }
            }
            if !cholesky_into(&sp.kmm, &mut sp.lmm) {
                sp.profile = None;
                return false;
            }
            sp.profile = Some((kind, h.lengthscale, h.variance, n, mi));
        }
        // noise-dependent part, rebuilt every evaluation: A = K_mm + σ⁻²G
        let sn2 = h.noise + DIAG_JITTER;
        sp.a.resize(mi, mi);
        for (a, (k, g)) in sp.a.data.iter_mut().zip(sp.kmm.data.iter().zip(&sp.g.data)) {
            *a = k + g / sn2;
        }
        cholesky_into(&sp.a, &mut sp.la)
    }

    /// The information-form intermediates shared by the sparse NLML and
    /// the sparse posterior: b = K_mn·y and c = A⁻¹·b.  Call after a
    /// successful [`FitWorkspace::prepare_sparse`].
    fn sparse_information(&mut self, ys: &[f64]) {
        let sp = &mut self.sp;
        let (n, mi) = (sp.knm.rows, sp.idx.len());
        sp.b.resize(mi, 0.0);
        for a in 0..mi {
            let mut s = 0.0;
            for i in 0..n {
                s += sp.knm[(i, a)] * ys[i];
            }
            sp.b[a] = s;
        }
        sp.c.resize(mi, 0.0);
        sp.tmp.resize(mi, 0.0);
        let (b, c, tmp) = (&sp.b, &mut sp.c, &mut sp.tmp);
        chol_solve_into(&sp.la, b, tmp, c);
    }

    /// Sparse (SoR) negative log marginal likelihood with `m` inducing
    /// points: O(n·m²) worst case per evaluation (O(m³) on noise-only
    /// moves) against the exact path's O(n³).
    ///
    /// With Q = K_nm·K_mm⁻¹·K_mn + σ²I and A = K_mm + σ⁻²·K_mn·K_nm:
    ///   yᵀQ⁻¹y  = σ⁻²·(yᵀy − σ⁻²·bᵀA⁻¹b)        (Woodbury)
    ///   log|Q|  = log|A| − log|K_mm| + n·log σ²   (determinant lemma)
    fn nlml_sparse(&mut self, kind: KernelKind, ys: &[f64], h: GpHyper, m: usize) -> Option<f64> {
        let n = self.gram.len();
        assert_eq!(ys.len(), n, "workspace not synced to the target vector");
        if !self.prepare_sparse(kind, h, m) {
            return None;
        }
        self.sparse_information(ys);
        let sn2 = h.noise + DIAG_JITTER;
        let sp = &self.sp;
        let yy: f64 = ys.iter().map(|y| y * y).sum();
        let bc: f64 = sp.b.iter().zip(&sp.c).map(|(b, c)| b * c).sum();
        let fit = (yy - bc / sn2) / sn2;
        let logdet = chol_logdet(&sp.la) - chol_logdet(&sp.lmm) + n as f64 * sn2.ln();
        Some(0.5 * fit + 0.5 * logdet + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln())
    }
}

/// Negative log marginal likelihood (standardized targets) — the naive
/// reference path: rebuilds the gram and allocates per call.  The hot
/// path is [`FitWorkspace::nlml`]; this stays as the oracle the property
/// tests compare against.
pub fn nlml(kind: KernelKind, xs: &[Vec<f64>], ys: &[f64], h: GpHyper) -> Option<f64> {
    let kern = Kernel { kind, lengthscale: h.lengthscale, variance: h.variance };
    let mut k = kern.gram(xs);
    for i in 0..xs.len() {
        k[(i, i)] += h.noise + DIAG_JITTER;
    }
    let l = cholesky(&k)?;
    let alpha = chol_solve(&l, ys);
    let fit: f64 = ys.iter().zip(&alpha).map(|(y, a)| y * a).sum();
    Some(0.5 * fit + 0.5 * chol_logdet(&l) + 0.5 * xs.len() as f64 * (2.0 * std::f64::consts::PI).ln())
}

/// Coordinate descent in log-space with shrinking step, over the
/// workspace's cached distances.  `m = Some(_)` descends the sparse NLML
/// instead of the exact one — same schedule, same bounds.  Returns the
/// best hypers and their NLML (`INFINITY` when no evaluation succeeded).
fn coord_descent_ws(
    ws: &mut FitWorkspace,
    kind: KernelKind,
    ys: &[f64],
    start: GpHyper,
    m: Option<usize>,
) -> (GpHyper, f64) {
    let mut logs = [start.lengthscale.ln(), start.variance.ln(), start.noise.ln()];
    let bounds = [(-4.0, 2.0), (-4.0, 4.0), (-9.0, 0.0)];
    // Baseline at the *exact* start (not the ln/exp roundtrip): a warm
    // start equals the previous fit's hypers bit-for-bit, which is what
    // lets `factor()`'s bordered-Cholesky fast path fire.
    let mut cur = start;
    let mut best = ws.nlml_b(kind, ys, cur, m).unwrap_or(f64::INFINITY);
    let mut step = 0.8;
    for _sweep in 0..6 {
        for d in 0..3 {
            for dir in [-1.0, 1.0] {
                let mut cand = logs;
                cand[d] = (cand[d] + dir * step).clamp(bounds[d].0, bounds[d].1);
                let cand_h = from_logs(cand);
                if let Some(v) = ws.nlml_b(kind, ys, cand_h, m) {
                    if v < best {
                        best = v;
                        logs = cand;
                        cur = cand_h;
                    }
                }
            }
        }
        step *= 0.6;
    }
    (cur, best)
}

fn from_logs(l: [f64; 3]) -> GpHyper {
    GpHyper { lengthscale: l[0].exp(), variance: l[1].exp(), noise: l[2].exp() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn toy_1d(n: usize, noise: f64, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let xs: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 50.0 + 30.0 * (6.0 * x[0]).sin() + noise * rng.normal())
            .collect();
        (xs, ys)
    }

    #[test]
    fn interpolates_smooth_function() {
        let (xs, ys) = toy_1d(15, 0.0, 1);
        let gp = GpModel::fit(KernelKind::Matern52, xs.clone(), &ys).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let (m, _) = gp.predict(x);
            assert!((m - y).abs() < 2.0, "{m} vs {y}");
        }
        // interpolation between points is sane
        let (m, v) = gp.predict(&[0.5 / 14.0 + 1.0 / 14.0]);
        assert!(m.is_finite() && v >= 0.0);
    }

    #[test]
    fn variance_shrinks_near_data_grows_far() {
        let (xs, ys) = toy_1d(10, 0.5, 2);
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
        let (_, v_near) = gp.predict(&[0.0]);
        let (_, v_far) = gp.predict(&[3.0]);
        assert!(v_far > 5.0 * v_near.max(1e-12), "near {v_near} far {v_far}");
    }

    #[test]
    fn fit_beats_bad_fixed_hypers_on_nlml() {
        let (xs, ys) = toy_1d(20, 1.0, 3);
        let y_mean = crate::util::stats::mean(&ys);
        let y_scale = crate::util::stats::std_dev(&ys);
        let ys_std: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_scale).collect();
        let fitted = GpModel::fit(KernelKind::Matern52, xs.clone(), &ys).unwrap();
        let bad = GpHyper { lengthscale: 10.0, variance: 0.01, noise: 0.9 };
        let n_fit = nlml(KernelKind::Matern52, &xs, &ys_std, fitted.hyper).unwrap();
        let n_bad = nlml(KernelKind::Matern52, &xs, &ys_std, bad).unwrap();
        assert!(n_fit < n_bad, "{n_fit} vs {n_bad}");
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let (xs, ys) = toy_1d(12, 0.3, 4);
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
        let j = gp.to_json();
        let back = GpModel::from_json(&crate::util::json::Json::parse(&j.to_string()).unwrap()).unwrap();
        for q in [[0.1], [0.45], [0.99]] {
            let (m1, v1) = gp.predict(&q);
            let (m2, v2) = back.predict(&q);
            assert!((m1 - m2).abs() < 1e-6 * m1.abs().max(1.0), "{m1} {m2}");
            assert!((v1 - v2).abs() < 1e-6 * v1.abs().max(1e-9));
        }
    }

    /// The checkpoint/resume byte-identity contract rests here: a model
    /// reloaded from its JSON must predict bit-identically (the raw
    /// targets are serialized verbatim, and the refit re-standardizes the
    /// exact fit-time inputs), and re-serializing must reproduce the same
    /// bytes (idempotence — the fleet store can be saved, resumed, and
    /// saved again without drifting a single ULP).
    #[test]
    fn json_roundtrip_is_bit_exact_and_idempotent() {
        let (xs, ys) = toy_1d(14, 0.25, 9);
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
        let j1 = gp.to_json().to_string();
        let back =
            GpModel::from_json(&crate::util::json::Json::parse(&j1).unwrap()).unwrap();
        let j2 = back.to_json().to_string();
        assert_eq!(j1, j2, "to_json ∘ from_json must be byte-idempotent");
        for q in [[0.0], [0.17], [0.5], [0.83], [1.0]] {
            let (m1, v1) = gp.predict(&q);
            let (m2, v2) = back.predict(&q);
            assert_eq!(m1.to_bits(), m2.to_bits(), "mean drifted at {q:?}: {m1} vs {m2}");
            assert_eq!(v1.to_bits(), v2.to_bits(), "variance drifted at {q:?}: {v1} vs {v2}");
        }
    }

    #[test]
    fn predict_batch_matches_scalar() {
        let (xs, ys) = toy_1d(10, 0.2, 5);
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
        let qs: Vec<Vec<f64>> = (0..7).map(|i| vec![i as f64 / 6.0]).collect();
        let (ms, vs) = gp.predict_batch(&qs);
        for (i, q) in qs.iter().enumerate() {
            let (m, v) = gp.predict(q);
            assert_eq!(ms[i], m);
            assert_eq!(vs[i], v);
        }
    }

    #[test]
    fn handles_2d_inputs() {
        let mut rng = Pcg64::new(6);
        let xs: Vec<Vec<f64>> = (0..25).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 10.0 * x[0] + 5.0 * (4.0 * x[1]).cos()).collect();
        let gp = GpModel::fit(KernelKind::Matern52, xs.clone(), &ys).unwrap();
        let mut err = 0.0;
        for (x, y) in xs.iter().zip(&ys) {
            err += (gp.predict(x).0 - y).abs();
        }
        assert!(err / 25.0 < 1.0, "mean abs err {}", err / 25.0);
    }

    #[test]
    fn dotproduct_fits_linear_data_well() {
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64 / 9.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 4.0 + 2.0 * x[0]).collect();
        let gp = GpModel::fit(KernelKind::DotProduct, xs, &ys).unwrap();
        let (m, _) = gp.predict(&[0.55]);
        assert!((m - 5.1).abs() < 0.1, "{m}");
    }

    #[test]
    fn prop_workspace_nlml_matches_naive_bitwise() {
        use crate::util::proptest::{check, Config};
        check(
            "workspace nlml == naive nlml",
            Config { cases: 40, seed: 41 },
            |r| {
                let n = r.range_usize(2, 18);
                let xs: Vec<Vec<f64>> = (0..n).map(|_| vec![r.f64(), r.f64()]).collect();
                let ys: Vec<f64> = (0..n).map(|_| r.normal()).collect();
                let h = GpHyper {
                    lengthscale: r.range_f64(0.05, 2.0),
                    variance: r.range_f64(0.1, 3.0),
                    noise: r.range_f64(1e-6, 0.5),
                };
                (xs, ys, h)
            },
            |(xs, ys, h)| {
                let mut ws = FitWorkspace::new();
                ws.sync(xs);
                for kind in [KernelKind::Matern52, KernelKind::Rbf, KernelKind::DotProduct] {
                    let naive = nlml(kind, xs, ys, *h);
                    let fast = ws.nlml(kind, ys, *h);
                    // repeat at perturbed noise: exercises the diag-only path
                    let h2 = GpHyper { noise: h.noise * 2.0, ..*h };
                    let naive2 = nlml(kind, xs, ys, h2);
                    let fast2 = ws.nlml(kind, ys, h2);
                    crate::prop_assert!(
                        naive == fast && naive2 == fast2,
                        "{kind:?}: naive {naive:?}/{naive2:?} vs ws {fast:?}/{fast2:?}"
                    );
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_fit_fixed_with_matches_naive_fit_fixed_bitwise() {
        use crate::util::proptest::{check, Config};
        check(
            "fit_fixed via workspace == naive",
            Config { cases: 24, seed: 43 },
            |r| {
                let n = r.range_usize(3, 14);
                let xs: Vec<Vec<f64>> = (0..n).map(|_| vec![r.f64()]).collect();
                let ys: Vec<f64> = xs.iter().map(|x| 3.0 + x[0] + 0.1 * r.normal()).collect();
                let h = GpHyper {
                    lengthscale: r.range_f64(0.1, 1.5),
                    variance: r.range_f64(0.2, 2.0),
                    noise: r.range_f64(1e-5, 0.2),
                };
                (xs, ys, h)
            },
            |(xs, ys, h)| {
                let naive = GpModel::fit_fixed(KernelKind::Matern52, *h, xs.clone(), ys)
                    .ok_or("naive fit failed")?;
                let mut ws = FitWorkspace::new();
                let fast =
                    GpModel::fit_fixed_with(&mut ws, KernelKind::Matern52, *h, xs.clone(), ys)
                        .ok_or("workspace fit failed")?;
                for q in [[0.0], [0.33], [0.77], [1.0]] {
                    let (m1, v1) = naive.predict(&q);
                    let (m2, v2) = fast.predict(&q);
                    crate::prop_assert!(
                        m1 == m2 && v1 == v2,
                        "predict({q:?}): ({m1},{v1}) vs ({m2},{v2})"
                    );
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fit_with_reused_workspace_matches_fresh_fit() {
        // The acquisition-loop shape: grow the point set one at a time,
        // refitting through ONE workspace; every refit must equal the
        // fresh-workspace (and therefore the legacy) fit bit-for-bit.
        let (xs_all, ys_all) = toy_1d(14, 0.3, 8);
        let mut ws = FitWorkspace::new();
        for n in 3..=14 {
            let xs: Vec<Vec<f64>> = xs_all[..n].to_vec();
            let ys = &ys_all[..n];
            let warm = GpModel::fit_with(&mut ws, KernelKind::Matern52, xs.clone(), ys).unwrap();
            let cold = GpModel::fit(KernelKind::Matern52, xs, ys).unwrap();
            let (m1, v1) = warm.predict(&[0.41]);
            let (m2, v2) = cold.predict(&[0.41]);
            assert_eq!((m1, v1), (m2, v2), "n={n}: reused workspace diverged");
        }
    }

    #[test]
    fn fit_warm_tracks_multistart_quality() {
        // Warm refits across a growing point set must stay within a hair
        // of the full multi-start NLML optimum at every size.
        let (xs_all, ys_all) = toy_1d(20, 0.4, 9);
        let mut ws = FitWorkspace::new();
        let mut prev = GpModel::fit_with(
            &mut ws,
            KernelKind::Matern52,
            xs_all[..5].to_vec(),
            &ys_all[..5],
        )
        .unwrap()
        .hyper;
        for n in 6..=20 {
            let xs: Vec<Vec<f64>> = xs_all[..n].to_vec();
            let ys = &ys_all[..n];
            let warm =
                GpModel::fit_warm(&mut ws, KernelKind::Matern52, xs.clone(), ys, prev).unwrap();
            prev = warm.hyper;
            let full = GpModel::fit(KernelKind::Matern52, xs.clone(), ys).unwrap();
            let (ys_std, _, _) = super::standardized(ys);
            let n_warm = nlml(KernelKind::Matern52, &xs, &ys_std, warm.hyper).unwrap();
            let n_full = nlml(KernelKind::Matern52, &xs, &ys_std, full.hyper).unwrap();
            // warm may differ, but not collapse: allow modest slack on
            // the (negative log-lik) objective
            assert!(
                n_warm <= n_full + 0.15 * n_full.abs() + 2.0,
                "n={n}: warm nlml {n_warm} vs full {n_full}"
            );
        }
    }

    #[test]
    fn workspace_sync_rebuilds_on_point_change() {
        // Same length, different points: the workspace must detect the
        // mismatch and rebuild instead of reusing stale distances.
        let mut ws = FitWorkspace::new();
        let xs1: Vec<Vec<f64>> = (0..6).map(|i| vec![i as f64 / 5.0]).collect();
        let ys1: Vec<f64> = xs1.iter().map(|x| 1.0 + x[0]).collect();
        let _ = GpModel::fit_with(&mut ws, KernelKind::Matern52, xs1, &ys1);
        let xs2: Vec<Vec<f64>> = (0..6).map(|i| vec![(i as f64 / 5.0).powi(2)]).collect();
        let ys2: Vec<f64> = xs2.iter().map(|x| 1.0 + 2.0 * x[0]).collect();
        let from_ws = GpModel::fit_with(&mut ws, KernelKind::Matern52, xs2.clone(), &ys2).unwrap();
        let fresh = GpModel::fit(KernelKind::Matern52, xs2, &ys2).unwrap();
        let (m1, v1) = from_ws.predict(&[0.5]);
        let (m2, v2) = fresh.predict(&[0.5]);
        assert_eq!((m1, v1), (m2, v2));
    }

    #[test]
    fn singular_inputs_do_not_panic() {
        // duplicate points with different noise-free targets: noise floor
        // keeps the gram invertible
        let xs = vec![vec![0.5], vec![0.5], vec![0.5]];
        let ys = [1.0, 2.0, 3.0];
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys);
        assert!(gp.is_some());
    }

    // ------------------------- sparse backend -------------------------

    #[test]
    fn gp_backend_parse_and_resolve() {
        assert_eq!(GpBackend::parse("exact"), Ok(GpBackend::Exact));
        assert_eq!(GpBackend::parse("auto"), Ok(GpBackend::default()));
        assert_eq!(GpBackend::parse("sparse:16"), Ok(GpBackend::Sparse { m: 16 }));
        assert_eq!(
            GpBackend::parse("auto:32:100"),
            Ok(GpBackend::Auto { m: 32, n_threshold: 100 })
        );
        for bad in ["", "sparse", "sparse:0", "sparse:x", "auto:8", "fitc:4"] {
            assert!(GpBackend::parse(bad).is_err(), "{bad:?} should not parse");
        }
        // resolution: exact never sparsifies; sparse clamps m ≥ n back to
        // exact; auto crosses over at the threshold
        assert_eq!(GpBackend::Exact.resolve(10_000), None);
        assert_eq!(GpBackend::Sparse { m: 8 }.resolve(40), Some(8));
        assert_eq!(GpBackend::Sparse { m: 40 }.resolve(40), None);
        let auto = GpBackend::default();
        assert_eq!(auto.resolve(DEFAULT_SPARSE_THRESHOLD - 1), None);
        assert_eq!(auto.resolve(DEFAULT_SPARSE_THRESHOLD), Some(DEFAULT_SPARSE_M));
        // every store the pipeline builds today stays exact by default
        assert_eq!(auto.resolve(crate::gp::MAX_POINTS), None);
    }

    #[test]
    fn select_inducing_is_deterministic_sorted_and_dedups() {
        let mut rng = Pcg64::new(77);
        let xs: Vec<Vec<f64>> = (0..50).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let a = select_inducing(&xs, 12);
        let b = select_inducing(&xs, 12);
        assert_eq!(a, b, "selection must be a pure function of (xs, m)");
        assert_eq!(a.len(), 12);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted unique: {a:?}");
        assert!(a.iter().all(|&i| i < xs.len()));
        // m ≥ n: everything is inducing
        assert_eq!(select_inducing(&xs[..5], 8), vec![0, 1, 2, 3, 4]);
        // duplicates collapse: only distinct locations get selected
        let dup = vec![vec![0.1], vec![0.9], vec![0.1], vec![0.9], vec![0.5]];
        let sel = select_inducing(&dup, 5);
        assert_eq!(sel.len(), 3, "only 3 distinct locations: {sel:?}");
    }

    #[test]
    fn sparse_fit_approximates_exact() {
        let (xs, ys) = toy_1d(48, 0.3, 11);
        let exact = GpModel::fit(KernelKind::Matern52, xs.clone(), &ys).unwrap();
        let mut ws = FitWorkspace::new();
        let sparse = GpModel::fit_b(
            &mut ws,
            KernelKind::Matern52,
            xs,
            &ys,
            GpBackend::Sparse { m: 12 },
        )
        .unwrap();
        assert_eq!(sparse.backend(), GpBackend::Sparse { m: 12 });
        assert_eq!(sparse.inducing().len(), 12);
        for i in 0..=20 {
            let q = [0.05 + 0.9 * i as f64 / 20.0];
            let (me, _) = exact.predict(&q);
            let (ms, vs) = sparse.predict(&q);
            assert!(
                (me - ms).abs() < 5.0,
                "sparse mean drifted at {q:?}: exact {me} vs sparse {ms}"
            );
            assert!(vs.is_finite() && vs >= 0.0);
        }
    }

    #[test]
    fn sparse_variance_shrinks_near_data_grows_far() {
        // DTC variance (not SoR): far from the inducing set the posterior
        // variance must recover toward the prior, keeping the acquisition
        // signal meaningful on sparse stores.
        let (xs, ys) = toy_1d(40, 0.5, 12);
        let mut ws = FitWorkspace::new();
        let gp =
            GpModel::fit_b(&mut ws, KernelKind::Matern52, xs, &ys, GpBackend::Sparse { m: 10 })
                .unwrap();
        let (_, v_near) = gp.predict(&[0.5]);
        let (_, v_far) = gp.predict(&[4.0]);
        assert!(v_far > 5.0 * v_near.max(1e-12), "near {v_near} far {v_far}");
    }

    #[test]
    fn auto_crossover_below_threshold_is_bit_identical_to_exact() {
        // The default-config contract: every fit below the crossover
        // resolves to the exact path — same bytes, same bits, same JSON.
        let (xs, ys) = toy_1d(14, 0.25, 13);
        let exact = GpModel::fit(KernelKind::Matern52, xs.clone(), &ys).unwrap();
        let mut ws = FitWorkspace::new();
        let auto =
            GpModel::fit_b(&mut ws, KernelKind::Matern52, xs, &ys, GpBackend::default()).unwrap();
        assert_eq!(auto.backend(), GpBackend::Exact);
        assert_eq!(auto.to_json().to_string(), exact.to_json().to_string());
        for q in [[0.0], [0.31], [0.73], [1.0]] {
            let (m1, v1) = exact.predict(&q);
            let (m2, v2) = auto.predict(&q);
            assert_eq!(m1.to_bits(), m2.to_bits());
            assert_eq!(v1.to_bits(), v2.to_bits());
        }
    }

    #[test]
    fn sparse_with_m_not_below_n_falls_back_to_exact() {
        let (xs, ys) = toy_1d(10, 0.2, 14);
        let exact = GpModel::fit(KernelKind::Matern52, xs.clone(), &ys).unwrap();
        let mut ws = FitWorkspace::new();
        let gp = GpModel::fit_b(
            &mut ws,
            KernelKind::Matern52,
            xs,
            &ys,
            GpBackend::Sparse { m: 10 },
        )
        .unwrap();
        assert_eq!(gp.backend(), GpBackend::Exact, "m ≥ n must resolve exact");
        assert_eq!(gp.to_json().to_string(), exact.to_json().to_string());
    }

    /// Sparse counterpart of `json_roundtrip_is_bit_exact_and_idempotent`:
    /// the artifact records the inducing indices, the reload pins them
    /// (no re-selection), and the rebuilt posterior predicts
    /// bit-identically — so sparse stores survive save → serve → save.
    #[test]
    fn sparse_json_roundtrip_is_bit_exact_and_idempotent() {
        let (xs, ys) = toy_1d(40, 0.3, 15);
        let mut ws = FitWorkspace::new();
        let gp =
            GpModel::fit_b(&mut ws, KernelKind::Matern52, xs, &ys, GpBackend::Sparse { m: 9 })
                .unwrap();
        let j1 = gp.to_json().to_string();
        assert!(j1.contains("\"backend\":\"sparse\""), "sparse artifact must self-describe");
        let back = GpModel::from_json(&crate::util::json::Json::parse(&j1).unwrap()).unwrap();
        assert_eq!(back.inducing(), gp.inducing());
        let j2 = back.to_json().to_string();
        assert_eq!(j1, j2, "to_json ∘ from_json must be byte-idempotent");
        for q in [[0.0], [0.17], [0.5], [0.83], [1.0]] {
            let (m1, v1) = gp.predict(&q);
            let (m2, v2) = back.predict(&q);
            assert_eq!(m1.to_bits(), m2.to_bits(), "mean drifted at {q:?}: {m1} vs {m2}");
            assert_eq!(v1.to_bits(), v2.to_bits(), "variance drifted at {q:?}: {v1} vs {v2}");
        }
    }

    #[test]
    fn sparse_fit_is_deterministic_across_workspace_reuse() {
        // One dirty workspace (used for an unrelated exact fit first) and
        // one fresh workspace must produce byte-identical sparse models:
        // nothing about the cached state may leak into the result.
        let (xs0, ys0) = toy_1d(9, 0.4, 16);
        let (xs, ys) = toy_1d(36, 0.3, 17);
        let mut dirty = FitWorkspace::new();
        let _ = GpModel::fit_with(&mut dirty, KernelKind::Matern52, xs0, &ys0);
        let a = GpModel::fit_b(
            &mut dirty,
            KernelKind::Matern52,
            xs.clone(),
            &ys,
            GpBackend::Sparse { m: 8 },
        )
        .unwrap();
        let b = GpModel::fit_b(
            &mut FitWorkspace::new(),
            KernelKind::Matern52,
            xs,
            &ys,
            GpBackend::Sparse { m: 8 },
        )
        .unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        for q in [[0.1], [0.6], [0.95]] {
            assert_eq!(a.predict(&q).0.to_bits(), b.predict(&q).0.to_bits());
            assert_eq!(a.predict(&q).1.to_bits(), b.predict(&q).1.to_bits());
        }
    }

    #[test]
    fn sparse_nlml_noise_only_moves_match_full_rebuild() {
        // The noise-only fast path (cached Nyström factors, rebuilt A)
        // must produce the same NLML a cold workspace computes.
        let (xs, ys) = toy_1d(30, 0.4, 18);
        let (ys_std, _, _) = standardized(&ys);
        let h1 = GpHyper { lengthscale: 0.4, variance: 1.2, noise: 1e-3 };
        let h2 = GpHyper { noise: 3e-2, ..h1 };
        let mut warm = FitWorkspace::new();
        warm.sync(&xs);
        let w1 = warm.nlml_sparse(KernelKind::Matern52, &ys_std, h1, 8);
        let w2 = warm.nlml_sparse(KernelKind::Matern52, &ys_std, h2, 8);
        let mut cold = FitWorkspace::new();
        cold.sync(&xs);
        let c2 = cold.nlml_sparse(KernelKind::Matern52, &ys_std, h2, 8);
        assert!(w1.is_some());
        assert_eq!(w2, c2, "noise-only sparse move diverged from cold rebuild");
    }
}
