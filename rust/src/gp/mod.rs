//! Gaussian-process regression library (paper §3.3).
//!
//! Kernels: Matérn-5/2 (the paper's choice, ν = 2.5 — twice
//! differentiable, robust to length-scale misspecification), RBF and
//! DotProduct (the Appendix A6.2 ablation).  Fitting maximizes the log
//! marginal likelihood over (lengthscale, signal variance, noise) with
//! multi-start coordinate descent in log-space; prediction gives posterior
//! mean and variance; the max-variance acquisition drives guided profiling
//! (active learning, Fig 4).
//!
//! Per-family acquisition sets are small (≤ `MAX_POINTS`), so those fits
//! use the exact native Cholesky path; fleet-scale stores cross over to
//! the sparse inducing-point backend ([`GpBackend`], default crossover at
//! `model::DEFAULT_SPARSE_THRESHOLD` points).  *Batched prediction* — the
//! estimation hot path — can be offloaded to the AOT Pallas artifact
//! through [`crate::runtime::GpExecutor`], which is bit-compatible with
//! [`GpModel::predict`] (cross-checked in integration tests).

pub mod acquisition;
pub mod kernel;
pub mod model;

pub use kernel::{DistGram, Kernel, KernelKind};
pub use model::{select_inducing, FitWorkspace, GpBackend, GpHyper, GpModel};

/// Cap on profiled points per layer family (end condition 1, §3.3).
pub const MAX_POINTS: usize = 64;
