//! Guided profiling (paper §3.3 "Guided Profiling", Fig 4): pick the next
//! profiling point as the candidate with the largest posterior variance
//! (pure-exploration active learning), with the paper's two end
//! conditions: point budget exhausted, or max posterior std below 5 % of
//! the profiled data scale.

use crate::gp::GpModel;

/// Candidate grid over channel configurations (already normalized).
pub struct CandidateGrid {
    pub points: Vec<Vec<f64>>,
}

impl CandidateGrid {
    /// 1-D grid of `n` points over [lo, hi] inclusive.
    pub fn dim1(lo: f64, hi: f64, n: usize) -> Self {
        assert!(n >= 2);
        let points = (0..n)
            .map(|i| vec![lo + (hi - lo) * i as f64 / (n - 1) as f64])
            .collect();
        Self { points }
    }

    /// 2-D grid (n × n) over [lo, hi]².
    pub fn dim2(lo: f64, hi: f64, n: usize) -> Self {
        assert!(n >= 2);
        let mut points = Vec::with_capacity(n * n);
        for i in 0..n {
            for j in 0..n {
                points.push(vec![
                    lo + (hi - lo) * i as f64 / (n - 1) as f64,
                    lo + (hi - lo) * j as f64 / (n - 1) as f64,
                ]);
            }
        }
        Self { points }
    }
}

/// Result of one acquisition decision.
#[derive(Clone, Debug)]
pub enum Acquire {
    /// Profile this point next (it had the given posterior std).
    Next(Vec<f64>, f64),
    /// Converged: the max posterior std is below the threshold.
    Converged(f64),
}

/// Result of one *batched* acquisition decision.
#[derive(Clone, Debug)]
pub enum AcquireBatch {
    /// Profile these points next, in descending posterior-std order
    /// (each paired with its std).  The fold-back order of their
    /// measurements is this declaration order — the batched-acquisition
    /// determinism rule.
    Next(Vec<(Vec<f64>, f64)>),
    /// Converged: the max posterior std is below the threshold.
    Converged(f64),
}

/// Pick the unprofiled candidate with the largest posterior variance.
///
/// `threshold_frac`: the paper's 5 % — converged when max posterior std
/// < threshold_frac × mean(|y|) of the profiled data (in raw target
/// units).
pub fn max_variance(gp: &GpModel, grid: &CandidateGrid, threshold_frac: f64, y_abs_mean: f64) -> Acquire {
    match top_k_variance(gp, grid, threshold_frac, y_abs_mean, 1) {
        AcquireBatch::Converged(s) => Acquire::Converged(s),
        AcquireBatch::Next(mut ps) => {
            let (p, std) = ps.swap_remove(0);
            Acquire::Next(p, std)
        }
    }
}

/// Pick the `k` unprofiled candidates with the largest posterior
/// variances (ties broken by grid index, so the selection is a pure
/// function of the posterior).  Convergence is judged on the *maximum*
/// posterior std exactly as in [`max_variance`] — at `k = 1` this is
/// bit-identical to the scalar decision, which is what keeps batch-size-1
/// runs byte-equal to the sequential acquisition loop.
pub fn top_k_variance(
    gp: &GpModel,
    grid: &CandidateGrid,
    threshold_frac: f64,
    y_abs_mean: f64,
    k: usize,
) -> AcquireBatch {
    if k <= 1 {
        // Hot path (every sequential acquisition round): the original
        // allocation-free single-pass scan, first maximum wins.
        let mut best: Option<(usize, f64)> = None;
        for (i, q) in grid.points.iter().enumerate() {
            // skip (numerically) already-profiled candidates — gp.xs is
            // the FULL training set even under the sparse backend (the
            // inducing basis only drives the posterior), so a measured
            // point is never re-proposed just because it isn't inducing
            if gp.xs.iter().any(|x| crate::gp::kernel::dist(x, q) < 1e-9) {
                continue;
            }
            let (_, var) = gp.predict(q);
            if best.map_or(true, |(_, b)| var > b) {
                best = Some((i, var));
            }
        }
        return match best {
            None => AcquireBatch::Converged(0.0),
            Some((i, var)) => {
                let std = var.sqrt();
                if std < threshold_frac * y_abs_mean {
                    AcquireBatch::Converged(std)
                } else {
                    AcquireBatch::Next(vec![(grid.points[i].clone(), std)])
                }
            }
        };
    }
    let mut cands: Vec<(usize, f64)> = Vec::new();
    for (i, q) in grid.points.iter().enumerate() {
        // skip (numerically) already-profiled candidates
        if gp.xs.iter().any(|x| crate::gp::kernel::dist(x, q) < 1e-9) {
            continue;
        }
        let (_, var) = gp.predict(q);
        cands.push((i, var));
    }
    if cands.is_empty() {
        return AcquireBatch::Converged(0.0);
    }
    // Deterministic top-k: variance descending, grid index ascending on
    // ties (matches the k = 1 scan's first-maximum-wins rule, asserted
    // by `top_k_first_point_matches_scalar_max_variance`).
    cands.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    let best_std = cands[0].1.sqrt();
    if best_std < threshold_frac * y_abs_mean {
        return AcquireBatch::Converged(best_std);
    }
    AcquireBatch::Next(
        cands
            .into_iter()
            .take(k)
            .map(|(i, var)| (grid.points[i].clone(), var.sqrt()))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{GpModel, KernelKind};

    fn fit_on(points: &[f64]) -> GpModel {
        let xs: Vec<Vec<f64>> = points.iter().map(|&p| vec![p]).collect();
        let ys: Vec<f64> = points.iter().map(|&p| 100.0 + 40.0 * (4.0 * p).sin()).collect();
        GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap()
    }

    #[test]
    fn picks_point_far_from_data() {
        // data clustered at the ends -> next point should be central
        let gp = fit_on(&[0.0, 0.05, 0.95, 1.0]);
        let grid = CandidateGrid::dim1(0.0, 1.0, 21);
        match max_variance(&gp, &grid, 0.0, 100.0) {
            Acquire::Next(p, _) => {
                assert!((p[0] - 0.5).abs() < 0.25, "picked {p:?}");
            }
            Acquire::Converged(_) => panic!("should not converge with threshold 0 until grid is dense"),
        }
    }

    #[test]
    fn converges_when_grid_covered() {
        let pts: Vec<f64> = (0..21).map(|i| i as f64 / 20.0).collect();
        let gp = fit_on(&pts);
        let grid = CandidateGrid::dim1(0.0, 1.0, 21);
        match max_variance(&gp, &grid, 0.05, 100.0) {
            Acquire::Converged(_) => {}
            Acquire::Next(p, s) => panic!("expected convergence, got {p:?} std {s}"),
        }
    }

    #[test]
    fn variance_of_next_point_decreases_after_profiling_it() {
        // Fig 4's mechanism: fitting the max-variance point kills its
        // uncertainty.
        let mut points = vec![0.0, 1.0];
        let gp = fit_on(&points);
        let grid = CandidateGrid::dim1(0.0, 1.0, 41);
        let (p, std_before) = match max_variance(&gp, &grid, 0.0, 100.0) {
            Acquire::Next(p, s) => (p, s),
            _ => panic!(),
        };
        points.push(p[0]);
        let gp2 = fit_on(&points);
        let (_, var_after) = gp2.predict(&p);
        assert!(var_after.sqrt() < 0.6 * std_before, "{} vs {std_before}", var_after.sqrt());
    }

    #[test]
    fn dim2_grid_shape() {
        let g = CandidateGrid::dim2(0.0, 1.0, 7);
        assert_eq!(g.points.len(), 49);
        assert!(g.points.iter().all(|p| p.len() == 2));
    }

    #[test]
    fn top_k_first_point_matches_scalar_max_variance() {
        let gp = fit_on(&[0.0, 0.1, 0.6, 1.0]);
        let grid = CandidateGrid::dim1(0.0, 1.0, 21);
        let scalar = max_variance(&gp, &grid, 0.0, 100.0);
        match (scalar, top_k_variance(&gp, &grid, 0.0, 100.0, 3)) {
            (Acquire::Next(p, s), AcquireBatch::Next(ps)) => {
                assert!(ps.len() == 3);
                assert_eq!(ps[0].0, p);
                assert_eq!(ps[0].1.to_bits(), s.to_bits());
                // descending-std order, all distinct grid points
                assert!(ps[0].1 >= ps[1].1 && ps[1].1 >= ps[2].1, "{ps:?}");
                assert_ne!(ps[0].0, ps[1].0);
                assert_ne!(ps[1].0, ps[2].0);
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn top_k_converges_exactly_like_scalar() {
        let pts: Vec<f64> = (0..21).map(|i| i as f64 / 20.0).collect();
        let gp = fit_on(&pts);
        let grid = CandidateGrid::dim1(0.0, 1.0, 21);
        match top_k_variance(&gp, &grid, 0.05, 100.0, 4) {
            AcquireBatch::Converged(_) => {}
            AcquireBatch::Next(ps) => panic!("expected convergence, got {ps:?}"),
        }
    }

    #[test]
    fn top_k_caps_at_available_candidates() {
        let gp = fit_on(&[0.0, 1.0]);
        let grid = CandidateGrid::dim1(0.0, 1.0, 5);
        match top_k_variance(&gp, &grid, 0.0, 100.0, 10) {
            AcquireBatch::Next(ps) => assert_eq!(ps.len(), 3, "{ps:?}"), // 5 grid − 2 profiled
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn sparse_model_still_skips_all_profiled_points() {
        // The sparse posterior predicts through the inducing basis, but
        // the already-profiled skip must see the full training set: a
        // grid identical to the training set leaves no candidates, even
        // though only 6 of 21 points are inducing.
        use crate::gp::{FitWorkspace, GpBackend};
        let xs: Vec<Vec<f64>> = (0..21).map(|i| vec![i as f64 / 20.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 100.0 + 40.0 * (4.0 * x[0]).sin()).collect();
        let mut ws = FitWorkspace::new();
        let gp = GpModel::fit_b(
            &mut ws,
            KernelKind::Matern52,
            xs,
            &ys,
            GpBackend::Sparse { m: 6 },
        )
        .unwrap();
        assert_eq!(gp.inducing().len(), 6);
        let grid = CandidateGrid::dim1(0.0, 1.0, 21);
        match top_k_variance(&gp, &grid, 0.0, 100.0, 4) {
            AcquireBatch::Converged(_) => {}
            AcquireBatch::Next(ps) => panic!("non-inducing points re-proposed: {ps:?}"),
        }
    }
}
