//! Covariance kernels.  All operate on feature vectors of dimension 1 or 2
//! (channel configurations), pre-normalized to ~[0, 1] by the caller.

pub const SQRT5: f64 = 2.236_067_977_499_79;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Matérn ν = 5/2 — the paper's kernel (eq. 3 with ν = 2.5).
    Matern52,
    /// Squared exponential (A6.2 ablation: overfits, worst).
    Rbf,
    /// Linear / dot-product (A6.2 ablation: second).
    DotProduct,
}

#[derive(Clone, Copy, Debug)]
pub struct Kernel {
    pub kind: KernelKind,
    /// Length-scale ℓ (ignored by DotProduct).
    pub lengthscale: f64,
    /// Signal variance σ².
    pub variance: f64,
}

impl Kernel {
    pub fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), z.len());
        match self.kind {
            KernelKind::Matern52 => {
                let r = dist(x, z);
                let s = SQRT5 * r / self.lengthscale;
                self.variance * (1.0 + s + s * s / 3.0) * (-s).exp()
            }
            KernelKind::Rbf => {
                let d2 = sq_dist(x, z);
                self.variance * (-0.5 * d2 / (self.lengthscale * self.lengthscale)).exp()
            }
            KernelKind::DotProduct => {
                let dot: f64 = x.iter().zip(z).map(|(a, b)| a * b).sum();
                self.variance * (dot + 1.0)
            }
        }
    }

    /// Gram matrix K(X, X) (+ nothing on the diagonal; noise added by the
    /// GP model).
    pub fn gram(&self, xs: &[Vec<f64>]) -> crate::util::linalg::Mat {
        let n = xs.len();
        let mut k = crate::util::linalg::Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = self.eval(&xs[i], &xs[j]);
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
        }
        k
    }

    /// Cross-covariance vector k(q, X).
    pub fn cross(&self, q: &[f64], xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.eval(q, x)).collect()
    }
}

pub fn sq_dist(x: &[f64], z: &[f64]) -> f64 {
    x.iter().zip(z).map(|(a, b)| (a - b) * (a - b)).sum()
}

pub fn dist(x: &[f64], z: &[f64]) -> f64 {
    sq_dist(x, z).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    fn matern(ls: f64, var: f64) -> Kernel {
        Kernel { kind: KernelKind::Matern52, lengthscale: ls, variance: var }
    }

    #[test]
    fn matern_at_zero_distance_is_variance() {
        let k = matern(0.7, 3.0);
        assert!((k.eval(&[0.5], &[0.5]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn matern_matches_python_oracle() {
        // Values from python/compile/kernels/ref.py: matern52 with ℓ=0.8,
        // σ²=2.0 at r=0.5 ->  2*(1+s+s²/3)exp(-s), s=√5*0.5/0.8
        let s = SQRT5 * 0.5 / 0.8;
        let want = 2.0 * (1.0 + s + s * s / 3.0) * (-s as f64).exp();
        let k = matern(0.8, 2.0);
        let got = k.eval(&[0.0], &[0.5]);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn kernels_symmetric() {
        for kind in [KernelKind::Matern52, KernelKind::Rbf, KernelKind::DotProduct] {
            let k = Kernel { kind, lengthscale: 0.5, variance: 1.5 };
            let a = [0.2, 0.9];
            let b = [0.7, 0.1];
            assert!((k.eval(&a, &b) - k.eval(&b, &a)).abs() < 1e-14);
        }
    }

    #[test]
    fn gram_psd_via_cholesky() {
        use crate::util::linalg::cholesky;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(3);
        for kind in [KernelKind::Matern52, KernelKind::Rbf] {
            let xs: Vec<Vec<f64>> = (0..20).map(|_| vec![rng.f64(), rng.f64()]).collect();
            let k = Kernel { kind, lengthscale: 0.4, variance: 1.0 };
            let mut g = k.gram(&xs);
            for i in 0..20 {
                g[(i, i)] += 1e-9; // jitter
            }
            assert!(cholesky(&g).is_some(), "{kind:?} gram not PSD");
        }
    }

    #[test]
    fn prop_matern_decays_with_distance() {
        check(
            "matern monotone in r",
            Config { cases: 128, seed: 9 },
            |r| {
                let a = r.range_f64(0.0, 2.0);
                let b = a + r.range_f64(0.01, 2.0);
                (a, b, r.range_f64(0.1, 3.0))
            },
            |&(r1, r2, ls)| {
                let k = Kernel { kind: KernelKind::Matern52, lengthscale: ls, variance: 1.0 };
                let v1 = k.eval(&[0.0], &[r1]);
                let v2 = k.eval(&[0.0], &[r2]);
                crate::prop_assert!(v1 >= v2, "k({r1})={v1} < k({r2})={v2} at ls={ls}");
                Ok(())
            },
        );
    }

    #[test]
    fn rbf_narrower_than_matern_at_large_r() {
        let m = Kernel { kind: KernelKind::Matern52, lengthscale: 0.5, variance: 1.0 };
        let r = Kernel { kind: KernelKind::Rbf, lengthscale: 0.5, variance: 1.0 };
        assert!(m.eval(&[0.0], &[2.0]) > r.eval(&[0.0], &[2.0])); // heavier tail
    }
}
