//! Covariance kernels.  All operate on feature vectors of dimension 1 or 2
//! (channel configurations), pre-normalized to ~[0, 1] by the caller.

pub const SQRT5: f64 = 2.236_067_977_499_79;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Matérn ν = 5/2 — the paper's kernel (eq. 3 with ν = 2.5).
    Matern52,
    /// Squared exponential (A6.2 ablation: overfits, worst).
    Rbf,
    /// Linear / dot-product (A6.2 ablation: second).
    DotProduct,
}

#[derive(Clone, Copy, Debug)]
pub struct Kernel {
    pub kind: KernelKind,
    /// Length-scale ℓ (ignored by DotProduct).
    pub lengthscale: f64,
    /// Signal variance σ².
    pub variance: f64,
}

impl Kernel {
    pub fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), z.len());
        match self.kind {
            KernelKind::Matern52 => {
                let r = dist(x, z);
                let s = SQRT5 * r / self.lengthscale;
                self.variance * (1.0 + s + s * s / 3.0) * (-s).exp()
            }
            KernelKind::Rbf => {
                let d2 = sq_dist(x, z);
                self.variance * (-0.5 * d2 / (self.lengthscale * self.lengthscale)).exp()
            }
            KernelKind::DotProduct => {
                let dot: f64 = x.iter().zip(z).map(|(a, b)| a * b).sum();
                self.variance * (dot + 1.0)
            }
        }
    }

    /// Gram matrix K(X, X) (+ nothing on the diagonal; noise added by the
    /// GP model).
    pub fn gram(&self, xs: &[Vec<f64>]) -> crate::util::linalg::Mat {
        let n = xs.len();
        let mut k = crate::util::linalg::Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = self.eval(&xs[i], &xs[j]);
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
        }
        k
    }

    /// Cross-covariance vector k(q, X).
    pub fn cross(&self, q: &[f64], xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.eval(q, x)).collect()
    }
}

/// Precomputed pairwise statistics of a point set, packed lower-
/// triangular (row `i` holds entries for `j ≤ i`).
///
/// During hyper-parameter search the points never move: only the scalar
/// kernel profile (ℓ, σ², noise) changes between the ~100 NLML
/// evaluations of a fit.  `DistGram` computes the distances / dot
/// products once per point set and [`DistGram::apply_into`] maps them
/// through the kernel into a reusable gram buffer — bit-identical to
/// building the gram from [`Kernel::eval`] on the original vectors,
/// because the stored `r`/`d²`/`x·z` feed the exact same expressions.
/// Appending a point ([`DistGram::push`]) appends one packed row; noise-
/// only candidate moves touch just the diagonal
/// ([`DistGram::apply_diag`]).
#[derive(Clone, Debug, Default)]
pub struct DistGram {
    n: usize,
    /// Pairwise Euclidean distances (Matérn path).
    r: Vec<f64>,
    /// Squared distances (RBF path).
    d2: Vec<f64>,
    /// Dot products (DotProduct path).
    dot: Vec<f64>,
}

impl DistGram {
    pub fn new(xs: &[Vec<f64>]) -> Self {
        let mut g = Self::default();
        for i in 1..=xs.len() {
            g.push(&xs[..i]);
        }
        g
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn clear(&mut self) {
        self.n = 0;
        self.r.clear();
        self.d2.clear();
        self.dot.clear();
    }

    /// Append the pairwise row of the *last* point of `xs`
    /// (`xs.len()` must be exactly one more than the covered count).
    pub fn push(&mut self, xs: &[Vec<f64>]) {
        assert_eq!(xs.len(), self.n + 1, "push expects exactly one new point");
        let x = &xs[self.n];
        for z in xs {
            let d2 = sq_dist(x, z);
            self.d2.push(d2);
            self.r.push(d2.sqrt());
            self.dot.push(x.iter().zip(z).map(|(a, b)| a * b).sum());
        }
        self.n += 1;
    }

    #[inline]
    fn idx(i: usize, j: usize) -> usize {
        debug_assert!(j <= i);
        i * (i + 1) / 2 + j
    }

    /// Apply the scalar kernel profile into `k` (resized to n×n), adding
    /// `diag_add` (noise + jitter) on the diagonal.  The kernel kind is
    /// matched once outside the loops — no per-element dispatch, no
    /// per-element sqrt — and each packed row is walked as a contiguous
    /// slab zipped against the destination row: no index arithmetic in
    /// the hot loop, so the compiler can vectorize it.  The per-element
    /// expressions are exactly the ones [`Kernel::eval`] uses, so the
    /// result stays bit-identical to the naive gram (pinned by
    /// `prop_distgram_matches_naive_gram_bitwise`); the upper triangle
    /// is mirrored from the computed lower triangle afterwards.
    pub fn apply_into(&self, kern: &Kernel, diag_add: f64, k: &mut crate::util::linalg::Mat) {
        let n = self.n;
        k.resize(n, n);
        match kern.kind {
            KernelKind::Matern52 => {
                let mut off = 0;
                for i in 0..n {
                    let slab = &self.r[off..off + i + 1];
                    let row = &mut k.row_mut(i)[..i + 1];
                    for (dst, &rij) in row.iter_mut().zip(slab) {
                        let s = SQRT5 * rij / kern.lengthscale;
                        *dst = kern.variance * (1.0 + s + s * s / 3.0) * (-s).exp();
                    }
                    off += i + 1;
                }
            }
            KernelKind::Rbf => {
                let mut off = 0;
                for i in 0..n {
                    let slab = &self.d2[off..off + i + 1];
                    let row = &mut k.row_mut(i)[..i + 1];
                    for (dst, &d2) in row.iter_mut().zip(slab) {
                        *dst = kern.variance
                            * (-0.5 * d2 / (kern.lengthscale * kern.lengthscale)).exp();
                    }
                    off += i + 1;
                }
            }
            KernelKind::DotProduct => {
                let mut off = 0;
                for i in 0..n {
                    let slab = &self.dot[off..off + i + 1];
                    let row = &mut k.row_mut(i)[..i + 1];
                    for (dst, &d) in row.iter_mut().zip(slab) {
                        *dst = kern.variance * (d + 1.0);
                    }
                    off += i + 1;
                }
            }
        }
        for i in 1..n {
            for j in 0..i {
                k[(j, i)] = k[(i, j)];
            }
        }
        self.apply_diag(kern, diag_add, k);
    }

    /// One kernel entry K[i][j] from the packed statistics, through the
    /// exact per-element expressions [`DistGram::apply_into`] uses (so a
    /// gram assembled entry-by-entry is bit-identical to an applied one).
    /// Symmetric: indices are swapped into the stored lower triangle.
    pub fn kern_at(&self, kern: &Kernel, i: usize, j: usize) -> f64 {
        let (i, j) = if i >= j { (i, j) } else { (j, i) };
        let p = Self::idx(i, j);
        match kern.kind {
            KernelKind::Matern52 => {
                let s = SQRT5 * self.r[p] / kern.lengthscale;
                kern.variance * (1.0 + s + s * s / 3.0) * (-s).exp()
            }
            KernelKind::Rbf => {
                kern.variance * (-0.5 * self.d2[p] / (kern.lengthscale * kern.lengthscale)).exp()
            }
            KernelKind::DotProduct => kern.variance * (self.dot[p] + 1.0),
        }
    }

    /// Rewrite only the diagonal of an already-applied gram: correct when
    /// nothing but the additive `diag_add` (noise) changed since the last
    /// [`DistGram::apply_into`] with the same (kind, ℓ, σ²) profile.
    pub fn apply_diag(&self, kern: &Kernel, diag_add: f64, k: &mut crate::util::linalg::Mat) {
        debug_assert_eq!(k.rows, self.n);
        for i in 0..self.n {
            let v = match kern.kind {
                // r = 0 on the diagonal: (1 + 0 + 0)·exp(-0) = 1 exactly,
                // so this matches eval(x, x) bit-for-bit.
                KernelKind::Matern52 | KernelKind::Rbf => kern.variance,
                KernelKind::DotProduct => kern.variance * (self.dot[Self::idx(i, i)] + 1.0),
            };
            k[(i, i)] = v + diag_add;
        }
    }
}

pub fn sq_dist(x: &[f64], z: &[f64]) -> f64 {
    x.iter().zip(z).map(|(a, b)| (a - b) * (a - b)).sum()
}

pub fn dist(x: &[f64], z: &[f64]) -> f64 {
    sq_dist(x, z).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    fn matern(ls: f64, var: f64) -> Kernel {
        Kernel { kind: KernelKind::Matern52, lengthscale: ls, variance: var }
    }

    #[test]
    fn matern_at_zero_distance_is_variance() {
        let k = matern(0.7, 3.0);
        assert!((k.eval(&[0.5], &[0.5]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn matern_matches_python_oracle() {
        // Values from python/compile/kernels/ref.py: matern52 with ℓ=0.8,
        // σ²=2.0 at r=0.5 ->  2*(1+s+s²/3)exp(-s), s=√5*0.5/0.8
        let s = SQRT5 * 0.5 / 0.8;
        let want = 2.0 * (1.0 + s + s * s / 3.0) * (-s as f64).exp();
        let k = matern(0.8, 2.0);
        let got = k.eval(&[0.0], &[0.5]);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn kernels_symmetric() {
        for kind in [KernelKind::Matern52, KernelKind::Rbf, KernelKind::DotProduct] {
            let k = Kernel { kind, lengthscale: 0.5, variance: 1.5 };
            let a = [0.2, 0.9];
            let b = [0.7, 0.1];
            assert!((k.eval(&a, &b) - k.eval(&b, &a)).abs() < 1e-14);
        }
    }

    #[test]
    fn gram_psd_via_cholesky() {
        use crate::util::linalg::cholesky;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(3);
        for kind in [KernelKind::Matern52, KernelKind::Rbf] {
            let xs: Vec<Vec<f64>> = (0..20).map(|_| vec![rng.f64(), rng.f64()]).collect();
            let k = Kernel { kind, lengthscale: 0.4, variance: 1.0 };
            let mut g = k.gram(&xs);
            for i in 0..20 {
                g[(i, i)] += 1e-9; // jitter
            }
            assert!(cholesky(&g).is_some(), "{kind:?} gram not PSD");
        }
    }

    #[test]
    fn prop_matern_decays_with_distance() {
        check(
            "matern monotone in r",
            Config { cases: 128, seed: 9 },
            |r| {
                let a = r.range_f64(0.0, 2.0);
                let b = a + r.range_f64(0.01, 2.0);
                (a, b, r.range_f64(0.1, 3.0))
            },
            |&(r1, r2, ls)| {
                let k = Kernel { kind: KernelKind::Matern52, lengthscale: ls, variance: 1.0 };
                let v1 = k.eval(&[0.0], &[r1]);
                let v2 = k.eval(&[0.0], &[r2]);
                crate::prop_assert!(v1 >= v2, "k({r1})={v1} < k({r2})={v2} at ls={ls}");
                Ok(())
            },
        );
    }

    #[test]
    fn prop_distgram_matches_naive_gram_bitwise() {
        use crate::util::linalg::Mat;
        check(
            "distgram == naive gram",
            Config { cases: 48, seed: 31 },
            |r| {
                // n up to 24: several full slab rows past the 20-point
                // range the pre-slab path was pinned at
                let n = r.range_usize(1, 24);
                let dim = r.range_usize(1, 2);
                let xs: Vec<Vec<f64>> =
                    (0..n).map(|_| (0..dim).map(|_| r.f64()).collect()).collect();
                (xs, r.range_f64(0.05, 2.0), r.range_f64(0.1, 3.0), r.range_f64(1e-6, 0.5))
            },
            |(xs, ls, var, noise)| {
                for kind in [KernelKind::Matern52, KernelKind::Rbf, KernelKind::DotProduct] {
                    let kern = Kernel { kind, lengthscale: *ls, variance: *var };
                    let mut want = kern.gram(xs);
                    for i in 0..xs.len() {
                        want[(i, i)] += noise + 1e-10;
                    }
                    let dg = DistGram::new(xs);
                    let mut got = Mat::zeros(1, 1);
                    dg.apply_into(&kern, noise + 1e-10, &mut got);
                    crate::prop_assert!(
                        got.data == want.data,
                        "{kind:?} gram diverged at ls={ls} var={var}"
                    );
                    // entry-wise accessor: off-diagonal entries (both
                    // orientations) must match the naive gram bit-for-bit
                    for i in 0..xs.len() {
                        for j in 0..xs.len() {
                            if i == j {
                                continue;
                            }
                            let at = dg.kern_at(&kern, i, j);
                            crate::prop_assert!(
                                at.to_bits() == want[(i, j)].to_bits(),
                                "{kind:?} kern_at({i},{j}) = {at} vs {}",
                                want[(i, j)]
                            );
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn distgram_push_equals_fresh_build() {
        use crate::util::linalg::Mat;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(12);
        let xs: Vec<Vec<f64>> = (0..10).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let fresh = DistGram::new(&xs);
        let mut inc = DistGram::default();
        for i in 1..=xs.len() {
            inc.push(&xs[..i]);
        }
        let kern = Kernel { kind: KernelKind::Matern52, lengthscale: 0.4, variance: 1.3 };
        let (mut a, mut b) = (Mat::zeros(1, 1), Mat::zeros(1, 1));
        fresh.apply_into(&kern, 1e-3, &mut a);
        inc.apply_into(&kern, 1e-3, &mut b);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn apply_diag_equals_full_reapply_on_noise_move() {
        use crate::util::linalg::Mat;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(13);
        for kind in [KernelKind::Matern52, KernelKind::Rbf, KernelKind::DotProduct] {
            let xs: Vec<Vec<f64>> = (0..8).map(|_| vec![rng.f64()]).collect();
            let dg = DistGram::new(&xs);
            let kern = Kernel { kind, lengthscale: 0.7, variance: 2.0 };
            let mut k = Mat::zeros(1, 1);
            dg.apply_into(&kern, 1e-3, &mut k);
            // noise-only move: diag rewrite must equal a full re-apply
            dg.apply_diag(&kern, 5e-2, &mut k);
            let mut full = Mat::zeros(1, 1);
            dg.apply_into(&kern, 5e-2, &mut full);
            assert_eq!(k.data, full.data, "{kind:?} diag-only move diverged");
        }
    }

    #[test]
    fn rbf_narrower_than_matern_at_large_r() {
        let m = Kernel { kind: KernelKind::Matern52, lengthscale: 0.5, variance: 1.0 };
        let r = Kernel { kind: KernelKind::Rbf, lengthscale: 0.5, variance: 1.0 };
        assert!(m.eval(&[0.0], &[2.0]) > r.eval(&[0.0], &[2.0])); // heavier tail
    }
}
