//! Kernel-configuration model: how many threads a launch actually uses on
//! a device, and the resulting utilization.
//!
//! This is the structural source of the paper's observed non-linearity
//! (Figs 5, 11): kernels are scheduled in *waves* of `compute_units ×
//! threads_per_unit` threads.  A problem that needs one thread more than a
//! wave boundary pays for a whole extra wave at marginal utilization —
//! energy plateaus between boundaries and jumps across them, exactly the
//! plateau/ridge morphology the paper profiles.  Pruned (narrow) models
//! sit on low-occupancy plateaus where energy is *not* proportional to
//! FLOPs, which is why FLOPs-ratio-guided pruning overshoots its budget
//! (Fig 13) and THOR does not.

/// Utilization of the compute array for a launch needing `parallelism`
/// threads on a device exposing `slots = units × threads_per_unit`
/// concurrent threads.
///
/// Returns (waves, utilization ∈ (0, 1]).
pub fn occupancy(parallelism: f64, slots: f64) -> (f64, f64) {
    assert!(parallelism > 0.0 && slots > 0.0);
    let waves = (parallelism / slots).ceil().max(1.0);
    let util = parallelism / (waves * slots);
    (waves, util)
}

/// Effective compute efficiency: utilization tempered by a per-class
/// efficiency ceiling (dense kernels reach near-peak; elementwise kernels
/// are bandwidth-limited and cap much lower), plus a small-launch penalty
/// modeling under-filled pipelines.
pub fn compute_efficiency(parallelism: f64, slots: f64, class_ceiling: f64) -> f64 {
    let (_, util) = occupancy(parallelism, slots);
    // Launches much smaller than one wave additionally underfill the
    // pipeline: ramp efficiency with a saturating curve.
    let fill = (parallelism / slots).min(1.0);
    let ramp = 0.25 + 0.75 * fill.sqrt();
    (util * ramp * class_ceiling).clamp(1e-3, 1.0)
}

/// GEMM-shape efficiency: dense kernels reach peak only when both the
/// row dimension (M = batch·spatial) and the channel dimension (N =
/// c_out) are large enough to fill the compute array's pipelines.
/// Late conv layers (tiny spatial), small-batch FC layers (M = batch)
/// and narrow/pruned channels all fall off the roofline — by *shape*,
/// not by FLOP count, which is precisely the signal a FLOPs proxy
/// cannot see and THOR's per-family GPs can (the family fixes the
/// shape; the channels are the GP features).
///
/// `m_sat` / `n_sat` are device-specific saturation points (a 4090
/// needs far larger tiles to saturate than a phone GPU).
pub fn shape_efficiency(m_rows: f64, n_cols: f64, m_sat: f64, n_sat: f64) -> f64 {
    let fm = (m_rows / m_sat).min(1.0).powf(0.35);
    let fn_ = (n_cols / n_sat).min(1.0).powf(0.35);
    (fm * fn_).clamp(0.02, 1.0)
}

/// Channel-tile padding: the kernel library executes a channel dimension
/// `c` as `ceil(c / tile) * tile` lanes, where the tile grows with the
/// problem (vendor libraries pick wider tiles for wider layers).
/// `quantum` is the device's base lane granularity (vec4 for WebGL,
/// 8-lane tensor tiles for CUDA).
///
/// This staircase is the paper's central non-linearity: energy vs channel
/// count is flat inside a tile and jumps at tile boundaries (Figs 5/11),
/// and pruned models keep paying for padded lanes (Fig 13).
pub fn padded_channels(c: usize, quantum: usize) -> usize {
    if c == 0 {
        return 0; // not channel-tiled
    }
    let tile = if c < 32 {
        quantum
    } else if c < 128 {
        2 * quantum
    } else {
        4 * quantum
    };
    c.div_ceil(tile) * tile
}

/// Multiplicative FLOP inflation from channel padding on both GEMM dims.
pub fn pad_ratio(c_in: usize, c_out: usize, quantum: usize) -> f64 {
    let r = |c: usize| {
        if c == 0 {
            1.0
        } else {
            padded_channels(c, quantum) as f64 / c as f64
        }
    };
    r(c_in) * r(c_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    #[test]
    fn padding_staircase() {
        assert_eq!(padded_channels(1, 8), 8);
        assert_eq!(padded_channels(8, 8), 8);
        assert_eq!(padded_channels(9, 8), 16);
        assert_eq!(padded_channels(33, 8), 48); // tile 16 above 32
        assert_eq!(padded_channels(129, 8), 160); // tile 32 above 128
        assert_eq!(padded_channels(0, 8), 0);
    }

    #[test]
    fn pad_ratio_worst_for_narrow() {
        assert!(pad_ratio(1, 1, 8) > 16.0); // 8x8 lanes for a 1x1 problem
        assert!(pad_ratio(256, 256, 8) < 1.01);
    }

    #[test]
    fn prop_padding_covers_and_bounded() {
        check(
            "padding ≥ c and < c + tile",
            Config { cases: 256, seed: 21 },
            |r| (r.range_usize(1, 4096), *r.choose(&[4usize, 8])),
            |&(c, q)| {
                let p = padded_channels(c, q);
                crate::prop_assert!(p >= c, "p {p} < c {c}");
                crate::prop_assert!(p < c + 4 * q, "p {p} too padded for c {c}");
                crate::prop_assert!(p % q == 0, "p {p} not multiple of {q}");
                Ok(())
            },
        );
    }

    #[test]
    fn one_wave_full_utilization() {
        let (w, u) = occupancy(1024.0, 1024.0);
        assert_eq!(w, 1.0);
        assert!((u - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wave_boundary_cliff() {
        // one thread past the boundary halves utilization
        let (_, u1) = occupancy(1024.0, 1024.0);
        let (_, u2) = occupancy(1025.0, 1024.0);
        assert!(u2 < 0.52 && u1 > 0.99);
    }

    #[test]
    fn plateau_within_wave() {
        // within a wave, utilization grows linearly -> time constant
        let (w1, _) = occupancy(1030.0, 1024.0);
        let (w2, _) = occupancy(2040.0, 1024.0);
        assert_eq!(w1, 2.0);
        assert_eq!(w2, 2.0);
    }

    #[test]
    fn prop_utilization_bounded() {
        check(
            "occupancy in (0,1]",
            Config { cases: 256, seed: 5 },
            |r| (r.range_f64(1.0, 1e8), r.range_f64(32.0, 1e5)),
            |&(p, s)| {
                let (w, u) = occupancy(p, s);
                crate::prop_assert!(u > 0.0 && u <= 1.0 + 1e-12, "u={u}");
                crate::prop_assert!(w >= 1.0, "w={w}");
                // waves * slots covers parallelism
                crate::prop_assert!(w * s >= p - 1e-6, "cover");
                Ok(())
            },
        );
    }

    #[test]
    fn efficiency_monotone_ceiling() {
        let lo = compute_efficiency(100.0, 1024.0, 0.9);
        let hi = compute_efficiency(1024.0, 1024.0, 0.9);
        assert!(hi > lo);
        assert!(hi <= 0.9 + 1e-12);
    }
}
