//! Workload compiler: lowers a [`crate::model::ModelGraph`] to the
//! per-training-iteration op trace a DNN framework would actually execute,
//! including the runtime optimizations that make proxy-based energy
//! estimation inaccurate (paper §2.3):
//!
//! * forward, backward (grad-input + grad-weight) and optimizer-update op
//!   emission per layer ([`lower`]);
//! * Conv-BN-ReLU and elementwise-into-producer fusion, fused optimizer
//!   update ([`fusion`]);
//! * kernel-configuration selection — threads-per-kernel as a function of
//!   problem size, which creates the occupancy plateaus/waves responsible
//!   for the non-linear energy curves in Figs 5 and 11 ([`kernelcfg`]).

pub mod fusion;
pub mod kernelcfg;
pub mod lower;

/// Execution class of an op — determines its parallelism shape and how the
/// device model schedules it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// MXU/tensor-core style dense compute (conv, matmul, lstm gates).
    Dense,
    /// Elementwise / normalization / pooling — memory-bound.
    Elementwise,
    /// Gather/scatter (embedding lookup) — latency-bound.
    Gather,
    /// Optimizer parameter update — memory-bound over parameters.
    Update,
}

/// Training phase an op belongs to (NeuralPower-style baselines profile
/// these separately; THOR never needs the distinction).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Forward,
    Backward,
    Update,
}

/// One lowered kernel launch.
#[derive(Clone, Debug)]
pub struct Op {
    /// Index of the source layer in the model graph (provenance — used to
    /// verify layer-wise additivity in tests).
    pub layer: usize,
    pub class: OpClass,
    pub phase: Phase,
    pub flops: f64,
    /// Bytes that must come from / go to DRAM if nothing is cached.
    pub bytes_in: f64,
    pub bytes_out: f64,
    /// Resident working set (weights + tiles) the kernel re-touches.
    pub working_set: f64,
    /// Maximum useful parallelism (threads) for this problem size.
    pub parallelism: f64,
    /// Channel dimensions of the underlying GEMM-shaped kernel, for the
    /// device's tile-padding rule (0 = not channel-tiled, e.g.
    /// elementwise).  Kernel libraries pad channels to tile multiples —
    /// "the kernel configure tends to launch fewer threads for pruned
    /// models" (paper §2.3) — so narrow/pruned layers waste lanes and
    /// energy stops being proportional to architectural FLOPs.
    pub c_in: usize,
    pub c_out: usize,
    /// Number of ops fused into this launch (1 = unfused).
    pub fused: usize,
}

/// A full training-iteration trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub ops: Vec<Op>,
}

impl Trace {
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    pub fn total_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.bytes_in + o.bytes_out).sum()
    }

    pub fn launches(&self) -> usize {
        self.ops.len()
    }

    /// Ops restricted to one source layer (additivity checks).
    pub fn layer_ops(&self, layer: usize) -> impl Iterator<Item = &Op> {
        self.ops.iter().filter(move |o| o.layer == layer)
    }
}
