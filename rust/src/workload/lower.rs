//! Lowering: model graph → unfused per-iteration op trace.
//!
//! Emission order mirrors a framework's autograd schedule: all forward ops
//! in layer order, then backward ops in reverse layer order (grad-input
//! followed by grad-weight per parametric layer), then one update op per
//! parametric layer.  This sequential, layer-tagged order is what makes
//! layer-wise energy additivity hold to first order (paper §3.2) — ops of
//! different layers never overlap in time on the simulated devices.

use crate::model::{flops, LayerKind, LayerSpec, ModelGraph};
use crate::workload::{Op, OpClass, Phase, Trace};

fn class_of(kind: &LayerKind) -> OpClass {
    match kind {
        LayerKind::Conv2d { .. } | LayerKind::Fc | LayerKind::Lstm | LayerKind::Attention { .. } => OpClass::Dense,
        LayerKind::Embedding => OpClass::Gather,
        _ => OpClass::Elementwise,
    }
}

/// Maximum useful parallelism for a layer's kernels: one thread per output
/// element for elementwise work; for dense ops, one thread per output
/// element of the implicit GEMM (rows × cols), independent of the
/// reduction depth.
fn parallelism(l: &LayerSpec) -> f64 {
    match &l.kind {
        LayerKind::Fc => (l.batch * l.c_out) as f64,
        LayerKind::Lstm => (l.batch * 4 * l.c_out) as f64, // per-timestep gate GEMM rows
        LayerKind::Attention { .. } => (l.batch * l.h * l.c_out) as f64,
        _ => l.out_elems() as f64,
    }
}

fn input_elems(l: &LayerSpec) -> f64 {
    match &l.kind {
        LayerKind::Fc => (l.batch * l.c_in) as f64,
        LayerKind::Embedding => (l.batch * l.h) as f64, // token ids
        LayerKind::Lstm | LayerKind::Attention { .. } => (l.batch * l.h * l.c_in) as f64,
        _ => (l.batch * l.c_in * l.h * l.w) as f64,
    }
}

/// Lower one layer's forward op.
fn channel_dims(l: &LayerSpec) -> (usize, usize) {
    // Only dense channel-tiled kernels are padded by the library.
    if class_of(&l.kind) == OpClass::Dense {
        (l.c_in, l.c_out)
    } else {
        (0, 0)
    }
}

fn fwd_op(idx: usize, l: &LayerSpec) -> Op {
    let (c_in, c_out) = channel_dims(l);
    Op {
        layer: idx,
        class: class_of(&l.kind),
        phase: Phase::Forward,
        flops: flops::fwd_flops(l),
        bytes_in: 4.0 * input_elems(l) + flops::param_bytes(l),
        bytes_out: flops::activation_bytes(l),
        working_set: flops::param_bytes(l) + 4.0 * input_elems(l),
        parallelism: parallelism(l),
        c_in,
        c_out,
        fused: 1,
    }
}

/// Backward ops: grad-input (propagates to the previous layer) and, for
/// parametric layers, grad-weight.
fn bwd_ops(idx: usize, l: &LayerSpec) -> Vec<Op> {
    let mut ops = Vec::new();
    let gin_flops = flops::fwd_flops(l); // dL/dx ≈ same cost as forward
    let (c_in, c_out) = channel_dims(l);
    ops.push(Op {
        layer: idx,
        class: class_of(&l.kind),
        phase: Phase::Backward,
        flops: gin_flops,
        bytes_in: flops::activation_bytes(l) + flops::param_bytes(l),
        bytes_out: 4.0 * input_elems(l),
        working_set: flops::param_bytes(l),
        parallelism: parallelism(l),
        c_in,
        c_out,
        fused: 1,
    });
    if l.kind.is_parametric() {
        ops.push(Op {
            layer: idx,
            class: OpClass::Dense,
            phase: Phase::Backward,
            flops: flops::bwd_flops(l) - gin_flops, // grad-weight share
            bytes_in: flops::activation_bytes(l) + 4.0 * input_elems(l),
            bytes_out: flops::param_bytes(l),
            working_set: flops::param_bytes(l),
            // grad-weight GEMMs have a small output (params) but a large
            // reduction; libraries recover parallelism with split-k, so
            // the launch exposes far more threads than `params`.
            parallelism: (l.params() as f64).max(parallelism(l) / 2.0),
            c_in,
            c_out,
            fused: 1,
        });
    }
    ops
}

fn update_op(idx: usize, l: &LayerSpec) -> Op {
    Op {
        layer: idx,
        class: OpClass::Update,
        phase: Phase::Update,
        flops: flops::update_flops(l),
        bytes_in: 2.0 * flops::param_bytes(l), // read weight + grad
        bytes_out: flops::param_bytes(l),
        working_set: 0.0,
        parallelism: l.params() as f64,
        c_in: 0,
        c_out: 0,
        fused: 1,
    }
}

/// Lower a model to its unfused training-iteration trace.
pub fn lower(g: &ModelGraph) -> Trace {
    let mut ops = Vec::new();
    for (i, l) in g.layers.iter().enumerate() {
        ops.push(fwd_op(i, l));
    }
    for (i, l) in g.layers.iter().enumerate().rev() {
        ops.extend(bwd_ops(i, l));
    }
    for (i, l) in g.layers.iter().enumerate() {
        // Every layer with parameters gets an update op — including
        // BatchNorm/LayerNorm, which are grouped as non-parametric for
        // *parsing* but still own trainable affine parameters.
        if l.params() > 0 {
            ops.push(update_op(i, l));
        }
    }
    Trace { ops }
}

/// Lower only one phase (the NeuralPower-style baseline profiles stages
/// separately; see `baselines::neuralpower`).
pub fn lower_phase(g: &ModelGraph, phase: Phase) -> Trace {
    let full = lower(g);
    Trace { ops: full.ops.into_iter().filter(|o| o.phase == phase).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn trace_flops_match_flops_module() {
        let g = zoo::cnn5(&[16, 32, 64, 128], 28, 10);
        let t = lower(&g);
        let want = crate::model::flops::model_train_flops(&g);
        assert!((t.total_flops() - want).abs() / want < 1e-9);
    }

    #[test]
    fn backward_emitted_in_reverse_layer_order() {
        let g = zoo::lenet5(&[6, 16, 120, 84], 10);
        let t = lower(&g);
        let bwd_layers: Vec<usize> =
            t.ops.iter().filter(|o| o.phase == Phase::Backward).map(|o| o.layer).collect();
        let mut sorted = bwd_layers.clone();
        sorted.sort_by(|a, b| b.cmp(a));
        assert_eq!(bwd_layers, sorted);
    }

    #[test]
    fn every_layer_with_params_gets_one_update() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let n_param = g.layers.iter().filter(|l| l.params() > 0).count();
        let t = lower(&g);
        let n_upd = t.ops.iter().filter(|o| o.phase == Phase::Update).count();
        assert_eq!(n_param, n_upd);
    }

    #[test]
    fn phases_partition_the_trace() {
        let g = zoo::har(&[16, 32, 64], 10);
        let full = lower(&g).ops.len();
        let parts: usize = [Phase::Forward, Phase::Backward, Phase::Update]
            .iter()
            .map(|&p| lower_phase(&g, p).ops.len())
            .sum();
        assert_eq!(full, parts);
    }

    #[test]
    fn layer_provenance_covers_all_layers() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let t = lower(&g);
        for i in 0..g.layers.len() {
            assert!(t.layer_ops(i).count() >= 1, "layer {i} lost in lowering");
        }
    }
}
