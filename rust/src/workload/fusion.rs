//! Fusion pass: the runtime optimizations that break naive stage-sum
//! estimation (paper §2.3 and Fig 2).
//!
//! * **Producer fusion** (Conv-BN-ReLU and elementwise-into-producer): a
//!   memory-bound elementwise op following a dense op on the *same layer
//!   or a grouped non-parametric successor* is folded into the producer
//!   launch — its FLOPs are kept but its intermediate tensor no longer
//!   round-trips DRAM, and one kernel launch disappears.
//! * **Fused optimizer**: all update ops are coalesced into a single
//!   launch (frameworks emit one fused optimizer kernel), keeping bytes
//!   but removing per-layer launch overhead.
//!
//! The NeuralPower-style baseline profiles layers/stages standalone, i.e.
//! *unfused and cold*, which is precisely why it overestimates (Fig 2).

use crate::workload::{Op, OpClass, Phase, Trace};

/// Whether `next` can fold into `prev` as a producer-consumer fusion.
fn fusible(prev: &Op, next: &Op) -> bool {
    prev.phase == next.phase
        && next.class == OpClass::Elementwise
        && prev.class != OpClass::Update
        // producer's output feeds the consumer: same or adjacent layer
        && (next.layer == prev.layer
            || next.layer == prev.layer + 1
            || prev.layer == next.layer + 1)
        // only fuse when the elementwise op is small relative to producer
        && next.flops <= prev.flops.max(1.0)
}

/// Apply producer fusion + fused optimizer to a lowered trace.
pub fn fuse(trace: &Trace) -> Trace {
    let mut out: Vec<Op> = Vec::with_capacity(trace.ops.len());
    for op in &trace.ops {
        if op.phase == Phase::Update {
            // Coalesce updates into one launch (keep per-layer provenance of
            // the first update op; bytes/flops accumulate).
            if let Some(last) = out.last_mut() {
                if last.phase == Phase::Update {
                    last.flops += op.flops;
                    last.bytes_in += op.bytes_in;
                    last.bytes_out += op.bytes_out;
                    last.parallelism += op.parallelism;
                    last.fused += 1;
                    continue;
                }
            }
            out.push(op.clone());
            continue;
        }
        if let Some(last) = out.last_mut() {
            if fusible(last, op) {
                // The intermediate activation stays in registers/VMEM: the
                // consumer's input read and the producer's output write are
                // both eliminated.
                last.bytes_out = op.bytes_out;
                last.flops += op.flops;
                last.fused += 1;
                continue;
            }
        }
        out.push(op.clone());
    }
    Trace { ops: out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::workload::lower::lower;

    #[test]
    fn fusion_reduces_launches_preserves_flops() {
        let g = zoo::cnn5(&[16, 32, 64, 128], 28, 10);
        let t = lower(&g);
        let f = fuse(&t);
        assert!(f.launches() < t.launches(), "{} !< {}", f.launches(), t.launches());
        assert!((f.total_flops() - t.total_flops()).abs() / t.total_flops() < 1e-9);
    }

    #[test]
    fn fusion_reduces_bytes() {
        let g = zoo::cnn5(&[16, 32, 64, 128], 28, 10);
        let t = lower(&g);
        let f = fuse(&t);
        assert!(f.total_bytes() < t.total_bytes());
    }

    #[test]
    fn conv_bn_relu_chain_becomes_one_launch() {
        // cnn5 forward: conv, bn, relu, pool per block -> fused to at most
        // 2 launches per block (conv+bn+relu merged, pool may merge too).
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let t = lower(&g);
        let f = fuse(&t);
        let fwd_launches = f.ops.iter().filter(|o| o.phase == Phase::Forward).count();
        let fwd_unfused = t.ops.iter().filter(|o| o.phase == Phase::Forward).count();
        assert!(fwd_launches * 2 <= fwd_unfused, "{fwd_launches} vs {fwd_unfused}");
    }

    #[test]
    fn updates_coalesce_to_single_launch() {
        let g = zoo::lenet5(&[6, 16, 120, 84], 10);
        let f = fuse(&lower(&g));
        let upd = f.ops.iter().filter(|o| o.phase == Phase::Update).count();
        assert_eq!(upd, 1);
    }

    #[test]
    fn fused_counter_tracks_members() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let t = lower(&g);
        let f = fuse(&t);
        let members: usize = f.ops.iter().map(|o| o.fused).sum();
        assert_eq!(members, t.ops.len());
    }

    #[test]
    fn dense_ops_never_fuse_into_each_other() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let f = fuse(&lower(&g));
        // every layer with a conv still has at least one dense launch
        for (i, l) in g.layers.iter().enumerate() {
            if l.kind.is_parametric() {
                assert!(
                    f.ops.iter().any(|o| o.layer == i && o.class == OpClass::Dense),
                    "dense op of layer {i} disappeared"
                );
            }
        }
    }
}
