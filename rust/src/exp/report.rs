//! Structured experiment results.
//!
//! Every registered [`crate::exp::registry::Experiment`] returns an
//! [`ExpReport`]: tables (pre-formatted cells), named (x, y) series,
//! scalar metrics and free-form notes, plus metadata (seeds, quick-mode
//! flag, device set).  Reports render to the same ASCII tables the paper
//! prints (via [`crate::util::table`]) and serialize to JSON (via
//! [`crate::util::json`]) for the golden-run regression harness.
//!
//! Determinism contract: everything stored in a report — and therefore
//! everything serialized — must be a pure function of the experiment's
//! [`crate::exp::ExpConfig`].  Wall-clock quantities (e.g. GP fitting
//! seconds, runner elapsed time) are deliberately excluded; simulated
//! device-seconds are fine.  `util::json::Json` objects are `BTreeMap`s,
//! so key order is stable by construction.

use crate::exp::ExpConfig;
use crate::util::json::Json;
use crate::util::table;

/// Report metadata: which configuration produced the numbers.
#[derive(Clone, Debug, Default)]
pub struct ExpMeta {
    /// Suite-level seed the per-experiment seed was derived from
    /// (filled in by the runner; 0 when an experiment is run directly).
    pub base_seed: u64,
    /// The derived seed the experiment actually ran with
    /// ([`ExpConfig::derive_seed`]).
    pub seed: u64,
    pub quick: bool,
    /// Simulated devices the experiment touched.
    pub devices: Vec<String>,
}

/// One titled table: headers + pre-formatted cell strings.
#[derive(Clone, Debug)]
pub struct TableData {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TableData {
    /// Cells of one column, by header name.
    pub fn column(&self, header: &str) -> Option<Vec<&str>> {
        let i = self.headers.iter().position(|h| h == header)?;
        Some(self.rows.iter().map(|r| r[i].as_str()).collect())
    }
}

/// One titled set of named (x, y) series sharing an x axis (the "figure"
/// analogue: pipe into any plotting tool to regenerate the paper's plot).
#[derive(Clone, Debug)]
pub struct SeriesData {
    pub title: String,
    pub xlabel: String,
    pub series: Vec<(String, Vec<(f64, f64)>)>,
}

/// Structured result of one experiment.
#[derive(Clone, Debug, Default)]
pub struct ExpReport {
    pub id: String,
    pub title: String,
    pub meta: ExpMeta,
    pub tables: Vec<TableData>,
    pub series: Vec<SeriesData>,
    /// Named scalar results (e.g. `pearson_r`), machine-checkable without
    /// parsing table cells.
    pub metrics: Vec<(String, f64)>,
    /// Free-form annotation lines appended to the rendering.
    pub notes: Vec<String>,
    /// Set when the experiment panicked inside the runner.
    pub error: Option<String>,
}

impl ExpReport {
    pub fn new(id: &str, title: &str, cfg: &ExpConfig, devices: &[&str]) -> Self {
        Self {
            id: id.to_string(),
            title: title.to_string(),
            meta: ExpMeta {
                base_seed: 0,
                seed: cfg.seed,
                quick: cfg.quick,
                devices: devices.iter().map(|d| d.to_string()).collect(),
            },
            ..Self::default()
        }
    }

    /// A report recording a failed run (runner-caught panic).
    pub fn failed(id: &str, cfg: &ExpConfig, msg: &str) -> Self {
        let mut r = Self::new(id, "(failed)", cfg, &[]);
        r.error = Some(msg.to_string());
        r
    }

    pub fn push_table(&mut self, title: &str, headers: &[&str], rows: Vec<Vec<String>>) {
        self.tables.push(TableData {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows,
        });
    }

    pub fn push_series(&mut self, title: &str, xlabel: &str, series: Vec<(String, Vec<(f64, f64)>)>) {
        self.series.push(SeriesData { title: title.to_string(), xlabel: xlabel.to_string(), series });
    }

    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    pub fn get_metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn note(&mut self, line: impl Into<String>) {
        self.notes.push(line.into());
    }

    pub fn table(&self, title: &str) -> Option<&TableData> {
        self.tables.iter().find(|t| t.title == title)
    }

    /// Human rendering: the same tables/series `cargo bench` and the
    /// `thor exp` CLI have always printed.
    pub fn render(&self) -> String {
        let mut out = format!("## {} — {}\n", self.id, self.title);
        if let Some(err) = &self.error {
            out.push_str(&format!("EXPERIMENT FAILED: {err}\n"));
            return out;
        }
        for t in &self.tables {
            if !t.title.is_empty() {
                out.push_str(&format!("# {}\n", t.title));
            }
            let headers: Vec<&str> = t.headers.iter().map(|h| h.as_str()).collect();
            out.push_str(&table::render(&headers, &t.rows));
        }
        for s in &self.series {
            let named: Vec<(&str, &[(f64, f64)])> =
                s.series.iter().map(|(n, pts)| (n.as_str(), pts.as_slice())).collect();
            out.push_str(&table::render_series(&s.title, &s.xlabel, &named));
        }
        for (name, v) in &self.metrics {
            out.push_str(&format!("{name} = {v:.4}\n"));
        }
        for n in &self.notes {
            out.push_str(n);
            out.push('\n');
        }
        out
    }

    /// Canonical JSON (deterministic: object keys are sorted, values are
    /// pure functions of the experiment seed).  Schema is documented in
    /// the [`crate::exp`] module docs.
    pub fn to_json(&self) -> Json {
        let meta = Json::obj(vec![
            ("base_seed", Json::str(&self.meta.base_seed.to_string())),
            ("seed", Json::str(&self.meta.seed.to_string())),
            ("quick", Json::Bool(self.meta.quick)),
            ("devices", Json::Arr(self.meta.devices.iter().map(|d| Json::str(d)).collect())),
        ]);
        let tables = Json::Arr(
            self.tables
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        ("title", Json::str(&t.title)),
                        ("headers", Json::Arr(t.headers.iter().map(|h| Json::str(h)).collect())),
                        (
                            "rows",
                            Json::Arr(
                                t.rows
                                    .iter()
                                    .map(|r| Json::Arr(r.iter().map(|c| Json::str(c)).collect()))
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let series = Json::Arr(
            self.series
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("title", Json::str(&s.title)),
                        ("xlabel", Json::str(&s.xlabel)),
                        (
                            "series",
                            Json::Arr(
                                s.series
                                    .iter()
                                    .map(|(name, pts)| {
                                        Json::obj(vec![
                                            ("name", Json::str(name)),
                                            (
                                                "points",
                                                Json::Arr(
                                                    pts.iter()
                                                        .map(|(x, y)| Json::arr_f64(&[*x, *y]))
                                                        .collect(),
                                                ),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let metrics = Json::Arr(
            self.metrics
                .iter()
                .map(|(name, v)| Json::obj(vec![("name", Json::str(name)), ("value", Json::Num(*v))]))
                .collect(),
        );
        Json::obj(vec![
            ("id", Json::str(&self.id)),
            ("title", Json::str(&self.title)),
            ("meta", meta),
            ("tables", tables),
            ("series", series),
            ("metrics", metrics),
            ("notes", Json::Arr(self.notes.iter().map(|n| Json::str(n)).collect())),
            (
                "error",
                match &self.error {
                    Some(e) => Json::str(e),
                    None => Json::Null,
                },
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> ExpReport {
        let cfg = ExpConfig::new(true, 42);
        let mut r = ExpReport::new("figX", "sample", &cfg, &["xavier"]);
        r.push_table(
            "t",
            &["a", "b"],
            vec![vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        );
        r.push_series("s", "x", vec![("y".to_string(), vec![(0.0, 1.5), (1.0, 2.5)])]);
        r.metric("m", 0.25);
        r.note("hello");
        r
    }

    #[test]
    fn render_contains_tables_series_notes() {
        let s = sample_report().render();
        assert!(s.contains("figX"));
        assert!(s.contains("| a | b |"));
        assert!(s.contains("# s"));
        assert!(s.contains("m = 0.2500"));
        assert!(s.contains("hello"));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let j = sample_report().to_json().to_string();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), "figX");
        assert_eq!(v.get("meta").unwrap().get("seed").unwrap().as_str().unwrap(), "42");
        assert_eq!(v.get("tables").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("error").unwrap(), &Json::Null);
    }

    #[test]
    fn json_serialization_is_stable() {
        assert_eq!(sample_report().to_json().to_string(), sample_report().to_json().to_string());
    }

    #[test]
    fn column_lookup() {
        let r = sample_report();
        let t = r.table("t").unwrap();
        assert_eq!(t.column("b").unwrap(), vec!["2", "4"]);
        assert!(t.column("nope").is_none());
    }

    #[test]
    fn failed_report_renders_error() {
        let cfg = ExpConfig::new(false, 1);
        let r = ExpReport::failed("figY", &cfg, "boom");
        assert!(r.render().contains("FAILED"));
        assert_eq!(r.to_json().get("error").unwrap().as_str().unwrap(), "boom");
    }
}
