//! Experiment registry: every paper table/figure is a registered
//! [`registry::Experiment`] producing a structured, JSON-serializable
//! [`report::ExpReport`], executed (possibly many at a time) by the
//! multi-threaded [`runner::Runner`] with deterministic within-experiment
//! subtask fan-out ([`registry::Subtask`]).
//!
//! # Layout
//!
//! | module          | contents                                             |
//! |-----------------|------------------------------------------------------|
//! | [`report`]      | `ExpReport` (tables, series, metrics, notes) + JSON  |
//! | [`registry`]    | `Experiment` + `Subtask` traits, id → experiment map |
//! | [`runner`]      | shared worker pool, subtask fan-out, suite JSON      |
//! | [`tables`]      | fig2, fig7, fig8 (+ Table 1), fig9, fig12            |
//! | [`figures`]     | fig4, fig5, fig6, fig10, fig11                       |
//! | [`pruning_exp`] | fig13 (energy-aware pruning case study)              |
//! | [`ablation`]    | a14 (point budget), a15 (kernels), a16 (iterations)  |
//! | [`fleet_exp`]   | fleet1/fleetN/fleetH/fleetE (fleet profiling, A5.2)  |
//! | [`serve_exp`]   | serve1 (estimation-serving daemon under load)        |
//! | [`gpscale`]     | gpscale (sparse-vs-exact GP backend drift, PR 9)     |
//!
//! Experiment ids: `fig2 fig4 fig5 fig6 fig7 fig8 fig9 fig10 fig11 fig12
//! fig13 a14 a15 a16 fleet1 fleetN fleetH fleetE fleetS serve1 gpscale`
//! (`tab1` aliases `fig8`).
//!
//! # Entry points
//!
//! * CLI: `thor exp <id> | --all [--quick] [--seed N] [--threads N]
//!   [--json out.json] [--list]`
//! * bench: `cargo bench --bench paper_experiments`
//! * tests: `rust/tests/exp_smoke.rs` (directions), `rust/tests/
//!   golden_runs.rs` (full-suite regression + determinism),
//!   `rust/tests/properties.rs` (fan-out determinism),
//!   `rust/tests/fleet.rs` (coordinator invariants at integration level)
//!
//! # Determinism & the `--json` schema
//!
//! Each experiment runs with a seed derived from the suite seed and its
//! id ([`ExpConfig::for_experiment`]); each subtask of a fanned-out
//! experiment runs with a seed derived from the experiment seed and the
//! subtask label ([`ExpConfig::for_subtask`]), and subtask outputs merge
//! in declaration order.  Results are therefore independent of thread
//! scheduling: `thor exp --all --quick --json out.json` is
//! byte-identical run-to-run and across `--threads 1/2/8` for a fixed
//! `--seed`.  Wall-clock values never enter reports (simulated
//! device-seconds do).  Schema (version 1):
//!
//! ```text
//! { "schema_version": 1, "base_seed": "<u64>", "quick": bool,
//!   "experiments": [ { "id", "title",
//!       "meta": { "base_seed", "seed", "quick", "devices": [..] },
//!       "tables": [ { "title", "headers": [..], "rows": [[..cell..]] } ],
//!       "series": [ { "title", "xlabel",
//!                     "series": [ { "name", "points": [[x, y], ..] } ] } ],
//!       "metrics": [ { "name", "value" } ],
//!       "notes": [..], "error": null | "<panic message>" } ] }
//! ```
//!
//! # Golden-run workflow
//!
//! `rust/tests/golden_runs.rs` runs every registered experiment in quick
//! mode at a fixed seed and diffs each report's JSON against
//! `rust/tests/golden/<id>.json`.  Blessing (writing goldens) happens
//! only with `UPDATE_GOLDENS=1` — or, as a bootstrap convenience, when a
//! golden is missing *and* `GOLDEN_STRICT` is unset; CI exports
//! `GOLDEN_STRICT=1`, so missing or stale goldens fail there instead of
//! silently self-blessing.  After an intentional change to experiment
//! output, regen with `UPDATE_GOLDENS=1 cargo test --test golden_runs`
//! and commit the diff.

pub mod ablation;
pub mod figures;
pub mod fleet_exp;
pub mod gpscale;
pub mod pruning_exp;
pub mod registry;
pub mod report;
pub mod runner;
pub mod serve_exp;
pub mod tables;

pub use registry::{by_id, ids, Experiment, Subtask, SubtaskOutput};
pub use report::ExpReport;
pub use runner::{Runner, SuiteResult};

use crate::baselines::flops_lr::FlopsLr;
use crate::model::flops::model_train_flops;
use crate::model::sampler::{sample_n, Family};
use crate::simdevice::{devices, Device};
use crate::thor::{Thor, ThorConfig};
use crate::util::stats::{mape, mean};
use crate::workload::{fusion::fuse, lower::lower};

/// Global experiment scale: `quick` shrinks sample counts ~10× so the
/// whole suite runs in minutes on one core.
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    pub quick: bool,
    pub seed: u64,
}

impl ExpConfig {
    pub fn new(quick: bool, seed: u64) -> Self {
        Self { quick, seed }
    }

    /// The config an experiment runs with inside a suite: quick flag +
    /// per-experiment seed derived from the suite seed and the id.
    pub fn for_experiment(base_seed: u64, quick: bool, id: &str) -> Self {
        Self { quick, seed: Self::derive_seed(base_seed, id) }
    }

    /// The config one subtask of a fanned-out experiment runs with: same
    /// quick flag, seed derived from the experiment seed and the subtask
    /// label — so results depend only on (suite seed, experiment id,
    /// label), never on scheduling.
    pub fn for_subtask(&self, label: &str) -> Self {
        Self { quick: self.quick, seed: Self::derive_seed(self.seed, label) }
    }

    /// FNV-1a over (base seed ‖ experiment id) — [`crate::util::hash`]:
    /// stable across platforms and releases, so golden files and suite
    /// JSON never shift underneath a refactor.
    pub fn derive_seed(base_seed: u64, id: &str) -> u64 {
        let mut h = crate::util::hash::Fnv1a::new();
        h.write(&base_seed.to_le_bytes());
        h.write(id.as_bytes());
        h.finish()
    }

    pub fn n_test(&self) -> usize {
        if self.quick { 15 } else { 100 }
    }

    pub fn n_train_lr(&self) -> usize {
        if self.quick { 12 } else { 30 }
    }

    pub fn repeats(&self) -> usize {
        if self.quick { 1 } else { 3 }
    }

    pub fn iterations(&self) -> usize {
        if self.quick { 120 } else { 500 }
    }

    pub fn thor_cfg(&self) -> ThorConfig {
        if self.quick {
            ThorConfig { iterations: 120, ..ThorConfig::quick() }
        } else {
            ThorConfig::default()
        }
    }
}

/// Measured ground truth: mean of `repeats` metered runs (eq. 6 protocol).
pub fn measured_energy(dev: &mut Device, g: &crate::model::ModelGraph, iters: usize, repeats: usize) -> f64 {
    let tr = fuse(&lower(g));
    let runs: Vec<f64> = (0..repeats).map(|_| dev.run(&tr, iters).energy_per_iter()).collect();
    mean(&runs)
}

/// Fit a per-device FLOPs-LR across all Fig-8 families (the proxy method
/// sees only FLOPs, so one regressor per device is the faithful reading
/// of "use FLOPs to fit a Linear Regression Model").
pub fn fit_flops_lr(dev: &mut Device, cfg: &ExpConfig) -> FlopsLr {
    let mut data = Vec::new();
    for (i, fam) in Family::fig8_families().iter().enumerate() {
        for g in sample_n(*fam, cfg.n_train_lr() / 4 + 1, cfg.seed + 100 + i as u64, 10) {
            let e = measured_energy(dev, &g, cfg.iterations(), 1);
            data.push((model_train_flops(&g), e));
        }
    }
    FlopsLr::fit(&data)
}

/// Reference (full-width) model per family, used to profile THOR.
/// Canonical definition lives in [`crate::model::spec`] so the serving
/// tier's model specs resolve to the exact graphs profiling used.
pub fn reference_model(fam: Family) -> crate::model::ModelGraph {
    crate::model::spec::reference(fam)
}

/// MAPE of THOR and FLOPs-LR on one (device, family) pair.
/// Returns (thor_mape, flops_mape, thor_profile_report).
pub fn mape_pair(
    dev_name: &str,
    fam: Family,
    cfg: &ExpConfig,
) -> (f64, f64, crate::thor::pipeline::ProfileReport) {
    let profile = devices::by_name(dev_name).expect("device");
    let mut dev = Device::new(profile, cfg.seed);
    let lr = fit_flops_lr(&mut dev, cfg);

    let mut thor = Thor::new(cfg.thor_cfg());
    let report = thor.profile_local(&mut dev, &reference_model(fam));

    let test = sample_n(fam, cfg.n_test(), cfg.seed + 1, 10);
    let (mut actual, mut p_lr, mut p_th) = (vec![], vec![], vec![]);
    for g in &test {
        actual.push(measured_energy(&mut dev, g, cfg.iterations(), cfg.repeats()));
        p_lr.push(lr.predict(g));
        p_th.push(thor.estimate(dev_name, g).map(|e| e.energy_per_iter).unwrap_or(0.0));
    }
    (mape(&actual, &p_th), mape(&actual, &p_lr), report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_stable_and_id_sensitive() {
        // Pinned: golden files depend on this mapping never changing.
        assert_eq!(ExpConfig::derive_seed(2025, "fig8"), ExpConfig::derive_seed(2025, "fig8"));
        assert_ne!(ExpConfig::derive_seed(2025, "fig8"), ExpConfig::derive_seed(2025, "fig9"));
        assert_ne!(ExpConfig::derive_seed(2025, "fig8"), ExpConfig::derive_seed(2026, "fig8"));
    }

    #[test]
    fn for_experiment_threads_quick_flag() {
        let cfg = ExpConfig::for_experiment(7, true, "fig2");
        assert!(cfg.quick);
        assert_eq!(cfg.seed, ExpConfig::derive_seed(7, "fig2"));
    }

    #[test]
    fn for_subtask_derives_from_experiment_seed_and_label() {
        let cfg = ExpConfig::for_experiment(7, true, "fig8");
        let a = cfg.for_subtask("xavier/cnn5");
        let b = cfg.for_subtask("server/cnn5");
        assert!(a.quick && b.quick);
        assert_eq!(a.seed, ExpConfig::derive_seed(cfg.seed, "xavier/cnn5"));
        assert_ne!(a.seed, b.seed);
        assert_ne!(a.seed, cfg.seed);
    }
}
