//! Experiment harness: one module-level function per paper table/figure
//! (see DESIGN.md §6 for the index).  Each returns printable rows /
//! series in the same shape the paper reports, and is invoked both by
//! `cargo bench` (rust/benches/paper_experiments.rs) and by the
//! `thor exp <id>` CLI.

use crate::baselines::flops_lr::FlopsLr;
use crate::baselines::neuralpower;
use crate::model::flops::model_train_flops;
use crate::model::sampler::{sample, sample_n, Family};
use crate::model::zoo;
use crate::simdevice::{devices, Device};
use crate::thor::{Thor, ThorConfig};
use crate::util::rng::Pcg64;
use crate::util::stats::{cdf, mape, mean, pearson, std_err};
use crate::util::table;
use crate::workload::{fusion::fuse, lower::lower};

/// Global experiment scale: `quick` shrinks sample counts ~10× so the
/// whole suite runs in minutes on one core.
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    pub quick: bool,
    pub seed: u64,
}

impl ExpConfig {
    pub fn new(quick: bool, seed: u64) -> Self {
        Self { quick, seed }
    }

    pub fn n_test(&self) -> usize {
        if self.quick { 15 } else { 100 }
    }

    pub fn n_train_lr(&self) -> usize {
        if self.quick { 12 } else { 30 }
    }

    pub fn repeats(&self) -> usize {
        if self.quick { 1 } else { 3 }
    }

    pub fn iterations(&self) -> usize {
        if self.quick { 120 } else { 500 }
    }

    pub fn thor_cfg(&self) -> ThorConfig {
        if self.quick {
            ThorConfig { iterations: 120, ..ThorConfig::quick() }
        } else {
            ThorConfig::default()
        }
    }
}

/// Measured ground truth: mean of `repeats` metered runs (eq. 6 protocol).
pub fn measured_energy(dev: &mut Device, g: &crate::model::ModelGraph, iters: usize, repeats: usize) -> f64 {
    let tr = fuse(&lower(g));
    let runs: Vec<f64> = (0..repeats).map(|_| dev.run(&tr, iters).energy_per_iter()).collect();
    mean(&runs)
}

/// Fit a per-device FLOPs-LR across all Fig-8 families (the proxy method
/// sees only FLOPs, so one regressor per device is the faithful reading
/// of "use FLOPs to fit a Linear Regression Model").
pub fn fit_flops_lr(dev: &mut Device, cfg: &ExpConfig) -> FlopsLr {
    let mut data = Vec::new();
    for (i, fam) in Family::fig8_families().iter().enumerate() {
        for g in sample_n(*fam, cfg.n_train_lr() / 4 + 1, cfg.seed + 100 + i as u64, 10) {
            let e = measured_energy(dev, &g, cfg.iterations(), 1);
            data.push((model_train_flops(&g), e));
        }
    }
    FlopsLr::fit(&data)
}

/// Reference (full-width) model per family, used to profile THOR.
pub fn reference_model(fam: Family) -> crate::model::ModelGraph {
    match fam {
        Family::LeNet5 => zoo::lenet5(&[6, 16, 120, 84], 10),
        Family::Cnn5 => zoo::cnn5(&[32, 64, 128, 256], 28, 10),
        Family::Har => zoo::har(&[32, 64, 128], 10),
        Family::Lstm => zoo::lstm(64, &[128, 128], 2000, 32, 10),
        Family::Transformer => zoo::transformer(4, 256, 4, 32, 2000, 10),
        Family::ResNet20 => zoo::resnet(20, 16, 10),
        Family::ResNet56 => zoo::resnet(56, 16, 10),
        Family::ResNet110 => zoo::resnet(110, 16, 10),
    }
}

/// MAPE of THOR and FLOPs-LR on one (device, family) pair.
/// Returns (thor_mape, flops_mape, thor_profile_report).
pub fn mape_pair(
    dev_name: &str,
    fam: Family,
    cfg: &ExpConfig,
) -> (f64, f64, crate::thor::pipeline::ProfileReport) {
    let profile = devices::by_name(dev_name).expect("device");
    let mut dev = Device::new(profile, cfg.seed);
    let lr = fit_flops_lr(&mut dev, cfg);

    let mut thor = Thor::new(cfg.thor_cfg());
    let report = thor.profile(&mut dev, &reference_model(fam));

    let test = sample_n(fam, cfg.n_test(), cfg.seed + 1, 10);
    let (mut actual, mut p_lr, mut p_th) = (vec![], vec![], vec![]);
    for g in &test {
        actual.push(measured_energy(&mut dev, g, cfg.iterations(), cfg.repeats()));
        p_lr.push(lr.predict(g));
        p_th.push(thor.estimate(dev_name, g).map(|e| e.energy_per_iter).unwrap_or(0.0));
    }
    (mape(&actual, &p_th), mape(&actual, &p_lr), report)
}

pub mod fig2 {
    use super::*;

    /// NeuralPower-style per-stage estimation vs observation, CNN depth
    /// sweep (the overestimation validation).
    pub fn run(cfg: &ExpConfig) -> String {
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let mut rows = Vec::new();
        for depth in 1..=4usize {
            // input conv + (depth-1) hidden convs + fc
            let ch: Vec<usize> = (0..depth).map(|i| 16 << i.min(3)).collect();
            let mut padded = [16usize, 32, 64, 128];
            for (i, c) in ch.iter().enumerate() {
                padded[i] = *c;
            }
            let g = match depth {
                1 => zoo::cnn5(&[padded[0], 1, 1, 1], 28, 10),
                2 => zoo::cnn5(&[padded[0], padded[1], 1, 1], 28, 10),
                3 => zoo::cnn5(&[padded[0], padded[1], padded[2], 1], 28, 10),
                _ => zoo::cnn5(&padded, 28, 10),
            };
            let observed = measured_energy(&mut dev, &g, cfg.iterations(), cfg.repeats());
            let np_est = neuralpower::estimate(&mut dev, &g, cfg.iterations().min(100));
            rows.push(vec![
                format!("{depth}"),
                format!("{observed:.4e}"),
                format!("{np_est:.4e}"),
                format!("{:.2}", np_est / observed),
            ]);
        }
        table::render(&["#conv layers", "observed J/iter", "NeuralPower-style est", "ratio"], &rows)
    }
}

pub mod fig4 {
    use super::*;
    use crate::gp::acquisition::{max_variance, Acquire, CandidateGrid};
    use crate::gp::{GpModel, KernelKind};
    use crate::thor::pipeline::log_channel;
    use crate::thor::profiler;

    /// GP + acquisition after k and k+1 steps (FC output family on OPPO).
    pub fn run(cfg: &ExpConfig) -> String {
        let mut dev = Device::new(devices::oppo(), cfg.seed);
        let reference = zoo::cnn5(&[32, 64, 128, 256], 28, 10);
        let parsed = crate::thor::parse::parse(&reference);
        let out = parsed.output_groups().next().unwrap();
        let c_max = 512.0;
        let mut pts: Vec<(Vec<f64>, f64)> = Vec::new();
        let mut out_s = String::new();
        for step in 0..6 {
            let p = if step == 0 {
                vec![0.0]
            } else if step == 1 {
                vec![1.0]
            } else {
                let xs: Vec<Vec<f64>> = pts.iter().map(|p| p.0.clone()).collect();
                let ys: Vec<f64> = pts.iter().map(|p| p.1.ln()).collect();
                let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
                match max_variance(&gp, &CandidateGrid::dim1(0.0, 1.0, 33), 0.0, 1.0) {
                    Acquire::Next(p, _) => p,
                    Acquire::Converged(_) => break,
                }
            };
            let c = log_channel(p[0], c_max);
            let (e, _) = profiler::measure(&mut dev, &profiler::output_variant(out, c), cfg.iterations());
            pts.push((p, e));
            if step >= 4 {
                // dump posterior after this step
                let xs: Vec<Vec<f64>> = pts.iter().map(|p| p.0.clone()).collect();
                let ys: Vec<f64> = pts.iter().map(|p| p.1.ln()).collect();
                let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
                let series: Vec<(f64, f64)> = (0..=32)
                    .map(|i| {
                        let x = i as f64 / 32.0;
                        let (m, _) = gp.predict(&[x]);
                        (log_channel(x, c_max) as f64, m.exp())
                    })
                    .collect();
                let var_series: Vec<(f64, f64)> = (0..=32)
                    .map(|i| {
                        let x = i as f64 / 32.0;
                        let (_, v) = gp.predict(&[x]);
                        (log_channel(x, c_max) as f64, v.sqrt())
                    })
                    .collect();
                out_s.push_str(&table::render_series(
                    &format!("GP posterior after {} steps (FC output family, OPPO)", pts.len()),
                    "channel",
                    &[("mean J/iter", &series), ("posterior std (log)", &var_series)],
                ));
            }
        }
        out_s
    }
}

pub mod fig5 {
    use super::*;

    /// FC-layer energy vs input channel on Xavier: non-linear staircase.
    pub fn run(cfg: &ExpConfig) -> String {
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let reference = zoo::cnn5(&[32, 64, 128, 256], 28, 10);
        let parsed = crate::thor::parse::parse(&reference);
        let out = parsed.output_groups().next().unwrap();
        let step = if cfg.quick { 64 } else { 16 };
        let series: Vec<(f64, f64)> = (1..=512usize)
            .step_by(step)
            .map(|c| {
                let (e, _) = crate::thor::profiler::measure(
                    &mut dev,
                    &crate::thor::profiler::output_variant(out, c),
                    cfg.iterations(),
                );
                (c as f64, e)
            })
            .collect();
        let flops_line: Vec<(f64, f64)> = series
            .iter()
            .map(|(c, _)| {
                let g = crate::thor::profiler::output_variant(out, *c as usize);
                (*c, model_train_flops(&g))
            })
            .collect();
        table::render_series(
            "FC layer energy vs input channel (Xavier) — energy is NOT linear in FLOPs",
            "channel",
            &[("energy J/iter", &series), ("train FLOPs", &flops_line)],
        )
    }
}

pub mod fig6 {
    use super::*;

    /// Time ↔ energy correlation across random 5-layer CNNs (justifies
    /// the time-uncertainty surrogate).
    pub fn run(cfg: &ExpConfig) -> String {
        let mut dev = Device::new(devices::oppo(), cfg.seed);
        let n = if cfg.quick { 10 } else { 40 };
        let models = sample_n(Family::Cnn5, n, cfg.seed + 5, 10);
        let mut ts = Vec::new();
        let mut es = Vec::new();
        for g in &models {
            let m = dev.run(&fuse(&lower(g)), cfg.iterations());
            ts.push(m.time_per_iter());
            es.push(m.energy_per_iter());
        }
        let r = pearson(&ts, &es);
        let pts: Vec<(f64, f64)> = ts.iter().zip(&es).map(|(t, e)| (*t, *e)).collect();
        format!(
            "{}\nPearson r(time, energy) = {r:.4} (paper: 'obvious positive relationship')\n",
            table::render_series("time vs energy per iteration (5-layer CNN, OPPO)", "time s/iter", &[("energy J/iter", &pts)])
        )
    }
}

pub mod fig7 {
    use super::*;

    /// Estimated-vs-actual scatter (FLOPs vs THOR) for random CNNs on
    /// Xavier.
    pub fn run(cfg: &ExpConfig) -> String {
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let lr = fit_flops_lr(&mut dev, cfg);
        let mut thor = Thor::new(cfg.thor_cfg());
        thor.profile(&mut dev, &reference_model(Family::Cnn5));
        let test = sample_n(Family::Cnn5, cfg.n_test(), cfg.seed + 1, 10);
        let mut rows = Vec::new();
        for g in &test {
            let act = measured_energy(&mut dev, g, cfg.iterations(), cfg.repeats());
            rows.push(vec![
                format!("{act:.4e}"),
                format!("{:.4e}", lr.predict(g)),
                format!("{:.4e}", thor.estimate("xavier", g).unwrap().energy_per_iter),
            ]);
        }
        table::render(&["actual J/iter", "FLOPs-LR est", "THOR est"], &rows)
    }
}

pub mod fig8 {
    use super::*;

    /// End-to-end MAPE: 5 devices × 4 families, THOR vs FLOPs-LR, with
    /// std error over repeats.  Also feeds Table 1.
    pub fn run(cfg: &ExpConfig) -> (String, String) {
        let devices_list = if cfg.quick { vec!["xavier", "server"] } else { vec!["oppo", "iphone", "xavier", "tx2", "server"] };
        let fams = Family::fig8_families();
        let mut rows = Vec::new();
        let mut tab1_rows = Vec::new();
        for dev_name in &devices_list {
            for fam in &fams {
                let reps = cfg.repeats();
                let mut thor_m = Vec::new();
                let mut lr_m = Vec::new();
                let mut dev_secs = 0.0;
                let mut fit_secs = 0.0;
                for rep in 0..reps {
                    let cfg_r = ExpConfig { seed: cfg.seed + rep as u64 * 1000, ..*cfg };
                    let (t, f, report) = mape_pair(dev_name, *fam, &cfg_r);
                    thor_m.push(t);
                    lr_m.push(f);
                    dev_secs += report.device_seconds() / reps as f64;
                    fit_secs += report.fit_seconds() / reps as f64;
                }
                rows.push(vec![
                    dev_name.to_string(),
                    fam.name().to_string(),
                    format!("{:.1} ± {:.1}", mean(&thor_m), std_err(&thor_m)),
                    format!("{:.1} ± {:.1}", mean(&lr_m), std_err(&lr_m)),
                ]);
                tab1_rows.push(vec![
                    dev_name.to_string(),
                    fam.name().to_string(),
                    format!("{:.0}", dev_secs + fit_secs),
                ]);
            }
        }
        (
            table::render(&["device", "model", "THOR MAPE %", "FLOPs-LR MAPE %"], &rows),
            table::render(&["device", "model", "profile+fit sec"], &tab1_rows),
        )
    }
}

pub mod fig9 {
    use super::*;

    /// Transformer estimation on Xavier + Server.
    pub fn run(cfg: &ExpConfig) -> String {
        let mut rows = Vec::new();
        for dev_name in ["xavier", "server"] {
            let (t, f, _) = mape_pair(dev_name, Family::Transformer, cfg);
            rows.push(vec![dev_name.to_string(), format!("{t:.1}"), format!("{f:.1}")]);
        }
        table::render(&["device", "THOR MAPE %", "FLOPs-LR MAPE %"], &rows)
    }
}

pub mod fig10 {
    use super::*;

    /// ResNet relative-error CDF on Xavier + Server.
    pub fn run(cfg: &ExpConfig) -> String {
        let mut out = String::new();
        let fams = if cfg.quick {
            vec![Family::ResNet20]
        } else {
            vec![Family::ResNet20, Family::ResNet56, Family::ResNet110]
        };
        for dev_name in ["xavier", "server"] {
            let profile = devices::by_name(dev_name).unwrap();
            let mut dev = Device::new(profile, cfg.seed);
            let lr = fit_flops_lr(&mut dev, cfg);
            let mut thor = Thor::new(cfg.thor_cfg());
            let mut errs_thor = Vec::new();
            let mut errs_lr = Vec::new();
            for fam in &fams {
                thor.profile(&mut dev, &reference_model(*fam));
                for g in sample_n(*fam, cfg.n_test() / 3 + 2, cfg.seed + 2, 10) {
                    let act = measured_energy(&mut dev, &g, cfg.iterations(), 1);
                    let e_t = thor.estimate(dev_name, &g).unwrap().energy_per_iter;
                    errs_thor.push(((act - e_t) / act).abs());
                    errs_lr.push(((act - lr.predict(&g)) / act).abs());
                }
            }
            let grid: Vec<f64> = (0..=20).map(|i| i as f64 * 0.05).collect();
            let c_t = cdf(&errs_thor, &grid);
            let c_l = cdf(&errs_lr, &grid);
            let s_t: Vec<(f64, f64)> = grid.iter().zip(&c_t).map(|(g, c)| (*g, *c)).collect();
            let s_l: Vec<(f64, f64)> = grid.iter().zip(&c_l).map(|(g, c)| (*g, *c)).collect();
            out.push_str(&table::render_series(
                &format!("ResNet relative-error CDF ({dev_name})"),
                "rel err",
                &[("THOR", &s_t), ("FLOPs-LR", &s_l)],
            ));
        }
        out
    }
}

pub mod fig11 {
    use super::*;
    use crate::thor::profiler;

    /// Conv2d energy surface vs (C_in, C_out) at several spatial sizes
    /// (profiled points + GP surface values on a grid).
    pub fn run(cfg: &ExpConfig) -> String {
        let mut out = String::new();
        for dev_name in ["xavier", "server"] {
            let profile = devices::by_name(dev_name).unwrap();
            let mut dev = Device::new(profile, cfg.seed);
            let reference = zoo::cnn5(&[32, 64, 128, 256], 28, 10);
            let parsed = crate::thor::parse::parse(&reference);
            let hid = parsed.hidden_groups().next().unwrap(); // 14x14 conv
            let inp = parsed.input_groups().next().unwrap();
            let outg = parsed.output_groups().next().unwrap();
            let n = if cfg.quick { 4 } else { 8 };
            let mut rows = Vec::new();
            for i in 0..n {
                for j in 0..n {
                    let a = 1 + i * 32 / n.max(1);
                    let b = 1 + j * 64 / n.max(1);
                    let (g, _, _) = profiler::hidden_variant(inp, hid, outg, a, b);
                    let (e, _) = profiler::measure(&mut dev, &g, cfg.iterations().min(200));
                    rows.push(vec![format!("{a}"), format!("{b}"), format!("{e:.4e}")]);
                }
            }
            out.push_str(&format!("# conv2d 3x3 @14x14 variant energy surface ({dev_name})\n"));
            out.push_str(&table::render(&["C_in", "C_out", "variant J/iter"], &rows));
        }
        out
    }
}

pub mod fig12 {
    use super::*;

    /// Held-out error of the hidden-conv GP surface (est − obs).
    pub fn run(cfg: &ExpConfig) -> String {
        let mut out = String::new();
        for dev_name in ["xavier", "server"] {
            let profile = devices::by_name(dev_name).unwrap();
            let mut dev = Device::new(profile, cfg.seed);
            let mut thor = Thor::new(cfg.thor_cfg());
            thor.profile(&mut dev, &reference_model(Family::Cnn5));
            let mut rng = Pcg64::new(cfg.seed + 3);
            let mut rows = Vec::new();
            for _ in 0..if cfg.quick { 6 } else { 20 } {
                let g = sample(Family::Cnn5, &mut rng, 10);
                let act = measured_energy(&mut dev, &g, cfg.iterations(), 1);
                let est = thor.estimate(dev_name, &g).unwrap().energy_per_iter;
                rows.push(vec![
                    format!("{act:.4e}"),
                    format!("{est:.4e}"),
                    format!("{:+.1}%", 100.0 * (est - act) / act),
                ]);
            }
            out.push_str(&format!("# estimation vs observation ({dev_name})\n"));
            out.push_str(&table::render(&["observed", "estimated", "diff"], &rows));
        }
        out
    }
}

pub mod a14 {
    use super::*;
    use crate::thor::pipeline::ThorConfig;

    /// #profiled points vs MAPE (energy acquisition vs time surrogate).
    pub fn run(cfg: &ExpConfig) -> String {
        let mut out = String::new();
        for dev_name in ["oppo", "xavier"] {
            let mut rows = Vec::new();
            for budget in [6usize, 10, 16, 24] {
                for surrogate in [false, true] {
                    let profile = devices::by_name(dev_name).unwrap();
                    let mut dev = Device::new(profile, cfg.seed);
                    let tcfg = ThorConfig {
                        max_points_1d: budget,
                        max_points_2d: budget * 2,
                        threshold_frac: 0.0, // force budget use
                        time_surrogate: surrogate,
                        ..cfg.thor_cfg()
                    };
                    let mut thor = Thor::new(tcfg);
                    thor.profile(&mut dev, &reference_model(Family::Cnn5));
                    let test = sample_n(Family::Cnn5, cfg.n_test().min(20), cfg.seed + 1, 10);
                    let (mut actual, mut est) = (vec![], vec![]);
                    for g in &test {
                        actual.push(measured_energy(&mut dev, g, cfg.iterations(), 1));
                        est.push(thor.estimate(dev_name, g).unwrap().energy_per_iter);
                    }
                    rows.push(vec![
                        format!("{budget}"),
                        if surrogate { "time" } else { "energy" }.into(),
                        format!("{:.1}", mape(&actual, &est)),
                    ]);
                }
            }
            out.push_str(&format!("# points-budget sweep ({dev_name})\n"));
            out.push_str(&table::render(&["1D budget", "acquisition", "MAPE %"], &rows));
        }
        out
    }
}

pub mod a15 {
    use super::*;
    use crate::gp::KernelKind;

    /// GP kernel ablation: Matérn vs RBF vs DotProduct vs random-Matérn.
    pub fn run(cfg: &ExpConfig) -> String {
        let mut rows = Vec::new();
        for (label, kind, random) in [
            ("Matern52 (guided)", KernelKind::Matern52, false),
            ("RBF (guided)", KernelKind::Rbf, false),
            ("DotProduct (guided)", KernelKind::DotProduct, false),
            ("Matern52 (random)", KernelKind::Matern52, true),
        ] {
            let profile = devices::by_name("xavier").unwrap();
            let mut dev = Device::new(profile, cfg.seed);
            let tcfg = ThorConfig { kind, random_sampling: random, ..cfg.thor_cfg() };
            let mut thor = Thor::new(tcfg);
            thor.profile(&mut dev, &reference_model(Family::Cnn5));
            let test = sample_n(Family::Cnn5, cfg.n_test().min(25), cfg.seed + 1, 10);
            let (mut actual, mut est) = (vec![], vec![]);
            for g in &test {
                actual.push(measured_energy(&mut dev, g, cfg.iterations(), 1));
                est.push(thor.estimate("xavier", g).unwrap().energy_per_iter);
            }
            rows.push(vec![label.to_string(), format!("{:.1}", mape(&actual, &est))]);
        }
        table::render(&["kernel / sampling", "MAPE %"], &rows)
    }
}

pub mod a16 {
    use super::*;

    /// Energy normalized to 1000 iterations vs profiling-iteration count
    /// (few samples ⇒ unstable).
    pub fn run(cfg: &ExpConfig) -> String {
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let g = zoo::lenet5(&[6, 16, 120, 84], 10);
        let tr = fuse(&lower(&g));
        let reps = if cfg.quick { 5 } else { 15 };
        let mut rows = Vec::new();
        for iters in [10usize, 50, 100, 200, 500, 1000] {
            let vals: Vec<f64> = (0..reps)
                .map(|_| dev.run(&tr, iters).energy_per_iter() * 1000.0)
                .collect();
            rows.push(vec![
                format!("{iters}"),
                format!("{:.3}", mean(&vals)),
                format!("{:.1}%", 100.0 * crate::util::stats::std_dev(&vals) / mean(&vals)),
            ]);
        }
        table::render(&["profiling iterations", "energy per 1000 iters (J)", "spread (CV)"], &rows)
    }
}
