//! Experiment registry: every paper table/figure is a registered
//! [`registry::Experiment`] producing a structured, JSON-serializable
//! [`report::ExpReport`], executed (possibly many at a time) by the
//! multi-threaded [`runner::Runner`].
//!
//! # Layout
//!
//! | module       | contents                                              |
//! |--------------|-------------------------------------------------------|
//! | [`report`]   | `ExpReport` (tables, series, metrics, notes) + JSON   |
//! | [`registry`] | the `Experiment` trait and the id → experiment table  |
//! | [`runner`]   | work-stealing thread pool + suite JSON/render         |
//! | [`tables`]   | fig2, fig7, fig8 (+ Table 1), fig9, fig12             |
//! | [`figures`]  | fig4, fig5, fig6, fig10, fig11                        |
//! | [`ablation`] | a14 (point budget), a15 (kernels), a16 (iterations)   |
//!
//! Experiment ids: `fig2 fig4 fig5 fig6 fig7 fig8 fig9 fig10 fig11 fig12
//! a14 a15 a16` (`tab1` aliases `fig8`; fig13 is the pruning case study
//! in `examples/energy_aware_pruning.rs`).
//!
//! # Entry points
//!
//! * CLI: `thor exp <id> | --all [--quick] [--seed N] [--threads N]
//!   [--json out.json] [--list]`
//! * bench: `cargo bench --bench paper_experiments`
//! * tests: `rust/tests/exp_smoke.rs` (directions), `rust/tests/
//!   golden_runs.rs` (full-suite regression + determinism)
//!
//! # Determinism & the `--json` schema
//!
//! Each experiment runs with a seed derived from the suite seed and its
//! id ([`ExpConfig::for_experiment`]), so results are independent of
//! thread scheduling: `thor exp --all --quick --json out.json` is
//! byte-identical run-to-run for a fixed `--seed`.  Wall-clock values
//! never enter reports (simulated device-seconds do).  Schema (version
//! 1):
//!
//! ```text
//! { "schema_version": 1, "base_seed": "<u64>", "quick": bool,
//!   "experiments": [ { "id", "title",
//!       "meta": { "base_seed", "seed", "quick", "devices": [..] },
//!       "tables": [ { "title", "headers": [..], "rows": [[..cell..]] } ],
//!       "series": [ { "title", "xlabel",
//!                     "series": [ { "name", "points": [[x, y], ..] } ] } ],
//!       "metrics": [ { "name", "value" } ],
//!       "notes": [..], "error": null | "<panic message>" } ] }
//! ```
//!
//! # Golden-run workflow
//!
//! `rust/tests/golden_runs.rs` runs every registered experiment in quick
//! mode at a fixed seed and diffs each report's JSON against
//! `rust/tests/golden/<id>.json`.  Missing goldens are written ("blessed")
//! on first run; after an intentional change to experiment output, regen
//! with `UPDATE_GOLDENS=1 cargo test --test golden_runs` and commit the
//! diff.

pub mod ablation;
pub mod figures;
pub mod registry;
pub mod report;
pub mod runner;
pub mod tables;

pub use registry::{by_id, ids, Experiment};
pub use report::ExpReport;
pub use runner::{Runner, SuiteResult};

use crate::baselines::flops_lr::FlopsLr;
use crate::model::flops::model_train_flops;
use crate::model::sampler::{sample_n, Family};
use crate::model::zoo;
use crate::simdevice::{devices, Device};
use crate::thor::{Thor, ThorConfig};
use crate::util::stats::{mape, mean};
use crate::workload::{fusion::fuse, lower::lower};

/// Global experiment scale: `quick` shrinks sample counts ~10× so the
/// whole suite runs in minutes on one core.
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    pub quick: bool,
    pub seed: u64,
}

impl ExpConfig {
    pub fn new(quick: bool, seed: u64) -> Self {
        Self { quick, seed }
    }

    /// The config an experiment runs with inside a suite: quick flag +
    /// per-experiment seed derived from the suite seed and the id.
    pub fn for_experiment(base_seed: u64, quick: bool, id: &str) -> Self {
        Self { quick, seed: Self::derive_seed(base_seed, id) }
    }

    /// FNV-1a over (base seed ‖ experiment id): stable across platforms
    /// and releases (unlike `DefaultHasher`), so golden files and suite
    /// JSON never shift underneath a refactor.
    pub fn derive_seed(base_seed: u64, id: &str) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        for b in base_seed.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
        for b in id.as_bytes() {
            h = (h ^ *b as u64).wrapping_mul(PRIME);
        }
        h
    }

    pub fn n_test(&self) -> usize {
        if self.quick { 15 } else { 100 }
    }

    pub fn n_train_lr(&self) -> usize {
        if self.quick { 12 } else { 30 }
    }

    pub fn repeats(&self) -> usize {
        if self.quick { 1 } else { 3 }
    }

    pub fn iterations(&self) -> usize {
        if self.quick { 120 } else { 500 }
    }

    pub fn thor_cfg(&self) -> ThorConfig {
        if self.quick {
            ThorConfig { iterations: 120, ..ThorConfig::quick() }
        } else {
            ThorConfig::default()
        }
    }
}

/// Measured ground truth: mean of `repeats` metered runs (eq. 6 protocol).
pub fn measured_energy(dev: &mut Device, g: &crate::model::ModelGraph, iters: usize, repeats: usize) -> f64 {
    let tr = fuse(&lower(g));
    let runs: Vec<f64> = (0..repeats).map(|_| dev.run(&tr, iters).energy_per_iter()).collect();
    mean(&runs)
}

/// Fit a per-device FLOPs-LR across all Fig-8 families (the proxy method
/// sees only FLOPs, so one regressor per device is the faithful reading
/// of "use FLOPs to fit a Linear Regression Model").
pub fn fit_flops_lr(dev: &mut Device, cfg: &ExpConfig) -> FlopsLr {
    let mut data = Vec::new();
    for (i, fam) in Family::fig8_families().iter().enumerate() {
        for g in sample_n(*fam, cfg.n_train_lr() / 4 + 1, cfg.seed + 100 + i as u64, 10) {
            let e = measured_energy(dev, &g, cfg.iterations(), 1);
            data.push((model_train_flops(&g), e));
        }
    }
    FlopsLr::fit(&data)
}

/// Reference (full-width) model per family, used to profile THOR.
pub fn reference_model(fam: Family) -> crate::model::ModelGraph {
    match fam {
        Family::LeNet5 => zoo::lenet5(&[6, 16, 120, 84], 10),
        Family::Cnn5 => zoo::cnn5(&[32, 64, 128, 256], 28, 10),
        Family::Har => zoo::har(&[32, 64, 128], 10),
        Family::Lstm => zoo::lstm(64, &[128, 128], 2000, 32, 10),
        Family::Transformer => zoo::transformer(4, 256, 4, 32, 2000, 10),
        Family::ResNet20 => zoo::resnet(20, 16, 10),
        Family::ResNet56 => zoo::resnet(56, 16, 10),
        Family::ResNet110 => zoo::resnet(110, 16, 10),
    }
}

/// MAPE of THOR and FLOPs-LR on one (device, family) pair.
/// Returns (thor_mape, flops_mape, thor_profile_report).
pub fn mape_pair(
    dev_name: &str,
    fam: Family,
    cfg: &ExpConfig,
) -> (f64, f64, crate::thor::pipeline::ProfileReport) {
    let profile = devices::by_name(dev_name).expect("device");
    let mut dev = Device::new(profile, cfg.seed);
    let lr = fit_flops_lr(&mut dev, cfg);

    let mut thor = Thor::new(cfg.thor_cfg());
    let report = thor.profile(&mut dev, &reference_model(fam));

    let test = sample_n(fam, cfg.n_test(), cfg.seed + 1, 10);
    let (mut actual, mut p_lr, mut p_th) = (vec![], vec![], vec![]);
    for g in &test {
        actual.push(measured_energy(&mut dev, g, cfg.iterations(), cfg.repeats()));
        p_lr.push(lr.predict(g));
        p_th.push(thor.estimate(dev_name, g).map(|e| e.energy_per_iter).unwrap_or(0.0));
    }
    (mape(&actual, &p_th), mape(&actual, &p_lr), report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_stable_and_id_sensitive() {
        // Pinned: golden files depend on this mapping never changing.
        assert_eq!(ExpConfig::derive_seed(2025, "fig8"), ExpConfig::derive_seed(2025, "fig8"));
        assert_ne!(ExpConfig::derive_seed(2025, "fig8"), ExpConfig::derive_seed(2025, "fig9"));
        assert_ne!(ExpConfig::derive_seed(2025, "fig8"), ExpConfig::derive_seed(2026, "fig8"));
    }

    #[test]
    fn for_experiment_threads_quick_flag() {
        let cfg = ExpConfig::for_experiment(7, true, "fig2");
        assert!(cfg.quick);
        assert_eq!(cfg.seed, ExpConfig::derive_seed(7, "fig2"));
    }
}
