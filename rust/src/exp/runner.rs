//! Multi-threaded experiment runner with deterministic subtask fan-out.
//!
//! The suite is flattened into a list of *units* before any worker
//! starts: one unit per monolithic experiment, one unit per subtask of a
//! fanned-out experiment ([`Experiment::subtasks`]).  Workers pull units
//! from a shared atomic cursor, so an experiment that fans into many
//! subtasks (fig8's device × family grid, fig13's budget sweep) shares
//! the whole pool instead of serializing behind one worker.
//!
//! Determinism is by construction: each experiment runs with a seed
//! derived from the suite seed + its id ([`ExpConfig::for_experiment`]),
//! each subtask with a seed derived from the experiment seed + its label
//! ([`ExpConfig::for_subtask`]); subtask outputs are merged in
//! declaration order and experiment reports are collected into
//! registry-order slots — so suite output is byte-identical regardless
//! of thread count or scheduling (asserted by `rust/tests/golden_runs.rs`
//! and `rust/tests/properties.rs`).
//!
//! A panicking experiment — or any of its subtasks, or its merge — is
//! caught and recorded as a failed [`ExpReport`] instead of tearing down
//! the suite.  A failing subtask fails only its own experiment, and the
//! reported message is the *first* failing subtask in declaration order,
//! keeping even failures byte-stable across thread counts.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::exp::registry::{Experiment, Subtask, SubtaskOutput};
use crate::exp::report::ExpReport;
use crate::exp::ExpConfig;
use crate::util::json::Json;

pub struct Runner {
    pub threads: usize,
}

/// Result of one suite run.
pub struct SuiteResult {
    /// Reports in registry (submission) order, independent of completion
    /// order.
    pub reports: Vec<ExpReport>,
    pub base_seed: u64,
    pub quick: bool,
    pub threads_used: usize,
    /// Wall-clock of the whole suite — diagnostic only, never serialized
    /// (see the determinism contract in [`crate::exp::report`]).
    pub wall_seconds: f64,
}

/// Shared state of one fanned-out experiment while its subtasks are in
/// flight on the pool.
struct FanState {
    exp_index: usize,
    cfg: ExpConfig,
    subs: Vec<Subtask>,
    /// Subtask outcomes in declaration order (`Err` = panic message).
    results: Vec<Mutex<Option<Result<SubtaskOutput, String>>>>,
    /// Unfinished subtasks; whoever completes the last one merges.
    remaining: AtomicUsize,
}

/// One schedulable unit of suite work.
enum Unit {
    /// Run a monolithic experiment end to end.
    Whole(usize),
    /// Run subtask `sub` of fanned-out experiment `fan`.
    Sub { fan: usize, sub: usize },
}

impl Runner {
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// Thread count sized by available cores (min 2, so the suite always
    /// exercises the parallel path) — *not* by top-level task count: one
    /// experiment fanning out into many subtasks must still fill the
    /// machine.
    pub fn auto() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        Self::new(cores.max(2))
    }

    /// Runner from a user-supplied thread count, where 0 means "auto"
    /// (shared by the CLI and the bench harness).
    pub fn from_arg(threads: usize) -> Self {
        if threads == 0 {
            Self::auto()
        } else {
            Self::new(threads)
        }
    }

    /// Run `exps` (quick/full at `base_seed`) across the worker pool.
    pub fn run(&self, exps: Vec<Box<dyn Experiment>>, quick: bool, base_seed: u64) -> SuiteResult {
        let t0 = Instant::now();
        let n = exps.len();
        let slots: Vec<Mutex<Option<ExpReport>>> = (0..n).map(|_| Mutex::new(None)).collect();

        // Expand every experiment into units up front (sequentially, so
        // unit order — and therefore nothing at all — depends on thread
        // scheduling).  subtasks() itself may panic; that fails just the
        // one experiment.
        let mut fans: Vec<FanState> = Vec::new();
        let mut units: Vec<Unit> = Vec::new();
        for (i, exp) in exps.iter().enumerate() {
            let cfg = ExpConfig::for_experiment(base_seed, quick, exp.id());
            let subs = match std::panic::catch_unwind(AssertUnwindSafe(|| exp.subtasks(&cfg))) {
                Ok(s) => s,
                Err(payload) => {
                    let msg = format!("subtasks() panicked: {}", panic_message(payload));
                    *slots[i].lock().unwrap() = Some(ExpReport::failed(exp.id(), &cfg, &msg));
                    continue;
                }
            };
            if subs.is_empty() {
                units.push(Unit::Whole(i));
                continue;
            }
            if let Some(dup) = first_duplicate_label(&subs) {
                let msg = format!("duplicate subtask label '{dup}' (seeds would collide)");
                *slots[i].lock().unwrap() = Some(ExpReport::failed(exp.id(), &cfg, &msg));
                continue;
            }
            let k = subs.len();
            fans.push(FanState {
                exp_index: i,
                cfg,
                subs,
                results: (0..k).map(|_| Mutex::new(None)).collect(),
                remaining: AtomicUsize::new(k),
            });
            let f = fans.len() - 1;
            units.extend((0..k).map(|sub| Unit::Sub { fan: f, sub }));
        }

        let threads = self.threads.min(units.len().max(1));
        let next = AtomicUsize::new(0);

        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let u = next.fetch_add(1, Ordering::Relaxed);
                    if u >= units.len() {
                        break;
                    }
                    match units[u] {
                        Unit::Whole(i) => {
                            let exp = exps[i].as_ref();
                            let cfg = ExpConfig::for_experiment(base_seed, quick, exp.id());
                            let mut report = run_caught(exp, &cfg);
                            report.meta.base_seed = base_seed;
                            *slots[i].lock().unwrap() = Some(report);
                        }
                        Unit::Sub { fan, sub } => {
                            let f = &fans[fan];
                            let scfg = f.cfg.for_subtask(&f.subs[sub].label);
                            let out =
                                std::panic::catch_unwind(AssertUnwindSafe(|| f.subs[sub].run(&scfg)))
                                    .map_err(|payload| {
                                        format!(
                                            "subtask '{}' panicked: {}",
                                            f.subs[sub].label,
                                            panic_message(payload)
                                        )
                                    });
                            *f.results[sub].lock().unwrap() = Some(out);
                            // Whoever finishes the last subtask merges —
                            // on any worker, but from declaration-order
                            // inputs, so the result is schedule-free.
                            if f.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                                let exp = exps[f.exp_index].as_ref();
                                let mut report = merge_fanout(exp, f);
                                report.meta.base_seed = base_seed;
                                *slots[f.exp_index].lock().unwrap() = Some(report);
                            }
                        }
                    }
                });
            }
        });

        let reports = slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("runner slot unfilled"))
            .collect();
        SuiteResult {
            reports,
            base_seed,
            quick,
            threads_used: threads,
            wall_seconds: t0.elapsed().as_secs_f64(),
        }
    }
}

/// Collect a fan-out's results in declaration order and merge them.
/// Any subtask failure fails the experiment with the first (declaration
/// order) message; a panicking merge fails it too.
fn merge_fanout(exp: &dyn Experiment, f: &FanState) -> ExpReport {
    let mut parts = Vec::with_capacity(f.subs.len());
    let mut first_err: Option<String> = None;
    for slot in &f.results {
        match slot.lock().unwrap().take().expect("fan-out slot unfilled") {
            Ok(v) => parts.push(v),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    match first_err {
        Some(e) => ExpReport::failed(exp.id(), &f.cfg, &e),
        None => match std::panic::catch_unwind(AssertUnwindSafe(|| exp.merge(&f.cfg, parts))) {
            Ok(r) => r,
            Err(payload) => {
                let msg = format!("merge() panicked: {}", panic_message(payload));
                ExpReport::failed(exp.id(), &f.cfg, &msg)
            }
        },
    }
}

fn first_duplicate_label(subs: &[Subtask]) -> Option<String> {
    let mut seen: Vec<&str> = Vec::with_capacity(subs.len());
    for s in subs {
        if seen.contains(&s.label.as_str()) {
            return Some(s.label.clone());
        }
        seen.push(&s.label);
    }
    None
}

/// Human-readable panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Run one experiment, converting a panic into a failed report.
fn run_caught(exp: &dyn Experiment, cfg: &ExpConfig) -> ExpReport {
    match std::panic::catch_unwind(AssertUnwindSafe(|| exp.run(cfg))) {
        Ok(r) => r,
        Err(payload) => ExpReport::failed(exp.id(), cfg, &panic_message(payload)),
    }
}

impl SuiteResult {
    /// Canonical suite JSON: suite metadata + per-experiment reports, in
    /// registry order.  Byte-identical across runs with the same seed
    /// (wall-clock and thread count are deliberately excluded).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(1.0)),
            ("base_seed", Json::str(&self.base_seed.to_string())),
            ("quick", Json::Bool(self.quick)),
            ("experiments", Json::Arr(self.reports.iter().map(|r| r.to_json()).collect())),
        ])
    }

    /// Human rendering of every report, in order.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.reports {
            out.push_str(&r.render());
            out.push('\n');
        }
        out
    }

    pub fn failures(&self) -> Vec<&ExpReport> {
        self.reports.iter().filter(|r| r.error.is_some()).collect()
    }

    /// Print one stderr line per failed experiment; returns the failure
    /// count (shared by the CLI and the bench harness).
    pub fn eprint_failures(&self) -> usize {
        let failures = self.failures();
        for f in &failures {
            eprintln!("experiment {} FAILED: {}", f.id, f.error.as_deref().unwrap_or(""));
        }
        failures.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::report::ExpReport;

    struct Echo(&'static str);

    impl Experiment for Echo {
        fn id(&self) -> &'static str {
            self.0
        }
        fn description(&self) -> &'static str {
            "echoes its derived seed"
        }
        fn run(&self, cfg: &ExpConfig) -> ExpReport {
            let mut r = ExpReport::new(self.0, "echo", cfg, &[]);
            r.metric("seed_lo", (cfg.seed % 1_000_000) as f64);
            r
        }
    }

    struct Boom;

    impl Experiment for Boom {
        fn id(&self) -> &'static str {
            "boom"
        }
        fn description(&self) -> &'static str {
            "always panics"
        }
        fn run(&self, _cfg: &ExpConfig) -> ExpReport {
            panic!("intentional test panic");
        }
    }

    /// Fan-out experiment: one subtask per label, each echoing its
    /// derived seed; merge records them as metrics in declaration order.
    struct Fan {
        id: &'static str,
        labels: Vec<&'static str>,
        panic_on: Option<&'static str>,
    }

    impl Fan {
        fn ok(id: &'static str, labels: &[&'static str]) -> Self {
            Self { id, labels: labels.to_vec(), panic_on: None }
        }
    }

    impl Experiment for Fan {
        fn id(&self) -> &'static str {
            self.id
        }
        fn description(&self) -> &'static str {
            "fan-out echo"
        }
        fn subtasks(&self, _cfg: &ExpConfig) -> Vec<Subtask> {
            self.labels
                .iter()
                .map(|&l| {
                    let boom = self.panic_on == Some(l);
                    Subtask::new(l, move |scfg: &ExpConfig| {
                        if boom {
                            panic!("sub-boom {l}");
                        }
                        (l.to_string(), scfg.seed)
                    })
                })
                .collect()
        }
        fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
            let mut r = ExpReport::new(self.id, "fan-out echo", cfg, &[]);
            for part in parts {
                let (label, seed) = *part.downcast::<(String, u64)>().expect("fan part");
                r.metric(&label, (seed % 1_000_000) as f64);
            }
            r
        }
    }

    fn echo_suite() -> Vec<Box<dyn Experiment>> {
        vec![Box::new(Echo("e1")), Box::new(Echo("e2")), Box::new(Echo("e3")), Box::new(Echo("e4"))]
    }

    #[test]
    fn preserves_submission_order_and_derives_distinct_seeds() {
        let suite = Runner::new(3).run(echo_suite(), true, 9);
        let ids: Vec<&str> = suite.reports.iter().map(|r| r.id.as_str()).collect();
        assert_eq!(ids, vec!["e1", "e2", "e3", "e4"]);
        let seeds: Vec<u64> = suite.reports.iter().map(|r| r.meta.seed).collect();
        let mut uniq = seeds.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "per-experiment seeds collide: {seeds:?}");
        assert!(suite.reports.iter().all(|r| r.meta.base_seed == 9));
    }

    #[test]
    fn json_identical_across_thread_counts() {
        let a = Runner::new(1).run(echo_suite(), true, 5).to_json().to_string();
        let b = Runner::new(4).run(echo_suite(), true, 5).to_json().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn panic_becomes_failed_report() {
        let suite = Runner::new(2).run(vec![Box::new(Echo("ok")), Box::new(Boom)], true, 1);
        assert_eq!(suite.reports.len(), 2);
        assert!(suite.reports[0].error.is_none());
        let err = suite.reports[1].error.as_deref().unwrap();
        assert!(err.contains("intentional"), "{err}");
        assert_eq!(suite.failures().len(), 1);
    }

    #[test]
    fn fanout_merges_in_declaration_order_for_any_thread_count() {
        let mk = || -> Vec<Box<dyn Experiment>> {
            vec![Box::new(Fan::ok("fan1", &["a", "b", "c", "d", "e"])), Box::new(Echo("e1"))]
        };
        let one = Runner::new(1).run(mk(), true, 3);
        let many = Runner::new(8).run(mk(), true, 3);
        assert_eq!(one.to_json().to_string(), many.to_json().to_string());
        let names: Vec<&str> =
            one.reports[0].metrics.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c", "d", "e"], "merge order broke");
        // parallel path == sequential default run()
        let direct = Fan::ok("fan1", &["a", "b", "c", "d", "e"])
            .run(&ExpConfig::for_experiment(3, true, "fan1"));
        assert_eq!(direct.metrics, one.reports[0].metrics);
    }

    #[test]
    fn subtask_seeds_are_distinct_and_label_derived() {
        let rep = Fan::ok("fan2", &["x", "y", "z"]).run(&ExpConfig::for_experiment(1, true, "fan2"));
        let vals: Vec<u64> = rep.metrics.iter().map(|(_, v)| *v as u64).collect();
        let mut uniq = vals.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), vals.len(), "subtask seeds collide: {vals:?}");
    }

    #[test]
    fn panicking_subtask_fails_only_its_experiment_deterministically() {
        let mk = || -> Vec<Box<dyn Experiment>> {
            vec![
                Box::new(Fan { id: "sick", labels: vec!["a", "bad", "c"], panic_on: Some("bad") }),
                Box::new(Fan::ok("healthy", &["p", "q"])),
                Box::new(Echo("e1")),
            ]
        };
        let one = Runner::new(1).run(mk(), true, 2);
        let many = Runner::new(8).run(mk(), true, 2);
        assert_eq!(one.to_json().to_string(), many.to_json().to_string());
        let err = one.reports[0].error.as_deref().unwrap();
        assert!(err.contains("subtask 'bad'") && err.contains("sub-boom"), "{err}");
        assert!(one.reports[1].error.is_none());
        assert!(one.reports[2].error.is_none());
        assert_eq!(one.failures().len(), 1);
    }

    #[test]
    fn duplicate_subtask_labels_fail_the_experiment() {
        let suite = Runner::new(2).run(
            vec![
                Box::new(Fan::ok("dup", &["a", "a"])) as Box<dyn Experiment>,
                Box::new(Echo("e1")),
            ],
            true,
            1,
        );
        let err = suite.reports[0].error.as_deref().unwrap();
        assert!(err.contains("duplicate subtask label 'a'"), "{err}");
        assert!(suite.reports[1].error.is_none());
    }

    #[test]
    fn auto_sizes_by_cores_not_task_count() {
        // The pool must not starve when one experiment fans out into many
        // subtasks: auto() ignores top-level task count entirely.
        assert!(Runner::auto().threads >= 2);
        assert_eq!(Runner::from_arg(3).threads, 3);
        assert!(Runner::from_arg(0).threads >= 2);
    }
}
