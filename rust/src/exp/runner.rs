//! Multi-threaded experiment runner.
//!
//! Fans registered experiments across `std::thread` workers pulling from
//! a shared atomic work queue.  Determinism is by construction: each
//! experiment runs with its own seed derived from the suite seed + the
//! experiment id ([`ExpConfig::for_experiment`]), owns its own simulated
//! devices/RNGs, and results are collected into registry-order slots —
//! so the suite output is byte-identical regardless of thread count or
//! scheduling (asserted by `rust/tests/golden_runs.rs`).
//!
//! A panicking experiment is caught per-worker and recorded as a failed
//! [`ExpReport`] instead of tearing down the suite.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::exp::registry::Experiment;
use crate::exp::report::ExpReport;
use crate::exp::ExpConfig;
use crate::util::json::Json;

pub struct Runner {
    pub threads: usize,
}

/// Result of one suite run.
pub struct SuiteResult {
    /// Reports in registry (submission) order, independent of completion
    /// order.
    pub reports: Vec<ExpReport>,
    pub base_seed: u64,
    pub quick: bool,
    pub threads_used: usize,
    /// Wall-clock of the whole suite — diagnostic only, never serialized
    /// (see the determinism contract in [`crate::exp::report`]).
    pub wall_seconds: f64,
}

impl Runner {
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// Thread count for `n_tasks` experiments: all available cores, at
    /// least 2 (the suite must exercise the parallel path), at most one
    /// per task.
    pub fn auto(n_tasks: usize) -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        Self::new(cores.max(2).min(n_tasks.max(1)))
    }

    /// Runner from a user-supplied thread count, where 0 means "auto"
    /// (shared by the CLI and the bench harness).
    pub fn from_arg(threads: usize, n_tasks: usize) -> Self {
        if threads == 0 {
            Self::auto(n_tasks)
        } else {
            Self::new(threads)
        }
    }

    /// Run `exps` (quick/full at `base_seed`) across the worker pool.
    pub fn run(&self, exps: Vec<Box<dyn Experiment>>, quick: bool, base_seed: u64) -> SuiteResult {
        let t0 = Instant::now();
        let n = exps.len();
        let threads = self.threads.min(n.max(1));
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<ExpReport>>> = (0..n).map(|_| Mutex::new(None)).collect();

        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let exp = exps[i].as_ref();
                    let cfg = ExpConfig::for_experiment(base_seed, quick, exp.id());
                    let mut report = run_caught(exp, &cfg);
                    report.meta.base_seed = base_seed;
                    *slots[i].lock().unwrap() = Some(report);
                });
            }
        });

        let reports = slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("runner slot unfilled"))
            .collect();
        SuiteResult {
            reports,
            base_seed,
            quick,
            threads_used: threads,
            wall_seconds: t0.elapsed().as_secs_f64(),
        }
    }
}

/// Run one experiment, converting a panic into a failed report.
fn run_caught(exp: &dyn Experiment, cfg: &ExpConfig) -> ExpReport {
    match std::panic::catch_unwind(AssertUnwindSafe(|| exp.run(cfg))) {
        Ok(r) => r,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            ExpReport::failed(exp.id(), cfg, &msg)
        }
    }
}

impl SuiteResult {
    /// Canonical suite JSON: suite metadata + per-experiment reports, in
    /// registry order.  Byte-identical across runs with the same seed
    /// (wall-clock and thread count are deliberately excluded).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(1.0)),
            ("base_seed", Json::str(&self.base_seed.to_string())),
            ("quick", Json::Bool(self.quick)),
            ("experiments", Json::Arr(self.reports.iter().map(|r| r.to_json()).collect())),
        ])
    }

    /// Human rendering of every report, in order.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.reports {
            out.push_str(&r.render());
            out.push('\n');
        }
        out
    }

    pub fn failures(&self) -> Vec<&ExpReport> {
        self.reports.iter().filter(|r| r.error.is_some()).collect()
    }

    /// Print one stderr line per failed experiment; returns the failure
    /// count (shared by the CLI and the bench harness).
    pub fn eprint_failures(&self) -> usize {
        let failures = self.failures();
        for f in &failures {
            eprintln!("experiment {} FAILED: {}", f.id, f.error.as_deref().unwrap_or(""));
        }
        failures.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::report::ExpReport;

    struct Echo(&'static str);

    impl Experiment for Echo {
        fn id(&self) -> &'static str {
            self.0
        }
        fn description(&self) -> &'static str {
            "echoes its derived seed"
        }
        fn run(&self, cfg: &ExpConfig) -> ExpReport {
            let mut r = ExpReport::new(self.0, "echo", cfg, &[]);
            r.metric("seed_lo", (cfg.seed % 1_000_000) as f64);
            r
        }
    }

    struct Boom;

    impl Experiment for Boom {
        fn id(&self) -> &'static str {
            "boom"
        }
        fn description(&self) -> &'static str {
            "always panics"
        }
        fn run(&self, _cfg: &ExpConfig) -> ExpReport {
            panic!("intentional test panic");
        }
    }

    fn echo_suite() -> Vec<Box<dyn Experiment>> {
        vec![Box::new(Echo("e1")), Box::new(Echo("e2")), Box::new(Echo("e3")), Box::new(Echo("e4"))]
    }

    #[test]
    fn preserves_submission_order_and_derives_distinct_seeds() {
        let suite = Runner::new(3).run(echo_suite(), true, 9);
        let ids: Vec<&str> = suite.reports.iter().map(|r| r.id.as_str()).collect();
        assert_eq!(ids, vec!["e1", "e2", "e3", "e4"]);
        let seeds: Vec<u64> = suite.reports.iter().map(|r| r.meta.seed).collect();
        let mut uniq = seeds.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "per-experiment seeds collide: {seeds:?}");
        assert!(suite.reports.iter().all(|r| r.meta.base_seed == 9));
    }

    #[test]
    fn json_identical_across_thread_counts() {
        let a = Runner::new(1).run(echo_suite(), true, 5).to_json().to_string();
        let b = Runner::new(4).run(echo_suite(), true, 5).to_json().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn panic_becomes_failed_report() {
        let suite =
            Runner::new(2).run(vec![Box::new(Echo("ok")), Box::new(Boom)], true, 1);
        assert_eq!(suite.reports.len(), 2);
        assert!(suite.reports[0].error.is_none());
        let err = suite.reports[1].error.as_deref().unwrap();
        assert!(err.contains("intentional"), "{err}");
        assert_eq!(suite.failures().len(), 1);
    }

    #[test]
    fn auto_uses_multiple_threads() {
        assert!(Runner::auto(8).threads >= 2);
        assert_eq!(Runner::auto(1).threads, 1);
    }
}
