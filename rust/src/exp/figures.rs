//! Series-shaped experiments: fig4 (GP acquisition steps), fig5 (FC
//! energy vs channel), fig6 (time↔energy correlation), fig10 (ResNet
//! error CDF), fig11 (conv2d energy surface).

use crate::exp::registry::Experiment;
use crate::exp::report::ExpReport;
use crate::exp::{fit_flops_lr, measured_energy, reference_model, ExpConfig};
use crate::gp::acquisition::{max_variance, Acquire, CandidateGrid};
use crate::gp::{GpModel, KernelKind};
use crate::model::flops::model_train_flops;
use crate::model::sampler::{sample_n, Family};
use crate::model::zoo;
use crate::simdevice::{devices, Device};
use crate::thor::pipeline::log_channel;
use crate::thor::{profiler, Thor};
use crate::util::stats::{cdf, pearson};
use crate::workload::{fusion::fuse, lower::lower};

/// GP + acquisition after k and k+1 steps (FC output family on OPPO).
pub struct Fig4;

impl Experiment for Fig4 {
    fn id(&self) -> &'static str {
        "fig4"
    }

    fn description(&self) -> &'static str {
        "GP posterior + max-variance acquisition steps (FC output family, OPPO)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "GP + max-variance acquisition steps", cfg, &["oppo"]);
        let mut dev = Device::new(devices::oppo(), cfg.seed);
        let reference = zoo::cnn5(&[32, 64, 128, 256], 28, 10);
        let parsed = crate::thor::parse::parse(&reference);
        let out = parsed.output_groups().next().unwrap();
        let c_max = 512.0;
        let mut pts: Vec<(Vec<f64>, f64)> = Vec::new();
        for step in 0..6 {
            let p = if step == 0 {
                vec![0.0]
            } else if step == 1 {
                vec![1.0]
            } else {
                let xs: Vec<Vec<f64>> = pts.iter().map(|p| p.0.clone()).collect();
                let ys: Vec<f64> = pts.iter().map(|p| p.1.ln()).collect();
                let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
                match max_variance(&gp, &CandidateGrid::dim1(0.0, 1.0, 33), 0.0, 1.0) {
                    Acquire::Next(p, _) => p,
                    Acquire::Converged(_) => break,
                }
            };
            let c = log_channel(p[0], c_max);
            let (e, _) = profiler::measure(&mut dev, &profiler::output_variant(out, c), cfg.iterations());
            pts.push((p, e));
            if step >= 4 {
                // dump posterior after this step
                let xs: Vec<Vec<f64>> = pts.iter().map(|p| p.0.clone()).collect();
                let ys: Vec<f64> = pts.iter().map(|p| p.1.ln()).collect();
                let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
                let mean_series: Vec<(f64, f64)> = (0..=32)
                    .map(|i| {
                        let x = i as f64 / 32.0;
                        let (m, _) = gp.predict(&[x]);
                        (log_channel(x, c_max) as f64, m.exp())
                    })
                    .collect();
                let var_series: Vec<(f64, f64)> = (0..=32)
                    .map(|i| {
                        let x = i as f64 / 32.0;
                        let (_, v) = gp.predict(&[x]);
                        (log_channel(x, c_max) as f64, v.sqrt())
                    })
                    .collect();
                rep.push_series(
                    &format!("GP posterior after {} steps (FC output family, OPPO)", pts.len()),
                    "channel",
                    vec![
                        ("mean J/iter".to_string(), mean_series),
                        ("posterior std (log)".to_string(), var_series),
                    ],
                );
            }
        }
        rep
    }
}

/// FC-layer energy vs input channel on Xavier: non-linear staircase.
pub struct Fig5;

impl Experiment for Fig5 {
    fn id(&self) -> &'static str {
        "fig5"
    }

    fn description(&self) -> &'static str {
        "FC-layer energy vs input channel is non-linear in FLOPs (Xavier)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep = ExpReport::new(self.id(), "FC energy vs channel (non-linear)", cfg, &["xavier"]);
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let reference = zoo::cnn5(&[32, 64, 128, 256], 28, 10);
        let parsed = crate::thor::parse::parse(&reference);
        let out = parsed.output_groups().next().unwrap();
        let step = if cfg.quick { 64 } else { 16 };
        let series: Vec<(f64, f64)> = (1..=512usize)
            .step_by(step)
            .map(|c| {
                let (e, _) =
                    profiler::measure(&mut dev, &profiler::output_variant(out, c), cfg.iterations());
                (c as f64, e)
            })
            .collect();
        let flops_line: Vec<(f64, f64)> = series
            .iter()
            .map(|(c, _)| {
                let g = profiler::output_variant(out, *c as usize);
                (*c, model_train_flops(&g))
            })
            .collect();
        rep.push_series(
            "FC layer energy vs input channel (Xavier) — energy is NOT linear in FLOPs",
            "channel",
            vec![("energy J/iter".to_string(), series), ("train FLOPs".to_string(), flops_line)],
        );
        rep
    }
}

/// Time ↔ energy correlation across random 5-layer CNNs (justifies the
/// time-uncertainty surrogate).
pub struct Fig6;

impl Experiment for Fig6 {
    fn id(&self) -> &'static str {
        "fig6"
    }

    fn description(&self) -> &'static str {
        "time vs energy correlation across random CNNs (OPPO)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep = ExpReport::new(self.id(), "time ↔ energy correlation", cfg, &["oppo"]);
        let mut dev = Device::new(devices::oppo(), cfg.seed);
        let n = if cfg.quick { 10 } else { 40 };
        let models = sample_n(Family::Cnn5, n, cfg.seed + 5, 10);
        let mut ts = Vec::new();
        let mut es = Vec::new();
        for g in &models {
            let m = dev.run(&fuse(&lower(g)), cfg.iterations());
            ts.push(m.time_per_iter());
            es.push(m.energy_per_iter());
        }
        let r = pearson(&ts, &es);
        let pts: Vec<(f64, f64)> = ts.iter().zip(&es).map(|(t, e)| (*t, *e)).collect();
        rep.push_series(
            "time vs energy per iteration (5-layer CNN, OPPO)",
            "time s/iter",
            vec![("energy J/iter".to_string(), pts)],
        );
        rep.metric("pearson_r", r);
        rep.note(format!(
            "Pearson r(time, energy) = {r:.4} (paper: 'obvious positive relationship')"
        ));
        rep
    }
}

/// ResNet relative-error CDF on Xavier + Server.
pub struct Fig10;

impl Experiment for Fig10 {
    fn id(&self) -> &'static str {
        "fig10"
    }

    fn description(&self) -> &'static str {
        "ResNet relative-error CDF, THOR vs FLOPs-LR (Xavier + server)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "ResNet relative-error CDF", cfg, &["xavier", "server"]);
        let fams = if cfg.quick {
            vec![Family::ResNet20]
        } else {
            vec![Family::ResNet20, Family::ResNet56, Family::ResNet110]
        };
        for dev_name in ["xavier", "server"] {
            let profile = devices::by_name(dev_name).unwrap();
            let mut dev = Device::new(profile, cfg.seed);
            let lr = fit_flops_lr(&mut dev, cfg);
            let mut thor = Thor::new(cfg.thor_cfg());
            let mut errs_thor = Vec::new();
            let mut errs_lr = Vec::new();
            for fam in &fams {
                thor.profile_local(&mut dev, &reference_model(*fam));
                for g in sample_n(*fam, cfg.n_test() / 3 + 2, cfg.seed + 2, 10) {
                    let act = measured_energy(&mut dev, &g, cfg.iterations(), 1);
                    let e_t = thor.estimate(dev_name, &g).unwrap().energy_per_iter;
                    errs_thor.push(((act - e_t) / act).abs());
                    errs_lr.push(((act - lr.predict(&g)) / act).abs());
                }
            }
            let grid: Vec<f64> = (0..=20).map(|i| i as f64 * 0.05).collect();
            let c_t = cdf(&errs_thor, &grid);
            let c_l = cdf(&errs_lr, &grid);
            let s_t: Vec<(f64, f64)> = grid.iter().zip(&c_t).map(|(g, c)| (*g, *c)).collect();
            let s_l: Vec<(f64, f64)> = grid.iter().zip(&c_l).map(|(g, c)| (*g, *c)).collect();
            rep.push_series(
                &format!("ResNet relative-error CDF ({dev_name})"),
                "rel err",
                vec![("THOR".to_string(), s_t), ("FLOPs-LR".to_string(), s_l)],
            );
        }
        rep
    }
}

/// Conv2d energy surface vs (C_in, C_out) at several spatial sizes
/// (profiled points + GP surface values on a grid).
pub struct Fig11;

impl Experiment for Fig11 {
    fn id(&self) -> &'static str {
        "fig11"
    }

    fn description(&self) -> &'static str {
        "conv2d variant energy surface vs (C_in, C_out) (Xavier + server)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "conv2d energy surfaces", cfg, &["xavier", "server"]);
        for dev_name in ["xavier", "server"] {
            let profile = devices::by_name(dev_name).unwrap();
            let mut dev = Device::new(profile, cfg.seed);
            let reference = zoo::cnn5(&[32, 64, 128, 256], 28, 10);
            let parsed = crate::thor::parse::parse(&reference);
            let hid = parsed.hidden_groups().next().unwrap(); // 14x14 conv
            let inp = parsed.input_groups().next().unwrap();
            let outg = parsed.output_groups().next().unwrap();
            let n = if cfg.quick { 4 } else { 8 };
            let mut rows = Vec::new();
            for i in 0..n {
                for j in 0..n {
                    let a = 1 + i * 32 / n.max(1);
                    let b = 1 + j * 64 / n.max(1);
                    let (g, _, _) = profiler::hidden_variant(inp, hid, outg, a, b);
                    let (e, _) = profiler::measure(&mut dev, &g, cfg.iterations().min(200));
                    rows.push(vec![format!("{a}"), format!("{b}"), format!("{e:.4e}")]);
                }
            }
            rep.push_table(
                &format!("conv2d 3x3 @14x14 variant energy surface ({dev_name})"),
                &["C_in", "C_out", "variant J/iter"],
                rows,
            );
        }
        rep
    }
}
