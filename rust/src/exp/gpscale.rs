//! gpscale (PR 9, §Perf): sparse-vs-exact GP backend comparison.
//!
//! One controlled profile-and-estimate run per backend arm — exact
//! first, then `sparse:<m>` at several inducing counts — on the Xavier
//! CNN zoo.  Every arm reports its own MAPE against metered ground
//! truth, and the merge computes each sparse arm's **estimate drift
//! envelope** against the exact arm: the mean and max relative
//! deviation of the per-model energy estimates.  The golden pin on this
//! table is the repo's accuracy contract for the sparse backend — if a
//! kernel or selection change moves the sparse posterior, this drifts
//! and the golden diff shows exactly how much, per inducing count.
//!
//! Like the other controlled comparisons ([`crate::exp::ablation`]),
//! every arm captures the *parent* config, so all arms share one test
//! set and one device-noise seed; arm-to-arm differences isolate the
//! backend treatment (plus whatever acquisition-path divergence the
//! changed posterior induces — that end-to-end effect is deliberately
//! in scope, since it is what `--gp sparse:<m>` ships).

use crate::exp::registry::{Experiment, Subtask, SubtaskOutput};
use crate::exp::report::ExpReport;
use crate::exp::{measured_energy, reference_model, ExpConfig};
use crate::gp::GpBackend;
use crate::model::sampler::{sample_n, Family};
use crate::simdevice::{devices, Device};
use crate::thor::{Thor, ThorConfig};
use crate::util::stats::mape;

/// Sparse-vs-exact MAPE drift across inducing counts (the tentpole's
/// evidence experiment).
pub struct GpScale;

/// Inducing counts swept by the sparse arms.  Chosen to straddle the
/// quick-mode family budgets (1-D: 10 points, 2-D: 14): m = 4 and 8
/// exercise the sparse path on every family, m = 12 only on the 2-D
/// ones (1-D fits fall back exact by the `m < n` rule — the fallback is
/// part of what the golden pins).
const GPSCALE_M: [usize; 3] = [4, 8, 12];

/// (per-model measured energy, per-model estimated energy) for one arm.
type ArmOut = (Vec<f64>, Vec<f64>);

impl GpScale {
    fn arm(backend: GpBackend, cfg: &ExpConfig) -> ArmOut {
        let profile = devices::by_name("xavier").unwrap();
        let mut dev = Device::new(profile, cfg.seed);
        let tcfg = ThorConfig { gp_backend: backend, ..cfg.thor_cfg() };
        let mut thor = Thor::new(tcfg);
        thor.profile_local(&mut dev, &reference_model(Family::Cnn5));
        let test = sample_n(Family::Cnn5, cfg.n_test().min(25), cfg.seed + 1, 10);
        let (mut actual, mut est) = (vec![], vec![]);
        for g in &test {
            actual.push(measured_energy(&mut dev, g, cfg.iterations(), 1));
            est.push(thor.estimate("xavier", g).unwrap().energy_per_iter);
        }
        (actual, est)
    }
}

impl Experiment for GpScale {
    fn id(&self) -> &'static str {
        "gpscale"
    }

    fn description(&self) -> &'static str {
        "sparse-vs-exact GP backend: MAPE + estimate-drift envelope across inducing counts (Xavier)"
    }

    fn subtasks(&self, cfg: &ExpConfig) -> Vec<Subtask> {
        let parent = *cfg; // shared across arms: controlled comparison
        let mut subs = vec![Subtask::new("exact", move |_scfg: &ExpConfig| {
            Self::arm(GpBackend::Exact, &parent)
        })];
        for m in GPSCALE_M {
            subs.push(Subtask::new(format!("sparse-m{m}"), move |_scfg: &ExpConfig| {
                Self::arm(GpBackend::Sparse { m }, &parent)
            }));
        }
        subs
    }

    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "sparse GP backend accuracy", cfg, &["xavier"]);
        let arms: Vec<ArmOut> =
            parts.into_iter().map(|p| *p.downcast::<ArmOut>().expect("gpscale arm")).collect();
        let (_, exact_est) = &arms[0]; // declaration order: exact first
        let mut rows = Vec::new();
        for (i, (actual, est)) in arms.iter().enumerate() {
            let label = if i == 0 {
                "exact".to_string()
            } else {
                format!("sparse:{}", GPSCALE_M[i - 1])
            };
            let (mean_drift, max_drift) = if i == 0 {
                (0.0, 0.0)
            } else {
                let rel: Vec<f64> = est
                    .iter()
                    .zip(exact_est)
                    .map(|(s, e)| 100.0 * (s - e).abs() / e.abs().max(1e-12))
                    .collect();
                let max = rel.iter().cloned().fold(0.0f64, f64::max);
                (rel.iter().sum::<f64>() / rel.len() as f64, max)
            };
            rows.push(vec![
                label,
                format!("{:.1}", mape(actual, est)),
                format!("{mean_drift:.2}"),
                format!("{max_drift:.2}"),
            ]);
        }
        rep.push_table(
            "",
            &["backend", "MAPE %", "mean drift vs exact %", "max drift vs exact %"],
            rows,
        );
        rep
    }
}
