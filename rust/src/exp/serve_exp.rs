//! `serve1` — the estimation-serving daemon under concurrent load.
//!
//! Profiles one deterministic store (xavier, cnn5 reference), stands up
//! a loopback [`EstimateServer`] with a worker per client, then hammers
//! it from [`N_CLIENTS`] client threads, each sending both single
//! `EstimateRequest`s and coalescing `EstimateBatch`es over
//! [`SPECS`]-many cnn5 width variants.  Every reply is compared
//! bit-for-bit against a direct [`estimate`] call made *before* the
//! daemon took the store — the serving tier's core contract
//! (`byte_stable == 1.0`).
//!
//! Determinism: the report contains only scheduling-independent values
//! (query counts, the byte-stability fraction, final cache entry count,
//! protocol request/error totals).  Throughput and latency are
//! wall-clock and therefore go to **stderr only** (`eprintln!`), never
//! into the report or its golden.  (Cache hit/miss *splits* are racy
//! across client threads — two threads can miss the same key
//! concurrently — so they stay out of the report too; the final entry
//! count is a pure function of the query set.)

use std::time::Instant;

use crate::coordinator::{EstimateClient, EstimateServer};
use crate::exp::registry::Experiment;
use crate::exp::report::ExpReport;
use crate::exp::ExpConfig;
use crate::model::spec::parse_spec;
use crate::model::zoo;
use crate::simdevice::{devices, Device};
use crate::thor::estimator::estimate;
use crate::thor::Thor;

/// Concurrent client threads (and daemon worker threads — each client
/// holds its connection for the whole run, so workers ≥ clients).
const N_CLIENTS: usize = 4;

/// The query mix: cnn5 width variants, all covered by one profile of
/// the cnn5 reference (that is the point of per-family GPs).
const SPECS: [&str; 6] = [
    "cnn5:8,16,32,64:16",
    "cnn5:4,8,16,32:16",
    "cnn5:16,32,64,128:16",
    "cnn5:32,64,128,256:16",
    "cnn5:24,48,96,20:16",
    "cnn5:3,30,60,100:16",
];

const DEVICE: &str = "xavier";

pub struct Serve1;

impl Experiment for Serve1 {
    fn id(&self) -> &'static str {
        "serve1"
    }

    fn description(&self) -> &'static str {
        "estimation-serving daemon: 4 clients x 6 models over loopback, replies bit-identical to local estimate()"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep = ExpReport::new(
            self.id(),
            "estimate-serving daemon under concurrent load (loopback)",
            cfg,
            &[DEVICE],
        );
        let rounds = if cfg.quick { 8 } else { 50 };

        // Fit once, locally — the daemon never fits.
        let profile = devices::by_name(DEVICE).expect("device");
        let mut dev = Device::new(profile, cfg.seed);
        let mut thor = Thor::new(cfg.thor_cfg());
        thor.profile_local(&mut dev, &zoo::cnn5(&[32, 64, 128, 256], 16, 10));
        let store = thor.store;
        let families = store.len();

        // Ground truth *before* the daemon takes the store: the exact
        // bits a local estimate() produces per spec.
        let expected: Vec<(u64, u64)> = SPECS
            .iter()
            .map(|s| {
                let e = estimate(&store, DEVICE, &parse_spec(s).expect("spec")).expect("covered");
                (e.energy_per_iter.to_bits(), e.variance.to_bits())
            })
            .collect();

        let handle = EstimateServer::bind("127.0.0.1:0", store)
            .expect("bind loopback")
            .start(N_CLIENTS)
            .expect("start daemon");
        let addr = handle.addr();

        let t_all = Instant::now();
        let mut joins = Vec::new();
        for _ in 0..N_CLIENTS {
            let expected = expected.clone();
            joins.push(std::thread::spawn(move || {
                let mut client = EstimateClient::connect(&addr).expect("connect");
                let batch: Vec<(String, String)> =
                    SPECS.iter().map(|s| (DEVICE.to_string(), s.to_string())).collect();
                let (mut ok, mut total) = (0usize, 0usize);
                let mut lat_us: Vec<f64> = Vec::with_capacity(rounds * (SPECS.len() + 1));
                for _ in 0..rounds {
                    for (si, spec) in SPECS.iter().enumerate() {
                        let t0 = Instant::now();
                        let (e, v) = client.estimate(DEVICE, spec).expect("estimate");
                        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
                        total += 1;
                        if (e.to_bits(), v.to_bits()) == expected[si] {
                            ok += 1;
                        }
                    }
                    let t0 = Instant::now();
                    let got = client.estimate_batch(&batch).expect("batch");
                    lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
                    for (g, want) in got.iter().zip(&expected) {
                        total += 1;
                        if let Ok((e, v)) = g {
                            if (e.to_bits(), v.to_bits()) == *want {
                                ok += 1;
                            }
                        }
                    }
                }
                (ok, total, lat_us)
            }));
        }
        let (mut ok, mut total) = (0usize, 0usize);
        let mut lat_us: Vec<f64> = Vec::new();
        for j in joins {
            let (o, t, l) = j.join().expect("client thread");
            ok += o;
            total += t;
            lat_us.extend(l);
        }
        let wall = t_all.elapsed().as_secs_f64();
        let cache_entries = handle.cache().len();
        let stats = handle.shutdown();

        // Wall-clock numbers: stderr only, never the report (goldens).
        lat_us.sort_by(f64::total_cmp);
        let p99 = lat_us[((lat_us.len() as f64 * 0.99) as usize).min(lat_us.len() - 1)];
        eprintln!(
            "serve1: {total} query answers over {} round-trips in {wall:.2}s \
             ({:.0} rt/s), p99 round-trip {p99:.0} us  [wall-clock; stderr only]",
            lat_us.len(),
            lat_us.len() as f64 / wall.max(1e-9),
        );

        rep.push_table(
            "serving-tier load (loopback daemon)",
            &["clients", "models", "rounds", "answers checked", "bit-identical"],
            vec![vec![
                format!("{N_CLIENTS}"),
                format!("{}", SPECS.len()),
                format!("{rounds}"),
                format!("{total}"),
                format!("{ok}"),
            ]],
        );
        rep.metric("n_queries", total as f64);
        rep.metric("byte_stable", ok as f64 / total as f64);
        rep.metric("clients", N_CLIENTS as f64);
        rep.metric("models", SPECS.len() as f64);
        rep.metric("families", families as f64);
        rep.metric("cache_entries", cache_entries as f64);
        rep.metric("protocol_requests", stats.requests as f64);
        rep.metric("protocol_errors", stats.errors as f64);
        rep.note(format!(
            "{N_CLIENTS} concurrent clients x {rounds} rounds: {ok}/{total} daemon answers \
             bit-identical to local estimate(); {} family GPs served, {} cache entries \
             (throughput/latency on stderr — wall-clock never enters the report)",
            families, cache_entries
        ));
        rep
    }
}
