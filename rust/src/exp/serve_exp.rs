//! `serve1` — the estimation-serving daemon under concurrent load.
//!
//! Profiles one deterministic store (xavier, cnn5 reference), stands up
//! a loopback [`EstimateServer`] with a worker per client, then hammers
//! it from [`N_CLIENTS`] client threads, each sending both single
//! `EstimateRequest`s and coalescing `EstimateBatch`es over
//! [`SPECS`]-many cnn5 width variants.  Every reply is compared
//! bit-for-bit against a direct [`estimate`] call made *before* the
//! daemon took the store — the serving tier's core contract
//! (`byte_stable == 1.0`).
//!
//! Determinism: the report contains only scheduling-independent values
//! (query counts, the byte-stability fraction, final cache entry count,
//! protocol request/error totals).  Throughput and latency are
//! wall-clock and therefore go to **stderr only** (`eprintln!`), never
//! into the report or its golden.  (Cache hit/miss *splits* are racy
//! across client threads — two threads can miss the same key
//! concurrently — so they stay out of the report too; the final entry
//! count is a pure function of the query set.)
//!
//! After the golden-pinned run, a **scaling comparison** (PR 10) stands
//! the daemon up under each [`IoModel`] at 4× and 32× connections per
//! serving thread and reports qps / p99 / worst first-reply to stderr —
//! every reply still bit-checked.  With `THOR_SERVE_BENCH_JSON=<path>`
//! (CI: `BENCH_pr10_serve.json`) the latency distributions are written
//! as `{schema_version, benches: [...]}` rows via
//! [`crate::util::bench::BenchResult::to_json`].  None of this enters
//! the report: the golden is byte-stable across io models.

use std::time::Instant;

use crate::coordinator::{EstimateClient, EstimateServer, IoModel};
use crate::exp::registry::Experiment;
use crate::exp::report::ExpReport;
use crate::exp::ExpConfig;
use crate::model::spec::parse_spec;
use crate::model::zoo;
use crate::simdevice::{devices, Device};
use crate::thor::estimator::estimate;
use crate::thor::store::GpStore;
use crate::thor::Thor;
use crate::util::bench::BenchResult;
use crate::util::json::Json;

/// Concurrent client threads (and daemon worker threads — each client
/// holds its connection for the whole run, so workers ≥ clients).
const N_CLIENTS: usize = 4;

/// The query mix: cnn5 width variants, all covered by one profile of
/// the cnn5 reference (that is the point of per-family GPs).
const SPECS: [&str; 6] = [
    "cnn5:8,16,32,64:16",
    "cnn5:4,8,16,32:16",
    "cnn5:16,32,64,128:16",
    "cnn5:32,64,128,256:16",
    "cnn5:24,48,96,20:16",
    "cnn5:3,30,60,100:16",
];

const DEVICE: &str = "xavier";

pub struct Serve1;

impl Experiment for Serve1 {
    fn id(&self) -> &'static str {
        "serve1"
    }

    fn description(&self) -> &'static str {
        "estimation-serving daemon: 4 clients x 6 models over loopback, replies bit-identical to local estimate()"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep = ExpReport::new(
            self.id(),
            "estimate-serving daemon under concurrent load (loopback)",
            cfg,
            &[DEVICE],
        );
        let rounds = if cfg.quick { 8 } else { 50 };

        // Fit once, locally — the daemon never fits.
        let profile = devices::by_name(DEVICE).expect("device");
        let mut dev = Device::new(profile, cfg.seed);
        let mut thor = Thor::new(cfg.thor_cfg());
        thor.profile_local(&mut dev, &zoo::cnn5(&[32, 64, 128, 256], 16, 10));
        let store = thor.store;
        let families = store.len();
        // The daemon takes the store by value; keep a serialized copy
        // so the scaling comparison below can stand up fresh daemons
        // against the identical fit.
        let store_json = store.to_json().to_string();

        // Ground truth *before* the daemon takes the store: the exact
        // bits a local estimate() produces per spec.
        let expected: Vec<(u64, u64)> = SPECS
            .iter()
            .map(|s| {
                let e = estimate(&store, DEVICE, &parse_spec(s).expect("spec")).expect("covered");
                (e.energy_per_iter.to_bits(), e.variance.to_bits())
            })
            .collect();

        let handle = EstimateServer::bind("127.0.0.1:0", store)
            .expect("bind loopback")
            .start(N_CLIENTS)
            .expect("start daemon");
        let addr = handle.addr();

        let t_all = Instant::now();
        let mut joins = Vec::new();
        for _ in 0..N_CLIENTS {
            let expected = expected.clone();
            joins.push(std::thread::spawn(move || {
                let mut client = EstimateClient::connect(&addr).expect("connect");
                let batch: Vec<(String, String)> =
                    SPECS.iter().map(|s| (DEVICE.to_string(), s.to_string())).collect();
                let (mut ok, mut total) = (0usize, 0usize);
                let mut lat_us: Vec<f64> = Vec::with_capacity(rounds * (SPECS.len() + 1));
                for _ in 0..rounds {
                    for (si, spec) in SPECS.iter().enumerate() {
                        let t0 = Instant::now();
                        let (e, v) = client.estimate(DEVICE, spec).expect("estimate");
                        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
                        total += 1;
                        if (e.to_bits(), v.to_bits()) == expected[si] {
                            ok += 1;
                        }
                    }
                    let t0 = Instant::now();
                    let got = client.estimate_batch(&batch).expect("batch");
                    lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
                    for (g, want) in got.iter().zip(&expected) {
                        total += 1;
                        if let Ok((e, v)) = g {
                            if (e.to_bits(), v.to_bits()) == *want {
                                ok += 1;
                            }
                        }
                    }
                }
                (ok, total, lat_us)
            }));
        }
        let (mut ok, mut total) = (0usize, 0usize);
        let mut lat_us: Vec<f64> = Vec::new();
        for j in joins {
            let (o, t, l) = j.join().expect("client thread");
            ok += o;
            total += t;
            lat_us.extend(l);
        }
        let wall = t_all.elapsed().as_secs_f64();
        let cache_entries = handle.cache().len();
        let stats = handle.shutdown();

        // Wall-clock numbers: stderr only, never the report (goldens).
        lat_us.sort_by(f64::total_cmp);
        let p99 = lat_us[((lat_us.len() as f64 * 0.99) as usize).min(lat_us.len() - 1)];
        eprintln!(
            "serve1: {total} query answers over {} round-trips in {wall:.2}s \
             ({:.0} rt/s), p99 round-trip {p99:.0} us  [wall-clock; stderr only]",
            lat_us.len(),
            lat_us.len() as f64 / wall.max(1e-9),
        );

        rep.push_table(
            "serving-tier load (loopback daemon)",
            &["clients", "models", "rounds", "answers checked", "bit-identical"],
            vec![vec![
                format!("{N_CLIENTS}"),
                format!("{}", SPECS.len()),
                format!("{rounds}"),
                format!("{total}"),
                format!("{ok}"),
            ]],
        );
        rep.metric("n_queries", total as f64);
        rep.metric("byte_stable", ok as f64 / total as f64);
        rep.metric("clients", N_CLIENTS as f64);
        rep.metric("models", SPECS.len() as f64);
        rep.metric("families", families as f64);
        rep.metric("cache_entries", cache_entries as f64);
        rep.metric("protocol_requests", stats.requests as f64);
        rep.metric("protocol_errors", stats.errors as f64);
        rep.note(format!(
            "{N_CLIENTS} concurrent clients x {rounds} rounds: {ok}/{total} daemon answers \
             bit-identical to local estimate(); {} family GPs served, {} cache entries \
             (throughput/latency on stderr — wall-clock never enters the report)",
            families, cache_entries
        ));

        scaling_comparison(&store_json, &expected, cfg.quick);
        rep
    }
}

/// Serving threads for the scaling comparison — deliberately small so
/// the connection multipliers stress connections-per-thread, not cores.
const SCALE_WORKERS: usize = 2;

/// Threads-vs-reactor scaling sweep (PR 10).  Every reply is still
/// bit-checked against `expected`; a mismatch panics the experiment.
/// All timing output is wall-clock → stderr / bench JSON only.
fn scaling_comparison(store_json: &str, expected: &[(u64, u64)], quick: bool) {
    let rounds = if quick { 10 } else { 40 };
    let mut results: Vec<BenchResult> = Vec::new();
    for io in [IoModel::Threads, IoModel::Reactor] {
        for mult in [4usize, 32] {
            let conns = SCALE_WORKERS * mult;
            let store = GpStore::from_json(&Json::parse(store_json).expect("store json"))
                .expect("store roundtrip");
            let handle = EstimateServer::bind("127.0.0.1:0", store)
                .expect("bind loopback")
                .with_io_model(io)
                .start(SCALE_WORKERS)
                .expect("start daemon");
            let addr = handle.addr();
            let t_all = Instant::now();
            let mut joins = Vec::new();
            for ci in 0..conns {
                let expected = expected.to_vec();
                joins.push(std::thread::spawn(move || {
                    let mut client = EstimateClient::connect(&addr).expect("connect");
                    let mut lat_ns: Vec<f64> = Vec::with_capacity(rounds);
                    for r in 0..rounds {
                        let si = (ci + r) % SPECS.len();
                        let t0 = Instant::now();
                        let (e, v) = client.estimate(DEVICE, SPECS[si]).expect("estimate");
                        lat_ns.push(t0.elapsed().as_nanos() as f64);
                        assert_eq!(
                            (e.to_bits(), v.to_bits()),
                            expected[si],
                            "scaling sweep reply diverged from local estimate ({io:?}, x{mult})"
                        );
                    }
                    lat_ns
                }));
            }
            let mut all_ns: Vec<f64> = Vec::new();
            let mut first_ns: Vec<f64> = Vec::new();
            for j in joins {
                let lat = j.join().expect("scaling client");
                first_ns.push(lat[0]);
                all_ns.extend(lat);
            }
            let wall = t_all.elapsed().as_secs_f64();
            let stats = handle.shutdown();
            let qps = all_ns.len() as f64 / wall.max(1e-9);
            let p99 = percentile(&mut all_ns, 0.99);
            let first_max = first_ns.iter().cloned().fold(0.0f64, f64::max);
            let tag = match io {
                IoModel::Threads => "threads",
                IoModel::Reactor => "reactor",
            };
            eprintln!(
                "serve1-scale[{tag} x{mult}]: {conns} conns / {SCALE_WORKERS} threads, \
                 {} replies in {wall:.2}s ({qps:.0} qps), p99 {:.0} us, \
                 worst first-reply {:.0} us, coalesced {}  [wall-clock; stderr only]",
                all_ns.len(),
                p99 / 1e3,
                first_max / 1e3,
                stats.coalesced,
            );
            results.push(summarize_ns(format!("serve1_scale/{tag}/conns_x{mult}/roundtrip"), all_ns));
            results.push(summarize_ns(
                format!("serve1_scale/{tag}/conns_x{mult}/first_reply"),
                first_ns,
            ));
        }
    }
    if let Ok(path) = std::env::var("THOR_SERVE_BENCH_JSON") {
        let json = Json::obj(vec![
            ("schema_version", Json::Num(1.0)),
            ("benches", Json::Arr(results.iter().map(|r| r.to_json()).collect())),
        ]);
        match std::fs::write(&path, json.to_string()) {
            Ok(()) => eprintln!("serve1-scale: wrote {} bench rows to {path}", results.len()),
            Err(e) => eprintln!("serve1-scale: could not write {path}: {e}"),
        }
    }
}

fn percentile(samples: &mut [f64], q: f64) -> f64 {
    samples.sort_by(f64::total_cmp);
    samples[((samples.len() as f64 * q) as usize).min(samples.len() - 1)]
}

fn summarize_ns(name: String, mut samples_ns: Vec<f64>) -> BenchResult {
    samples_ns.sort_by(f64::total_cmp);
    let n = samples_ns.len();
    BenchResult {
        name,
        iters: n,
        mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
        p50_ns: samples_ns[n / 2],
        p95_ns: samples_ns[(n as f64 * 0.95) as usize % n],
        min_ns: samples_ns[0],
    }
}
