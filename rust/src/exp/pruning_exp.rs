//! Fig 13 — the energy-aware pruning case study (paper §4.3), promoted
//! from `examples/energy_aware_pruning.rs` into a first-class registry
//! experiment.
//!
//! Random channel pruning of the 5-layer CNN on Xavier under an energy
//! budget, guided by (a) THOR's GP estimates and (b) the FLOPs-ratio
//! heuristic.  The paper's headline is the 50 % budget: THOR-guided
//! pruning lands within budget, FLOPs-guided pruning overshoots because
//! the ratio heuristic ignores occupancy/padding plateaus.  The
//! experiment sweeps one subtask per budget fraction so the arms profile
//! and search in parallel on the suite pool.

use crate::exp::registry::{Experiment, Subtask, SubtaskOutput};
use crate::exp::report::ExpReport;
use crate::exp::ExpConfig;
use crate::model::zoo;
use crate::pruning::{prune_cnn5, Guidance, PruneOutcome};
use crate::simdevice::{devices, Device};
use crate::thor::Thor;

/// Budget fractions swept, in presentation order; 0.5 is the paper's
/// headline budget and feeds the report's metrics.
pub const BUDGETS: [f64; 3] = [0.3, 0.5, 0.7];

/// Original ("dense") channel widths of the pruned CNN.
const ORIGINAL: [usize; 4] = [16, 32, 64, 128];
const IMG: usize = 16;
const BATCH: usize = 10;

/// Both guidance arms at one budget fraction.
struct Fig13Arm {
    budget: f64,
    thor: PruneOutcome,
    flops: PruneOutcome,
}

pub struct Fig13;

impl Fig13 {
    /// One budget arm: profile THOR on a fresh device, then search under
    /// the budget with both guidances.  Pure function of the subtask
    /// config.
    fn arm(budget: f64, cfg: &ExpConfig) -> Fig13Arm {
        let reference = zoo::cnn5(&ORIGINAL, IMG, BATCH);
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let mut thor = Thor::new(cfg.thor_cfg());
        thor.profile_local(&mut dev, &reference);

        let tries = if cfg.quick { 40 } else { 80 };
        let iters = cfg.iterations();
        let t = prune_cnn5(
            &mut dev,
            &ORIGINAL,
            IMG,
            BATCH,
            budget,
            Guidance::Thor(&thor, "xavier"),
            tries,
            iters,
            cfg.seed + 1,
        );
        let f = prune_cnn5(
            &mut dev,
            &ORIGINAL,
            IMG,
            BATCH,
            budget,
            Guidance::FlopsRatio { original_actual: t.original_actual },
            tries,
            iters,
            cfg.seed + 1,
        );
        Fig13Arm { budget, thor: t, flops: f }
    }

    fn row(budget: f64, guidance: &str, o: &PruneOutcome) -> Vec<String> {
        vec![
            format!("{:.0}%", budget * 100.0),
            guidance.to_string(),
            format!("{:?}", o.channels),
            format!("{:.1}%", 100.0 * o.predicted / o.original_actual),
            format!("{:.1}%", 100.0 * o.actual_ratio()),
            if o.actual_ratio() <= budget + 0.02 { "within".to_string() } else { "OVER".to_string() },
        ]
    }
}

impl Experiment for Fig13 {
    fn id(&self) -> &'static str {
        "fig13"
    }

    fn description(&self) -> &'static str {
        "energy-aware pruning under an energy budget: THOR vs FLOPs-ratio guidance (Xavier)"
    }

    fn subtasks(&self, _cfg: &ExpConfig) -> Vec<Subtask> {
        BUDGETS
            .iter()
            .map(|&budget| {
                Subtask::new(format!("budget-{:.0}pct", budget * 100.0), move |scfg: &ExpConfig| {
                    Self::arm(budget, scfg)
                })
            })
            .collect()
    }

    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "energy-aware pruning case study", cfg, &["xavier"]);
        let mut rows = Vec::new();
        let mut thor_within = 0usize;
        let mut flops_within = 0usize;
        let mut headline: Option<(f64, f64)> = None;
        let n_arms = parts.len();
        for part in parts {
            let arm = *part.downcast::<Fig13Arm>().expect("fig13 arm output");
            rows.push(Self::row(arm.budget, "THOR", &arm.thor));
            rows.push(Self::row(arm.budget, "FLOPs-ratio", &arm.flops));
            if arm.thor.actual_ratio() <= arm.budget + 0.02 {
                thor_within += 1;
            }
            if arm.flops.actual_ratio() <= arm.budget + 0.02 {
                flops_within += 1;
            }
            if (arm.budget - 0.5).abs() < 1e-9 {
                headline = Some((arm.thor.actual_ratio(), arm.flops.actual_ratio()));
            }
        }
        rep.push_table(
            "Fig 13 — pruning under an energy budget (actual vs predicted, Xavier)",
            &["budget", "guidance", "channels", "predicted", "actual", "verdict"],
            rows,
        );
        if let Some((t50, f50)) = headline {
            rep.metric("thor_actual_ratio_50", t50);
            rep.metric("flops_actual_ratio_50", f50);
        }
        rep.metric("thor_within_budget_frac", thor_within as f64 / n_arms as f64);
        rep.metric("flops_within_budget_frac", flops_within as f64 / n_arms as f64);
        rep.note(
            "FLOPs-ratio guidance underestimates pruned-model energy on occupancy/padding \
             plateaus and overshoots the budget; THOR's absolute GP estimates land within it.",
        );
        rep
    }
}
