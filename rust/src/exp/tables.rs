//! Table-shaped experiments: fig2 (overestimation), fig7 (estimated vs
//! actual), fig8 + Table 1 (end-to-end MAPE and profiling cost), fig9
//! (Transformer), fig12 (estimation − observation).

use crate::baselines::neuralpower;
use crate::exp::registry::{Experiment, Subtask, SubtaskOutput};
use crate::exp::report::ExpReport;
use crate::exp::{fit_flops_lr, mape_pair, measured_energy, reference_model, ExpConfig};
use crate::model::sampler::{sample, sample_n, Family};
use crate::model::zoo;
use crate::simdevice::{devices, Device};
use crate::thor::Thor;
use crate::util::rng::Pcg64;
use crate::util::stats::{mean, std_err};

/// NeuralPower-style per-stage estimation vs observation, CNN depth
/// sweep (the overestimation validation).
pub struct Fig2;

impl Experiment for Fig2 {
    fn id(&self) -> &'static str {
        "fig2"
    }

    fn description(&self) -> &'static str {
        "NeuralPower-style per-stage estimation overestimates (CNN depth sweep, Xavier)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep = ExpReport::new(
            self.id(),
            "NeuralPower-style per-stage estimation vs observation",
            cfg,
            &["xavier"],
        );
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let mut rows = Vec::new();
        for depth in 1..=4usize {
            // input conv + (depth-1) hidden convs + fc
            let ch: Vec<usize> = (0..depth).map(|i| 16 << i.min(3)).collect();
            let mut padded = [16usize, 32, 64, 128];
            for (i, c) in ch.iter().enumerate() {
                padded[i] = *c;
            }
            let g = match depth {
                1 => zoo::cnn5(&[padded[0], 1, 1, 1], 28, 10),
                2 => zoo::cnn5(&[padded[0], padded[1], 1, 1], 28, 10),
                3 => zoo::cnn5(&[padded[0], padded[1], padded[2], 1], 28, 10),
                _ => zoo::cnn5(&padded, 28, 10),
            };
            let observed = measured_energy(&mut dev, &g, cfg.iterations(), cfg.repeats());
            let np_est = neuralpower::estimate(&mut dev, &g, cfg.iterations().min(100));
            rows.push(vec![
                format!("{depth}"),
                format!("{observed:.4e}"),
                format!("{np_est:.4e}"),
                format!("{:.2}", np_est / observed),
            ]);
        }
        rep.push_table("", &["#conv layers", "observed J/iter", "NeuralPower-style est", "ratio"], rows);
        rep
    }
}

/// Estimated-vs-actual scatter (FLOPs vs THOR) for random CNNs on Xavier.
pub struct Fig7;

impl Experiment for Fig7 {
    fn id(&self) -> &'static str {
        "fig7"
    }

    fn description(&self) -> &'static str {
        "estimated vs actual energy, FLOPs-LR vs THOR (random CNNs, Xavier)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "estimated vs actual (FLOPs vs THOR)", cfg, &["xavier"]);
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let lr = fit_flops_lr(&mut dev, cfg);
        let mut thor = Thor::new(cfg.thor_cfg());
        thor.profile_local(&mut dev, &reference_model(Family::Cnn5));
        let test = sample_n(Family::Cnn5, cfg.n_test(), cfg.seed + 1, 10);
        let mut rows = Vec::new();
        for g in &test {
            let act = measured_energy(&mut dev, g, cfg.iterations(), cfg.repeats());
            rows.push(vec![
                format!("{act:.4e}"),
                format!("{:.4e}", lr.predict(g)),
                format!("{:.4e}", thor.estimate("xavier", g).unwrap().energy_per_iter),
            ]);
        }
        rep.push_table("", &["actual J/iter", "FLOPs-LR est", "THOR est"], rows);
        rep
    }
}

/// End-to-end MAPE: devices × families, THOR vs FLOPs-LR, with std error
/// over repeats.  Also produces Table 1 (profiling cost); `tab1` aliases
/// this experiment in the registry.
///
/// Fans out one subtask per device × family cell — the grid dominates
/// suite wall-clock, and every cell is independent (own device, own
/// seed), so the whole pool chews on it at once.
pub struct Fig8;

/// Output of one device × family cell subtask.
struct Fig8Cell {
    mape_row: Vec<String>,
    tab1_row: Vec<String>,
    thor_mape: f64,
    lr_mape: f64,
}

impl Fig8 {
    pub fn devices_for(cfg: &ExpConfig) -> Vec<&'static str> {
        if cfg.quick {
            vec!["xavier", "server"]
        } else {
            vec!["oppo", "iphone", "xavier", "tx2", "server"]
        }
    }

    /// One grid cell, a pure function of the subtask config.
    fn cell(dev_name: &'static str, fam: Family, cfg: &ExpConfig) -> Fig8Cell {
        let reps = cfg.repeats();
        let mut thor_m = Vec::new();
        let mut lr_m = Vec::new();
        let mut dev_secs = 0.0;
        for rep_i in 0..reps {
            let cfg_r = ExpConfig { seed: cfg.seed + rep_i as u64 * 1000, ..*cfg };
            let (t, f, report) = mape_pair(dev_name, fam, &cfg_r);
            thor_m.push(t);
            lr_m.push(f);
            // Simulated profiling cost only: GP-fit wall-clock is
            // machine-dependent and would break the byte-identical
            // JSON contract (see exp::report).
            dev_secs += report.device_seconds() / reps as f64;
        }
        Fig8Cell {
            mape_row: vec![
                dev_name.to_string(),
                fam.name().to_string(),
                format!("{:.1} ± {:.1}", mean(&thor_m), std_err(&thor_m)),
                format!("{:.1} ± {:.1}", mean(&lr_m), std_err(&lr_m)),
            ],
            tab1_row: vec![
                dev_name.to_string(),
                fam.name().to_string(),
                format!("{dev_secs:.0}"),
            ],
            thor_mape: mean(&thor_m),
            lr_mape: mean(&lr_m),
        }
    }
}

impl Experiment for Fig8 {
    fn id(&self) -> &'static str {
        "fig8"
    }

    fn description(&self) -> &'static str {
        "end-to-end MAPE across devices and families + Table 1 profiling cost"
    }

    fn subtasks(&self, cfg: &ExpConfig) -> Vec<Subtask> {
        let mut subs = Vec::new();
        for dev_name in Self::devices_for(cfg) {
            for fam in Family::fig8_families() {
                subs.push(Subtask::new(
                    format!("{dev_name}/{}", fam.name()),
                    move |scfg: &ExpConfig| Self::cell(dev_name, fam, scfg),
                ));
            }
        }
        subs
    }

    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let mut rep = ExpReport::new(
            self.id(),
            "end-to-end MAPE across devices",
            cfg,
            &Self::devices_for(cfg),
        );
        let mut rows = Vec::new();
        let mut tab1_rows = Vec::new();
        let mut thor_all = Vec::new();
        let mut lr_all = Vec::new();
        for part in parts {
            let cell = *part.downcast::<Fig8Cell>().expect("fig8 cell output");
            rows.push(cell.mape_row);
            tab1_rows.push(cell.tab1_row);
            thor_all.push(cell.thor_mape);
            lr_all.push(cell.lr_mape);
        }
        rep.push_table(
            "Fig 8 — MAPE by device × family",
            &["device", "model", "THOR MAPE %", "FLOPs-LR MAPE %"],
            rows,
        );
        rep.push_table(
            "Table 1 — profiling cost (simulated device-seconds)",
            &["device", "model", "profile sec"],
            tab1_rows,
        );
        rep.metric("thor_mape_mean", mean(&thor_all));
        rep.metric("flops_lr_mape_mean", mean(&lr_all));
        rep
    }
}

/// Transformer estimation on Xavier + Server.
pub struct Fig9;

impl Experiment for Fig9 {
    fn id(&self) -> &'static str {
        "fig9"
    }

    fn description(&self) -> &'static str {
        "Transformer estimation MAPE (Xavier + server)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "Transformer estimation", cfg, &["xavier", "server"]);
        let mut rows = Vec::new();
        for dev_name in ["xavier", "server"] {
            let (t, f, _) = mape_pair(dev_name, Family::Transformer, cfg);
            rows.push(vec![dev_name.to_string(), format!("{t:.1}"), format!("{f:.1}")]);
        }
        rep.push_table("", &["device", "THOR MAPE %", "FLOPs-LR MAPE %"], rows);
        rep
    }
}

/// Held-out error of the hidden-conv GP surface (est − obs).
///
/// Fans out one subtask per device: each device's profile + held-out
/// sweep is independent (own device, own seed via the subtask label),
/// and the runner merges the per-device tables in declaration order.
pub struct Fig12;

const FIG12_DEVICES: [&str; 2] = ["xavier", "server"];

impl Fig12 {
    /// One device's held-out table — a pure function of the subtask
    /// config.
    fn device_rows(dev_name: &'static str, cfg: &ExpConfig) -> Vec<Vec<String>> {
        let profile = devices::by_name(dev_name).unwrap();
        let mut dev = Device::new(profile, cfg.seed);
        let mut thor = Thor::new(cfg.thor_cfg());
        thor.profile_local(&mut dev, &reference_model(Family::Cnn5));
        let mut rng = Pcg64::new(cfg.seed + 3);
        let mut rows = Vec::new();
        for _ in 0..if cfg.quick { 6 } else { 20 } {
            let g = sample(Family::Cnn5, &mut rng, 10);
            let act = measured_energy(&mut dev, &g, cfg.iterations(), 1);
            let est = thor.estimate(dev_name, &g).unwrap().energy_per_iter;
            rows.push(vec![
                format!("{act:.4e}"),
                format!("{est:.4e}"),
                format!("{:+.1}%", 100.0 * (est - act) / act),
            ]);
        }
        rows
    }
}

impl Experiment for Fig12 {
    fn id(&self) -> &'static str {
        "fig12"
    }

    fn description(&self) -> &'static str {
        "estimation minus observation on held-out CNNs (Xavier + server)"
    }

    fn subtasks(&self, _cfg: &ExpConfig) -> Vec<Subtask> {
        FIG12_DEVICES
            .iter()
            .map(|&dev_name| {
                Subtask::new(dev_name, move |scfg: &ExpConfig| Self::device_rows(dev_name, scfg))
            })
            .collect()
    }

    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "estimation vs observation", cfg, &FIG12_DEVICES);
        for (dev_name, part) in FIG12_DEVICES.iter().zip(parts) {
            let rows = *part.downcast::<Vec<Vec<String>>>().expect("fig12 rows");
            rep.push_table(
                &format!("estimation vs observation ({dev_name})"),
                &["observed", "estimated", "diff"],
                rows,
            );
        }
        rep
    }
}
