//! The experiment registry: every paper table/figure is a registered
//! [`Experiment`] with a stable id, discoverable by the CLI
//! (`thor exp --list`), the bench harness, and the golden-run tests.
//!
//! Adding an experiment = implement the trait in `tables.rs` /
//! `figures.rs` / `ablation.rs` and append it to [`registry`].  Order in
//! [`registry`] is the canonical presentation order (paper order) and is
//! preserved by the multi-threaded runner.

use crate::exp::report::ExpReport;
use crate::exp::{ablation, figures, tables, ExpConfig};

/// One paper table/figure, runnable in isolation or by the suite runner.
///
/// `run` must be a pure function of `cfg` (see the determinism contract
/// in [`crate::exp::report`]): same config, same report, regardless of
/// thread scheduling or wall-clock.
pub trait Experiment: Send + Sync {
    /// Stable identifier (`fig2`, `a15`, ...) — also the golden filename.
    fn id(&self) -> &'static str;
    /// One-line description for `thor exp --list`.
    fn description(&self) -> &'static str;
    fn run(&self, cfg: &ExpConfig) -> ExpReport;
}

/// All registered experiments, in paper order.
pub fn registry() -> Vec<Box<dyn Experiment>> {
    vec![
        Box::new(tables::Fig2),
        Box::new(figures::Fig4),
        Box::new(figures::Fig5),
        Box::new(figures::Fig6),
        Box::new(tables::Fig7),
        Box::new(tables::Fig8),
        Box::new(tables::Fig9),
        Box::new(figures::Fig10),
        Box::new(figures::Fig11),
        Box::new(tables::Fig12),
        Box::new(ablation::A14),
        Box::new(ablation::A15),
        Box::new(ablation::A16),
    ]
}

/// Registered ids, in registry order.
pub fn ids() -> Vec<&'static str> {
    registry().iter().map(|e| e.id()).collect()
}

/// Look up one experiment.  `tab1` is an alias for `fig8` (the Table-1
/// profiling-cost table is produced by the same device/family sweep).
pub fn by_id(id: &str) -> Option<Box<dyn Experiment>> {
    let id = if id == "tab1" { "fig8" } else { id };
    registry().into_iter().find(|e| e.id() == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_nonempty() {
        let ids = ids();
        assert!(ids.len() >= 13, "registry shrank: {ids:?}");
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate experiment ids");
    }

    #[test]
    fn by_id_resolves_every_registered_id() {
        for id in ids() {
            assert!(by_id(id).is_some(), "{id} not resolvable");
        }
        assert!(by_id("nope").is_none());
    }

    #[test]
    fn tab1_aliases_fig8() {
        assert_eq!(by_id("tab1").unwrap().id(), "fig8");
    }

    #[test]
    fn descriptions_are_single_line() {
        for e in registry() {
            assert!(!e.description().is_empty(), "{} has no description", e.id());
            assert!(!e.description().contains('\n'));
        }
    }
}
