//! The experiment registry: every paper table/figure is a registered
//! [`Experiment`] with a stable id, discoverable by the CLI
//! (`thor exp --list`), the bench harness, and the golden-run tests.
//!
//! Adding an experiment = implement the trait in `tables.rs` /
//! `figures.rs` / `ablation.rs` / `pruning_exp.rs` / `fleet_exp.rs` /
//! `serve_exp.rs` / `gpscale.rs` and append it to [`registry`].  Order in [`registry`] is the canonical
//! presentation order (paper order) and is preserved by the
//! multi-threaded runner.
//!
//! # Subtask fan-out
//!
//! An experiment whose work decomposes into independent cells (the
//! device × family grid of fig8, the per-budget arms of fig13) can
//! implement [`Experiment::subtasks`] + [`Experiment::merge`] instead of
//! [`Experiment::run`]: the runner then fans the subtasks across the
//! *suite-wide* worker pool and merges the outputs in declaration order,
//! so one huge experiment no longer serializes behind a single worker.
//!
//! Determinism rules for fan-out authors (enforced by
//! `tests/properties.rs` and the golden harness):
//!
//! * subtask labels must be unique and stable — each subtask's seed is
//!   derived from the experiment seed and its label
//!   ([`ExpConfig::for_subtask`]), never from scheduling;
//! * a subtask must be a pure function of its derived [`ExpConfig`]
//!   (own devices, own RNGs — no shared mutable state);
//! * [`Experiment::merge`] must be a pure function of the config and the
//!   outputs *in declaration order* (the runner guarantees that order
//!   regardless of completion order or thread count);
//! * a panicking subtask fails only its own experiment: the runner
//!   reports the first failing subtask in declaration order, so even the
//!   failure message is byte-stable across thread counts.

use std::any::Any;

use crate::exp::report::ExpReport;
use crate::exp::{ablation, figures, fleet_exp, gpscale, pruning_exp, serve_exp, tables, ExpConfig};

/// Type-erased output of one subtask, downcast by the experiment's
/// [`Experiment::merge`].
pub type SubtaskOutput = Box<dyn Any + Send>;

/// One independent, seeded unit of an experiment's fan-out.
pub struct Subtask {
    /// Stable label, unique within the experiment; the subtask seed is
    /// derived from it.
    pub label: String,
    body: Box<dyn Fn(&ExpConfig) -> SubtaskOutput + Send + Sync>,
}

impl Subtask {
    /// Wrap a closure producing any `Any + Send` value; the runner hands
    /// the boxed output back to [`Experiment::merge`].
    pub fn new<F, T>(label: impl Into<String>, body: F) -> Self
    where
        F: Fn(&ExpConfig) -> T + Send + Sync + 'static,
        T: Any + Send,
    {
        Self { label: label.into(), body: Box::new(move |cfg| Box::new(body(cfg)) as SubtaskOutput) }
    }

    /// Execute with the subtask-derived config.
    pub fn run(&self, cfg: &ExpConfig) -> SubtaskOutput {
        (self.body)(cfg)
    }
}

/// One paper table/figure, runnable in isolation or by the suite runner.
///
/// `run` must be a pure function of `cfg` (see the determinism contract
/// in [`crate::exp::report`]): same config, same report, regardless of
/// thread scheduling or wall-clock.  Monolithic experiments implement
/// `run`; fan-out experiments implement `subtasks` + `merge` and inherit
/// the provided `run` (which executes the subtasks sequentially in
/// declaration order — byte-identical to the runner's parallel path).
pub trait Experiment: Send + Sync {
    /// Stable identifier (`fig2`, `a15`, ...) — also the golden filename.
    fn id(&self) -> &'static str;

    /// One-line description for `thor exp --list`.
    fn description(&self) -> &'static str;

    /// Independent seeded subtasks, in declaration order.  Empty (the
    /// default) means the experiment is monolithic and `run` does all
    /// the work on one worker.
    fn subtasks(&self, cfg: &ExpConfig) -> Vec<Subtask> {
        let _ = cfg;
        Vec::new()
    }

    /// Combine subtask outputs (declaration order) into the report.
    /// Must be implemented by every experiment with non-empty
    /// [`Experiment::subtasks`].
    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let _ = (cfg, parts);
        unreachable!("experiment '{}' fans out but does not implement merge()", self.id())
    }

    /// Produce the report.  The default executes the fan-out
    /// sequentially; monolithic experiments override it.
    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let subs = self.subtasks(cfg);
        assert!(
            !subs.is_empty(),
            "experiment '{}' implements neither run() nor subtasks()",
            self.id()
        );
        let parts: Vec<SubtaskOutput> =
            subs.iter().map(|s| s.run(&cfg.for_subtask(&s.label))).collect();
        self.merge(cfg, parts)
    }
}

/// All registered experiments, in paper order.
pub fn registry() -> Vec<Box<dyn Experiment>> {
    vec![
        Box::new(tables::Fig2),
        Box::new(figures::Fig4),
        Box::new(figures::Fig5),
        Box::new(figures::Fig6),
        Box::new(tables::Fig7),
        Box::new(tables::Fig8),
        Box::new(tables::Fig9),
        Box::new(figures::Fig10),
        Box::new(figures::Fig11),
        Box::new(tables::Fig12),
        Box::new(pruning_exp::Fig13),
        Box::new(ablation::A14),
        Box::new(ablation::A15),
        Box::new(ablation::A16),
        Box::new(fleet_exp::Fleet1),
        Box::new(fleet_exp::FleetN),
        Box::new(fleet_exp::FleetH),
        Box::new(fleet_exp::FleetE),
        Box::new(fleet_exp::FleetS),
        Box::new(serve_exp::Serve1),
        Box::new(gpscale::GpScale),
    ]
}

/// Registered ids, in registry order.
pub fn ids() -> Vec<&'static str> {
    registry().iter().map(|e| e.id()).collect()
}

/// Look up one experiment.  `tab1` is an alias for `fig8` (the Table-1
/// profiling-cost table is produced by the same device/family sweep).
pub fn by_id(id: &str) -> Option<Box<dyn Experiment>> {
    let id = if id == "tab1" { "fig8" } else { id };
    registry().into_iter().find(|e| e.id() == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_nonempty() {
        let ids = ids();
        assert!(ids.len() >= 15, "registry shrank: {ids:?}");
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate experiment ids");
    }

    #[test]
    fn by_id_resolves_every_registered_id() {
        for id in ids() {
            assert!(by_id(id).is_some(), "{id} not resolvable");
        }
        assert!(by_id("nope").is_none());
    }

    #[test]
    fn tab1_aliases_fig8() {
        assert_eq!(by_id("tab1").unwrap().id(), "fig8");
    }

    #[test]
    fn fig13_and_fleet_experiments_are_registered() {
        assert_eq!(by_id("fig13").unwrap().id(), "fig13");
        assert_eq!(by_id("fleet1").unwrap().id(), "fleet1");
        assert_eq!(by_id("fleetN").unwrap().id(), "fleetN");
        assert_eq!(by_id("fleetH").unwrap().id(), "fleetH");
        assert_eq!(by_id("fleetE").unwrap().id(), "fleetE");
        assert_eq!(by_id("fleetS").unwrap().id(), "fleetS");
        assert_eq!(by_id("serve1").unwrap().id(), "serve1");
        assert_eq!(by_id("gpscale").unwrap().id(), "gpscale");
    }

    #[test]
    fn descriptions_are_single_line() {
        for e in registry() {
            assert!(!e.description().is_empty(), "{} has no description", e.id());
            assert!(!e.description().contains('\n'));
        }
    }

    #[test]
    fn subtask_labels_are_unique_and_stable() {
        let cfg = ExpConfig::new(true, 3);
        for e in registry() {
            let labels: Vec<String> =
                e.subtasks(&cfg).iter().map(|s| s.label.clone()).collect();
            let mut dedup = labels.clone();
            dedup.sort();
            dedup.dedup();
            assert_eq!(dedup.len(), labels.len(), "{}: duplicate subtask labels", e.id());
            let again: Vec<String> =
                e.subtasks(&cfg).iter().map(|s| s.label.clone()).collect();
            assert_eq!(labels, again, "{}: unstable subtask labels", e.id());
        }
    }

    #[test]
    fn subtask_closure_output_downcasts() {
        let s = Subtask::new("t", |cfg: &ExpConfig| cfg.seed);
        let out = s.run(&ExpConfig::new(true, 5));
        assert_eq!(*out.downcast::<u64>().unwrap(), 5);
    }
}
