//! Ablations: a14 (profiling-point budget vs MAPE, energy vs time
//! acquisition), a15 (GP kernel / sampling ablation), a16 (measurement
//! stability vs profiling-iteration count).
//!
//! All three are grid-shaped, so they fan out into one subtask per cell
//! (`Experiment::subtasks` + `merge`) and the runner's suite-wide pool
//! chews on the whole grid at once.  Merge reassembles the tables in
//! declaration order, so suite JSON stays byte-identical at any
//! `--threads`.
//!
//! Seeding: these are *controlled comparisons* — every arm of a sweep
//! must see the same held-out test set and the same device noise stream,
//! or row-to-row MAPE differences mix the treatment effect with
//! sampling noise.  The subtask closures therefore capture the parent
//! experiment config and ignore their label-derived seed: each cell is
//! still a pure, schedule-independent function (the parent config is
//! fixed at `subtasks()` time), it just reproduces exactly what the old
//! sequential loop computed.

use crate::exp::registry::{Experiment, Subtask, SubtaskOutput};
use crate::exp::report::ExpReport;
use crate::exp::{measured_energy, reference_model, ExpConfig};
use crate::gp::KernelKind;
use crate::model::sampler::{sample_n, Family};
use crate::model::zoo;
use crate::simdevice::{devices, Device};
use crate::thor::{Thor, ThorConfig};
use crate::util::stats::{mape, mean, std_dev};
use crate::workload::{fusion::fuse, lower::lower};

/// #profiled points vs MAPE (energy acquisition vs time surrogate).
pub struct A14;

const A14_DEVICES: [&str; 2] = ["oppo", "xavier"];
const A14_BUDGETS: [usize; 4] = [6, 10, 16, 24];

impl A14 {
    /// One (device, budget, acquisition) cell → its table row.
    fn cell(dev_name: &'static str, budget: usize, surrogate: bool, cfg: &ExpConfig) -> Vec<String> {
        let profile = devices::by_name(dev_name).unwrap();
        let mut dev = Device::new(profile, cfg.seed);
        let tcfg = ThorConfig {
            max_points_1d: budget,
            max_points_2d: budget * 2,
            threshold_frac: 0.0, // force budget use
            time_surrogate: surrogate,
            ..cfg.thor_cfg()
        };
        let mut thor = Thor::new(tcfg);
        thor.profile_local(&mut dev, &reference_model(Family::Cnn5));
        let test = sample_n(Family::Cnn5, cfg.n_test().min(20), cfg.seed + 1, 10);
        let (mut actual, mut est) = (vec![], vec![]);
        for g in &test {
            actual.push(measured_energy(&mut dev, g, cfg.iterations(), 1));
            est.push(thor.estimate(dev_name, g).unwrap().energy_per_iter);
        }
        vec![
            format!("{budget}"),
            if surrogate { "time" } else { "energy" }.into(),
            format!("{:.1}", mape(&actual, &est)),
        ]
    }
}

impl Experiment for A14 {
    fn id(&self) -> &'static str {
        "a14"
    }

    fn description(&self) -> &'static str {
        "profiled-point budget vs MAPE, energy vs time acquisition (OPPO + Xavier)"
    }

    fn subtasks(&self, cfg: &ExpConfig) -> Vec<Subtask> {
        let parent = *cfg; // shared across arms: controlled comparison
        let mut subs = Vec::new();
        for dev_name in A14_DEVICES {
            for budget in A14_BUDGETS {
                for surrogate in [false, true] {
                    let acq = if surrogate { "time" } else { "energy" };
                    subs.push(Subtask::new(
                        format!("{dev_name}/b{budget}/{acq}"),
                        move |_scfg: &ExpConfig| Self::cell(dev_name, budget, surrogate, &parent),
                    ));
                }
            }
        }
        subs
    }

    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "profiled points vs MAPE", cfg, &A14_DEVICES);
        let rows_per_device = A14_BUDGETS.len() * 2;
        let mut parts = parts.into_iter();
        for dev_name in A14_DEVICES {
            let rows: Vec<Vec<String>> = (&mut parts)
                .take(rows_per_device)
                .map(|p| *p.downcast::<Vec<String>>().expect("a14 row"))
                .collect();
            rep.push_table(
                &format!("points-budget sweep ({dev_name})"),
                &["1D budget", "acquisition", "MAPE %"],
                rows,
            );
        }
        rep
    }
}

/// GP kernel ablation: Matérn vs RBF vs DotProduct vs random-Matérn.
pub struct A15;

const A15_ARMS: [(&str, &str, KernelKind, bool); 4] = [
    ("matern52-guided", "Matern52 (guided)", KernelKind::Matern52, false),
    ("rbf-guided", "RBF (guided)", KernelKind::Rbf, false),
    ("dot-guided", "DotProduct (guided)", KernelKind::DotProduct, false),
    ("matern52-random", "Matern52 (random)", KernelKind::Matern52, true),
];

impl A15 {
    fn arm(label: &'static str, kind: KernelKind, random: bool, cfg: &ExpConfig) -> Vec<String> {
        let profile = devices::by_name("xavier").unwrap();
        let mut dev = Device::new(profile, cfg.seed);
        let tcfg = ThorConfig { kind, random_sampling: random, ..cfg.thor_cfg() };
        let mut thor = Thor::new(tcfg);
        thor.profile_local(&mut dev, &reference_model(Family::Cnn5));
        let test = sample_n(Family::Cnn5, cfg.n_test().min(25), cfg.seed + 1, 10);
        let (mut actual, mut est) = (vec![], vec![]);
        for g in &test {
            actual.push(measured_energy(&mut dev, g, cfg.iterations(), 1));
            est.push(thor.estimate("xavier", g).unwrap().energy_per_iter);
        }
        vec![label.to_string(), format!("{:.1}", mape(&actual, &est))]
    }
}

impl Experiment for A15 {
    fn id(&self) -> &'static str {
        "a15"
    }

    fn description(&self) -> &'static str {
        "GP kernel / sampling ablation on Xavier (Matern, RBF, DotProduct, random)"
    }

    fn subtasks(&self, cfg: &ExpConfig) -> Vec<Subtask> {
        let parent = *cfg; // shared across arms: controlled comparison
        A15_ARMS
            .iter()
            .map(|&(slug, label, kind, random)| {
                Subtask::new(slug, move |_scfg: &ExpConfig| Self::arm(label, kind, random, &parent))
            })
            .collect()
    }

    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let mut rep = ExpReport::new(self.id(), "GP kernel ablation", cfg, &["xavier"]);
        let rows: Vec<Vec<String>> =
            parts.into_iter().map(|p| *p.downcast::<Vec<String>>().expect("a15 row")).collect();
        rep.push_table("", &["kernel / sampling", "MAPE %"], rows);
        rep
    }
}

/// Energy normalized to 1000 iterations vs profiling-iteration count
/// (few samples ⇒ unstable).
pub struct A16;

const A16_ITERS: [usize; 6] = [10, 50, 100, 200, 500, 1000];

impl A16 {
    fn cell(iters: usize, cfg: &ExpConfig) -> Vec<String> {
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let g = zoo::lenet5(&[6, 16, 120, 84], 10);
        let tr = fuse(&lower(&g));
        let reps = if cfg.quick { 5 } else { 15 };
        let vals: Vec<f64> =
            (0..reps).map(|_| dev.run(&tr, iters).energy_per_iter() * 1000.0).collect();
        vec![
            format!("{iters}"),
            format!("{:.3}", mean(&vals)),
            format!("{:.1}%", 100.0 * std_dev(&vals) / mean(&vals)),
        ]
    }
}

impl Experiment for A16 {
    fn id(&self) -> &'static str {
        "a16"
    }

    fn description(&self) -> &'static str {
        "measurement spread vs profiling-iteration count (Xavier)"
    }

    fn subtasks(&self, cfg: &ExpConfig) -> Vec<Subtask> {
        // Each row gets a fresh device at the *same* parent seed, so the
        // spread-vs-iterations rows start from identical device state
        // (the old sequential loop carried one RNG stream across rows).
        let parent = *cfg;
        A16_ITERS
            .iter()
            .map(|&iters| {
                Subtask::new(format!("iters{iters}"), move |_scfg: &ExpConfig| {
                    Self::cell(iters, &parent)
                })
            })
            .collect()
    }

    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "energy vs profiling iterations", cfg, &["xavier"]);
        let rows: Vec<Vec<String>> =
            parts.into_iter().map(|p| *p.downcast::<Vec<String>>().expect("a16 row")).collect();
        rep.push_table(
            "",
            &["profiling iterations", "energy per 1000 iters (J)", "spread (CV)"],
            rows,
        );
        rep
    }
}
