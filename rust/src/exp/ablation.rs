//! Ablations: a14 (profiling-point budget vs MAPE, energy vs time
//! acquisition), a15 (GP kernel / sampling ablation), a16 (measurement
//! stability vs profiling-iteration count).

use crate::exp::registry::Experiment;
use crate::exp::report::ExpReport;
use crate::exp::{measured_energy, reference_model, ExpConfig};
use crate::gp::KernelKind;
use crate::model::sampler::{sample_n, Family};
use crate::model::zoo;
use crate::simdevice::{devices, Device};
use crate::thor::{Thor, ThorConfig};
use crate::util::stats::{mape, mean, std_dev};
use crate::workload::{fusion::fuse, lower::lower};

/// #profiled points vs MAPE (energy acquisition vs time surrogate).
pub struct A14;

impl Experiment for A14 {
    fn id(&self) -> &'static str {
        "a14"
    }

    fn description(&self) -> &'static str {
        "profiled-point budget vs MAPE, energy vs time acquisition (OPPO + Xavier)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "profiled points vs MAPE", cfg, &["oppo", "xavier"]);
        for dev_name in ["oppo", "xavier"] {
            let mut rows = Vec::new();
            for budget in [6usize, 10, 16, 24] {
                for surrogate in [false, true] {
                    let profile = devices::by_name(dev_name).unwrap();
                    let mut dev = Device::new(profile, cfg.seed);
                    let tcfg = ThorConfig {
                        max_points_1d: budget,
                        max_points_2d: budget * 2,
                        threshold_frac: 0.0, // force budget use
                        time_surrogate: surrogate,
                        ..cfg.thor_cfg()
                    };
                    let mut thor = Thor::new(tcfg);
                    thor.profile(&mut dev, &reference_model(Family::Cnn5));
                    let test = sample_n(Family::Cnn5, cfg.n_test().min(20), cfg.seed + 1, 10);
                    let (mut actual, mut est) = (vec![], vec![]);
                    for g in &test {
                        actual.push(measured_energy(&mut dev, g, cfg.iterations(), 1));
                        est.push(thor.estimate(dev_name, g).unwrap().energy_per_iter);
                    }
                    rows.push(vec![
                        format!("{budget}"),
                        if surrogate { "time" } else { "energy" }.into(),
                        format!("{:.1}", mape(&actual, &est)),
                    ]);
                }
            }
            rep.push_table(
                &format!("points-budget sweep ({dev_name})"),
                &["1D budget", "acquisition", "MAPE %"],
                rows,
            );
        }
        rep
    }
}

/// GP kernel ablation: Matérn vs RBF vs DotProduct vs random-Matérn.
pub struct A15;

impl Experiment for A15 {
    fn id(&self) -> &'static str {
        "a15"
    }

    fn description(&self) -> &'static str {
        "GP kernel / sampling ablation on Xavier (Matern, RBF, DotProduct, random)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep = ExpReport::new(self.id(), "GP kernel ablation", cfg, &["xavier"]);
        let mut rows = Vec::new();
        for (label, kind, random) in [
            ("Matern52 (guided)", KernelKind::Matern52, false),
            ("RBF (guided)", KernelKind::Rbf, false),
            ("DotProduct (guided)", KernelKind::DotProduct, false),
            ("Matern52 (random)", KernelKind::Matern52, true),
        ] {
            let profile = devices::by_name("xavier").unwrap();
            let mut dev = Device::new(profile, cfg.seed);
            let tcfg = ThorConfig { kind, random_sampling: random, ..cfg.thor_cfg() };
            let mut thor = Thor::new(tcfg);
            thor.profile(&mut dev, &reference_model(Family::Cnn5));
            let test = sample_n(Family::Cnn5, cfg.n_test().min(25), cfg.seed + 1, 10);
            let (mut actual, mut est) = (vec![], vec![]);
            for g in &test {
                actual.push(measured_energy(&mut dev, g, cfg.iterations(), 1));
                est.push(thor.estimate("xavier", g).unwrap().energy_per_iter);
            }
            rows.push(vec![label.to_string(), format!("{:.1}", mape(&actual, &est))]);
        }
        rep.push_table("", &["kernel / sampling", "MAPE %"], rows);
        rep
    }
}

/// Energy normalized to 1000 iterations vs profiling-iteration count
/// (few samples ⇒ unstable).
pub struct A16;

impl Experiment for A16 {
    fn id(&self) -> &'static str {
        "a16"
    }

    fn description(&self) -> &'static str {
        "measurement spread vs profiling-iteration count (Xavier)"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "energy vs profiling iterations", cfg, &["xavier"]);
        let mut dev = Device::new(devices::xavier(), cfg.seed);
        let g = zoo::lenet5(&[6, 16, 120, 84], 10);
        let tr = fuse(&lower(&g));
        let reps = if cfg.quick { 5 } else { 15 };
        let mut rows = Vec::new();
        for iters in [10usize, 50, 100, 200, 500, 1000] {
            let vals: Vec<f64> = (0..reps)
                .map(|_| dev.run(&tr, iters).energy_per_iter() * 1000.0)
                .collect();
            rows.push(vec![
                format!("{iters}"),
                format!("{:.3}", mean(&vals)),
                format!("{:.1}%", 100.0 * std_dev(&vals) / mean(&vals)),
            ]);
        }
        rep.push_table(
            "",
            &["profiling iterations", "energy per 1000 iters (J)", "spread (CV)"],
            rows,
        );
        rep
    }
}
