//! fleet1 — the decoupled fleet-profiling architecture (paper Appendix
//! A5.2), promoted from `examples/fleet_profiling.rs` into a first-class
//! registry experiment.
//!
//! An in-process loopback fleet: a [`FleetServer`] leader bound to an
//! ephemeral `127.0.0.1` port and `N_WORKERS` [`DeviceWorker`] threads
//! streaming measurements back over real TCP.  Workers run with
//! deterministic per-job measurement seeds and the leader pins jobs to
//! workers by family affinity, so the report — per-worker job counts and
//! the MAPE of estimates from the fleet-fitted [`GpStore`] — is a pure
//! function of the experiment config, byte-stable across runs and
//! thread counts despite the real sockets and threads underneath.

use crate::coordinator::{DeviceWorker, FleetServer};
use crate::exp::registry::Experiment;
use crate::exp::report::ExpReport;
use crate::exp::{measured_energy, ExpConfig};
use crate::model::zoo;
use crate::simdevice::{devices, Device};
use crate::thor::estimator::estimate;
use crate::util::stats::mape;

const N_WORKERS: usize = 3;

/// Unseen cnn5 variants the fleet-fitted store is scored on.
const TEST_VARIANTS: [[usize; 4]; 4] =
    [[8, 16, 32, 64], [3, 30, 60, 100], [16, 8, 4, 2], [24, 48, 96, 20]];

pub struct Fleet1;

impl Experiment for Fleet1 {
    fn id(&self) -> &'static str {
        "fleet1"
    }

    fn description(&self) -> &'static str {
        "loopback fleet profiling: leader + 3 TCP workers fit the GP store, then estimate"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "decoupled fleet profiling (loopback)", cfg, &["xavier"]);
        let reference = zoo::cnn5(&[32, 64, 128, 256], 16, 10);

        // leader on an ephemeral port; workers connect to it
        let server = FleetServer::new(cfg.thor_cfg());
        let bound = server.bind("127.0.0.1:0").expect("bind loopback");
        let addr = bound.local_addr().to_string();

        let mut handles = Vec::new();
        for w in 0..N_WORKERS {
            let reference = reference.clone();
            let addr = addr.clone();
            let base_seed = cfg.seed;
            handles.push(std::thread::spawn(move || {
                // The worker's own device seed is irrelevant under
                // per-job seeding; keep it distinct anyway, as a real
                // fleet would.
                let mut worker =
                    DeviceWorker::new(Device::new(devices::xavier(), 100 + w as u64), &reference)
                        .with_per_job_seed(base_seed);
                worker.run(&addr)
            }));
        }

        let run = bound.serve(&reference, N_WORKERS).expect("fleet serve");
        for h in handles {
            let _ = h.join();
        }

        // estimate unseen variants with the fleet-fitted store
        let mut dev = Device::new(devices::xavier(), cfg.seed + 9);
        let iters = cfg.iterations();
        let (mut actual, mut est) = (Vec::new(), Vec::new());
        for ch in TEST_VARIANTS {
            let g = zoo::cnn5(&ch, 16, 10);
            actual.push(measured_energy(&mut dev, &g, iters, 1));
            est.push(estimate(&run.store, "xavier", &g).expect("fleet store covers cnn5").energy_per_iter);
        }

        rep.push_table(
            "fleet job distribution (family-affinity scheduling)",
            &["worker", "jobs done"],
            run.per_worker
                .iter()
                .enumerate()
                .map(|(w, n)| vec![format!("{w}"), format!("{n}")])
                .collect(),
        );
        rep.metric("families_fitted", run.store.len() as f64);
        rep.metric("jobs_total", run.jobs_done as f64);
        rep.metric("jobs_requeued", run.requeued as f64);
        rep.metric("fleet_mape", mape(&actual, &est));
        rep.note(format!(
            "leader fitted {} family GPs from {} jobs across {} loopback workers",
            run.store.len(),
            run.jobs_done,
            N_WORKERS
        ));
        rep
    }
}
