//! Fleet-profiling experiments (paper Appendix A5.2).
//!
//! * `fleet1` — one leader + 3 TCP workers of one device type (Xavier),
//!   promoted from `examples/fleet_profiling.rs` in PR 2 and rebuilt in
//!   PR 4 on the [`crate::coordinator::FleetMeasurer`] backend: the
//!   leader now runs the *same* batched acquisition pipeline a local
//!   run does (batch = worker count, so every worker stays busy).
//! * `fleetN` — the multi-device fleet, sharded: one leader per device
//!   type (Xavier / TX2 / server), each with its own homogeneous worker
//!   group, fitting **concurrently** over the experiment runner's
//!   shared worker pool via subtask fan-out.  Reported with per-device
//!   MAPE and per-worker job counts.
//! * `fleetH` — the heterogeneous single-leader fleet: **one** leader,
//!   6 mixed TCP workers (2 per class), class-scoped scheduling and
//!   occupancy-adaptive (`Batch::Auto`) acquisition, one serve emitting
//!   one multi-device store.  Reported with per-device MAPE and
//!   per-class job counts.
//! * `fleetE` — the elasticity chaos suite: the `fleetH` fleet on a
//!   seeded chaos schedule — one worker per class dies mid-run and
//!   rejoins as a fresh connection, the leader itself is killed between
//!   absorbs and a successor resumes from its checkpoint
//!   ([`crate::thor::checkpoint`]).  The headline metric is
//!   `store_byte_equal`: the resumed store must be byte-identical to an
//!   uninterrupted local per-job run of the same config.
//! * `fleetS` — the straggler chaos suite: the same mixed fleet, but one
//!   worker per class *hangs without disconnecting* mid-run
//!   ([`crate::coordinator::FaultPlan`]) — the fault elasticity cannot
//!   see.  Per-job deadlines ([`FleetSpec::with_deadline`]) detect the
//!   silence and speculatively re-issue each held job to a healthy
//!   same-class peer; per-job measurement seeds make the duplicate
//!   results bitwise identical, so the headline metric is again
//!   `store_byte_equal` against an uninterrupted solo run.
//!
//! Workers run with deterministic per-job measurement seeds (per-class
//! derived via [`crate::coordinator::class_seed`] in `fleetH`) and the
//! leader pins jobs to same-class workers by per-class batch-index
//! affinity, so every report is a pure function of the experiment
//! config, byte-stable across runs and `--threads` counts despite the
//! real sockets and threads underneath.  (`fleetH` reports per-*class*
//! rather than per-*worker* job counts: with mixed workers racing to
//! one accept loop, the worker-id ↔ class mapping follows connection
//! order, but the per-class totals are scheduling-independent.)

use crate::coordinator::{DeviceWorker, FaultPlan, FleetRun, FleetServer, FleetSpec, ServeOptions};
use crate::exp::registry::{Experiment, Subtask, SubtaskOutput};
use crate::exp::report::ExpReport;
use crate::exp::{measured_energy, ExpConfig};
use crate::model::zoo;
use crate::model::ModelGraph;
use crate::simdevice::{devices, Device};
use crate::thor::checkpoint::{Checkpoint, Checkpointer};
use crate::thor::estimator::estimate;
use crate::thor::measure::LocalMeasurer;
use crate::thor::store::GpStore;
use crate::thor::{Batch, Thor, ThorConfig};
use crate::util::stats::mape;

const N_WORKERS: usize = 3;

/// Worker group size per device type in `fleetN` and `fleetH`.
const FLEETN_WORKERS: usize = 2;

/// Device types of the multi-device fleets (`fleetN`: one leader each;
/// `fleetH`: one leader for all — GPs never transfer across devices,
/// but with class-scoped scheduling they can share a leader).
const FLEETN_DEVICES: [&str; 3] = ["xavier", "tx2", "server"];

/// Unseen cnn5 variants the fleet-fitted stores are scored on.
const TEST_VARIANTS: [[usize; 4]; 4] =
    [[8, 16, 32, 64], [3, 30, 60, 100], [16, 8, 4, 2], [24, 48, 96, 20]];

fn fleet_reference() -> ModelGraph {
    zoo::cnn5(&[32, 64, 128, 256], 16, 10)
}

/// Run one loopback fleet: a leader bound to an ephemeral `127.0.0.1`
/// port and `n_workers` [`DeviceWorker`] threads of one device type,
/// all with per-job seeds derived from `base_seed`.  Batched
/// acquisition at `batch = n_workers` keeps the whole group busy.
fn run_loopback_fleet(
    dev_name: &str,
    n_workers: usize,
    base_seed: u64,
    cfg: &ExpConfig,
) -> FleetRun {
    let reference = fleet_reference();
    let thor_cfg = ThorConfig { batch: Batch::Fixed(n_workers), ..cfg.thor_cfg() };
    let server = FleetServer::new(thor_cfg);
    let bound = server.bind("127.0.0.1:0").expect("bind loopback");
    let addr = bound.local_addr().to_string();

    let mut handles = Vec::new();
    for w in 0..n_workers {
        let reference = reference.clone();
        let addr = addr.clone();
        let profile = devices::by_name(dev_name).expect("device");
        handles.push(std::thread::spawn(move || {
            // The worker's own device seed is irrelevant under per-job
            // seeding; keep it distinct anyway, as a real fleet would.
            let mut worker = DeviceWorker::new(Device::new(profile, 100 + w as u64), &reference)
                .with_per_job_seed(base_seed);
            worker.run(&addr)
        }));
    }

    let run = bound.serve(&reference, n_workers).expect("fleet serve");
    for h in handles {
        let _ = h.join();
    }
    run
}

/// Run the heterogeneous loopback fleet: **one** leader serving
/// [`FLEETN_WORKERS`] workers of *each* device type through one
/// [`FleetSpec::mixed`] serve, occupancy-adaptive acquisition
/// (`Batch::Auto` — each class's rounds are sized by its own live
/// worker count), per-class measurement seeds.
fn run_loopback_hetero_fleet(base_seed: u64, cfg: &ExpConfig) -> FleetRun {
    let reference = fleet_reference();
    let thor_cfg = ThorConfig { batch: Batch::Auto, ..cfg.thor_cfg() };
    let server = FleetServer::new(thor_cfg);
    let bound = server.bind("127.0.0.1:0").expect("bind loopback");
    let addr = bound.local_addr().to_string();
    let spec =
        FleetSpec::mixed(&FLEETN_DEVICES.map(|d| (d, FLEETN_WORKERS)));

    let mut handles = Vec::new();
    for (di, dev_name) in FLEETN_DEVICES.iter().enumerate() {
        for w in 0..FLEETN_WORKERS {
            let reference = reference.clone();
            let addr = addr.clone();
            let profile = devices::by_name(dev_name).expect("device");
            let dev_seed = 100 + (di * FLEETN_WORKERS + w) as u64;
            handles.push(std::thread::spawn(move || {
                let mut worker = DeviceWorker::new(Device::new(profile, dev_seed), &reference)
                    .with_class_seed(base_seed);
                worker.run(&addr)
            }));
        }
    }

    let run = bound.serve_spec(&reference, spec).expect("heterogeneous fleet serve");
    for h in handles {
        let _ = h.join();
    }
    run
}

/// Score a fleet-fitted store on the held-out variants for one device.
fn fleet_mape(store: &GpStore, dev_name: &str, cfg: &ExpConfig) -> f64 {
    let profile = devices::by_name(dev_name).expect("device");
    let mut dev = Device::new(profile, cfg.seed + 9);
    let iters = cfg.iterations();
    let (mut actual, mut est) = (Vec::new(), Vec::new());
    for ch in TEST_VARIANTS {
        let g = zoo::cnn5(&ch, 16, 10);
        actual.push(measured_energy(&mut dev, &g, iters, 1));
        est.push(
            estimate(store, dev_name, &g).expect("fleet store covers cnn5").energy_per_iter,
        );
    }
    mape(&actual, &est)
}

pub struct Fleet1;

impl Experiment for Fleet1 {
    fn id(&self) -> &'static str {
        "fleet1"
    }

    fn description(&self) -> &'static str {
        "loopback fleet profiling: leader + 3 TCP workers run the batched acquisition pipeline"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep =
            ExpReport::new(self.id(), "decoupled fleet profiling (loopback)", cfg, &["xavier"]);
        let run = run_loopback_fleet("xavier", N_WORKERS, cfg.seed, cfg);
        let m = fleet_mape(&run.store, "xavier", cfg);

        rep.push_table(
            "fleet job distribution (batch-index affinity scheduling)",
            &["worker", "jobs done"],
            run.per_worker
                .iter()
                .enumerate()
                .map(|(w, n)| vec![format!("{w}"), format!("{n}")])
                .collect(),
        );
        rep.metric("families_fitted", run.store.len() as f64);
        rep.metric("jobs_total", run.jobs_done as f64);
        rep.metric("jobs_requeued", run.requeued as f64);
        rep.metric("fleet_mape", m);
        rep.note(format!(
            "leader fitted {} family GPs from {} jobs across {} loopback workers",
            run.store.len(),
            run.jobs_done,
            N_WORKERS
        ));
        rep
    }
}

/// One device type's fleet result, shipped from subtask to merge.
struct FleetNPart {
    device: &'static str,
    families: usize,
    jobs_done: usize,
    requeued: usize,
    per_worker: Vec<usize>,
    mape: f64,
}

pub struct FleetN;

impl Experiment for FleetN {
    fn id(&self) -> &'static str {
        "fleetN"
    }

    fn description(&self) -> &'static str {
        "multi-device fleet: one leader per device type (xavier/tx2/server), fitted concurrently"
    }

    fn subtasks(&self, _cfg: &ExpConfig) -> Vec<Subtask> {
        FLEETN_DEVICES
            .iter()
            .map(|&dev_name| {
                Subtask::new(dev_name, move |sub_cfg: &ExpConfig| {
                    let run =
                        run_loopback_fleet(dev_name, FLEETN_WORKERS, sub_cfg.seed, sub_cfg);
                    FleetNPart {
                        device: dev_name,
                        families: run.store.len(),
                        jobs_done: run.jobs_done,
                        requeued: run.requeued,
                        per_worker: run.per_worker.clone(),
                        mape: fleet_mape(&run.store, dev_name, sub_cfg),
                    }
                })
            })
            .collect()
    }

    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let parts: Vec<FleetNPart> =
            parts.into_iter().map(|p| *p.downcast::<FleetNPart>().expect("FleetNPart")).collect();
        let mut rep = ExpReport::new(
            self.id(),
            "multi-device fleet profiling (one leader per device type)",
            cfg,
            &FLEETN_DEVICES,
        );
        rep.push_table(
            "per-device fleet runs (2 workers each)",
            &["device", "families", "jobs done", "requeued", "per-worker jobs", "MAPE %"],
            parts
                .iter()
                .map(|p| {
                    vec![
                        p.device.to_string(),
                        format!("{}", p.families),
                        format!("{}", p.jobs_done),
                        format!("{}", p.requeued),
                        p.per_worker
                            .iter()
                            .map(|n| n.to_string())
                            .collect::<Vec<_>>()
                            .join("/"),
                        format!("{:.1}", p.mape),
                    ]
                })
                .collect(),
        );
        for p in &parts {
            rep.metric(&format!("mape_{}", p.device), p.mape);
            rep.metric(&format!("jobs_{}", p.device), p.jobs_done as f64);
        }
        rep.metric("jobs_total", parts.iter().map(|p| p.jobs_done).sum::<usize>() as f64);
        rep.metric("devices", parts.len() as f64);
        rep.note(format!(
            "{} leaders × {} workers fitted {} family GPs in total",
            parts.len(),
            FLEETN_WORKERS,
            parts.iter().map(|p| p.families).sum::<usize>()
        ));
        rep
    }
}

pub struct FleetH;

impl Experiment for FleetH {
    fn id(&self) -> &'static str {
        "fleetH"
    }

    fn description(&self) -> &'static str {
        "heterogeneous single-leader fleet: 6 mixed workers (xavier/tx2/server x2), one serve, one multi-device store"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep = ExpReport::new(
            self.id(),
            "heterogeneous fleet profiling (one leader, class-scoped scheduling, auto batching)",
            cfg,
            &FLEETN_DEVICES,
        );
        let run = run_loopback_hetero_fleet(cfg.seed, cfg);
        let jobs_of = |c: &str| {
            run.per_class.iter().find(|(cc, _)| cc == c).map_or(0, |(_, n)| *n)
        };
        let mapes: Vec<(&str, f64)> = FLEETN_DEVICES
            .iter()
            .map(|&d| (d, fleet_mape(&run.store, d, cfg)))
            .collect();

        rep.push_table(
            "per-device results of the single-leader mixed fleet (2 workers per class)",
            &["device", "families", "jobs done", "MAPE %"],
            mapes
                .iter()
                .map(|(d, m)| {
                    vec![
                        d.to_string(),
                        format!("{}", run.store.len_for(d)),
                        format!("{}", jobs_of(d)),
                        format!("{m:.1}"),
                    ]
                })
                .collect(),
        );
        for (d, m) in &mapes {
            rep.metric(&format!("mape_{d}"), *m);
            rep.metric(&format!("jobs_{d}"), jobs_of(d) as f64);
            rep.metric(&format!("families_{d}"), run.store.len_for(d) as f64);
        }
        rep.metric("jobs_total", run.jobs_done as f64);
        rep.metric("jobs_requeued", run.requeued as f64);
        rep.metric("families_fitted", run.store.len() as f64);
        rep.metric("devices", FLEETN_DEVICES.len() as f64);
        rep.note(format!(
            "one leader fitted {} family GPs for {} device classes from {} class-routed jobs \
             across {} mixed loopback workers (batch=auto)",
            run.store.len(),
            FLEETN_DEVICES.len(),
            run.jobs_done,
            FLEETN_DEVICES.len() * FLEETN_WORKERS
        ));
        rep
    }
}

/// fleetE: one scheduled death + rejoin per class — worker 1 of each
/// class drops its connection with this many jobs completed (the next
/// job is left in flight and re-queued).
const DIE_AFTER_JOBS: usize = 2;

/// fleetE: leader A is killed before submitting this-plus-one-th joint
/// batch — "between absorbs", the durability point every checkpoint
/// write lands on, so the checkpoint it leaves behind covers exactly
/// this many absorbed joint batches.
const ABORT_AFTER_ROUNDS: usize = 6;

pub struct FleetE;

impl Experiment for FleetE {
    fn id(&self) -> &'static str {
        "fleetE"
    }

    fn description(&self) -> &'static str {
        "elastic-fleet chaos: worker deaths and rejoins, leader killed mid-run, successor resumes from checkpoint"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep = ExpReport::new(
            self.id(),
            "elastic fleet chaos (worker rejoin + leader checkpoint/resume)",
            cfg,
            &FLEETN_DEVICES,
        );
        let reference = fleet_reference();
        // Fixed batches, not Auto: chaos timing must never reach the
        // proposal stream.  Under Auto a death would shrink a class's
        // occupancy and with it the round size, making the store depend
        // on *when* the death lands; under Fixed + per-class/per-job
        // seeds every metric below is a pure function of the config.
        let thor_cfg = ThorConfig { batch: Batch::Fixed(FLEETN_WORKERS), ..cfg.thor_cfg() };
        let spec = FleetSpec::mixed(&FLEETN_DEVICES.map(|d| (d, FLEETN_WORKERS)));

        // Both leaders bind up front so the chaos script can name its
        // phases; leader B's listen backlog queues worker connections
        // until it actually serves.
        let bound_a = FleetServer::new(thor_cfg).bind("127.0.0.1:0").expect("bind leader A");
        let bound_b = FleetServer::new(thor_cfg).bind("127.0.0.1:0").expect("bind leader B");
        let addr_a = bound_a.local_addr().to_string();
        let addr_b = bound_b.local_addr().to_string();

        let ckpt_path = std::env::temp_dir()
            .join(format!("thor_fleete_{}_{}.json", std::process::id(), cfg.seed));
        let _ = std::fs::remove_file(&ckpt_path);

        // The chaos script, per class: worker 0 is steady and follows
        // the leaders; worker 1 dies with its third job in flight
        // (re-queue path), rejoins leader A as a fresh connection id,
        // then follows to leader B.  Phases whose leader is already
        // gone are skipped by `run_phases` — the script never assumes
        // its leaders outlive it.
        let mut handles = Vec::new();
        for (di, dev_name) in FLEETN_DEVICES.iter().enumerate() {
            for w in 0..FLEETN_WORKERS {
                let reference = reference.clone();
                let profile = devices::by_name(dev_name).expect("device");
                let dev_seed = 100 + (di * FLEETN_WORKERS + w) as u64;
                let phases: Vec<(String, Option<usize>)> = if w == 0 {
                    vec![(addr_a.clone(), None), (addr_b.clone(), None)]
                } else {
                    vec![
                        (addr_a.clone(), Some(DIE_AFTER_JOBS)),
                        (addr_a.clone(), None),
                        (addr_b.clone(), None),
                    ]
                };
                let base_seed = cfg.seed;
                handles.push(std::thread::spawn(move || {
                    DeviceWorker::new(Device::new(profile, dev_seed), &reference)
                        .with_class_seed(base_seed)
                        .run_phases(&phases)
                }));
            }
        }

        // Phase A: checkpoint after every absorbed joint batch, then die
        // at a deterministic batch boundary.
        let mut ck_writer = Checkpointer::new(&ckpt_path, 1);
        let leader_a_died = bound_a
            .serve_spec_with(
                &reference,
                spec.clone(),
                ServeOptions {
                    resume: None,
                    checkpointer: Some(&mut ck_writer),
                    abort_after_rounds: Some(ABORT_AFTER_ROUNDS),
                },
            )
            .is_err();

        // Phase B: a successor leader resumes from leader A's last
        // checkpoint — completed families load, in-flight machines
        // replay, only the one unabsorbed batch is re-measured.
        let ck = Checkpoint::load(&ckpt_path)
            .expect("read checkpoint")
            .expect("leader A checkpointed before dying");
        let families_checkpointed = ck.store.len();
        let inflight_resumed = ck.inflight.len();
        let run = bound_b
            .serve_spec_with(
                &reference,
                spec,
                ServeOptions { resume: Some(ck), ..Default::default() },
            )
            .expect("resumed fleet serve");
        for h in handles {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&ckpt_path);

        // The correctness contract: the chaos run's final store is
        // byte-identical to an uninterrupted in-process per-job run of
        // the same config — deaths, rejoins and the leader handover left
        // no trace in the fitted GPs.
        let mut solo = Thor::new(thor_cfg);
        let mut local = LocalMeasurer::per_job_fleet(
            FLEETN_DEVICES.iter().map(|d| devices::by_name(d).expect("device")).collect(),
            cfg.seed,
            &reference,
        );
        solo.profile(&mut local, &reference).expect("uninterrupted local run");
        let byte_equal = run.store.to_json().to_string() == solo.store.to_json().to_string();

        let jobs_of = |c: &str| {
            run.per_class.iter().find(|(cc, _)| cc == c).map_or(0, |(_, n)| *n)
        };
        let mapes: Vec<(&str, f64)> = FLEETN_DEVICES
            .iter()
            .map(|&d| (d, fleet_mape(&run.store, d, cfg)))
            .collect();
        rep.push_table(
            "per-device results of the resumed leader (phase-B jobs only)",
            &["device", "families", "phase-B jobs", "MAPE %"],
            mapes
                .iter()
                .map(|(d, m)| {
                    vec![
                        d.to_string(),
                        format!("{}", run.store.len_for(d)),
                        format!("{}", jobs_of(d)),
                        format!("{m:.1}"),
                    ]
                })
                .collect(),
        );
        for (d, m) in &mapes {
            rep.metric(&format!("mape_{d}"), *m);
            rep.metric(&format!("jobs_{d}"), jobs_of(d) as f64);
        }
        rep.metric("leader_a_died", if leader_a_died { 1.0 } else { 0.0 });
        rep.metric("checkpoint_writes", ck_writer.writes as f64);
        rep.metric("families_checkpointed", families_checkpointed as f64);
        rep.metric("inflight_resumed", inflight_resumed as f64);
        rep.metric("families_fitted", run.store.len() as f64);
        rep.metric("jobs_resumed_submitted", run.jobs_submitted as f64);
        rep.metric("jobs_resumed_done", run.jobs_done as f64);
        rep.metric("jobs_requeued_resumed", run.requeued as f64);
        rep.metric("deaths_scheduled", FLEETN_DEVICES.len() as f64);
        rep.metric("rejoins_scheduled", FLEETN_DEVICES.len() as f64);
        rep.metric("store_byte_equal", if byte_equal { 1.0 } else { 0.0 });
        rep.metric("devices", FLEETN_DEVICES.len() as f64);
        rep.note(format!(
            "leader A absorbed {ABORT_AFTER_ROUNDS} joint batches ({} checkpoint writes, \
             {families_checkpointed} families done, {inflight_resumed} in flight) and was killed; \
             leader B resumed and finished {} families from {} phase-B jobs; \
             resumed store byte-equal to an uninterrupted run: {byte_equal}",
            ck_writer.writes,
            run.store.len(),
            run.jobs_done,
        ));
        rep.note(format!(
            "chaos schedule: {} worker deaths ({DIE_AFTER_JOBS} jobs each, third left in flight) \
             and {} rejoins across {} classes; phase-A job splits are timing-dependent and \
             deliberately unreported",
            FLEETN_DEVICES.len(),
            FLEETN_DEVICES.len(),
            FLEETN_DEVICES.len(),
        ));
        rep
    }
}

/// fleetS: worker 1 of each class hangs — connected, reading, never
/// answering — upon receiving its this-plus-one-th job.
const STALL_AFTER_JOBS: usize = 2;

/// fleetS: the per-job straggler deadline.  Far above any healthy
/// simulated job (milliseconds) so only the scripted hangs can expire
/// it, far below "stuck forever" so the chaos run stays quick.
const STALL_DEADLINE_MS: u64 = 750;

pub struct FleetS;

impl Experiment for FleetS {
    fn id(&self) -> &'static str {
        "fleetS"
    }

    fn description(&self) -> &'static str {
        "straggler-fleet chaos: one worker per class hangs without disconnecting; deadlines + speculative re-issue finish the run byte-identically"
    }

    fn run(&self, cfg: &ExpConfig) -> ExpReport {
        let mut rep = ExpReport::new(
            self.id(),
            "straggler fleet chaos (job deadlines + speculative re-issue)",
            cfg,
            &FLEETN_DEVICES,
        );
        let reference = fleet_reference();
        // Fixed batches for the same reason fleetE uses them: straggler
        // timing must never reach the proposal stream, so every fitted
        // value is a pure function of the config.  Speculation itself is
        // byte-neutral — duplicate completions of one job carry
        // identical per-job-seeded measurements.
        let thor_cfg = ThorConfig { batch: Batch::Fixed(FLEETN_WORKERS), ..cfg.thor_cfg() };
        let spec = FleetSpec::mixed(&FLEETN_DEVICES.map(|d| (d, FLEETN_WORKERS)))
            .with_deadline(std::time::Duration::from_millis(STALL_DEADLINE_MS));

        let bound = FleetServer::new(thor_cfg).bind("127.0.0.1:0").expect("bind leader");
        let addr = bound.local_addr().to_string();

        // Worker 0 of each class is healthy; worker 1 hangs with its
        // third job held.  A hung worker stays connected (no
        // Disconnected event, no requeue) — only the deadline machinery
        // can get its job back.
        let mut handles = Vec::new();
        for (di, dev_name) in FLEETN_DEVICES.iter().enumerate() {
            for w in 0..FLEETN_WORKERS {
                let reference = reference.clone();
                let addr = addr.clone();
                let profile = devices::by_name(dev_name).expect("device");
                let dev_seed = 100 + (di * FLEETN_WORKERS + w) as u64;
                let base_seed = cfg.seed;
                handles.push(std::thread::spawn(move || {
                    let mut worker = DeviceWorker::new(Device::new(profile, dev_seed), &reference)
                        .with_class_seed(base_seed);
                    if w == 1 {
                        worker = worker.with_faults(FaultPlan::hang_after(STALL_AFTER_JOBS));
                    }
                    worker.run(&addr)
                }));
            }
        }

        let run = bound.serve_spec(&reference, spec).expect("straggler fleet serve");
        for h in handles {
            let _ = h.join();
        }

        // The correctness contract, straggler edition: hangs, expired
        // deadlines and speculative duplicates left no trace — the
        // store is byte-identical to an uninterrupted in-process
        // per-job run of the same config.
        let mut solo = Thor::new(thor_cfg);
        let mut local = LocalMeasurer::per_job_fleet(
            FLEETN_DEVICES.iter().map(|d| devices::by_name(d).expect("device")).collect(),
            cfg.seed,
            &reference,
        );
        solo.profile(&mut local, &reference).expect("uninterrupted local run");
        let byte_equal = run.store.to_json().to_string() == solo.store.to_json().to_string();

        let jobs_of = |c: &str| {
            run.per_class.iter().find(|(cc, _)| cc == c).map_or(0, |(_, n)| *n)
        };
        let mapes: Vec<(&str, f64)> = FLEETN_DEVICES
            .iter()
            .map(|&d| (d, fleet_mape(&run.store, d, cfg)))
            .collect();
        rep.push_table(
            "per-device results under one hung worker per class",
            &["device", "families", "jobs done", "MAPE %"],
            mapes
                .iter()
                .map(|(d, m)| {
                    vec![
                        d.to_string(),
                        format!("{}", run.store.len_for(d)),
                        format!("{}", jobs_of(d)),
                        format!("{m:.1}"),
                    ]
                })
                .collect(),
        );
        for (d, m) in &mapes {
            rep.metric(&format!("mape_{d}"), *m);
            rep.metric(&format!("jobs_{d}"), jobs_of(d) as f64);
        }
        rep.metric("stalls_scheduled", FLEETN_DEVICES.len() as f64);
        // Exact speculation counts are timing-dependent (a loaded host
        // can trip extra deadlines harmlessly); the invariant is that
        // every scripted hang forced at least one re-issue.
        rep.metric(
            "speculation_per_stall_met",
            if run.speculated >= FLEETN_DEVICES.len() { 1.0 } else { 0.0 },
        );
        rep.metric("jobs_submitted", run.jobs_submitted as f64);
        rep.metric("jobs_done", run.jobs_done as f64);
        rep.metric("jobs_requeued", run.requeued as f64);
        rep.metric("families_fitted", run.store.len() as f64);
        rep.metric("store_byte_equal", if byte_equal { 1.0 } else { 0.0 });
        rep.metric("devices", FLEETN_DEVICES.len() as f64);
        rep.note(format!(
            "{} workers hung silently (job {} held in flight); the {STALL_DEADLINE_MS}ms \
             job deadline re-issued each held job to the healthy same-class peer; \
             {} jobs resolved exactly once; \
             store byte-equal to an uninterrupted run: {byte_equal}",
            FLEETN_DEVICES.len(),
            STALL_AFTER_JOBS + 1,
            run.jobs_done,
        ));
        rep.note(
            "per-worker job splits and exact speculation counts are timing-dependent and \
             deliberately unreported"
                .to_string(),
        );
        rep
    }
}
