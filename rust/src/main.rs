//! `thor` — CLI for the THOR energy-estimation framework.
//!
//! Subcommands:
//!   profile   profile a model family on a simulated device, save the GP store
//!   estimate  estimate a model's training energy from a saved store
//!   exp       run registered paper experiments: `thor exp <id>` or
//!             `thor exp --all` (multi-threaded), `--json out.json` for the
//!             structured report, `--list` for the registry
//!   serve     run the fleet fitting leader (TCP); `--checkpoint` +
//!             `--resume` make it crash-tolerant (resume from acquired
//!             points instead of re-measuring)
//!   worker    run a device worker against a leader
//!   serve-estimates
//!             run the estimation-serving daemon: load fitted store
//!             artifacts and answer est/est_batch queries over TCP
//!   devices   list the simulated device fleet

use anyhow::{anyhow, Result};

use thor::coordinator::{DeviceWorker, FleetServer, FleetSpec};
use thor::exp::{self, Experiment};
use thor::gp::GpBackend;
use thor::model::sampler::Family;
use thor::simdevice::{devices, Device};
use thor::thor::{Batch, Thor, ThorConfig};
use thor::util::cli::{parse, Spec};

fn specs() -> Vec<Spec> {
    vec![
        Spec { name: "device", takes_value: true, help: "device name (oppo|iphone|xavier|tx2|server)" },
        Spec { name: "model", takes_value: true, help: "model family (lenet5|cnn5|...); estimate also takes spec strings like cnn5:8,16,32,64" },
        Spec { name: "store", takes_value: true, help: "GP store JSON path (default thor_store.json); serve-estimates: comma-separated list, merged left-to-right" },
        Spec { name: "seed", takes_value: true, help: "rng seed (default 2025)" },
        Spec { name: "quick", takes_value: false, help: "reduced sample counts" },
        Spec { name: "iterations", takes_value: true, help: "profiling iterations per measurement (default 500)" },
        Spec { name: "batch", takes_value: true, help: "acquisition batch per GP round: integer or 'auto' (live same-class worker count; profile default 1, serve default auto)" },
        Spec { name: "gp", takes_value: true, help: "profile/serve: GP fit backend — exact | auto | sparse:<m> | auto:<m>:<n> (default auto: exact below the crossover threshold)" },
        Spec { name: "addr", takes_value: true, help: "serve/worker: leader address (default 127.0.0.1:7707); serve-estimates: bind address (default 127.0.0.1:7708)" },
        Spec { name: "workers", takes_value: true, help: "expected worker count for serve (default 1; per class with --devices)" },
        Spec { name: "devices", takes_value: true, help: "serve: comma-separated device classes of a heterogeneous fleet (e.g. xavier,tx2,server)" },
        Spec { name: "checkpoint", takes_value: true, help: "serve: write an atomic leader checkpoint to this path as the run progresses" },
        Spec { name: "checkpoint-every", takes_value: true, help: "serve: absorbed acquisition rounds between checkpoint writes (default 1)" },
        Spec { name: "checkpoint-keep", takes_value: true, help: "serve: rotate the previous N checkpoints to <path>.1..<path>.N (default 0 = overwrite)" },
        Spec { name: "resume", takes_value: true, help: "serve: resume from a leader checkpoint instead of re-measuring (missing file = cold start)" },
        Spec { name: "job-deadline", takes_value: true, help: "serve: per-job straggler deadline in milliseconds; expired jobs are speculatively re-issued to a healthy same-class worker (default: off)" },
        Spec { name: "cache-cap", takes_value: true, help: "serve-estimates: bound the shared estimate cache to ~N entries, LRU-evicted (default 0 = unbounded)" },
        Spec { name: "io-model", takes_value: true, help: "serve-estimates: serving core — reactor (readiness-driven event loop + compute pool, default) or threads (thread-per-connection, kept for one release)" },
        Spec { name: "coalesce-max", takes_value: true, help: "serve-estimates: max pending requests a reactor compute worker drains into one coalesced GP solve (default 32; 1 disables coalescing)" },
        Spec { name: "all", takes_value: false, help: "exp: run every registered experiment" },
        Spec { name: "list", takes_value: false, help: "exp: list registered experiment ids" },
        Spec { name: "json", takes_value: true, help: "exp: write structured suite report to this path" },
        Spec { name: "threads", takes_value: true, help: "exp/serve-estimates: worker threads (default: all cores, min 2)" },
        Spec { name: "help", takes_value: false, help: "print usage" },
    ]
}

fn family_by_name(name: &str) -> Result<Family> {
    Family::by_name(name).ok_or_else(|| anyhow!("unknown model family '{name}'"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = parse(&argv, &specs()).map_err(|e| anyhow!("{e}\n{}", thor::util::cli::usage("thor", &specs())))?;
    if args.has("help") || args.positional().is_empty() {
        println!(
            "{}",
            thor::util::cli::usage(
                "thor <profile|estimate|exp|serve|worker|serve-estimates|devices>",
                &specs()
            )
        );
        return Ok(());
    }
    let cmd = args.positional()[0].as_str();
    let seed = args.get_usize("seed", 2025)? as u64;
    let store_path = std::path::PathBuf::from(args.get_str("store", "thor_store.json"));

    match cmd {
        "devices" => {
            for d in devices::all() {
                println!(
                    "{:8}  slots={:6}  peak={:.2e} FLOP/s  idle={:5.1} W  governor={:?}  meter={} ms",
                    d.name, d.slots, d.peak_flops, d.idle_power_w, d.governor, d.meter.interval_s * 1e3
                );
            }
        }
        "profile" => {
            let dev_name = args.get_str("device", "xavier");
            let fam = family_by_name(args.get_str("model", "cnn5"))?;
            let profile = devices::by_name(dev_name).ok_or_else(|| anyhow!("unknown device {dev_name}"))?;
            let mut dev = Device::new(profile, seed);
            let mut cfg = if args.has("quick") { ThorConfig::quick() } else { ThorConfig::default() };
            cfg.iterations = args.get_usize("iterations", cfg.iterations)?;
            cfg.batch = Batch::parse(args.get_str("batch", "1")).map_err(|e| anyhow!(e))?;
            cfg.gp_backend = GpBackend::parse(args.get_str("gp", "auto")).map_err(|e| anyhow!(e))?;
            let mut thor = Thor::new(cfg);
            if store_path.exists() {
                if let Ok(Some(s)) = thor::thor::store::GpStore::load(&store_path) {
                    thor.store = s;
                }
            }
            let report = thor.profile_local(&mut dev, &exp::reference_model(fam));
            for f in &report.families {
                println!(
                    "fitted {:45} points={:3} device={:8.1}s fit={:6.2}s converged={}",
                    f.family, f.points, f.device_seconds, f.fit_seconds, f.converged
                );
            }
            thor.store.save(&store_path)?;
            println!("saved {} family GPs to {store_path:?}", thor.store.len());
        }
        "estimate" => {
            let dev_name = args.get_str("device", "xavier");
            let store = thor::thor::store::GpStore::load(&store_path)?
                .ok_or_else(|| anyhow!("cannot parse {store_path:?}"))?;
            // Full spec grammar (`cnn5:8,16,32,64:16`), not just family
            // names — the same strings the serving daemon accepts.
            let g = thor::model::spec::parse_spec(args.get_str("model", "cnn5"))?;
            let est = thor::thor::estimator::estimate(&store, dev_name, &g)?;
            println!("model {}  on {dev_name}:", g.name);
            for (fam_id, feats, e) in &est.per_layer {
                println!("  {:45} {:?} -> {:.4e} J/iter", fam_id, feats, e);
            }
            println!("total: {:.4e} J/iter ({:.1} J per 1000 iterations)", est.energy_per_iter, est.total(1000));
        }
        "exp" => {
            if args.has("list") {
                for e in exp::registry::registry() {
                    println!("{:6}  {}", e.id(), e.description());
                }
                println!("tab1    (alias for fig8)");
                return Ok(());
            }
            let which = args.positional().get(1).map(|s| s.as_str());
            let exps: Vec<Box<dyn Experiment>> = if args.has("all") || which == Some("all") {
                exp::registry::registry()
            } else {
                let id = which.unwrap_or("fig8");
                vec![exp::by_id(id).ok_or_else(|| {
                    anyhow!("unknown experiment '{id}' — `thor exp --list` shows the registry")
                })?]
            };
            let runner = exp::Runner::from_arg(args.get_usize("threads", 0)?);
            let n_exps = exps.len();
            let quick = args.has("quick");
            let suite = runner.run(exps, quick, seed);
            print!("{}", suite.render());
            let n_failed = suite.eprint_failures();
            if let Some(path) = args.get("json") {
                std::fs::write(path, suite.to_json().to_string())?;
                eprintln!("wrote {n_exps} experiment report(s) to {path}");
            }
            eprintln!(
                "ran {n_exps} experiment(s) on {} thread(s) in {:.1}s (seed {seed}, quick={quick})",
                suite.threads_used, suite.wall_seconds
            );
            if n_failed > 0 {
                return Err(anyhow!("{n_failed} experiment(s) failed"));
            }
        }
        "serve" => {
            let addr = args.get_str("addr", "127.0.0.1:7707");
            let fam = family_by_name(args.get_str("model", "cnn5"))?;
            let workers = args.get_usize("workers", 1)?.max(1);
            let mut cfg = if args.has("quick") { ThorConfig::quick() } else { ThorConfig::default() };
            cfg.iterations = args.get_usize("iterations", cfg.iterations)?;
            // default the acquisition batch to the live same-class
            // worker count so every worker has a job each GP round
            cfg.batch = Batch::parse(args.get_str("batch", "auto")).map_err(|e| anyhow!(e))?;
            cfg.gp_backend = GpBackend::parse(args.get_str("gp", "auto")).map_err(|e| anyhow!(e))?;
            let server = FleetServer::new(cfg);
            let reference = exp::reference_model(fam);
            let spec = match args.get("devices") {
                Some(list) => {
                    // Heterogeneous single-leader fleet: one serve, one
                    // multi-device store, `workers` workers per class.
                    let classes: Vec<(&str, usize)> = list
                        .split(',')
                        .map(str::trim)
                        .filter(|c| !c.is_empty())
                        .map(|c| {
                            devices::by_name(c)
                                .map(|_| (c, workers))
                                .ok_or_else(|| anyhow!("unknown device class '{c}'"))
                        })
                        .collect::<Result<_>>()?;
                    if classes.is_empty() {
                        return Err(anyhow!("--devices given but no class named"));
                    }
                    println!(
                        "fitting leader on {addr} (model {}, heterogeneous fleet: {} workers per class over {})",
                        fam.name(),
                        workers,
                        classes.iter().map(|(c, _)| *c).collect::<Vec<_>>().join(",")
                    );
                    FleetSpec::mixed(&classes)
                }
                None => {
                    println!(
                        "fitting leader on {addr} (model {} , expecting {workers} workers)",
                        fam.name()
                    );
                    FleetSpec::untyped(workers)
                }
            };
            let spec = match args.get_usize("job-deadline", 0)? {
                0 => spec,
                ms => spec.with_deadline(std::time::Duration::from_millis(ms as u64)),
            };
            // Elasticity: crash-loop operation passes the same path to
            // --checkpoint and --resume; a missing resume file is a
            // cold start, so the very first launch needs no special
            // casing (a *corrupt* file is still a hard error).
            let resume = match args.get("resume") {
                Some(p) => {
                    let path = std::path::Path::new(p);
                    match thor::thor::checkpoint::Checkpoint::load(path)? {
                        Some(ck) => {
                            println!(
                                "resuming from {path:?}: {} finished family GP(s), {} in flight",
                                ck.store.len(),
                                ck.inflight.len()
                            );
                            Some(ck)
                        }
                        None => {
                            println!("checkpoint {path:?} not found — starting cold");
                            None
                        }
                    }
                }
                None => None,
            };
            let every = args.get_usize("checkpoint-every", 1)?;
            let keep = args.get_usize("checkpoint-keep", 0)?;
            let mut writer = args
                .get("checkpoint")
                .map(|p| thor::thor::checkpoint::Checkpointer::new(p, every).with_keep(keep));
            let opts = thor::coordinator::ServeOptions {
                resume,
                checkpointer: writer.as_mut(),
                abort_after_rounds: None,
            };
            let run = server.bind(addr)?.serve_spec_with(&reference, spec, opts)?;
            run.store.save(&store_path)?;
            println!("saved {} family GPs to {store_path:?}", run.store.len());
        }
        "serve-estimates" => {
            let addr = args.get_str("addr", "127.0.0.1:7708");
            let threads = args.get_usize("threads", 0)?;
            // `--store` may name several artifacts (one per fleet run);
            // merge left-to-right, later artifacts winning on key clash.
            let mut store = thor::thor::store::GpStore::default();
            let mut n_artifacts = 0usize;
            for path in args
                .get_str("store", "thor_store.json")
                .split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
            {
                let p = std::path::Path::new(path);
                let s = thor::thor::store::GpStore::load(p)?
                    .ok_or_else(|| anyhow!("cannot parse {p:?}"))?;
                println!("loaded {} family GPs from {p:?}", s.len());
                store.merge(s);
                n_artifacts += 1;
            }
            if n_artifacts == 0 {
                return Err(anyhow!("--store named no artifact"));
            }
            let families = store.len();
            let cache_cap = args.get_usize("cache-cap", 0)?;
            let io_model =
                thor::coordinator::IoModel::parse(args.get_str("io-model", "reactor"))?;
            let coalesce_max = args.get_usize("coalesce-max", 32)?;
            let handle = thor::coordinator::EstimateServer::bind(addr, store)?
                .with_cache_cap(cache_cap)
                .with_io_model(io_model)
                .with_coalesce_max(coalesce_max)
                .start(threads)?;
            println!(
                "serving estimates on {} ({families} family GPs from {n_artifacts} artifact(s); \
                 io model {io_model:?}, newline-delimited JSON, message types est/est_batch)",
                handle.addr()
            );
            let stats = handle.join();
            println!(
                "estimate daemon exited: {} connections, {} requests, {} errors",
                stats.connections, stats.requests, stats.errors
            );
        }
        "worker" => {
            let addr = args.get_str("addr", "127.0.0.1:7707");
            let dev_name = args.get_str("device", "xavier");
            let fam = family_by_name(args.get_str("model", "cnn5"))?;
            let profile = devices::by_name(dev_name).ok_or_else(|| anyhow!("unknown device {dev_name}"))?;
            let mut worker = DeviceWorker::new(Device::new(profile, seed), &exp::reference_model(fam));
            let done = worker.run(addr)?;
            println!("worker {dev_name} finished {done} jobs");
        }
        other => return Err(anyhow!("unknown command '{other}'")),
    }
    Ok(())
}
