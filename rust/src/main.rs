//! `thor` — CLI for the THOR energy-estimation framework.
//!
//! Subcommands:
//!   profile   profile a model family on a simulated device, save the GP store
//!   estimate  estimate a model's training energy from a saved store
//!   exp       regenerate a paper table/figure (fig2..fig13, tab1, a14..a16)
//!   serve     run the fleet fitting leader (TCP)
//!   worker    run a device worker against a leader
//!   devices   list the simulated device fleet

use anyhow::{anyhow, Result};

use thor::coordinator::{DeviceWorker, FleetServer};
use thor::exp::{self, ExpConfig};
use thor::model::sampler::Family;
use thor::simdevice::{devices, Device};
use thor::thor::{Thor, ThorConfig};
use thor::util::cli::{parse, Spec};

fn specs() -> Vec<Spec> {
    vec![
        Spec { name: "device", takes_value: true, help: "device name (oppo|iphone|xavier|tx2|server)" },
        Spec { name: "model", takes_value: true, help: "model family (lenet5|cnn5|har|lstm|transformer|resnet20|...)" },
        Spec { name: "store", takes_value: true, help: "GP store JSON path (default thor_store.json)" },
        Spec { name: "seed", takes_value: true, help: "rng seed (default 2025)" },
        Spec { name: "quick", takes_value: false, help: "reduced sample counts" },
        Spec { name: "iterations", takes_value: true, help: "profiling iterations per measurement (default 500)" },
        Spec { name: "addr", takes_value: true, help: "leader address (default 127.0.0.1:7707)" },
        Spec { name: "workers", takes_value: true, help: "expected worker count for serve (default 1)" },
        Spec { name: "help", takes_value: false, help: "print usage" },
    ]
}

fn family_by_name(name: &str) -> Result<Family> {
    Ok(match name {
        "lenet5" => Family::LeNet5,
        "cnn5" => Family::Cnn5,
        "har" => Family::Har,
        "lstm" => Family::Lstm,
        "transformer" => Family::Transformer,
        "resnet20" => Family::ResNet20,
        "resnet56" => Family::ResNet56,
        "resnet110" => Family::ResNet110,
        other => return Err(anyhow!("unknown model family '{other}'")),
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = parse(&argv, &specs()).map_err(|e| anyhow!("{e}\n{}", thor::util::cli::usage("thor", &specs())))?;
    if args.has("help") || args.positional().is_empty() {
        println!("{}", thor::util::cli::usage("thor <profile|estimate|exp|serve|worker|devices>", &specs()));
        return Ok(());
    }
    let cmd = args.positional()[0].as_str();
    let seed = args.get_usize("seed", 2025)? as u64;
    let store_path = std::path::PathBuf::from(args.get_str("store", "thor_store.json"));

    match cmd {
        "devices" => {
            for d in devices::all() {
                println!(
                    "{:8}  slots={:6}  peak={:.2e} FLOP/s  idle={:5.1} W  governor={:?}  meter={} ms",
                    d.name, d.slots, d.peak_flops, d.idle_power_w, d.governor, d.meter.interval_s * 1e3
                );
            }
        }
        "profile" => {
            let dev_name = args.get_str("device", "xavier");
            let fam = family_by_name(args.get_str("model", "cnn5"))?;
            let profile = devices::by_name(dev_name).ok_or_else(|| anyhow!("unknown device {dev_name}"))?;
            let mut dev = Device::new(profile, seed);
            let mut cfg = if args.has("quick") { ThorConfig::quick() } else { ThorConfig::default() };
            cfg.iterations = args.get_usize("iterations", cfg.iterations)?;
            let mut thor = Thor::new(cfg);
            if store_path.exists() {
                if let Ok(Some(s)) = thor::thor::store::GpStore::load(&store_path) {
                    thor.store = s;
                }
            }
            let report = thor.profile(&mut dev, &exp::reference_model(fam));
            for f in &report.families {
                println!(
                    "fitted {:45} points={:3} device={:8.1}s fit={:6.2}s converged={}",
                    f.family, f.points, f.device_seconds, f.fit_seconds, f.converged
                );
            }
            thor.store.save(&store_path)?;
            println!("saved {} family GPs to {store_path:?}", thor.store.len());
        }
        "estimate" => {
            let dev_name = args.get_str("device", "xavier");
            let fam = family_by_name(args.get_str("model", "cnn5"))?;
            let store = thor::thor::store::GpStore::load(&store_path)?
                .ok_or_else(|| anyhow!("cannot parse {store_path:?}"))?;
            let g = exp::reference_model(fam);
            let est = thor::thor::estimator::estimate(&store, dev_name, &g)?;
            println!("model {}  on {dev_name}:", g.name);
            for (fam_id, feats, e) in &est.per_layer {
                println!("  {:45} {:?} -> {:.4e} J/iter", fam_id, feats, e);
            }
            println!("total: {:.4e} J/iter ({:.1} J per 1000 iterations)", est.energy_per_iter, est.total(1000));
        }
        "exp" => {
            let which = args.positional().get(1).map(|s| s.as_str()).unwrap_or("fig8");
            let cfg = ExpConfig::new(args.has("quick"), seed);
            let out = match which {
                "fig2" => exp::fig2::run(&cfg),
                "fig4" => exp::fig4::run(&cfg),
                "fig5" => exp::fig5::run(&cfg),
                "fig6" => exp::fig6::run(&cfg),
                "fig7" => exp::fig7::run(&cfg),
                "fig8" => {
                    let (a, b) = exp::fig8::run(&cfg);
                    format!("{a}\n# Table 1 — profiling + fitting cost\n{b}")
                }
                "tab1" => exp::fig8::run(&cfg).1,
                "fig9" => exp::fig9::run(&cfg),
                "fig10" => exp::fig10::run(&cfg),
                "fig11" => exp::fig11::run(&cfg),
                "fig12" => exp::fig12::run(&cfg),
                "a14" => exp::a14::run(&cfg),
                "a15" => exp::a15::run(&cfg),
                "a16" => exp::a16::run(&cfg),
                other => return Err(anyhow!("unknown experiment '{other}' (fig13 lives in examples/energy_aware_pruning)")),
            };
            println!("{out}");
        }
        "serve" => {
            let addr = args.get_str("addr", "127.0.0.1:7707");
            let fam = family_by_name(args.get_str("model", "cnn5"))?;
            let workers = args.get_usize("workers", 1)?;
            let mut cfg = if args.has("quick") { ThorConfig::quick() } else { ThorConfig::default() };
            cfg.iterations = args.get_usize("iterations", cfg.iterations)?;
            let server = FleetServer::new(cfg);
            println!("fitting leader on {addr} (model {} , expecting {workers} workers)", fam.name());
            let store = server.run(addr, &exp::reference_model(fam), workers)?;
            store.save(&store_path)?;
            println!("saved {} family GPs to {store_path:?}", store.len());
        }
        "worker" => {
            let addr = args.get_str("addr", "127.0.0.1:7707");
            let dev_name = args.get_str("device", "xavier");
            let fam = family_by_name(args.get_str("model", "cnn5"))?;
            let profile = devices::by_name(dev_name).ok_or_else(|| anyhow!("unknown device {dev_name}"))?;
            let mut worker = DeviceWorker::new(Device::new(profile, seed), &exp::reference_model(fam));
            let done = worker.run(addr)?;
            println!("worker {dev_name} finished {done} jobs");
        }
        other => return Err(anyhow!("unknown command '{other}'")),
    }
    Ok(())
}
