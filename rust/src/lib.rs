//! THOR: a generic energy-estimation framework for on-device DNN training.
//!
//! Reproduction of "THOR: A Generic Energy Estimation Approach for On-Device
//! Training" (Zhang et al., 2025) as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: profiling orchestration,
//!   Gaussian-process fitting with active learning, layer parsing, the
//!   estimator, the device-fleet leader/worker protocol, baselines, and the
//!   device-energy simulator substrate that stands in for the paper's five
//!   physical devices.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs (GP batch
//!   posterior, CNN train step) AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (Matérn-5/2
//!   cross-covariance, fused GP posterior, tiled matmul with custom VJP)
//!   called from layer 2.
//!
//! Python never runs on the estimation path: artifacts are compiled once by
//! `make artifacts` and executed from [`runtime`] through PJRT.
//!
//! Start at [`thor::Thor`] for the estimation pipeline, [`simdevice`] for
//! the device substrate, and [`exp`] for the paper's tables and figures.

pub mod baselines;
pub mod coordinator;
pub mod exp;
pub mod gp;
pub mod model;
pub mod pruning;
pub mod runtime;
pub mod simdevice;
pub mod thor;
pub mod trainer;
pub mod util;
pub mod workload;
