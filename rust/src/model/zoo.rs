//! The paper's model families (Appendix A5.1), parameterized by channel
//! widths so the architecture sampler can draw random variants.
//!
//! * LeNet-5 (MNIST/FEMNIST shapes)
//! * 5-layer CNN: four Conv-BN-MaxPool blocks + FC
//! * HAR CNN (MotionSense shapes: 9-channel inertial windows)
//! * LSTM: embedding + 2 stacked LSTMs with dropout + FC
//! * Transformer encoder (sampled over #layers and d_model)
//! * ResNet-20/56/110 (CIFAR-style, modular residual stages)

use super::{LayerKind, LayerSpec, ModelGraph};

fn conv(kernel: usize, c_in: usize, c_out: usize, h: usize, w: usize, batch: usize, padded: bool) -> LayerSpec {
    LayerSpec { kind: LayerKind::Conv2d { kernel, stride: 1, padded }, c_in, c_out, h, w, batch }
}

fn np_layer(kind: LayerKind, c: usize, h: usize, w: usize, batch: usize) -> LayerSpec {
    LayerSpec { kind, c_in: c, c_out: c, h, w, batch }
}

fn fc(c_in: usize, c_out: usize, batch: usize) -> LayerSpec {
    LayerSpec { kind: LayerKind::Fc, c_in, c_out, h: 1, w: 1, batch }
}

/// LeNet-5: conv5(c0) pool conv5(c1) pool fc(f0) fc(f1) fc(classes).
/// Default channels (6, 16, 120, 84), input 28x28x1.
pub fn lenet5(ch: &[usize; 4], batch: usize) -> ModelGraph {
    let (c0, c1, f0, f1) = (ch[0], ch[1], ch[2], ch[3]);
    let mut layers = Vec::new();
    layers.push(conv(5, 1, c0, 28, 28, batch, false)); // -> 24
    layers.push(np_layer(LayerKind::Relu, c0, 24, 24, batch));
    layers.push(np_layer(LayerKind::MaxPool { size: 2 }, c0, 24, 24, batch)); // -> 12
    layers.push(conv(5, c0, c1, 12, 12, batch, false)); // -> 8
    layers.push(np_layer(LayerKind::Relu, c1, 8, 8, batch));
    layers.push(np_layer(LayerKind::MaxPool { size: 2 }, c1, 8, 8, batch)); // -> 4
    layers.push(fc(c1 * 16, f0, batch));
    layers.push(np_layer(LayerKind::Relu, f0, 1, 1, batch));
    layers.push(fc(f0, f1, batch));
    layers.push(np_layer(LayerKind::Relu, f1, 1, 1, batch));
    layers.push(fc(f1, 10, batch));
    layers.push(np_layer(LayerKind::Softmax, 10, 1, 1, batch));
    ModelGraph::new("lenet5", layers)
}

/// The paper's 5-layer CNN: four Conv3x3-BN-MaxPool blocks + FC.
/// Default channels (32, 64, 128, 256), input `img`x`img`x3.
pub fn cnn5(ch: &[usize; 4], img: usize, batch: usize) -> ModelGraph {
    let mut layers = Vec::new();
    let mut c_prev = 3;
    let mut hw = img;
    for &c in ch {
        layers.push(conv(3, c_prev, c, hw, hw, batch, true));
        layers.push(np_layer(LayerKind::BatchNorm, c, hw, hw, batch));
        layers.push(np_layer(LayerKind::Relu, c, hw, hw, batch));
        layers.push(np_layer(LayerKind::MaxPool { size: 2 }, c, hw, hw, batch));
        hw = (hw / 2).max(1);
        c_prev = c;
    }
    layers.push(fc(c_prev * hw * hw, 10, batch));
    layers.push(np_layer(LayerKind::Softmax, 10, 1, 1, batch));
    ModelGraph::new("cnn5", layers)
}

/// HAR CNN over MotionSense-like windows: input (batch, 9, 128, 1);
/// two temporal conv blocks + two FC layers.
pub fn har(ch: &[usize; 3], batch: usize) -> ModelGraph {
    let (c0, c1, f0) = (ch[0], ch[1], ch[2]);
    let mut layers = Vec::new();
    layers.push(conv(3, 9, c0, 128, 1, batch, true));
    layers.push(np_layer(LayerKind::Relu, c0, 128, 1, batch));
    layers.push(np_layer(LayerKind::MaxPool { size: 2 }, c0, 128, 1, batch)); // 64x1... pool w=1 floor
    layers.push(conv(3, c0, c1, 64, 1, batch, true));
    layers.push(np_layer(LayerKind::Relu, c1, 64, 1, batch));
    layers.push(np_layer(LayerKind::MaxPool { size: 2 }, c1, 64, 1, batch));
    layers.push(fc(c1 * 32, f0, batch));
    layers.push(np_layer(LayerKind::Relu, f0, 1, 1, batch));
    layers.push(fc(f0, 6, batch)); // 6 activity classes
    layers.push(np_layer(LayerKind::Softmax, 6, 1, 1, batch));
    ModelGraph::new("har", layers)
}

/// LSTM language model: embedding + LSTM(u0) + dropout + LSTM(u1) + FC(vocab).
pub fn lstm(embed: usize, units: &[usize; 2], vocab: usize, seq: usize, batch: usize) -> ModelGraph {
    let (u0, u1) = (units[0], units[1]);
    let layers = vec![
        LayerSpec { kind: LayerKind::Embedding, c_in: vocab, c_out: embed, h: seq, w: 1, batch },
        LayerSpec { kind: LayerKind::Lstm, c_in: embed, c_out: u0, h: seq, w: 1, batch },
        np_layer(LayerKind::Dropout, u0, seq, 1, batch),
        LayerSpec { kind: LayerKind::Lstm, c_in: u0, c_out: u1, h: seq, w: 1, batch },
        np_layer(LayerKind::Dropout, u1, seq, 1, batch),
        fc(u1, vocab, batch),
        np_layer(LayerKind::Softmax, vocab, 1, 1, batch),
    ];
    ModelGraph::new("lstm", layers)
}

/// Transformer encoder: embedding + n_layers × (MHA + LN + FFN + LN) + FC.
pub fn transformer(n_layers: usize, d_model: usize, heads: usize, seq: usize, vocab: usize, batch: usize) -> ModelGraph {
    let mut layers = Vec::new();
    layers.push(LayerSpec { kind: LayerKind::Embedding, c_in: vocab, c_out: d_model, h: seq, w: 1, batch });
    for _ in 0..n_layers {
        layers.push(LayerSpec { kind: LayerKind::Attention { heads }, c_in: d_model, c_out: d_model, h: seq, w: 1, batch });
        layers.push(np_layer(LayerKind::ResidualAdd, d_model, seq, 1, batch));
        layers.push(np_layer(LayerKind::LayerNorm, d_model, seq, 1, batch));
        // FFN as two FCs applied per token (batch·seq rows).
        layers.push(LayerSpec { kind: LayerKind::Fc, c_in: d_model, c_out: 4 * d_model, h: 1, w: 1, batch: batch * seq });
        layers.push(np_layer(LayerKind::Relu, 4 * d_model, 1, 1, batch * seq));
        layers.push(LayerSpec { kind: LayerKind::Fc, c_in: 4 * d_model, c_out: d_model, h: 1, w: 1, batch: batch * seq });
        layers.push(np_layer(LayerKind::ResidualAdd, d_model, seq, 1, batch));
        layers.push(np_layer(LayerKind::LayerNorm, d_model, seq, 1, batch));
    }
    layers.push(fc(d_model, vocab, batch));
    layers.push(np_layer(LayerKind::Softmax, vocab, 1, 1, batch));
    ModelGraph::new("transformer", layers)
}

/// CIFAR-style ResNet: depth ∈ {20, 56, 110} ⇒ n = (depth − 2) / 6 blocks
/// per stage, 3 stages with widths (w, 2w, 4w), each block = two 3x3 convs
/// + residual add.
pub fn resnet(depth: usize, width: usize, batch: usize) -> ModelGraph {
    assert!((depth - 2) % 6 == 0, "resnet depth must be 6n+2");
    let n = (depth - 2) / 6;
    let widths = [width, 2 * width, 4 * width];
    let mut layers = Vec::new();
    let mut hw = 32;
    layers.push(conv(3, 3, widths[0], hw, hw, batch, true));
    layers.push(np_layer(LayerKind::BatchNorm, widths[0], hw, hw, batch));
    layers.push(np_layer(LayerKind::Relu, widths[0], hw, hw, batch));
    let mut c_prev = widths[0];
    for (stage, &c) in widths.iter().enumerate() {
        if stage > 0 {
            hw /= 2; // stride-2 downsample at stage entry
        }
        for _ in 0..n {
            layers.push(conv(3, c_prev, c, hw, hw, batch, true));
            layers.push(np_layer(LayerKind::BatchNorm, c, hw, hw, batch));
            layers.push(np_layer(LayerKind::Relu, c, hw, hw, batch));
            layers.push(conv(3, c, c, hw, hw, batch, true));
            layers.push(np_layer(LayerKind::BatchNorm, c, hw, hw, batch));
            layers.push(np_layer(LayerKind::ResidualAdd, c, hw, hw, batch));
            layers.push(np_layer(LayerKind::Relu, c, hw, hw, batch));
            c_prev = c;
        }
    }
    layers.push(fc(c_prev, 10, batch));
    layers.push(np_layer(LayerKind::Softmax, 10, 1, 1, batch));
    ModelGraph::new(&format!("resnet{depth}"), layers)
}

/// Default-width instances of every family (used by tests and quick runs).
pub fn all_default_models() -> Vec<ModelGraph> {
    vec![
        lenet5(&[6, 16, 120, 84], 10),
        cnn5(&[32, 64, 128, 256], 28, 10),
        har(&[32, 64, 128], 10),
        lstm(64, &[128, 128], 2000, 32, 10),
        transformer(2, 128, 4, 32, 2000, 10),
        resnet(20, 16, 10),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_have_consistent_dims() {
        for g in all_default_models() {
            g.check_dims().unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn lenet_param_count_matches_classic() {
        // Classic LeNet-5 has ~61.7k parameters (conv padding variant
        // dependent); ours with (6,16,120,84) on 28x28 valid convs:
        let g = lenet5(&[6, 16, 120, 84], 10);
        let p = g.total_params();
        assert!(p > 40_000 && p < 80_000, "{p}");
    }

    #[test]
    fn resnet_depth_counts() {
        let g20 = resnet(20, 16, 10);
        let g56 = resnet(56, 16, 10);
        let convs20 = g20.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv2d { .. })).count();
        let convs56 = g56.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv2d { .. })).count();
        assert_eq!(convs20, 19); // 1 stem + 18 block convs
        assert_eq!(convs56, 55);
    }

    #[test]
    #[should_panic(expected = "6n+2")]
    fn resnet_rejects_bad_depth() {
        resnet(21, 16, 10);
    }

    #[test]
    fn transformer_scales_with_layers() {
        let t2 = transformer(2, 128, 4, 32, 2000, 10);
        let t4 = transformer(4, 128, 4, 32, 2000, 10);
        assert!(t4.layers.len() > t2.layers.len());
        assert!(t4.total_params() > t2.total_params());
    }

    #[test]
    fn cnn5_has_four_conv_blocks() {
        let g = cnn5(&[32, 64, 128, 256], 28, 10);
        let convs = g.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv2d { .. })).count();
        assert_eq!(convs, 4);
    }
}
