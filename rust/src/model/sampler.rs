//! Random architecture sampling (paper §4.1): "we randomly sample the DNN
//! architectures across channels ranging from 1 to the original channel.
//! For the Transformer model, we randomly sample the number of encoder
//! layers and hidden dimensions."

use super::{zoo, ModelGraph};
use crate::util::rng::Pcg64;

/// The model families evaluated in Fig 8 (plus Transformer/ResNet for
/// Figs 9-10).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    LeNet5,
    Cnn5,
    Har,
    Lstm,
    Transformer,
    ResNet20,
    ResNet56,
    ResNet110,
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::LeNet5 => "lenet5",
            Family::Cnn5 => "cnn5",
            Family::Har => "har",
            Family::Lstm => "lstm",
            Family::Transformer => "transformer",
            Family::ResNet20 => "resnet20",
            Family::ResNet56 => "resnet56",
            Family::ResNet110 => "resnet110",
        }
    }

    /// Inverse of [`Family::name`] (the CLI's and the estimate daemon's
    /// model-spec family token).
    pub fn by_name(name: &str) -> Option<Family> {
        Family::ALL.iter().copied().find(|f| f.name() == name)
    }

    /// Every family, in declaration order.
    pub const ALL: [Family; 8] = [
        Family::LeNet5,
        Family::Cnn5,
        Family::Har,
        Family::Lstm,
        Family::Transformer,
        Family::ResNet20,
        Family::ResNet56,
        Family::ResNet110,
    ];

    pub fn fig8_families() -> [Family; 4] {
        [Family::LeNet5, Family::Cnn5, Family::Har, Family::Lstm]
    }
}

/// Maximum ("original") channel widths per family — random structures are
/// drawn with each channel uniform in [1, original].
pub fn original_widths(f: Family) -> Vec<usize> {
    match f {
        Family::LeNet5 => vec![6, 16, 120, 84],
        Family::Cnn5 => vec![32, 64, 128, 256],
        Family::Har => vec![32, 64, 128],
        Family::Lstm => vec![64, 128, 128],
        Family::Transformer => vec![4, 256], // (#encoder layers, d_model)
        Family::ResNet20 | Family::ResNet56 | Family::ResNet110 => vec![16],
    }
}

/// Draw one random structure from a family.
pub fn sample(f: Family, rng: &mut Pcg64, batch: usize) -> ModelGraph {
    let orig = original_widths(f);
    let draw = |rng: &mut Pcg64, hi: usize| rng.range_usize(1, hi);
    match f {
        Family::LeNet5 => {
            let ch = [draw(rng, orig[0]), draw(rng, orig[1]), draw(rng, orig[2]), draw(rng, orig[3])];
            zoo::lenet5(&ch, batch)
        }
        Family::Cnn5 => {
            let ch = [draw(rng, orig[0]), draw(rng, orig[1]), draw(rng, orig[2]), draw(rng, orig[3])];
            zoo::cnn5(&ch, 28, batch)
        }
        Family::Har => {
            let ch = [draw(rng, orig[0]), draw(rng, orig[1]), draw(rng, orig[2])];
            zoo::har(&ch, batch)
        }
        Family::Lstm => {
            let e = draw(rng, orig[0]);
            let u = [draw(rng, orig[1]), draw(rng, orig[2])];
            zoo::lstm(e, &u, 2000, 32, batch)
        }
        Family::Transformer => {
            let n = rng.range_usize(1, orig[0]);
            // d_model must be divisible by heads; sample multiples of 8.
            let d = 8 * rng.range_usize(2, orig[1] / 8);
            zoo::transformer(n, d, 4, 32, 2000, batch)
        }
        Family::ResNet20 => zoo::resnet(20, rng.range_usize(4, orig[0]), batch),
        Family::ResNet56 => zoo::resnet(56, rng.range_usize(4, orig[0]), batch),
        Family::ResNet110 => zoo::resnet(110, rng.range_usize(4, orig[0]), batch),
    }
}

/// Draw `n` random structures (the paper uses 100 per family, 3 repeats).
pub fn sample_n(f: Family, n: usize, seed: u64, batch: usize) -> Vec<ModelGraph> {
    let mut rng = Pcg64::new(seed);
    (0..n).map(|_| sample(f, &mut rng, batch)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flops::model_train_flops;
    use crate::util::proptest::{check, Config};

    #[test]
    fn samples_are_valid_models() {
        for f in [Family::LeNet5, Family::Cnn5, Family::Har, Family::Lstm, Family::Transformer, Family::ResNet20] {
            for g in sample_n(f, 10, 1, 10) {
                g.check_dims().unwrap_or_else(|e| panic!("{}: {e}", g.name));
                assert!(model_train_flops(&g) > 0.0);
            }
        }
    }

    #[test]
    fn by_name_inverts_name_for_every_family() {
        for f in Family::ALL {
            assert_eq!(Family::by_name(f.name()), Some(f));
        }
        assert_eq!(Family::by_name("vgg16"), None);
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let a = sample_n(Family::Cnn5, 5, 9, 10);
        let b = sample_n(Family::Cnn5, 5, 9, 10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.layers, y.layers);
        }
    }

    #[test]
    fn prop_channels_within_bounds() {
        check(
            "sampled channels ≤ original",
            Config { cases: 64, seed: 3 },
            |r| sample(Family::Cnn5, r, 10),
            |g| {
                let orig = original_widths(Family::Cnn5);
                let mut ci = 0;
                for l in &g.layers {
                    if let crate::model::LayerKind::Conv2d { .. } = l.kind {
                        crate::prop_assert!(
                            l.c_out >= 1 && l.c_out <= orig[ci],
                            "conv{} c_out {} out of [1, {}]",
                            ci,
                            l.c_out,
                            orig[ci]
                        );
                        ci += 1;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn transformer_d_model_divisible_by_heads() {
        for g in sample_n(Family::Transformer, 20, 11, 10) {
            for l in &g.layers {
                if let crate::model::LayerKind::Attention { heads } = l.kind {
                    assert_eq!(l.c_in % heads, 0);
                }
            }
        }
    }
}
