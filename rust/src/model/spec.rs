//! Textual model specs — how a client names a model over the wire.
//!
//! The estimate daemon (and the `thor estimate` CLI) receive models as
//! strings, not graphs.  A spec is
//!
//! ```text
//! <family>[:w1,w2,...[:img[:batch]]]
//! ```
//!
//! where `<family>` is a [`Family::name`] token.  A bare family name
//! resolves to the canonical full-width reference model (the one
//! profiling uses, so a freshly profiled store always covers it);
//! optional channel widths select a variant of the same layer families
//! — cheap to serve, since one profile covers every width:
//!
//! - `cnn5` → reference `cnn5` (widths 32,64,128,256 at img 28)
//! - `cnn5:8,16,32,64` → those widths, default img/batch
//! - `cnn5:8,16,32,64:16:10` → explicit img and batch
//! - `lenet5:6,16,120,84` / `har:32,64,128` → widths (+ optional batch)
//! - `resnet20:8` → width 8 (+ optional batch); same for resnet56/110
//! - `lstm` / `transformer` → reference only (their shape space is not
//!   a flat width vector; variants are out of scope for specs)

use super::sampler::Family;
use super::{zoo, ModelGraph};

#[derive(Debug, thiserror::Error)]
pub enum SpecError {
    #[error("unknown model family '{0}'")]
    UnknownFamily(String),
    #[error("bad width list '{0}': expected {1} comma-separated positive integers")]
    BadWidths(String, usize),
    #[error("bad numeric field '{0}'")]
    BadNumber(String),
    #[error("family '{0}' takes no '{1}' field")]
    ExtraField(&'static str, String),
}

/// Canonical full-width reference model per family — the model profiling
/// runs against, so its families are exactly a fresh store's families.
pub fn reference(fam: Family) -> ModelGraph {
    match fam {
        Family::LeNet5 => zoo::lenet5(&[6, 16, 120, 84], 10),
        Family::Cnn5 => zoo::cnn5(&[32, 64, 128, 256], 28, 10),
        Family::Har => zoo::har(&[32, 64, 128], 10),
        Family::Lstm => zoo::lstm(64, &[128, 128], 2000, 32, 10),
        Family::Transformer => zoo::transformer(4, 256, 4, 32, 2000, 10),
        Family::ResNet20 => zoo::resnet(20, 16, 10),
        Family::ResNet56 => zoo::resnet(56, 16, 10),
        Family::ResNet110 => zoo::resnet(110, 16, 10),
    }
}

fn parse_widths(s: &str, n: usize) -> Result<Vec<usize>, SpecError> {
    let ws: Vec<usize> = s
        .split(',')
        .map(|t| t.trim().parse::<usize>().ok().filter(|&w| w > 0))
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| SpecError::BadWidths(s.to_string(), n))?;
    if ws.len() != n {
        return Err(SpecError::BadWidths(s.to_string(), n));
    }
    Ok(ws)
}

fn parse_num(s: &str) -> Result<usize, SpecError> {
    s.trim().parse::<usize>().ok().filter(|&v| v > 0).ok_or_else(|| SpecError::BadNumber(s.to_string()))
}

/// Parse a model spec string into a graph (see the module doc for the
/// grammar).  Deterministic: the same spec always yields the same graph.
pub fn parse_spec(spec: &str) -> Result<ModelGraph, SpecError> {
    let mut parts = spec.trim().split(':');
    let fam_tok = parts.next().unwrap_or("");
    let fam = Family::by_name(fam_tok).ok_or_else(|| SpecError::UnknownFamily(fam_tok.to_string()))?;
    let fields: Vec<&str> = parts.collect();
    if fields.is_empty() {
        return Ok(reference(fam));
    }
    let extra = |i: usize| -> Result<(), SpecError> {
        match fields.get(i) {
            Some(f) => Err(SpecError::ExtraField(fam.name(), f.to_string())),
            None => Ok(()),
        }
    };
    match fam {
        Family::Cnn5 => {
            let w = parse_widths(fields[0], 4)?;
            let img = fields.get(1).map(|s| parse_num(s)).transpose()?.unwrap_or(28);
            let batch = fields.get(2).map(|s| parse_num(s)).transpose()?.unwrap_or(10);
            extra(3)?;
            Ok(zoo::cnn5(&[w[0], w[1], w[2], w[3]], img, batch))
        }
        Family::LeNet5 => {
            let w = parse_widths(fields[0], 4)?;
            let batch = fields.get(1).map(|s| parse_num(s)).transpose()?.unwrap_or(10);
            extra(2)?;
            Ok(zoo::lenet5(&[w[0], w[1], w[2], w[3]], batch))
        }
        Family::Har => {
            let w = parse_widths(fields[0], 3)?;
            let batch = fields.get(1).map(|s| parse_num(s)).transpose()?.unwrap_or(10);
            extra(2)?;
            Ok(zoo::har(&[w[0], w[1], w[2]], batch))
        }
        Family::ResNet20 | Family::ResNet56 | Family::ResNet110 => {
            let depth = match fam {
                Family::ResNet20 => 20,
                Family::ResNet56 => 56,
                _ => 110,
            };
            let width = parse_num(fields[0])?;
            let batch = fields.get(1).map(|s| parse_num(s)).transpose()?.unwrap_or(10);
            extra(2)?;
            Ok(zoo::resnet(depth, width, batch))
        }
        Family::Lstm | Family::Transformer => Err(SpecError::ExtraField(fam.name(), fields[0].to_string())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::thor::parse::parse;

    #[test]
    fn bare_family_is_the_reference_model() {
        for fam in Family::ALL {
            let g = parse_spec(fam.name()).unwrap();
            assert_eq!(g.layers, reference(fam).layers, "{}", fam.name());
            g.check_dims().unwrap();
        }
    }

    #[test]
    fn width_variants_share_the_reference_families() {
        // The whole point of specs: any width variant of a family is
        // covered by the profile of its reference model.
        let reference_fams: Vec<String> =
            parse(&reference(Family::Cnn5)).families.iter().map(|f| f.id()).collect();
        for spec in ["cnn5:8,16,32,64", "cnn5:4,8,16,32:28", "cnn5:32,64,128,256:28:10"] {
            let g = parse_spec(spec).unwrap();
            g.check_dims().unwrap();
            for f in parse(&g).families {
                assert!(reference_fams.contains(&f.id()), "{spec}: family {} not covered", f.id());
            }
        }
    }

    #[test]
    fn explicit_fields_are_honored() {
        let g = parse_spec("cnn5:8,16,32,64:16:4").unwrap();
        let r = parse_spec("cnn5:8,16,32,64").unwrap();
        assert_ne!(g.layers, r.layers, "img/batch fields must matter");
        let l = parse_spec("lenet5:6,16,120,84:2").unwrap();
        l.check_dims().unwrap();
        let rn = parse_spec("resnet20:8").unwrap();
        rn.check_dims().unwrap();
    }

    #[test]
    fn malformed_specs_are_rejected() {
        assert!(matches!(parse_spec("vgg16"), Err(SpecError::UnknownFamily(_))));
        assert!(matches!(parse_spec("cnn5:1,2,3"), Err(SpecError::BadWidths(..))));
        assert!(matches!(parse_spec("cnn5:a,b,c,d"), Err(SpecError::BadWidths(..))));
        assert!(matches!(parse_spec("cnn5:8,16,32,64:0"), Err(SpecError::BadNumber(_))));
        assert!(matches!(parse_spec("cnn5:8,16,32,64:16:10:9"), Err(SpecError::ExtraField(..))));
        assert!(matches!(parse_spec("lstm:64"), Err(SpecError::ExtraField(..))));
        assert!(matches!(parse_spec(""), Err(SpecError::UnknownFamily(_))));
    }

    #[test]
    fn specs_are_deterministic() {
        let a = parse_spec("resnet56:12:4").unwrap();
        let b = parse_spec("resnet56:12:4").unwrap();
        assert_eq!(a.layers, b.layers);
    }
}
