//! Training-iteration FLOPs / parameter / activation-byte accounting.
//!
//! This is the information content available to the paper's comparison
//! baseline (proxy-based estimation): forward FLOPs from the architecture,
//! backward ≈ 2× forward (grad-input + grad-weight), update ≈ a few ops
//! per parameter.  The FLOPs-LR baseline regresses measured energy on
//! exactly these numbers; its failure modes (utilization plateaus, DVFS,
//! fusion) are what THOR's GP absorbs.

use super::{LayerKind, LayerSpec, ModelGraph};

/// Forward-pass FLOPs for one layer (multiply-add counted as 2 FLOPs).
pub fn fwd_flops(l: &LayerSpec) -> f64 {
    let b = l.batch as f64;
    let (oh, ow) = l.out_hw();
    match &l.kind {
        LayerKind::Conv2d { kernel, .. } => {
            2.0 * (kernel * kernel) as f64 * l.c_in as f64 * l.c_out as f64 * (oh * ow) as f64 * b
        }
        LayerKind::Fc => 2.0 * l.c_in as f64 * l.c_out as f64 * b,
        LayerKind::BatchNorm => 4.0 * l.out_elems() as f64,
        LayerKind::Relu | LayerKind::Dropout | LayerKind::ResidualAdd => l.out_elems() as f64,
        LayerKind::MaxPool { size } => (size * size) as f64 * l.out_elems() as f64,
        LayerKind::Softmax => 5.0 * l.out_elems() as f64,
        LayerKind::Embedding => l.out_elems() as f64, // gather
        LayerKind::Lstm => {
            // 4 gates, each a (c_in + c_out) x c_out matmul per timestep.
            2.0 * 4.0 * (l.c_in + l.c_out) as f64 * l.c_out as f64 * l.h as f64 * b
                + 9.0 * l.out_elems() as f64 // gate nonlinearities + cell update
        }
        LayerKind::Attention { .. } => {
            let d = l.c_in as f64;
            let s = l.h as f64;
            // qkv + output projections, plus the two s×s attention matmuls.
            2.0 * 4.0 * d * d * s * b + 2.0 * 2.0 * s * s * d * b
        }
        LayerKind::LayerNorm => 6.0 * l.out_elems() as f64,
    }
}

/// Backward-pass FLOPs: grad-input + grad-weight ≈ 2× forward for
/// parametric layers, ≈ 1× for elementwise.
pub fn bwd_flops(l: &LayerSpec) -> f64 {
    if l.kind.is_parametric() {
        2.0 * fwd_flops(l)
    } else {
        fwd_flops(l)
    }
}

/// Optimizer-update FLOPs (plain SGD: ~2 per parameter).
pub fn update_flops(l: &LayerSpec) -> f64 {
    2.0 * l.params() as f64
}

/// Full training-iteration FLOPs for one layer.
pub fn train_flops(l: &LayerSpec) -> f64 {
    fwd_flops(l) + bwd_flops(l) + update_flops(l)
}

/// Full training-iteration FLOPs for a model.
pub fn model_train_flops(g: &ModelGraph) -> f64 {
    g.layers.iter().map(train_flops).sum()
}

/// Activation bytes written per iteration (f32).
pub fn activation_bytes(l: &LayerSpec) -> f64 {
    4.0 * l.out_elems() as f64
}

/// Parameter bytes (weights + grads + optimizer state read/write).
pub fn param_bytes(l: &LayerSpec) -> f64 {
    4.0 * l.params() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn conv_flops_formula() {
        let l = LayerSpec {
            kind: LayerKind::Conv2d { kernel: 3, stride: 1, padded: true },
            c_in: 4,
            c_out: 8,
            h: 10,
            w: 10,
            batch: 2,
        };
        assert_eq!(fwd_flops(&l), 2.0 * 9.0 * 4.0 * 8.0 * 100.0 * 2.0);
    }

    #[test]
    fn fc_flops_formula() {
        let l = LayerSpec { kind: LayerKind::Fc, c_in: 100, c_out: 10, h: 1, w: 1, batch: 5 };
        assert_eq!(fwd_flops(&l), 2.0 * 100.0 * 10.0 * 5.0);
    }

    #[test]
    fn training_is_roughly_3x_forward_for_parametric() {
        let l = LayerSpec { kind: LayerKind::Fc, c_in: 512, c_out: 512, h: 1, w: 1, batch: 32 };
        let ratio = train_flops(&l) / fwd_flops(&l);
        assert!(ratio > 2.9 && ratio < 3.2, "{ratio}");
    }

    #[test]
    fn model_flops_monotone_in_width() {
        let small = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let big = zoo::cnn5(&[16, 32, 64, 128], 28, 10);
        assert!(model_train_flops(&big) > 2.0 * model_train_flops(&small));
    }

    #[test]
    fn flops_positive_for_all_zoo_models() {
        for g in zoo::all_default_models() {
            assert!(model_train_flops(&g) > 0.0, "{}", g.name);
        }
    }
}
