//! Model IR: layer specifications and sequential model graphs.
//!
//! THOR never executes these graphs itself — it parses them
//! ([`crate::thor::parse`]), counts their FLOPs for the baseline
//! ([`flops`]), lowers them to op traces for the simulated devices
//! ([`crate::workload`]), and sums per-layer GP estimates over them
//! ([`crate::thor::estimator`]).

pub mod flops;
pub mod sampler;
pub mod spec;
pub mod zoo;

/// Layer type plus the *structural* hyper-parameters that the paper's
/// layer-parsing rule keys on (kernel size, stride, ...).  Channel counts
/// and spatial sizes live in [`LayerSpec`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// 2-D convolution (kernel, stride, same-padding flag).
    Conv2d { kernel: usize, stride: usize, padded: bool },
    /// Fully-connected.
    Fc,
    /// Batch normalization (parametric but grouped with its producer).
    BatchNorm,
    Relu,
    MaxPool { size: usize },
    Dropout,
    Softmax,
    /// Token embedding lookup; `c_in` is the vocabulary size.
    Embedding,
    /// LSTM layer; `c_out` is the unit count, `h` the sequence length.
    Lstm,
    /// Multi-head self-attention; `c_in == c_out == d_model`.
    Attention { heads: usize },
    LayerNorm,
    /// Residual skip-add closing a ResNet block (elementwise).
    ResidualAdd,
}

impl LayerKind {
    /// Non-parametric layers are grouped with their preceding parametric
    /// layer during parsing (paper §3.2).  BatchNorm is treated as
    /// non-parametric for grouping because frameworks fuse it into the
    /// producing conv (Conv-BN-ReLU fusion).
    pub fn is_parametric(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv2d { .. }
                | LayerKind::Fc
                | LayerKind::Embedding
                | LayerKind::Lstm
                | LayerKind::Attention { .. }
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Conv2d { .. } => "conv2d",
            LayerKind::Fc => "fc",
            LayerKind::BatchNorm => "batchnorm",
            LayerKind::Relu => "relu",
            LayerKind::MaxPool { .. } => "maxpool",
            LayerKind::Dropout => "dropout",
            LayerKind::Softmax => "softmax",
            LayerKind::Embedding => "embedding",
            LayerKind::Lstm => "lstm",
            LayerKind::Attention { .. } => "attention",
            LayerKind::LayerNorm => "layernorm",
            LayerKind::ResidualAdd => "residual_add",
        }
    }
}

/// One layer instance with concrete dimensions.
///
/// Dimension conventions:
/// * conv/pool: input is `(batch, c_in, h, w)`, output channels `c_out`;
/// * fc: input features `c_in`, output features `c_out` (`h = w = 1`);
/// * embedding: vocabulary `c_in`, embedding dim `c_out`, seq len `h`;
/// * lstm: input dim `c_in`, units `c_out`, seq len `h`;
/// * attention: `d_model = c_in = c_out`, seq len `h`.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSpec {
    pub kind: LayerKind,
    pub c_in: usize,
    pub c_out: usize,
    pub h: usize,
    pub w: usize,
    pub batch: usize,
}

impl LayerSpec {
    /// Output spatial size (for conv/pool chains).
    pub fn out_hw(&self) -> (usize, usize) {
        match &self.kind {
            LayerKind::Conv2d { kernel, stride, padded } => {
                let eff = |d: usize| {
                    let d = if *padded { d } else { d.saturating_sub(kernel - 1) };
                    d.div_ceil(*stride).max(1)
                };
                (eff(self.h), eff(self.w))
            }
            LayerKind::MaxPool { size } => ((self.h / size).max(1), (self.w / size).max(1)),
            _ => (self.h, self.w),
        }
    }

    /// Parameter count (for FLOPs/bytes accounting).
    pub fn params(&self) -> usize {
        match &self.kind {
            LayerKind::Conv2d { kernel, .. } => kernel * kernel * self.c_in * self.c_out + self.c_out,
            LayerKind::Fc => self.c_in * self.c_out + self.c_out,
            LayerKind::BatchNorm => 2 * self.c_out,
            LayerKind::Embedding => self.c_in * self.c_out,
            LayerKind::Lstm => 4 * ((self.c_in + self.c_out) * self.c_out + self.c_out),
            LayerKind::Attention { .. } => 4 * (self.c_in * self.c_out + self.c_out),
            LayerKind::LayerNorm => 2 * self.c_out,
            _ => 0,
        }
    }

    /// Output activation element count per iteration.
    pub fn out_elems(&self) -> usize {
        let (oh, ow) = self.out_hw();
        match &self.kind {
            LayerKind::Fc => self.batch * self.c_out,
            LayerKind::Embedding | LayerKind::Lstm | LayerKind::Attention { .. } => {
                self.batch * self.h * self.c_out
            }
            _ => self.batch * self.c_out * oh * ow,
        }
    }
}

/// A sequential model: layers chained input → output.
#[derive(Clone, Debug)]
pub struct ModelGraph {
    pub name: String,
    pub layers: Vec<LayerSpec>,
}

impl ModelGraph {
    pub fn new(name: &str, layers: Vec<LayerSpec>) -> Self {
        Self { name: name.to_string(), layers }
    }

    /// Validate the dimension chaining between consecutive parametric
    /// layers (panics describe the first mismatch — used by zoo tests).
    pub fn check_dims(&self) -> Result<(), String> {
        let mut cur_c: Option<usize> = None;
        for (i, l) in self.layers.iter().enumerate() {
            if l.kind.is_parametric() {
                if let Some(c) = cur_c {
                    // Fc after conv consumes flattened features; allow both
                    // exact channel chaining and flattened chaining.
                    let ok = l.c_in == c || l.c_in % c == 0;
                    if !ok {
                        return Err(format!(
                            "layer {i} ({}) c_in {} incompatible with producer channels {c}",
                            l.kind.name(),
                            l.c_in
                        ));
                    }
                }
                cur_c = Some(l.c_out);
            }
        }
        Ok(())
    }

    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.params()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(c_in: usize, c_out: usize, h: usize) -> LayerSpec {
        LayerSpec {
            kind: LayerKind::Conv2d { kernel: 3, stride: 1, padded: true },
            c_in,
            c_out,
            h,
            w: h,
            batch: 10,
        }
    }

    #[test]
    fn conv_same_padding_keeps_hw() {
        let l = conv(3, 16, 28);
        assert_eq!(l.out_hw(), (28, 28));
    }

    #[test]
    fn conv_valid_shrinks() {
        let l = LayerSpec {
            kind: LayerKind::Conv2d { kernel: 5, stride: 1, padded: false },
            c_in: 1,
            c_out: 6,
            h: 28,
            w: 28,
            batch: 10,
        };
        assert_eq!(l.out_hw(), (24, 24));
    }

    #[test]
    fn pool_halves() {
        let l = LayerSpec { kind: LayerKind::MaxPool { size: 2 }, c_in: 8, c_out: 8, h: 28, w: 28, batch: 10 };
        assert_eq!(l.out_hw(), (14, 14));
    }

    #[test]
    fn params_conv_fc() {
        assert_eq!(conv(3, 16, 28).params(), 3 * 3 * 3 * 16 + 16);
        let fc = LayerSpec { kind: LayerKind::Fc, c_in: 100, c_out: 10, h: 1, w: 1, batch: 10 };
        assert_eq!(fc.params(), 1010);
    }

    #[test]
    fn dims_check_catches_mismatch() {
        let g = ModelGraph::new("bad", vec![conv(3, 16, 28), conv(17, 8, 28)]);
        assert!(g.check_dims().is_err());
        let good = ModelGraph::new("ok", vec![conv(3, 16, 28), conv(16, 8, 28)]);
        assert!(good.check_dims().is_ok());
    }

    #[test]
    fn grouping_classification() {
        assert!(LayerKind::Conv2d { kernel: 3, stride: 1, padded: true }.is_parametric());
        assert!(!LayerKind::Relu.is_parametric());
        assert!(!LayerKind::BatchNorm.is_parametric()); // fused with producer
        assert!(LayerKind::Lstm.is_parametric());
    }
}
