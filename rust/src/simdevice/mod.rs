//! Device-energy simulator: the substrate standing in for the paper's five
//! physical devices (OPPO Reno6 Pro+, iPhone 13, Jetson Xavier NX, Jetson
//! TX2, RTX-4090 Windows server) and their power meters (POWER-Z KT002,
//! INA3221 rails, nvidia-smi).
//!
//! THOR only ever observes `(variant architecture) → (energy J, time s)`
//! through [`Device::run`]; the simulator supplies the phenomenology the
//! paper reports — occupancy plateaus (Figs 5/11), DVFS + thermal
//! throttling variance on phones (Fig 8), stage-splitting overestimation
//! when profiled cold/unfused (Fig 2), and finite-sampling measurement
//! noise (Fig A16, eq. 6).  See DESIGN.md §2 for the substitution
//! rationale.

pub mod devices;
pub mod exec;
pub mod meter;

use crate::workload::Trace;

/// DVFS governor policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Governor {
    /// Locked to one ladder level (Jetson `nvpmodel`-style fixed clocks —
    /// the paper notes these devices estimate best).
    Fixed(usize),
    /// Utilization-driven up/down stepping with hysteresis (phone SoCs,
    /// desktop GPU boost).
    OnDemand,
}

/// Thermal throttling parameters (first-order thermal RC + clock cap).
#[derive(Clone, Copy, Debug)]
pub struct ThermalSpec {
    pub ambient_c: f64,
    /// °C per Joule of dissipated energy.
    pub heat_per_joule: f64,
    /// Fraction of (T − ambient) shed per second.
    pub cool_rate: f64,
    /// Above this temperature the governor caps the ladder level.
    pub throttle_c: f64,
    /// Ladder level cap while throttled.
    pub throttle_level: usize,
}

/// Power-meter characteristics (paper Appendix A5.2).
#[derive(Clone, Copy, Debug)]
pub struct MeterSpec {
    /// Sampling interval in seconds (0.1 for POWER-Z/INA3221, 0.02 for
    /// nvidia-smi).
    pub interval_s: f64,
    /// Multiplicative Gaussian sensor noise (std, fraction of reading).
    pub noise_frac: f64,
    /// Power quantization step in watts (ADC resolution).
    pub quantum_w: f64,
    /// Poisson rate (events/s) of background-process wakeups.
    pub wakeup_rate: f64,
    /// Mean extra power of one wakeup, watts.
    pub wakeup_power_w: f64,
    /// Mean wakeup duration, seconds.
    pub wakeup_dur_s: f64,
}

/// One memory level of the hierarchy.
#[derive(Clone, Copy, Debug)]
pub struct MemLevel {
    /// Capacity in bytes.
    pub capacity: f64,
    /// Energy per byte moved, joules.
    pub energy_per_byte: f64,
    /// Bandwidth, bytes/s.
    pub bandwidth: f64,
}

/// Static description of a device.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Concurrent hardware threads (compute units × threads each): the
    /// wave-quantization denominator.
    pub slots: f64,
    /// Peak FLOP/s at the *top* ladder level.
    pub peak_flops: f64,
    /// Dynamic energy per FLOP at nominal voltage, joules.
    pub energy_per_flop: f64,
    /// Frequency ladder as (relative frequency, relative voltage), sorted
    /// ascending; the last entry is nominal (1.0, 1.0).
    pub ladder: Vec<(f64, f64)>,
    /// On-chip cache level + DRAM.
    pub cache: MemLevel,
    pub dram: MemLevel,
    /// Idle (standby) power, watts — subtracted by the measurement
    /// protocol, eq. 6.
    pub idle_power_w: f64,
    /// Active-but-stalled power above idle (fraction of chip lit while
    /// waiting): creates the energy plateaus on partially-filled waves.
    pub stall_power_w: f64,
    /// Per-launch overhead (seconds) and energy (joules): WebGL dispatch
    /// on phones is far costlier than CUDA launches.
    pub launch_overhead_s: f64,
    pub launch_energy_j: f64,
    /// Base channel-tile granularity of the device's kernel library
    /// (vec4 lanes for WebGL, 8-lane tensor tiles for cuDNN): channel
    /// dims are padded to tile multiples — see
    /// [`crate::workload::kernelcfg::padded_channels`].
    pub pad_quantum: usize,
    /// GEMM-shape saturation points: row/column extents a dense kernel
    /// needs before it fills this device's compute array (see
    /// [`crate::workload::kernelcfg::shape_efficiency`]).
    pub m_sat: f64,
    pub n_sat: f64,
    /// Dense-kernel efficiency ceiling (fraction of peak reachable).
    pub dense_ceiling: f64,
    /// Elementwise-kernel efficiency ceiling.
    pub elementwise_ceiling: f64,
    pub governor: Governor,
    pub thermal: ThermalSpec,
    pub meter: MeterSpec,
}

/// What one profiling run returns to THOR (and to the baselines).
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Net energy (standby-subtracted), joules, for the whole run.
    pub energy_j: f64,
    /// Wall-clock of the run, seconds.
    pub time_s: f64,
    /// Iterations executed.
    pub iterations: usize,
}

impl Measurement {
    pub fn energy_per_iter(&self) -> f64 {
        self.energy_j / self.iterations as f64
    }

    pub fn time_per_iter(&self) -> f64 {
        self.time_s / self.iterations as f64
    }
}

/// A simulated device instance (owns mutable DVFS/thermal/meter state).
pub struct Device {
    pub profile: DeviceProfile,
    pub(crate) rng: crate::util::rng::Pcg64,
}

impl Device {
    pub fn new(profile: DeviceProfile, seed: u64) -> Self {
        Self { profile, rng: crate::util::rng::Pcg64::new(seed) }
    }

    /// Train `trace` for `iterations` and measure with the device's power
    /// meter (paper measurement protocol: standby-subtracted sampled
    /// integration, eq. 6).
    pub fn run(&mut self, trace: &Trace, iterations: usize) -> Measurement {
        exec::run(&self.profile, trace, iterations, &mut self.rng, false)
    }

    /// Run a trace standalone and *cold* (no warm caches, per-stage launch
    /// setup) — how an operator-level profiler measures stages in
    /// isolation.  Used by the NeuralPower-style baseline (Fig 2).
    pub fn run_cold(&mut self, trace: &Trace, iterations: usize) -> Measurement {
        exec::run(&self.profile, trace, iterations, &mut self.rng, true)
    }
}
