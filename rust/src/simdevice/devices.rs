//! The five device profiles (paper Tab. A2), calibrated to plausible
//! public specs.  Absolute joules are not the reproduction target — the
//! *relationships* are: phones are DVFS/thermally noisy, Jetsons run fixed
//! clocks and estimate best, the server is fast, high-powered and
//! boost-clocked (consistent but larger relative errors, Fig 8), and WebGL
//! dispatch overhead dwarfs CUDA launch overhead.

use crate::simdevice::{DeviceProfile, Governor, MemLevel, MeterSpec, ThermalSpec};

fn phone_ladder() -> Vec<(f64, f64)> {
    vec![(0.35, 0.65), (0.5, 0.72), (0.65, 0.8), (0.8, 0.9), (1.0, 1.0)]
}

fn jetson_ladder() -> Vec<(f64, f64)> {
    vec![(0.4, 0.7), (0.7, 0.85), (1.0, 1.0)]
}

fn server_ladder() -> Vec<(f64, f64)> {
    vec![(0.6, 0.78), (0.8, 0.9), (1.0, 1.0), (1.12, 1.07)] // boost bin
}

/// OPPO Reno6 Pro+ — Snapdragon 870, Adreno 650, TensorFlow.js/WebGL.
pub fn oppo() -> DeviceProfile {
    DeviceProfile {
        name: "oppo",
        slots: 512.0,
        peak_flops: 1.2e12,
        energy_per_flop: 2.5e-11, // effective J/FLOP for WebGL training kernels
        ladder: phone_ladder(),
        cache: MemLevel { capacity: 1.0e6, energy_per_byte: 1.2e-11, bandwidth: 1.5e11 },
        dram: MemLevel { capacity: 8.0e9, energy_per_byte: 9.0e-11, bandwidth: 3.0e10 },
        idle_power_w: 0.9,
        stall_power_w: 1.6,
        launch_overhead_s: 250e-6, // WebGL dispatch through the JS event loop
        launch_energy_j: 1.2e-5,
        pad_quantum: 4, // vec4 shader lanes
        m_sat: 512.0,
        n_sat: 32.0,
        dense_ceiling: 0.13, // WebGL training shaders: ~150 GFLOP/s effective
        elementwise_ceiling: 0.08,
        governor: Governor::OnDemand,
        // Thermal time constants are compressed relative to a physical
        // phone (minutes → seconds) so that throttling engages *within* a
        // 500-iteration profiling run, as it does on real hardware during
        // the much longer real-time runs (DESIGN.md §2).
        thermal: ThermalSpec {
            ambient_c: 30.0,
            heat_per_joule: 8.0,
            cool_rate: 0.3,
            throttle_c: 58.0,
            throttle_level: 1,
        },
        meter: MeterSpec {
            // POWER-Z KT002: 10 Hz bus sampling
            interval_s: 0.1,
            noise_frac: 0.02,
            quantum_w: 0.005,
            wakeup_rate: 0.08, // Android background services
            wakeup_power_w: 0.8,
            wakeup_dur_s: 0.6,
        },
    }
}

/// iPhone 13 — A15 Bionic, 4-core Apple GPU, TensorFlow.js/WebGL.
pub fn iphone() -> DeviceProfile {
    DeviceProfile {
        name: "iphone",
        slots: 640.0,
        peak_flops: 1.5e12,
        energy_per_flop: 1.8e-11, // A15 is more efficient
        ladder: phone_ladder(),
        cache: MemLevel { capacity: 1.6e6, energy_per_byte: 1.0e-11, bandwidth: 2.0e11 },
        dram: MemLevel { capacity: 4.0e9, energy_per_byte: 8.0e-11, bandwidth: 3.4e10 },
        idle_power_w: 0.7,
        stall_power_w: 1.2,
        launch_overhead_s: 200e-6,
        launch_energy_j: 8e-6,
        pad_quantum: 4,
        m_sat: 512.0,
        n_sat: 32.0,
        dense_ceiling: 0.16, // WebGL on Apple GPU
        elementwise_ceiling: 0.1,
        governor: Governor::OnDemand,
        // Compressed thermal time constants — see oppo().
        thermal: ThermalSpec {
            ambient_c: 30.0,
            heat_per_joule: 9.0, // smaller chassis heats faster
            cool_rate: 0.28,
            throttle_c: 56.0,
            throttle_level: 1,
        },
        meter: MeterSpec {
            interval_s: 0.1,
            noise_frac: 0.02,
            quantum_w: 0.005,
            wakeup_rate: 0.05,
            wakeup_power_w: 0.6,
            wakeup_dur_s: 0.5,
        },
    }
}

/// Jetson Xavier NX — 384-core Volta, fixed nvpmodel clocks, INA3221 rail.
pub fn xavier() -> DeviceProfile {
    DeviceProfile {
        name: "xavier",
        slots: 1536.0,
        peak_flops: 1.4e12,
        energy_per_flop: 9.0e-12,
        ladder: jetson_ladder(),
        cache: MemLevel { capacity: 4.0e6, energy_per_byte: 8.0e-12, bandwidth: 4.0e11 },
        dram: MemLevel { capacity: 8.0e9, energy_per_byte: 7.0e-11, bandwidth: 5.1e10 },
        idle_power_w: 4.5,
        stall_power_w: 1.2,
        launch_overhead_s: 60e-6, // CUDA launch + framework op dispatch
        launch_energy_j: 4e-6,
        pad_quantum: 8,
        m_sat: 2048.0,
        n_sat: 64.0,
        dense_ceiling: 0.8,
        elementwise_ceiling: 0.5,
        governor: Governor::Fixed(2), // clocks pinned (jetson_clocks)
        thermal: ThermalSpec {
            ambient_c: 35.0,
            heat_per_joule: 0.004, // heatsinked module
            cool_rate: 0.25,
            throttle_c: 95.0, // effectively never throttles
            throttle_level: 1,
        },
        meter: MeterSpec {
            // INA3221 via sysfs at 100 ms (1 ms degraded performance, A5.2)
            interval_s: 0.1,
            noise_frac: 0.01,
            quantum_w: 0.01,
            wakeup_rate: 0.01,
            wakeup_power_w: 0.4,
            wakeup_dur_s: 0.3,
        },
    }
}

/// Jetson TX2 — 256-core Pascal, fixed clocks, INA3221 rail.
pub fn tx2() -> DeviceProfile {
    DeviceProfile {
        name: "tx2",
        slots: 1024.0,
        peak_flops: 6.65e11,
        energy_per_flop: 1.4e-11,
        ladder: jetson_ladder(),
        cache: MemLevel { capacity: 2.0e6, energy_per_byte: 9.0e-12, bandwidth: 3.0e11 },
        dram: MemLevel { capacity: 8.0e9, energy_per_byte: 8.0e-11, bandwidth: 3.0e10 },
        idle_power_w: 3.5,
        stall_power_w: 1.0,
        launch_overhead_s: 80e-6,
        launch_energy_j: 5e-6,
        pad_quantum: 8,
        m_sat: 1536.0,
        n_sat: 64.0,
        dense_ceiling: 0.75,
        elementwise_ceiling: 0.45,
        governor: Governor::Fixed(2),
        thermal: ThermalSpec {
            ambient_c: 35.0,
            heat_per_joule: 0.005,
            cool_rate: 0.22,
            throttle_c: 92.0,
            throttle_level: 1,
        },
        meter: MeterSpec {
            interval_s: 0.1,
            noise_frac: 0.01,
            quantum_w: 0.01,
            wakeup_rate: 0.01,
            wakeup_power_w: 0.4,
            wakeup_dur_s: 0.3,
        },
    }
}

/// Windows server — i9-13900K + RTX 4090, PyTorch/CUDA, nvidia-smi meter.
pub fn server() -> DeviceProfile {
    DeviceProfile {
        name: "server",
        slots: 16384.0,
        peak_flops: 4.0e13,
        energy_per_flop: 5.0e-12,
        ladder: server_ladder(),
        cache: MemLevel { capacity: 7.2e7, energy_per_byte: 4.0e-12, bandwidth: 5.0e12 },
        dram: MemLevel { capacity: 2.4e10, energy_per_byte: 2.5e-11, bandwidth: 1.0e12 },
        idle_power_w: 85.0,
        stall_power_w: 45.0, // big die lit while underfilled
        launch_overhead_s: 120e-6, // eager-mode dispatch dominates small kernels
        launch_energy_j: 6e-5,
        pad_quantum: 8,
        m_sat: 8192.0,
        n_sat: 128.0,
        dense_ceiling: 0.9,
        elementwise_ceiling: 0.55,
        governor: Governor::OnDemand, // GPU boost
        thermal: ThermalSpec {
            ambient_c: 28.0,
            heat_per_joule: 0.0006,
            cool_rate: 0.3,
            throttle_c: 83.0,
            throttle_level: 2,
        },
        meter: MeterSpec {
            // nvidia-smi at ~50 Hz
            interval_s: 0.02,
            noise_frac: 0.015,
            quantum_w: 1.0, // watt-level reporting
            wakeup_rate: 0.02, // OS background tasks
            wakeup_power_w: 20.0,
            wakeup_dur_s: 1.0,
        },
    }
}

/// All five, in the paper's order.
pub fn all() -> Vec<DeviceProfile> {
    vec![oppo(), iphone(), xavier(), tx2(), server()]
}

pub fn by_name(name: &str) -> Option<DeviceProfile> {
    all().into_iter().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_devices_distinct() {
        let names: Vec<_> = all().iter().map(|d| d.name).collect();
        assert_eq!(names, ["oppo", "iphone", "xavier", "tx2", "server"]);
    }

    #[test]
    fn ladders_sorted_ending_at_nominal() {
        for d in all() {
            let fs: Vec<f64> = d.ladder.iter().map(|l| l.0).collect();
            assert!(fs.windows(2).all(|w| w[0] < w[1]), "{}", d.name);
            assert!(d.ladder.iter().any(|&(f, v)| f == 1.0 && v == 1.0), "{}", d.name);
        }
    }

    #[test]
    fn efficiency_ordering_server_best() {
        // J per FLOP: server (4090) most efficient, TX2/OPPO least.
        assert!(server().energy_per_flop < xavier().energy_per_flop);
        assert!(xavier().energy_per_flop < oppo().energy_per_flop);
    }

    #[test]
    fn jetsons_fixed_phones_ondemand() {
        assert!(matches!(xavier().governor, Governor::Fixed(_)));
        assert!(matches!(tx2().governor, Governor::Fixed(_)));
        assert!(matches!(oppo().governor, Governor::OnDemand));
        assert!(matches!(server().governor, Governor::OnDemand));
    }

    #[test]
    fn by_name_roundtrip() {
        for d in all() {
            assert_eq!(by_name(d.name).unwrap().name, d.name);
        }
        assert!(by_name("nokia3310").is_none());
    }
}
