//! Op-trace execution model: walks a training-iteration trace
//! iteration-by-iteration, evolving DVFS frequency, temperature and the
//! power-meter integrator, and returns the measured [`Measurement`].
//!
//! Per-op model:
//!
//! * occupancy/waves from [`crate::workload::kernelcfg`] — the source of
//!   the channel-axis nonlinearity;
//! * compute time `flops / (peak(f) · efficiency)`;
//! * memory time from a two-level working-set model (cache hit fraction
//!   shrinks once the working set spills);
//! * op time `max(compute, memory) + launch overhead`;
//! * dynamic energy `flops · e_flop · (V/V_nom)²` plus stall power over
//!   the op duration plus memory movement energy plus launch energy.
//!
//! The true power timeline is integrated by the sampled meter
//! ([`super::meter`]) exactly as the paper's eq. (6) does, including
//! sensor noise, quantization and background wakeups.

use crate::simdevice::{meter::Meter, DeviceProfile, Governor, Measurement};
use crate::util::rng::Pcg64;
use crate::workload::{kernelcfg, Op, OpClass, Trace};

/// DVFS governor sampling window, seconds (ondemand-style governors
/// evaluate busy fraction over fixed time windows, so long dense kernels
/// dominate the decision — op *time*, not op count).
const GOVERNOR_WINDOW_S: f64 = 0.02;

/// Mutable machine state across ops/iterations.
struct MachineState {
    level: usize,
    temp_c: f64,
    /// Array-busy and wall seconds accumulated in the open window.
    busy_acc: f64,
    wall_acc: f64,
    throttled: bool,
}

impl MachineState {
    fn new(p: &DeviceProfile) -> Self {
        let level = match p.governor {
            Governor::Fixed(l) => l.min(p.ladder.len() - 1),
            Governor::OnDemand => p.ladder.len() / 2,
        };
        Self { level, temp_c: p.thermal.ambient_c, busy_acc: 0.0, wall_acc: 0.0, throttled: false }
    }

    fn freq_volt(&self, p: &DeviceProfile) -> (f64, f64) {
        let cap = if self.throttled { p.thermal.throttle_level } else { p.ladder.len() - 1 };
        let l = self.level.min(cap);
        p.ladder[l]
    }

    /// `busy`: seconds the compute array was actually filled during the
    /// op; `wall`: the op's full duration.
    fn governor_tick(&mut self, p: &DeviceProfile, busy: f64, wall: f64) {
        self.busy_acc += busy;
        self.wall_acc += wall;
        if self.wall_acc < GOVERNOR_WINDOW_S {
            return;
        }
        let frac = self.busy_acc / self.wall_acc;
        self.busy_acc = 0.0;
        self.wall_acc = 0.0;
        if let Governor::OnDemand = p.governor {
            if frac > 0.6 && self.level + 1 < p.ladder.len() {
                self.level += 1;
            } else if frac < 0.3 && self.level > 0 {
                self.level -= 1;
            }
        }
    }

    fn thermal_tick(&mut self, p: &DeviceProfile, energy_j: f64, dt: f64) {
        let t = &p.thermal;
        self.temp_c += energy_j * t.heat_per_joule;
        self.temp_c -= (self.temp_c - t.ambient_c) * (t.cool_rate * dt).min(1.0);
        self.throttled = self.temp_c > t.throttle_c;
    }
}

/// Cache-hit fraction for a working set against the on-chip cache.
fn hit_fraction(working_set: f64, capacity: f64, cold: bool) -> f64 {
    if cold {
        return 0.0; // standalone stage profiling: nothing is warm
    }
    if working_set <= capacity {
        0.85
    } else {
        0.85 * capacity / working_set
    }
}

/// Execute one op; returns (duration_s, energy_j, utilization).
fn exec_op(p: &DeviceProfile, st: &MachineState, op: &Op, cold: bool) -> (f64, f64, f64) {
    let (freq, volt) = st.freq_volt(p);
    let ceiling = match op.class {
        OpClass::Dense => p.dense_ceiling,
        OpClass::Elementwise | OpClass::Update => p.elementwise_ceiling,
        OpClass::Gather => p.elementwise_ceiling * 0.5,
    };
    // Channel-tile padding: the library executes padded lanes, so both
    // the time and the dynamic energy are paid on the padded FLOPs —
    // the staircase non-linearity of Figs 5/11.
    let pad = kernelcfg::pad_ratio(op.c_in, op.c_out, p.pad_quantum);
    let flops_exec = op.flops * pad;
    let mut eff = kernelcfg::compute_efficiency(op.parallelism, p.slots, ceiling);
    if op.class == OpClass::Dense && op.c_out > 0 {
        // GEMM shape: M = parallelism / N (threads are one per output
        // element of the implicit GEMM).
        let n = kernelcfg::padded_channels(op.c_out, p.pad_quantum) as f64;
        let m = (op.parallelism / op.c_out as f64).max(1.0);
        eff *= kernelcfg::shape_efficiency(m, n, p.m_sat, p.n_sat);
    }
    // Floor: even a degenerate GEMV gets some fraction of the machine
    // (prevents unphysical micro-kernel stall blowups).
    let eff = eff.max(0.004);
    let compute_time = flops_exec / (p.peak_flops * freq * eff);

    let hit = hit_fraction(op.working_set, p.cache.capacity, cold);
    let dram_bytes = op.bytes_in * (1.0 - hit) + op.bytes_out;
    let cache_bytes = op.bytes_in * hit;
    let mem_time = dram_bytes / p.dram.bandwidth + cache_bytes / p.cache.bandwidth;

    let extra_launch = if cold { 2.0 * p.launch_overhead_s } else { 0.0 };
    let dur = compute_time.max(mem_time) + p.launch_overhead_s + extra_launch;

    let dyn_energy = flops_exec * p.energy_per_flop * volt * volt;
    let mem_energy = dram_bytes * p.dram.energy_per_byte + cache_bytes * p.cache.energy_per_byte;
    // Stall power burns while the kernel is *executing* but underfilled
    // (partial waves / bandwidth stalls) — this flattens energy across a
    // partially-filled wave (plateaus).  Dispatch gaps are idle power,
    // which the measurement protocol subtracts.
    let exec_busy = compute_time.max(mem_time);
    let stall_energy = p.stall_power_w * exec_busy * (1.0 - eff).max(0.0);
    let energy = dyn_energy + mem_energy + stall_energy + p.launch_energy_j;

    // Governor signal: array-busy seconds within this op — dispatch-bound
    // phases read as idle, so models dominated by small kernels settle at
    // low clocks while sustained dense models boost.  This is the DVFS
    // behaviour that degrades proxy-based estimation on phones and the
    // server (Fig 8) while fixed-clock Jetsons stay well-behaved.
    let busy = compute_time.min(dur);
    (dur, energy, busy)
}

/// Run `iterations` of `trace` on the device and measure with its meter.
///
/// `cold`: profile each op standalone (unfused traces passed by the
/// NeuralPower baseline) with cold caches and per-stage setup.
pub fn run(
    p: &DeviceProfile,
    trace: &Trace,
    iterations: usize,
    rng: &mut Pcg64,
    cold: bool,
) -> Measurement {
    let mut st = MachineState::new(p);
    let mut m = Meter::new(p, rng.fork(0x6d657465));
    let mut t = 0.0f64;
    for _ in 0..iterations {
        for op in &trace.ops {
            let (dur, energy, busy) = exec_op(p, &st, op, cold);
            // active power over the op interval = op energy / duration,
            // plus the device idle floor (meter sees gross power).
            let power = energy / dur + p.idle_power_w;
            m.advance(power, dur);
            st.governor_tick(p, busy, dur);
            st.thermal_tick(p, energy, dur);
            t += dur;
        }
    }
    let (gross_j, time_s) = m.finish();
    debug_assert!((time_s - t).abs() < 1e-6 * t.max(1.0));
    Measurement {
        energy_j: (gross_j - p.idle_power_w * time_s).max(0.0),
        time_s,
        iterations,
    }
}

/// Noise-free per-iteration ground truth (no meter, no governor noise):
/// used by experiments as the "actual" reference where the paper uses a
/// long averaged measurement.
pub fn ideal_energy_per_iter(p: &DeviceProfile, trace: &Trace) -> f64 {
    let st = MachineState::new(p);
    trace.ops.iter().map(|op| exec_op(p, &st, op, false).1).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::simdevice::devices;
    use crate::workload::{fusion::fuse, lower::lower};

    fn small_trace() -> Trace {
        fuse(&lower(&zoo::cnn5(&[8, 16, 32, 64], 28, 10)))
    }

    #[test]
    fn energy_and_time_positive() {
        let p = devices::xavier();
        let mut rng = Pcg64::new(1);
        let m = run(&p, &small_trace(), 50, &mut rng, false);
        assert!(m.energy_j > 0.0 && m.time_s > 0.0);
    }

    #[test]
    fn energy_scales_with_iterations() {
        let p = devices::xavier();
        let mut rng = Pcg64::new(2);
        let m1 = run(&p, &small_trace(), 100, &mut rng, false);
        let mut rng = Pcg64::new(2);
        let m2 = run(&p, &small_trace(), 200, &mut rng, false);
        let ratio = m2.energy_j / m1.energy_j;
        assert!(ratio > 1.8 && ratio < 2.2, "{ratio}");
    }

    #[test]
    fn cold_standalone_costs_more() {
        // The Fig-2 mechanism: per-stage cold profiling overestimates.
        let p = devices::xavier();
        let tr = small_trace();
        let mut rng = Pcg64::new(3);
        let warm = run(&p, &tr, 50, &mut rng, false);
        let mut rng = Pcg64::new(3);
        let cold = run(&p, &tr, 50, &mut rng, true);
        assert!(
            cold.energy_j > 1.05 * warm.energy_j,
            "cold {} vs warm {}",
            cold.energy_j,
            warm.energy_j
        );
    }

    #[test]
    fn bigger_model_costs_more() {
        let p = devices::server();
        let small = fuse(&lower(&zoo::cnn5(&[8, 16, 32, 64], 28, 10)));
        let big = fuse(&lower(&zoo::cnn5(&[32, 64, 128, 256], 28, 10)));
        assert!(ideal_energy_per_iter(&p, &big) > ideal_energy_per_iter(&p, &small));
    }

    #[test]
    fn energy_not_proportional_to_flops() {
        // The central claim motivating THOR: on narrow models energy/FLOP
        // rises (occupancy plateaus), so FLOPs-proportionality fails.
        let p = devices::xavier();
        let narrow = fuse(&lower(&zoo::cnn5(&[2, 2, 2, 2], 28, 10)));
        let wide = fuse(&lower(&zoo::cnn5(&[32, 64, 128, 256], 28, 10)));
        let e_per_flop_narrow = ideal_energy_per_iter(&p, &narrow) / narrow.total_flops();
        let e_per_flop_wide = ideal_energy_per_iter(&p, &wide) / wide.total_flops();
        assert!(
            e_per_flop_narrow > 2.0 * e_per_flop_wide,
            "narrow {e_per_flop_narrow} vs wide {e_per_flop_wide}"
        );
    }

    #[test]
    fn thermal_throttling_engages_on_phone_under_load() {
        let p = devices::oppo();
        let tr = fuse(&lower(&zoo::cnn5(&[32, 64, 128, 256], 28, 10)));
        let mut st = MachineState::new(&p);
        let mut throttled_any = false;
        for _ in 0..2000 {
            for op in &tr.ops {
                let (dur, energy, busy) = exec_op(&p, &st, op, false);
                st.governor_tick(&p, busy, dur);
                st.thermal_tick(&p, energy, dur);
                throttled_any |= st.throttled;
            }
        }
        assert!(throttled_any, "phone never throttled under sustained load");
    }

    #[test]
    fn fixed_governor_never_moves() {
        let p = devices::xavier(); // Fixed governor
        let mut st = MachineState::new(&p);
        let l0 = st.level;
        for busy in [0.001, 0.09, 0.095, 0.005] {
            st.governor_tick(&p, busy, 0.1);
        }
        assert_eq!(st.level, l0);
    }

    #[test]
    fn measurement_determinism_per_seed() {
        let p = devices::tx2();
        let tr = small_trace();
        let mut r1 = Pcg64::new(7);
        let mut r2 = Pcg64::new(7);
        let a = run(&p, &tr, 20, &mut r1, false);
        let b = run(&p, &tr, 20, &mut r2, false);
        assert_eq!(a.energy_j, b.energy_j);
    }
}

