//! Sampled power-meter simulation (paper Appendix A5.2, eq. 6).
//!
//! The meter integrates the *true* piecewise-constant power timeline at a
//! fixed sampling interval: `E ≈ Σ P(tᵢ)·Δt`.  Each sample carries
//! multiplicative Gaussian sensor noise and ADC quantization; background
//! processes wake up as a Poisson process and add power for a random
//! duration (the reason the paper closes background apps and still needs
//! 500-iteration averaging, Fig A16).
//!
//! The integrator is *online*: `advance(power, dur)` walks the timeline
//! op-by-op without materializing it.

use crate::simdevice::DeviceProfile;
use crate::util::rng::Pcg64;

pub struct Meter {
    interval: f64,
    noise_frac: f64,
    quantum: f64,
    wakeup_rate: f64,
    wakeup_power: f64,
    wakeup_dur: f64,
    rng: Pcg64,
    /// Absolute time of the next sample.
    next_sample: f64,
    /// Current absolute time.
    now: f64,
    /// Accumulated measured energy.
    energy_j: f64,
    /// Currently-active background wakeup: (end_time, extra_power).
    wakeup: Option<(f64, f64)>,
    /// Time the next wakeup arrives.
    next_wakeup: f64,
    /// True power of the most recent op (used for the tail sample when a
    /// run ends between samples — keeps short runs unbiased, Fig A16).
    last_power: f64,
    /// True energy accumulated inside the currently-open window.
    window_j: f64,
}

impl Meter {
    pub fn new(p: &DeviceProfile, mut rng: Pcg64) -> Self {
        let m = p.meter;
        let first_wakeup = if m.wakeup_rate > 0.0 {
            // exponential inter-arrival
            -rng.f64().max(1e-12).ln() / m.wakeup_rate
        } else {
            f64::INFINITY
        };
        Self {
            interval: m.interval_s,
            noise_frac: m.noise_frac,
            quantum: m.quantum_w,
            wakeup_rate: m.wakeup_rate,
            wakeup_power: m.wakeup_power_w,
            wakeup_dur: m.wakeup_dur_s,
            rng,
            next_sample: m.interval_s,
            now: 0.0,
            energy_j: 0.0,
            wakeup: None,
            next_wakeup: first_wakeup,
            last_power: 0.0,
            window_j: 0.0,
        }
    }

    fn instantaneous(&mut self, base_power: f64, t: f64) -> f64 {
        // background wakeup bookkeeping
        if t >= self.next_wakeup {
            let dur = self.wakeup_dur * (0.5 + self.rng.f64());
            let pw = self.wakeup_power * (0.5 + self.rng.f64());
            self.wakeup = Some((t + dur, pw));
            self.next_wakeup = t + (-self.rng.f64().max(1e-12).ln() / self.wakeup_rate).max(1e-3);
        }
        let extra = match self.wakeup {
            Some((end, pw)) if t < end => pw,
            _ => {
                self.wakeup = None;
                0.0
            }
        };
        let raw = (base_power + extra) * (1.0 + self.noise_frac * self.rng.normal());
        let quantized = if self.quantum > 0.0 { (raw / self.quantum).round() * self.quantum } else { raw };
        quantized.max(0.0)
    }

    /// Advance the timeline by one op of constant true power `power`
    /// lasting `dur` seconds.
    ///
    /// Physical ADCs (INA3221, POWER-Z) integrate over a conversion
    /// window rather than spot-sampling an instantaneous value, so each
    /// reading is the *window-averaged* power, corrupted by sensor noise,
    /// quantization and background-process power.  Ops much shorter than
    /// the window therefore average out; what survives is per-window
    /// noise — which is exactly why short profiling runs (few windows)
    /// are unstable (Fig A16).
    pub fn advance(&mut self, power: f64, dur: f64) {
        let mut t = self.now;
        let end = self.now + dur;
        while self.next_sample <= end {
            // close the current window at next_sample
            self.window_j += power * (self.next_sample - t);
            let avg_power = self.window_j / self.interval;
            let reading = self.instantaneous(avg_power, self.next_sample);
            self.energy_j += reading * self.interval;
            t = self.next_sample;
            self.window_j = 0.0;
            self.next_sample += self.interval;
        }
        self.window_j += power * (end - t);
        self.now = end;
        self.last_power = power;
    }

    /// Close the run; returns (gross energy J, total time s).  The open
    /// partial window is flushed with a noisy reading over its elapsed
    /// fraction, keeping short runs unbiased.
    pub fn finish(&mut self) -> (f64, f64) {
        let window_start = self.next_sample - self.interval;
        let tail = self.now - window_start;
        if tail > 1e-12 {
            let avg_power = self.window_j / tail;
            let reading = self.instantaneous(avg_power, self.now);
            self.energy_j += reading * tail;
            self.window_j = 0.0;
        }
        (self.energy_j, self.now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simdevice::devices;

    fn quiet_meter(interval: f64) -> Meter {
        let mut p = devices::xavier();
        p.meter.interval_s = interval;
        p.meter.noise_frac = 0.0;
        p.meter.quantum_w = 0.0;
        p.meter.wakeup_rate = 0.0;
        Meter::new(&p, Pcg64::new(1))
    }

    #[test]
    fn integrates_constant_power_exactly() {
        let mut m = quiet_meter(0.01);
        m.advance(10.0, 2.0); // 10 W for 2 s = 20 J
        let (e, t) = m.finish();
        assert!((t - 2.0).abs() < 1e-12);
        assert!((e - 20.0).abs() < 0.2, "{e}"); // within one sample
    }

    #[test]
    fn piecewise_power_integrates() {
        let mut m = quiet_meter(0.001);
        m.advance(5.0, 1.0);
        m.advance(15.0, 1.0);
        let (e, _) = m.finish();
        assert!((e - 20.0).abs() < 0.1, "{e}");
    }

    #[test]
    fn coarser_sampling_is_noisier_wrt_short_runs() {
        // Fig A16 mechanism: few samples => unstable estimates.
        let run = |interval: f64, seed: u64| {
            let mut p = devices::oppo();
            p.meter.interval_s = interval;
            let mut m = Meter::new(&p, Pcg64::new(seed));
            // alternating power bursts
            for i in 0..40 {
                m.advance(if i % 2 == 0 { 3.0 } else { 8.0 }, 0.013);
            }
            m.finish().0
        };
        let spread = |interval: f64| {
            let xs: Vec<f64> = (0..20).map(|s| run(interval, s)).collect();
            crate::util::stats::std_dev(&xs) / crate::util::stats::mean(&xs)
        };
        assert!(spread(0.1) > spread(0.005), "{} {}", spread(0.1), spread(0.005));
    }

    #[test]
    fn noise_is_unbiased() {
        let mut p = devices::server();
        p.meter.wakeup_rate = 0.0;
        let mut sum = 0.0;
        let n = 50;
        for seed in 0..n {
            let mut m = Meter::new(&p, Pcg64::new(seed));
            m.advance(100.0, 1.0);
            sum += m.finish().0;
        }
        let mean = sum / n as f64;
        assert!((mean - 100.0).abs() < 2.0, "{mean}");
    }

    #[test]
    fn wakeups_add_energy() {
        let mut p = devices::oppo();
        p.meter.noise_frac = 0.0;
        p.meter.quantum_w = 0.0;
        p.meter.wakeup_rate = 5.0; // frequent
        let mut with = Meter::new(&p, Pcg64::new(3));
        with.advance(5.0, 10.0);
        let (e_with, _) = with.finish();
        p.meter.wakeup_rate = 0.0;
        let mut without = Meter::new(&p, Pcg64::new(3));
        without.advance(5.0, 10.0);
        let (e_without, _) = without.finish();
        assert!(e_with > e_without, "{e_with} vs {e_without}");
    }
}
