//! Dense linear algebra for the GP library: column-major-free simple
//! row-major matrices, Cholesky factorization, triangular solves and a
//! symmetric inverse.  Sizes are small (inducing sets ≤ 128), so clarity
//! beats blocking; the hot path (posterior over many query points) runs
//! through the AOT Pallas artifact instead.

/// Row-major dense matrix.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Re-dimension in place, keeping the backing allocation when it is
    /// already large enough (the workspace buffers of the GP fit engine).
    /// Contents are unspecified afterwards — every caller overwrites.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Grow a square matrix by one zero row and one zero column, in
    /// place, preserving the existing entries (the bordered-Cholesky
    /// update appends into the new row).
    pub fn grow_square(&mut self) {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let m = n + 1;
        self.data.resize(m * m, 0.0);
        // Re-stride from n to n+1 back to front so rows never overlap.
        for i in (0..n).rev() {
            for j in (0..n).rev() {
                self.data[i * m + j] = self.data[i * n + j];
            }
        }
        // Zero the new column of every old row and the new last row.
        for i in 0..n {
            self.data[i * m + n] = 0.0;
        }
        for j in 0..m {
            self.data[n * m + j] = 0.0;
        }
        self.rows = m;
        self.cols = m;
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
/// Returns None if A is not (numerically) positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// In-place [`cholesky`]: factor `a` into the caller's `l` buffer
/// (resized to match), writing the same values as the allocating
/// version.  Returns `false` when `a` is not (numerically) positive
/// definite — `l` then holds a partial factor and must not be used.
pub fn cholesky_into(a: &Mat, l: &mut Mat) -> bool {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    l.resize(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return false;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
        // keep the strict upper triangle zeroed (the buffer is reused)
        for j in i + 1..n {
            l[(i, j)] = 0.0;
        }
    }
    true
}

/// Bordered Cholesky update: given the factor `l` of the leading n×n
/// block A, grow it in place to the factor of the (n+1)×(n+1) matrix
/// whose appended row/column is `row` (`row[j] = A'[n][j]` for j ≤ n).
/// This performs exactly the arithmetic [`cholesky`] would perform on
/// the last row of the bordered matrix, so the result is bit-identical
/// to a from-scratch factorization.  Returns `false` (leaving `l`
/// grown but with an unusable last row) when the bordered matrix is
/// not positive definite.
pub fn cholesky_append_row(l: &mut Mat, row: &[f64]) -> bool {
    let n = l.rows;
    assert_eq!(row.len(), n + 1);
    l.grow_square();
    for j in 0..n {
        let mut s = row[j];
        for k in 0..j {
            s -= l[(n, k)] * l[(j, k)];
        }
        l[(n, j)] = s / l[(j, j)];
    }
    let mut s = row[n];
    for k in 0..n {
        s -= l[(n, k)] * l[(n, k)];
    }
    if s <= 0.0 {
        return false;
    }
    l[(n, n)] = s.sqrt();
    true
}

/// Rank-1 Cholesky *downdate* by row removal, companion to
/// [`cholesky_append_row`]: given the factor `l` of an n×n SPD matrix A,
/// shrink it in place to the factor of the (n−1)×(n−1) matrix obtained
/// by deleting row and column `r` of A — without refactoring from
/// scratch (O(n²) for the trailing block instead of O(n³) overall).
///
/// The leading r×r block of the factor is untouched, so those rows stay
/// bit-identical to a from-scratch factorization of the reduced matrix;
/// removing the *last* row is a pure truncation and therefore bit-exact
/// everywhere.  For an interior row the trailing block is repaired by a
/// hypotenuse-form rank-1 update (L₂₂L₂₂ᵀ + vvᵀ with v the removed
/// column below the pivot), which performs different — though
/// numerically equivalent — arithmetic from a fresh factorization.
///
/// The +vvᵀ update of an SPD trailing block is itself SPD, so this
/// cannot fail on a valid factor.
pub fn cholesky_remove_row(l: &mut Mat, r: usize) {
    assert_eq!(l.rows, l.cols);
    let n = l.rows;
    assert!(r < n);
    let m = n - 1;
    // Save the removed column below the pivot before the shift clobbers it.
    let v: Vec<f64> = (r + 1..n).map(|i| l[(i, r)]).collect();
    // Drop row r and column r, re-striding front to back.  Safe in place:
    // every source offset (si·n + sj with si ≥ i, sj ≥ j, n > m) is ≥ its
    // destination offset (i·m + j), so reads always see original data.
    for i in 0..m {
        let si = if i < r { i } else { i + 1 };
        for j in 0..m {
            let sj = if j < r { j } else { j + 1 };
            l.data[i * m + j] = l.data[si * n + sj];
        }
    }
    l.data.truncate(m * m);
    l.rows = m;
    l.cols = m;
    // Rank-1 update of the trailing block: rows r.. of the shifted factor
    // currently satisfy L₂₂L₂₂ᵀ = A₂₂ − vvᵀ; fold vvᵀ back in column by
    // column with stable hypotenuse rotations.
    let mut v = v;
    for k in r..m {
        let lkk = l[(k, k)];
        let vk = v[k - r];
        let rr = (lkk * lkk + vk * vk).sqrt();
        let c = rr / lkk;
        let s = vk / lkk;
        l[(k, k)] = rr;
        for i in k + 1..m {
            l[(i, k)] = (l[(i, k)] + s * v[i - r]) / c;
            v[i - r] = c * v[i - r] - s * l[(i, k)];
        }
    }
}

/// Solve L x = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve Lᵀ x = b (back substitution), L lower-triangular.
pub fn solve_lower_t(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve A x = b given the Cholesky factor of A.
pub fn chol_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    solve_lower_t(l, &solve_lower(l, b))
}

/// [`solve_lower`] into a caller-provided buffer (no allocation).
pub fn solve_lower_into(l: &Mat, b: &[f64], x: &mut [f64]) {
    let n = l.rows;
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
}

/// [`solve_lower_t`] into a caller-provided buffer (no allocation).
pub fn solve_lower_t_into(l: &Mat, b: &[f64], x: &mut [f64]) {
    let n = l.rows;
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
}

/// [`chol_solve`] through two caller-provided buffers (no allocation):
/// `tmp` receives the forward-solve, `x` the final solution.
pub fn chol_solve_into(l: &Mat, b: &[f64], tmp: &mut [f64], x: &mut [f64]) {
    solve_lower_into(l, b, tmp);
    solve_lower_t_into(l, tmp, x);
}

/// A⁻¹ for SPD A via its Cholesky factor (column-by-column solves).
pub fn chol_inverse(l: &Mat) -> Mat {
    let n = l.rows;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = chol_solve(l, &e);
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
        e[j] = 0.0;
    }
    inv
}

/// [`chol_inverse`] into a caller-provided matrix through one scratch
/// buffer — no allocation at steady state, bit-identical columns (the
/// in-place forward/back substitutions perform exactly the arithmetic
/// [`solve_lower`] / [`solve_lower_t`] perform, in the same order).
pub fn chol_inverse_into(l: &Mat, inv: &mut Mat, tmp: &mut Vec<f64>) {
    let n = l.rows;
    inv.resize(n, n);
    tmp.resize(n, 0.0);
    for j in 0..n {
        // forward solve L y = e_j, in place in tmp
        for i in 0..n {
            let mut s = if i == j { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= l[(i, k)] * tmp[k];
            }
            tmp[i] = s / l[(i, i)];
        }
        // back solve Lᵀ x = y, in place in tmp
        for i in (0..n).rev() {
            let mut s = tmp[i];
            for k in i + 1..n {
                s -= l[(k, i)] * tmp[k];
            }
            tmp[i] = s / l[(i, i)];
        }
        for i in 0..n {
            inv[(i, j)] = tmp[i];
        }
    }
}

/// log det A = 2 Σ log L_ii.
pub fn chol_logdet(l: &Mat) -> f64 {
    (0..l.rows).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64; // well-conditioned
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let l = cholesky(&a).unwrap();
        let llt = l.matmul(&l.transpose());
        for i in 0..12 {
            for j in 0..12 {
                assert!((llt[(i, j)] - a[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_matches_matvec() {
        let a = random_spd(9, 2);
        let l = cholesky(&a).unwrap();
        let x_true: Vec<f64> = (0..9).map(|i| (i as f64) - 4.0).collect();
        let b = a.matvec(&x_true);
        let x = chol_solve(&l, &b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8, "{xi} vs {ti}");
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(8, 3);
        let l = cholesky(&a).unwrap();
        let inv = chol_inverse(&l);
        let prod = a.matmul(&inv);
        for i in 0..8 {
            for j in 0..8 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn logdet_matches_identity_scaling() {
        let mut a = Mat::eye(5);
        for i in 0..5 {
            a[(i, i)] = 2.0;
        }
        let l = cholesky(&a).unwrap();
        assert!((chol_logdet(&l) - 5.0 * 2.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn cholesky_into_matches_allocating_bitwise() {
        let a = random_spd(14, 7);
        let l_alloc = cholesky(&a).unwrap();
        let mut l = Mat::zeros(1, 1); // wrong size on purpose: resize path
        assert!(cholesky_into(&a, &mut l));
        assert_eq!(l.rows, 14);
        assert_eq!(l.data, l_alloc.data, "in-place factor diverged");
        // reuse of a dirty buffer must still match (upper re-zeroed)
        let b = random_spd(9, 8);
        let lb = cholesky(&b).unwrap();
        assert!(cholesky_into(&b, &mut l));
        assert_eq!(l.data, lb.data);
    }

    #[test]
    fn cholesky_into_rejects_indefinite() {
        let mut a = Mat::eye(4);
        a[(3, 3)] = -2.0;
        let mut l = Mat::zeros(4, 4);
        assert!(!cholesky_into(&a, &mut l));
    }

    #[test]
    fn grow_square_preserves_entries() {
        let mut m = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                m[(i, j)] = (10 * i + j) as f64;
            }
        }
        m.grow_square();
        assert_eq!((m.rows, m.cols), (4, 4));
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], (10 * i + j) as f64);
            }
            assert_eq!(m[(i, 3)], 0.0);
        }
        for j in 0..4 {
            assert_eq!(m[(3, j)], 0.0);
        }
    }

    #[test]
    fn prop_cholesky_append_row_matches_scratch() {
        use crate::util::proptest::{check, Config};
        check(
            "bordered cholesky == from-scratch",
            Config { cases: 60, seed: 21 },
            |r| (r.range_usize(2, 16), r.next_u64()),
            |&(n, seed)| {
                let a = random_spd(n, seed);
                // factor the leading (n-1)×(n-1) block, then border with
                // the last row/column of the full matrix
                let mut lead = Mat::zeros(n - 1, n - 1);
                for i in 0..n - 1 {
                    for j in 0..n - 1 {
                        lead[(i, j)] = a[(i, j)];
                    }
                }
                let mut l = cholesky(&lead).expect("leading block PD");
                let row: Vec<f64> = (0..n).map(|j| a[(n - 1, j)]).collect();
                crate::prop_assert!(cholesky_append_row(&mut l, &row), "bordered not PD");
                let full = cholesky(&a).expect("full PD");
                for i in 0..n {
                    for j in 0..n {
                        let (got, want) = (l[(i, j)], full[(i, j)]);
                        crate::prop_assert!(
                            (got - want).abs() < 1e-10 * want.abs().max(1.0),
                            "L[{i}][{j}] = {got} vs {want}"
                        );
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_cholesky_remove_row_matches_scratch() {
        use crate::util::proptest::{check, Config};
        check(
            "cholesky downdate == from-scratch",
            Config { cases: 60, seed: 22 },
            |r| {
                let n = r.range_usize(2, 14);
                (n, r.range_usize(0, n - 1), r.next_u64())
            },
            |&(n, rm, seed)| {
                let a = random_spd(n, seed);
                let mut l = cholesky(&a).expect("full PD");
                cholesky_remove_row(&mut l, rm);
                // from-scratch factor of A with row/col `rm` deleted
                let mut b = Mat::zeros(n - 1, n - 1);
                for i in 0..n - 1 {
                    let si = if i < rm { i } else { i + 1 };
                    for j in 0..n - 1 {
                        let sj = if j < rm { j } else { j + 1 };
                        b[(i, j)] = a[(si, sj)];
                    }
                }
                let want = cholesky(&b).expect("reduced PD");
                for i in 0..n - 1 {
                    for j in 0..n - 1 {
                        let (got, w) = (l[(i, j)], want[(i, j)]);
                        if i < rm {
                            // leading block untouched: bit-identical
                            crate::prop_assert!(
                                got.to_bits() == w.to_bits(),
                                "leading row L[{i}][{j}] = {got} vs {w} (rm={rm})"
                            );
                        } else {
                            crate::prop_assert!(
                                (got - w).abs() < 1e-9 * w.abs().max(1.0),
                                "L[{i}][{j}] = {got} vs {w} (rm={rm})"
                            );
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cholesky_remove_last_row_is_bit_exact_truncation() {
        let a = random_spd(10, 41);
        let mut l = cholesky(&a).unwrap();
        cholesky_remove_row(&mut l, 9);
        let mut lead = Mat::zeros(9, 9);
        for i in 0..9 {
            for j in 0..9 {
                lead[(i, j)] = a[(i, j)];
            }
        }
        let want = cholesky(&lead).unwrap();
        assert_eq!(l.data, want.data, "last-row downdate must be a pure truncation");
    }

    #[test]
    fn cholesky_remove_then_append_roundtrips() {
        // remove an interior row, append it back at the end: the result
        // must factor the permuted matrix to tight tolerance
        let a = random_spd(8, 55);
        let mut l = cholesky(&a).unwrap();
        cholesky_remove_row(&mut l, 3);
        let order: Vec<usize> = (0..8).filter(|&i| i != 3).chain([3]).collect();
        let row: Vec<f64> = order.iter().map(|&j| a[(3, j)]).collect();
        assert!(cholesky_append_row(&mut l, &row));
        let mut perm = Mat::zeros(8, 8);
        for (i, &si) in order.iter().enumerate() {
            for (j, &sj) in order.iter().enumerate() {
                perm[(i, j)] = a[(si, sj)];
            }
        }
        let want = cholesky(&perm).unwrap();
        for i in 0..8 {
            for j in 0..8 {
                assert!(
                    (l[(i, j)] - want[(i, j)]).abs() < 1e-9 * want[(i, j)].abs().max(1.0),
                    "L[{i}][{j}] = {} vs {}",
                    l[(i, j)],
                    want[(i, j)]
                );
            }
        }
    }

    #[test]
    fn chol_inverse_into_matches_allocating_bitwise() {
        let a = random_spd(13, 6);
        let l = cholesky(&a).unwrap();
        let want = chol_inverse(&l);
        let mut inv = Mat::zeros(1, 1); // wrong size on purpose: resize path
        let mut tmp = Vec::new();
        chol_inverse_into(&l, &mut inv, &mut tmp);
        assert_eq!(inv.data, want.data, "in-place inverse diverged");
        // dirty-buffer reuse must still match
        let b = random_spd(7, 12);
        let lb = cholesky(&b).unwrap();
        let want_b = chol_inverse(&lb);
        chol_inverse_into(&lb, &mut inv, &mut tmp);
        assert_eq!(inv.data, want_b.data);
    }

    #[test]
    fn solve_into_matches_allocating() {
        let a = random_spd(11, 9);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..11).map(|i| (i as f64) - 3.0).collect();
        let want = chol_solve(&l, &b);
        let mut tmp = vec![0.0; 11];
        let mut x = vec![0.0; 11];
        chol_solve_into(&l, &b, &mut tmp, &mut x);
        assert_eq!(x, want, "buffered solve diverged");
    }

    #[test]
    fn triangular_solves_roundtrip() {
        let a = random_spd(6, 4);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..6).map(|i| i as f64 + 1.0).collect();
        let y = solve_lower(&l, &b);
        // L y should reconstruct b
        for i in 0..6 {
            let s: f64 = (0..=i).map(|k| l[(i, k)] * y[k]).sum();
            assert!((s - b[i]).abs() < 1e-9);
        }
    }
}
