//! Deterministic PCG-XSL-RR 128/64 PRNG.
//!
//! Everything stochastic in the repo (device noise, architecture sampling,
//! pruning search, property tests) flows through this generator so that
//! every experiment is reproducible from a seed.

/// PCG-XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
}

const MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
const INC: u128 = 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        let mut rng = Self { state: (seed as u128).wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1 };
        // Warm up so nearby seeds decorrelate.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent stream (for per-device / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Self {
        Self::new(self.next_u64() ^ tag.wrapping_mul(0xd134_2543_de82_ef95))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(INC);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Poisson(lambda) via Knuth (lambda is always small here).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // guard against pathological lambda
            }
        }
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Pcg64::new(13);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.poisson(3.0) as f64).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "{m}");
    }

    #[test]
    fn range_usize_bounds() {
        let mut r = Pcg64::new(17);
        let mut seen = [false; 5];
        for _ in 0..500 {
            let v = r.range_usize(2, 6);
            assert!((2..=6).contains(&v));
            seen[v - 2] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Pcg64::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
