//! Readiness polling without `libc`/`mio`: a minimal [`Poller`] over
//! raw-FFI `epoll` (Linux) with a portable `poll(2)` fallback on other
//! unix, plus a [`WakePipe`] for waking a blocked wait from another
//! thread.  Non-unix hosts get a stub whose constructor errors cleanly,
//! so `--io-model reactor` degrades to a startup error there instead of
//! a compile failure (`--io-model threads` remains fully portable).
//!
//! Semantics are deliberately the lowest common denominator the reactor
//! needs: **level-triggered** readiness (an event repeats every wait
//! until the condition is consumed), one interest set per fd, and a
//! caller-chosen `u64` token per registration.  Error/hangup conditions
//! are folded into `readable`/`writable` (and flagged via
//! [`Event::hangup`]) so handlers discover them through the usual
//! `read()`/`write()` return paths — the same convention mio and libuv
//! settled on.

use std::io;
use std::time::Duration;

/// File descriptor (matches `std::os::unix::io::RawFd` on unix; a dummy
/// on other hosts so signatures stay portable).
pub type Fd = i32;

/// Extract the raw fd of a socket/pipe without the caller naming the
/// unix-only `AsRawFd` trait (keeps the reactor compiling off-unix).
#[cfg(unix)]
pub fn fd_of<T: std::os::unix::io::AsRawFd>(t: &T) -> Fd {
    t.as_raw_fd()
}

/// Non-unix stub: never reached at runtime ([`Poller::new`] errors
/// first), but keeps call sites compiling.
#[cfg(not(unix))]
pub fn fd_of<T>(_t: &T) -> Fd {
    -1
}

/// One readiness event from [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token passed at registration.
    pub token: u64,
    /// Readable (includes EOF, peer hangup, and error conditions — a
    /// `read()` will resolve them without blocking).
    pub readable: bool,
    /// Writable (includes error conditions — a `write()` will surface
    /// them without blocking).
    pub writable: bool,
    /// The peer hung up or the fd errored; informational (the
    /// readable/writable flags already route the handler correctly).
    pub hangup: bool,
}

#[cfg(unix)]
fn timeout_ms(t: Option<Duration>) -> i32 {
    match t {
        None => -1,
        Some(d) if d.is_zero() => 0,
        Some(d) => {
            // Round sub-millisecond timeouts *up* so a short deadline
            // polls once instead of busy-spinning at 0ms.
            let ms = d.as_millis().max(1);
            ms.min(i32::MAX as u128) as i32
        }
    }
}

// ---------------------------------------------------------------------------
// Linux: epoll.
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod imp {
    use super::{timeout_ms, Event, Fd};
    use std::io;
    use std::os::raw::c_int;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0o2000000;

    /// Kernel ABI struct; packed on x86_64 (the one architecture where
    /// the kernel's layout differs from natural C alignment).
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    fn cvt(ret: c_int) -> io::Result<c_int> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    fn mask(readable: bool, writable: bool) -> u32 {
        let mut m = 0;
        if readable {
            m |= EPOLLIN | EPOLLRDHUP;
        }
        if writable {
            m |= EPOLLOUT;
        }
        m
    }

    pub struct Poller {
        epfd: Fd,
        buf: Vec<EpollEvent>,
    }

    // The epoll fd is plain kernel state; moving it across threads is fine.
    unsafe impl Send for Poller {}

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Poller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 256] })
        }

        fn ctl(&self, op: c_int, fd: Fd, token: u64, m: u32) -> io::Result<()> {
            let mut ev = EpollEvent { events: m, data: token };
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
            Ok(())
        }

        pub fn register(&mut self, fd: Fd, token: u64, r: bool, w: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, mask(r, w))
        }

        pub fn reregister(&mut self, fd: Fd, token: u64, r: bool, w: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, mask(r, w))
        }

        pub fn deregister(&mut self, fd: Fd) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            cvt(unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) })?;
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let ms = timeout_ms(timeout);
            let n = loop {
                let r = unsafe {
                    epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, ms)
                };
                if r >= 0 {
                    break r as usize;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
                // EINTR: retry (worst case we over-wait one timeout).
            };
            for i in 0..n {
                let ev = self.buf[i];
                let bits = ev.events;
                let hup = bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0;
                out.push(Event {
                    token: ev.data,
                    readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                    writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                    hangup: hup,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Other unix: poll(2) over a registration table.
// ---------------------------------------------------------------------------

#[cfg(all(unix, not(target_os = "linux")))]
mod imp {
    use super::{timeout_ms, Event, Fd};
    use std::io;
    use std::os::raw::{c_int, c_short, c_uint};
    use std::time::Duration;

    const POLLIN: c_short = 0x1;
    const POLLOUT: c_short = 0x4;
    const POLLERR: c_short = 0x8;
    const POLLHUP: c_short = 0x10;

    #[repr(C)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_uint, timeout: c_int) -> c_int;
    }

    struct Entry {
        fd: Fd,
        token: u64,
        readable: bool,
        writable: bool,
    }

    pub struct Poller {
        entries: Vec<Entry>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { entries: Vec::new() })
        }

        pub fn register(&mut self, fd: Fd, token: u64, r: bool, w: bool) -> io::Result<()> {
            if self.entries.iter().any(|e| e.fd == fd) {
                return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd registered"));
            }
            self.entries.push(Entry { fd, token, readable: r, writable: w });
            Ok(())
        }

        pub fn reregister(&mut self, fd: Fd, token: u64, r: bool, w: bool) -> io::Result<()> {
            let e = self
                .entries
                .iter_mut()
                .find(|e| e.fd == fd)
                .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "fd not registered"))?;
            e.token = token;
            e.readable = r;
            e.writable = w;
            Ok(())
        }

        pub fn deregister(&mut self, fd: Fd) -> io::Result<()> {
            let before = self.entries.len();
            self.entries.retain(|e| e.fd != fd);
            if self.entries.len() == before {
                return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
            }
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<PollFd> = self
                .entries
                .iter()
                .map(|e| PollFd {
                    fd: e.fd,
                    events: if e.readable { POLLIN } else { 0 }
                        | if e.writable { POLLOUT } else { 0 },
                    revents: 0,
                })
                .collect();
            let ms = timeout_ms(timeout);
            loop {
                let r = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_uint, ms) };
                if r >= 0 {
                    break;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
            }
            for (pf, e) in fds.iter().zip(&self.entries) {
                let bits = pf.revents;
                if bits == 0 {
                    continue;
                }
                let hup = bits & (POLLERR | POLLHUP) != 0;
                out.push(Event {
                    token: e.token,
                    readable: bits & (POLLIN | POLLERR | POLLHUP) != 0,
                    writable: bits & (POLLOUT | POLLERR | POLLHUP) != 0,
                    hangup: hup,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Non-unix: constructor errors; nothing else is reachable.
// ---------------------------------------------------------------------------

#[cfg(not(unix))]
mod imp {
    use super::{Event, Fd};
    use std::io;
    use std::time::Duration;

    pub struct Poller;

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "readiness polling requires a unix host (epoll/poll)",
            ))
        }

        pub fn register(&mut self, _: Fd, _: u64, _: bool, _: bool) -> io::Result<()> {
            unreachable!("Poller::new always errors off-unix")
        }

        pub fn reregister(&mut self, _: Fd, _: u64, _: bool, _: bool) -> io::Result<()> {
            unreachable!("Poller::new always errors off-unix")
        }

        pub fn deregister(&mut self, _: Fd) -> io::Result<()> {
            unreachable!("Poller::new always errors off-unix")
        }

        pub fn wait(&mut self, _: &mut Vec<Event>, _: Option<Duration>) -> io::Result<()> {
            unreachable!("Poller::new always errors off-unix")
        }
    }
}

pub use imp::Poller;

// ---------------------------------------------------------------------------
// WakePipe: a self-pipe for waking a blocked Poller::wait.
// ---------------------------------------------------------------------------

/// A non-blocking pipe whose read end is registered with the [`Poller`]:
/// any thread calls [`WakePipe::wake`] to make a blocked `wait` return.
/// Writes to a full pipe are dropped (a wake is already pending — the
/// semantics are a saturating flag, not a counter), so `wake` never
/// blocks and is safe from any thread.
#[cfg(unix)]
pub struct WakePipe {
    r: Fd,
    w: Fd,
}

#[cfg(unix)]
mod wake_imp {
    use super::{Fd, WakePipe};
    use std::io;
    use std::os::raw::c_int;

    extern "C" {
        fn close(fd: c_int) -> c_int;
        fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
        fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
    }

    #[cfg(target_os = "linux")]
    fn make_pipe() -> io::Result<[Fd; 2]> {
        extern "C" {
            fn pipe2(fds: *mut c_int, flags: c_int) -> c_int;
        }
        const O_NONBLOCK: c_int = 0o4000;
        const O_CLOEXEC: c_int = 0o2000000;
        let mut fds: [c_int; 2] = [-1, -1];
        if unsafe { pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(fds)
    }

    #[cfg(not(target_os = "linux"))]
    fn make_pipe() -> io::Result<[Fd; 2]> {
        extern "C" {
            fn pipe(fds: *mut c_int) -> c_int;
            fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
        }
        const F_SETFL: c_int = 4;
        #[cfg(target_os = "macos")]
        const O_NONBLOCK: c_int = 0x0004;
        #[cfg(not(target_os = "macos"))]
        const O_NONBLOCK: c_int = 0o4000;
        let mut fds: [c_int; 2] = [-1, -1];
        if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        for fd in fds {
            if unsafe { fcntl(fd, F_SETFL, O_NONBLOCK) } < 0 {
                let e = io::Error::last_os_error();
                unsafe {
                    close(fds[0]);
                    close(fds[1]);
                }
                return Err(e);
            }
        }
        Ok(fds)
    }

    impl WakePipe {
        pub fn new() -> io::Result<WakePipe> {
            let [r, w] = make_pipe()?;
            Ok(WakePipe { r, w })
        }

        /// The end to register with the poller (read interest).
        pub fn read_fd(&self) -> Fd {
            self.r
        }

        /// Wake a blocked `wait`.  Never blocks; a full pipe means a
        /// wake is already pending, which is all we need.
        pub fn wake(&self) {
            let buf = [1u8];
            unsafe {
                let _ = write(self.w, buf.as_ptr(), 1);
            }
        }

        /// Consume pending wake bytes (call on the wake event, before
        /// handling completions, so a wake arriving mid-drain re-arms).
        pub fn drain(&self) {
            let mut buf = [0u8; 64];
            loop {
                let n = unsafe { read(self.r, buf.as_mut_ptr(), buf.len()) };
                if n <= 0 {
                    break;
                }
            }
        }
    }

    impl Drop for WakePipe {
        fn drop(&mut self) {
            unsafe {
                close(self.r);
                close(self.w);
            }
        }
    }
}

/// Non-unix stub (constructor errors, like [`Poller::new`]).
#[cfg(not(unix))]
pub struct WakePipe;

#[cfg(not(unix))]
impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        Err(io::Error::new(io::ErrorKind::Unsupported, "wake pipe requires a unix host"))
    }

    pub fn read_fd(&self) -> Fd {
        -1
    }

    pub fn wake(&self) {}

    pub fn drain(&self) {}
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    const SHORT: Duration = Duration::from_millis(500);

    #[test]
    fn wake_pipe_levels_and_drains() {
        let mut p = Poller::new().unwrap();
        let wp = WakePipe::new().unwrap();
        p.register(wp.read_fd(), 7, true, false).unwrap();
        let mut evs = Vec::new();

        // Nothing pending: a zero timeout returns immediately, empty.
        p.wait(&mut evs, Some(Duration::ZERO)).unwrap();
        assert!(evs.is_empty());

        // A wake (even several) makes wait return with the right token;
        // level-triggered, so it repeats until drained.
        wp.wake();
        wp.wake();
        p.wait(&mut evs, Some(SHORT)).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].token, 7);
        assert!(evs[0].readable);
        p.wait(&mut evs, Some(SHORT)).unwrap();
        assert!(!evs.is_empty(), "level-triggered: undrained pipe stays ready");
        wp.drain();
        p.wait(&mut evs, Some(Duration::ZERO)).unwrap();
        assert!(evs.is_empty(), "drained pipe is quiet");
    }

    #[test]
    fn socket_readable_and_writable_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        client.set_nonblocking(true).unwrap();

        let mut p = Poller::new().unwrap();
        // A fresh connected socket: writable, not readable.
        p.register(fd_of(&client), 1, true, true).unwrap();
        let mut evs = Vec::new();
        p.wait(&mut evs, Some(SHORT)).unwrap();
        assert!(evs.iter().any(|e| e.token == 1 && e.writable && !e.readable));

        // Bytes from the peer flip it readable.
        server.write_all(b"ping").unwrap();
        server.flush().unwrap();
        // Wait for readable (may need a few polls for loopback delivery).
        let mut saw_readable = false;
        for _ in 0..50 {
            p.wait(&mut evs, Some(SHORT)).unwrap();
            if evs.iter().any(|e| e.token == 1 && e.readable) {
                saw_readable = true;
                break;
            }
        }
        assert!(saw_readable, "peer bytes never became readable");

        // Interest is dynamic: read-only registration stops write events.
        p.reregister(fd_of(&client), 1, true, false).unwrap();
        p.wait(&mut evs, Some(SHORT)).unwrap();
        assert!(evs.iter().all(|e| !e.writable || e.hangup));
        let mut buf = [0u8; 8];
        let mut c = &client;
        assert_eq!(c.read(&mut buf).unwrap(), 4);

        // Deregistered fds report nothing.
        p.deregister(fd_of(&client)).unwrap();
        server.write_all(b"more").unwrap();
        p.wait(&mut evs, Some(Duration::from_millis(50))).unwrap();
        assert!(evs.iter().all(|e| e.token != 1));
    }

    #[test]
    fn hangup_reports_readable_for_eof_discovery() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        client.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.register(fd_of(&client), 3, true, false).unwrap();
        drop(server); // peer closes
        let mut evs = Vec::new();
        let mut saw = false;
        for _ in 0..50 {
            p.wait(&mut evs, Some(SHORT)).unwrap();
            if let Some(e) = evs.iter().find(|e| e.token == 3) {
                assert!(e.readable, "hangup must be discoverable via read()");
                saw = true;
                break;
            }
        }
        assert!(saw, "peer close never surfaced");
        let mut c = &client;
        let mut buf = [0u8; 8];
        assert_eq!(c.read(&mut buf).unwrap(), 0, "EOF");
    }
}
