//! Thin OS-interface shims the repo would normally pull a crate for.
//! Offline we have no `libc`/`mio`, so the handful of raw syscalls the
//! reactor serving model needs (readiness polling, a wakeup pipe) live
//! here behind a portable API — epoll on Linux, `poll(2)` on other unix
//! ([`poll::Poller`]), and a stub that errors cleanly elsewhere.

pub mod poll;

pub use poll::{Event, Poller, WakePipe};
