//! Statistics helpers shared by the estimator, the experiments and the
//! bench harness: MAPE (the paper's metric, eq. 5), correlation (Fig 6),
//! percentiles and CDFs (Fig 10), simple linear regression (the FLOPs
//! baseline).

/// Mean Absolute Percentage Error, paper eq. (5), in percent.
pub fn mape(actual: &[f64], estimated: &[f64]) -> f64 {
    assert_eq!(actual.len(), estimated.len());
    assert!(!actual.is_empty());
    let s: f64 = actual
        .iter()
        .zip(estimated)
        .map(|(a, e)| ((a - e) / a).abs())
        .sum();
    100.0 * s / actual.len() as f64
}

pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Standard error of the mean (the paper reports mean ± SE over 3 repeats).
pub fn std_err(xs: &[f64]) -> f64 {
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Pearson correlation coefficient (Fig 6: time vs energy).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    num / (dx.sqrt() * dy.sqrt()).max(1e-300)
}

/// p-th percentile (0..=100), linear interpolation, on a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Empirical CDF evaluated at `grid` points (Fig 10 ResNet error CDF).
pub fn cdf(xs: &[f64], grid: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    grid.iter()
        .map(|g| {
            let cnt = v.partition_point(|x| x <= g);
            cnt as f64 / v.len() as f64
        })
        .collect()
}

/// Ordinary least squares y = a*x + b. Returns (a, b).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let a = if den.abs() < 1e-300 { 0.0 } else { num / den };
    (a, my - a * mx)
}

/// Relative error |a - e| / |a| (unsigned, fraction not percent).
pub fn rel_err(actual: f64, estimated: f64) -> f64 {
    ((actual - estimated) / actual).abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mape_zero_for_exact() {
        assert_eq!(mape(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn mape_known_value() {
        // errors: 10%, 20% -> MAPE 15%
        let m = mape(&[10.0, 10.0], &[11.0, 8.0]);
        assert!((m - 15.0).abs() < 1e-9, "{m}");
    }

    #[test]
    fn pearson_perfect_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_median() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let xs = [0.1, 0.5, 0.9, 0.3];
        let grid: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
        let c = cdf(&xs, &grid);
        assert_eq!(*c.last().unwrap(), 1.0);
        for w in c.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9 && (b - 7.0).abs() < 1e-6);
    }

    #[test]
    fn std_err_shrinks_with_n() {
        let xs4 = [1.0, 2.0, 3.0, 4.0];
        let xs16: Vec<f64> = xs4.repeat(4);
        assert!(std_err(&xs16) < std_err(&xs4));
    }
}
