//! Mini property-testing harness (the `proptest` crate is unavailable
//! offline).  Deterministic seeded generation, configurable case counts,
//! and first-failure reporting with the generating seed so a failure is
//! reproducible by construction.
//!
//! Used for the coordinator invariants (routing, batching, state machine),
//! the GP algebra, the JSON codec and the layer parser.

use crate::util::rng::Pcg64;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xC0FFEE }
    }
}

/// Run `prop` against `cases` inputs produced by `gen`.
/// Panics with the case index + seed on the first falsified case.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Pcg64::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' falsified at case {case} (seed {:#x}):\n  input: {input:?}\n  reason: {msg}",
                cfg.seed
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        check("sum-commutes", Config::default(), |r| (r.f64(), r.f64()), |(a, b)| {
            prop_assert!((a + b - (b + a)).abs() < 1e-15, "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn fails_false_property() {
        check(
            "always-small",
            Config { cases: 64, seed: 1 },
            |r| r.range_usize(0, 100),
            |&n| {
                prop_assert!(n < 50, "n = {n}");
                Ok(())
            },
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen_a = Vec::new();
        let mut seen_b = Vec::new();
        for out in [&mut seen_a, &mut seen_b] {
            check("collect", Config { cases: 10, seed: 7 }, |r| r.next_u64(), |&v| {
                out.push(v);
                Ok(())
            });
        }
        assert_eq!(seen_a, seen_b);
    }
}
