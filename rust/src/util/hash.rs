//! Seed-derivation hashing: FNV-1a, chosen because it is trivially
//! stable across platforms and releases (unlike `DefaultHasher`), so
//! golden files, per-experiment seeds and fleet job seeds never shift
//! underneath a refactor.  Both [`crate::exp::ExpConfig::derive_seed`]
//! and [`crate::coordinator::worker::job_seed`] fold through this one
//! implementation.

/// Incremental FNV-1a over byte chunks.
pub struct Fnv1a(u64);

impl Fnv1a {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Self {
        Self(Self::OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for b in bytes {
            self.0 = (self.0 ^ *b as u64).wrapping_mul(Self::PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // FNV-1a of the empty input is the offset basis; of "a" is the
        // published 64-bit test vector.
        assert_eq!(Fnv1a::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv1a::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn chunking_is_transparent() {
        let mut a = Fnv1a::new();
        a.write(b"hello world");
        let mut b = Fnv1a::new();
        b.write(b"hello");
        b.write(b" world");
        assert_eq!(a.finish(), b.finish());
    }
}
