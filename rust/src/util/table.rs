//! ASCII table / series printers for the experiment harness.  Every bench
//! prints the same rows the paper's tables and figures report, through
//! these helpers, so `cargo bench` output is directly comparable with the
//! paper.

/// Render a table with a header row; columns are padded to the widest cell.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncol, "row arity mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let sep: String = widths
        .iter()
        .map(|w| format!("+{}", "-".repeat(w + 2)))
        .collect::<String>()
        + "+\n";
    let fmt_row = |cells: &[String]| -> String {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("| {:width$} ", c, width = widths[i]));
        }
        s.push_str("|\n");
        s
    };
    let mut out = sep.clone();
    out.push_str(&fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    out.push_str(&sep);
    for row in rows {
        out.push_str(&fmt_row(row));
    }
    out.push_str(&sep);
    out
}

/// A labelled (x, y) series, printed as aligned columns (the "figure"
/// analogue: pipe into any plotting tool to regenerate the paper's plot).
pub fn render_series(title: &str, xlabel: &str, series: &[(&str, &[(f64, f64)])]) -> String {
    let mut out = format!("# {title}\n");
    out.push_str(&format!("# {:>12}", xlabel));
    for (name, _) in series {
        out.push_str(&format!(" {:>14}", name));
    }
    out.push('\n');
    let n = series.iter().map(|(_, pts)| pts.len()).max().unwrap_or(0);
    for i in 0..n {
        let x = series
            .iter()
            .find_map(|(_, pts)| pts.get(i).map(|p| p.0))
            .unwrap_or(f64::NAN);
        out.push_str(&format!("  {x:>12.4}"));
        for (_, pts) in series {
            match pts.get(i) {
                Some(p) => out.push_str(&format!(" {:>14.6}", p.1)),
                None => out.push_str(&format!(" {:>14}", "-")),
            }
        }
        out.push('\n');
    }
    out
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let s = render(
            &["device", "MAPE"],
            &[
                vec!["OPPO".into(), "9.1".into()],
                vec!["iPhone".into(), "11.3".into()],
            ],
        );
        assert!(s.contains("| device | MAPE |"));
        assert!(s.contains("| OPPO   | 9.1  |"));
        // all lines same width
        let w: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(w.windows(2).all(|p| p[0] == p[1]));
    }

    #[test]
    fn renders_series_with_missing_points() {
        let a = [(1.0, 2.0), (2.0, 3.0)];
        let b = [(1.0, 5.0)];
        let s = render_series("t", "x", &[("a", &a), ("b", &b)]);
        assert!(s.lines().count() == 4);
        assert!(s.contains('-'));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_ragged_rows() {
        render(&["a", "b"], &[vec!["1".into()]]);
    }
}
