//! Small self-contained substrates (no external crates are available
//! offline beyond `xla`/`anyhow`/`thiserror`, so the JSON codec, CLI
//! parser, stats, bench harness and property-testing harness live here).

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod linalg;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sys;
pub mod table;
