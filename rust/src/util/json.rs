//! Minimal JSON codec (serde is not available offline).
//!
//! Used for: the artifact manifest written by `python/compile/aot.py`, the
//! persisted GP stores (`thor::store`), and the coordinator's line-delimited
//! wire protocol.  Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (sufficient: all our payloads are ASCII).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[1.0, 2.0]` -> Vec<f64> (used heavily by the GP store / protocol).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---- constructors ----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.25", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip_float_vec() {
        let xs = vec![1.5, -2.25, 1e-9, 123456.0];
        let j = Json::arr_f64(&xs);
        let back = Json::parse(&j.to_string()).unwrap().as_f64_vec().unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn float_roundtrip_is_bit_exact() {
        // The coordinator wire protocol and the GP store lean on this:
        // Rust's f64 Display is shortest-roundtrip, so Num → text → Num
        // preserves the exact bit pattern (this is what lets a fleet-
        // profiled store be byte-identical to a local one).
        let mut rng = crate::util::rng::Pcg64::new(99);
        for _ in 0..500 {
            let x = match rng.range_usize(0, 3) {
                0 => rng.normal() * 1e-9,
                1 => rng.normal(),
                2 => rng.normal() * 1e12,
                _ => (rng.range_usize(0, 1 << 20)) as f64,
            };
            let back = Json::parse(&Json::Num(x).to_string()).unwrap().as_f64().unwrap();
            assert_eq!(x.to_bits(), back.to_bits(), "{x} reparsed as {back}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let s = r#"{"gp_posterior_d1": {"dim": 1, "file": "gp_posterior_d1.hlo.txt",
                    "inputs": ["xq","xi","alpha","kinv","lengthscale","variance"],
                    "n_inducing": 64, "n_queries": 256}}"#;
        let v = Json::parse(s).unwrap();
        let e = v.get("gp_posterior_d1").unwrap();
        assert_eq!(e.get("n_inducing").unwrap().as_usize().unwrap(), 64);
        assert_eq!(e.get("inputs").unwrap().as_arr().unwrap().len(), 6);
    }

    #[test]
    fn escape_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{7}".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"Matérn ν=2.5\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "Matérn ν=2.5");
    }
}
