//! Measurement harness for `cargo bench` targets (criterion is not
//! available offline).  Provides warmup, a fixed-iteration or
//! fixed-duration loop, and mean/p50/p95 reporting — enough to drive the
//! §Perf optimization loop with before/after numbers.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    /// Structured form for the perf-trajectory emitters (`cargo bench
    /// --bench hotpath -- --json BENCH_<pr>.json`, EXPERIMENTS.md §Perf).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p95_ns", Json::Num(self.p95_ns)),
            ("min_ns", Json::Num(self.min_ns)),
        ])
    }

    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            self.iters.to_string(),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        ]
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, running for at least `budget` after a 10% warmup.
/// Each sample is one call; the result folds all samples.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup: run until 10% of budget is spent (at least once).
    let warm_deadline = Instant::now() + budget.mul_f64(0.1);
    loop {
        f();
        if Instant::now() >= warm_deadline {
            break;
        }
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let deadline = Instant::now() + budget;
    loop {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if Instant::now() >= deadline {
            break;
        }
    }
    summarize(name, samples_ns)
}

/// Benchmark with an exact number of iterations (deterministic workloads).
pub fn bench_n<F: FnMut()>(name: &str, n: usize, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples_ns = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(name, samples_ns)
}

fn summarize(name: &str, mut samples_ns: Vec<f64>) -> BenchResult {
    assert!(!samples_ns.is_empty());
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: samples_ns[n / 2],
        p95_ns: samples_ns[(n as f64 * 0.95) as usize % n],
        min_ns: samples_ns[0],
    }
}

/// Prevent the optimizer from eliding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_n_counts_iters() {
        let r = bench_n("noop", 50, || {
            black_box(1 + 1);
        });
        assert_eq!(r.iters, 50);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn bench_measures_sleep_scale() {
        let r = bench_n("sleep", 5, || std::thread::sleep(Duration::from_millis(2)));
        assert!(r.mean_ns > 1.5e6, "{}", r.mean_ns);
    }

    #[test]
    fn to_json_carries_all_fields() {
        let r = bench_n("probe", 10, || {
            black_box(2 * 2);
        });
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "probe");
        assert_eq!(j.get("iters").unwrap().as_usize().unwrap(), 10);
        for k in ["mean_ns", "p50_ns", "p95_ns", "min_ns"] {
            assert!(j.get(k).unwrap().as_f64().unwrap() >= 0.0);
        }
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
