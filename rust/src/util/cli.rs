//! Minimal declarative CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments; generates usage text; unknown flags are hard errors.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown flag --{0}")]
    UnknownFlag(String),
    #[error("flag --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    BadValue(String, String),
}

/// Specification of accepted flags: (name, takes_value, help).
pub struct Spec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

pub fn parse(argv: &[String], specs: &[Spec]) -> Result<Args, CliError> {
    let mut out = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(body) = a.strip_prefix("--") {
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n.to_string(), Some(v.to_string())),
                None => (body.to_string(), None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
            if spec.takes_value {
                let v = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?
                    }
                };
                out.flags.insert(name, v);
            } else {
                out.flags.insert(name, "true".to_string());
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

pub fn usage(program: &str, specs: &[Spec]) -> String {
    let mut s = format!("usage: {program} [options] [args...]\n\noptions:\n");
    for spec in specs {
        let arg = if spec.takes_value { format!("--{} <v>", spec.name) } else { format!("--{}", spec.name) };
        s.push_str(&format!("  {arg:<24} {}\n", spec.help));
    }
    s
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.to_string(), v.clone())),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.to_string(), v.clone())),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<Spec> {
        vec![
            Spec { name: "device", takes_value: true, help: "device name" },
            Spec { name: "quick", takes_value: false, help: "quick mode" },
            Spec { name: "seed", takes_value: true, help: "rng seed" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = parse(&sv(&["run", "--device=xavier", "--quick", "--seed", "7", "extra"]), &specs()).unwrap();
        assert_eq!(a.get("device"), Some("xavier"));
        assert!(a.has("quick"));
        assert_eq!(a.get_usize("seed", 0).unwrap(), 7);
        assert_eq!(a.positional(), &["run".to_string(), "extra".to_string()]);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(matches!(parse(&sv(&["--nope"]), &specs()), Err(CliError::UnknownFlag(_))));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(matches!(parse(&sv(&["--device"]), &specs()), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn bad_numeric_value() {
        let a = parse(&sv(&["--seed", "abc"]), &specs()).unwrap();
        assert!(matches!(a.get_usize("seed", 0), Err(CliError::BadValue(..))));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get_usize("seed", 42).unwrap(), 42);
        assert_eq!(a.get_str("device", "server"), "server");
    }
}
