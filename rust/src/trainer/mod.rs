//! Training driver over the PJRT train-step artifact: synthetic datasets
//! with matching shapes (DESIGN.md §2 substitutions for MNIST / CelebA
//! gender) plus the loop that feeds Fig 6 (real wall-clock), Fig 13 and
//! the end-to-end example.

use anyhow::Result;
use std::time::Instant;

use crate::runtime::{trainstep::{StepResult, BATCH, IMG}, Runtime, TrainStep};
use crate::util::rng::Pcg64;

/// Synthetic binary-image task (CelebA-gender stand-in): class is the
/// sign of a smooth spatial template response + noise — learnable by a
/// small CNN but not linearly trivial.
pub struct GenderLikeData {
    rng: Pcg64,
    noise: f64,
}

impl GenderLikeData {
    pub fn new(seed: u64, noise: f64) -> Self {
        Self { rng: Pcg64::new(seed), noise }
    }

    /// Next batch: (images flat NHWC, labels).
    pub fn batch(&mut self) -> (Vec<f32>, Vec<i32>) {
        let mut x = vec![0f32; BATCH * IMG * IMG];
        let mut y = vec![0i32; BATCH];
        for b in 0..BATCH {
            let label = self.rng.bool(0.5);
            y[b] = label as i32;
            // template: vertical gradient for class 1, horizontal for 0
            for i in 0..IMG {
                for j in 0..IMG {
                    let t = if label {
                        (i as f64 / IMG as f64 - 0.5) * 2.0
                    } else {
                        (j as f64 / IMG as f64 - 0.5) * 2.0
                    };
                    x[b * IMG * IMG + i * IMG + j] =
                        (t + self.noise * self.rng.normal()) as f32;
                }
            }
        }
        (x, y)
    }
}

/// Outcome of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// (step, loss) samples.
    pub losses: Vec<(usize, f32)>,
    pub final_train: Option<StepResult>,
    pub eval: Option<StepResult>,
    /// Wall-clock seconds of the pure train-step executions.
    pub step_seconds: f64,
    pub steps: usize,
}

/// Train for `steps` batches; logs loss every `log_every`.
pub fn train(
    rt: &mut Runtime,
    ts: &mut TrainStep,
    data: &mut GenderLikeData,
    steps: usize,
    lr: f32,
    log_every: usize,
) -> Result<TrainReport> {
    let mut report = TrainReport { steps, ..Default::default() };
    let mut last = None;
    for s in 0..steps {
        let (x, y) = data.batch();
        let t0 = Instant::now();
        let r = ts.step(rt, &x, &y, lr)?;
        report.step_seconds += t0.elapsed().as_secs_f64();
        if s % log_every == 0 || s + 1 == steps {
            report.losses.push((s, r.loss));
        }
        last = Some(r);
    }
    report.final_train = last;
    // held-out evaluation on fresh batches
    let mut acc = 0.0;
    let mut loss = 0.0;
    let evals = 8;
    for _ in 0..evals {
        let (x, y) = data.batch();
        let r = ts.eval(rt, &x, &y)?;
        acc += r.acc;
        loss += r.loss;
    }
    report.eval = Some(StepResult { loss: loss / evals as f32, acc: acc / evals as f32 });
    Ok(report)
}
