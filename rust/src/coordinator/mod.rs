//! Decoupled profiling architecture (paper Appendix A5.2): the *fitting
//! server* (leader) owns GP state and picks probe points; *device
//! workers* (clients) run variant trainings and stream measurements
//! back over TCP with a line-delimited JSON protocol.  `std::net` +
//! scoped threads (no async runtime is available offline).
//!
//! Invariants (property-tested in `scheduler`, and promoted to
//! integration level over real sockets in `rust/tests/fleet.rs`):
//! * every issued job is eventually resolved exactly once (no
//!   double-assignment, no loss on worker failure — jobs are re-queued);
//! * per-family measurement order does not affect the final GP (the GP
//!   is permutation-invariant in its training set);
//! * the scheduler terminates once every family converges or exhausts
//!   its budget;
//! * with per-job measurement seeds ([`worker::job_seed`]) the final
//!   store is a pure function of (reference, config, base seed) —
//!   independent of worker count, scheduling, and mid-run worker death.

pub mod protocol;
pub mod scheduler;
pub mod server;
pub mod worker;

pub use protocol::Msg;
pub use scheduler::{JobQueue, JobState};
pub use server::{BoundFleetServer, FleetRun, FleetServer};
pub use worker::{job_seed, DeviceWorker};
