//! Decoupled profiling architecture (paper Appendix A5.2): the *fitting
//! server* (leader) owns GP state and picks probe points; *device
//! workers* (clients) run variant trainings and stream measurements
//! back over TCP with a line-delimited JSON protocol.  `std::net` +
//! scoped threads (no async runtime is available offline).
//!
//! The fleet is just one [`crate::thor::measure::Measurer`] backend:
//! [`server::FleetMeasurer`] turns each batched acquisition round of
//! the shared pipeline ([`crate::thor::pipeline::Thor::profile`]) into
//! a batch of jobs fanned across the workers — the leader runs the
//! exact acquisition code a local run does, so the fleet-profiled store
//! is byte-identical to a local per-job-seeded run at any worker count.
//!
//! A single leader can serve a **heterogeneous** fleet
//! ([`server::FleetSpec::mixed`]): jobs are tagged with the device
//! class they must run on, [`scheduler::JobQueue::assign`] routes
//! same-class only (requeue-on-death included), the pipeline
//! interleaves class acquisition rounds so every class stays saturated,
//! and one `serve` emits one multi-device store.  Per-class worker
//! counts feed occupancy-adaptive batching
//! ([`crate::thor::fit::Batch::Auto`]).
//!
//! The same protocol also carries the **estimation-serving** tier
//! ([`estimate_server`], `thor serve-estimates`): a long-running daemon
//! that loads fitted stores and answers estimate queries at high rate —
//! the query-heavy, fit-rarely counterpart of the profiling fleet.  Its
//! default core is the readiness-driven [`reactor`] (one event thread
//! multiplexing all connections, a compute pool coalescing queries
//! across clients); `--io-model threads` keeps the original
//! thread-per-connection loop for one release.
//!
//! Invariants (property-tested in `scheduler`, and promoted to
//! integration level over real sockets in `rust/tests/fleet.rs` and
//! `rust/tests/backend_equiv.rs`):
//! * every issued job is eventually resolved exactly once (no
//!   double-assignment, no loss on worker failure — jobs are re-queued);
//! * per-family measurement order does not affect the final GP (the GP
//!   is permutation-invariant in its training set);
//! * the leader terminates once every family converges or exhausts
//!   its budget;
//! * with per-job measurement seeds ([`worker::job_seed`]) the final
//!   store is a pure function of (reference, config, base seed) —
//!   independent of worker count, scheduling, mid-run worker death, and
//!   of whether the measurements ran locally or over the fleet.
//!
//! The fault model is two-tier: workers that *die* disconnect and
//! their jobs requeue (PR 7's elasticity); workers that *stall* stay
//! connected and silent, and are handled by per-job deadlines with
//! speculative re-issue ([`server::FleetSpec::with_deadline`]).  The
//! [`faults`] module scripts both kinds deterministically for the chaos
//! tests and the fleetS experiment.

pub mod estimate_server;
pub mod faults;
pub mod protocol;
pub mod reactor;
pub mod scheduler;
pub mod server;
pub mod worker;

pub use estimate_server::{
    BoundEstimateServer, EstimateClient, EstimateServer, EstimateServerHandle, IoModel,
    ServeStats, ServeTuning,
};
pub use faults::{reconnect_backoff, slow_loris_send, FaultPlan, Stall};
pub use protocol::{read_line_capped, Msg, MAX_LINE_BYTES};
pub use scheduler::{JobQueue, JobState};
pub use server::{BoundFleetServer, FleetMeasurer, FleetRun, FleetServer, FleetSpec, ServeOptions};
pub use worker::{class_seed, job_seed, DeviceWorker};
