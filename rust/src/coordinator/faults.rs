//! Deterministic fault injection for the chaos tests and the straggler
//! experiment (`fleetS`).
//!
//! A [`FaultPlan`] scripts the *slowness* failure modes that PR 7's
//! death/rejoin chaos could not express: a worker that stalls for a
//! while and recovers, a worker that hangs **without disconnecting**
//! (the connection stays open, nothing ever comes back — the classic
//! thermal-throttled straggler), and a chronically slow writer.  A
//! slow-loris *client* (bytes trickled one at a time, newline withheld)
//! is scripted with [`slow_loris_send`] against the estimation daemon.
//!
//! Everything here is a pure function of its inputs: a plan derived
//! from a seed ([`FaultPlan::seeded`]) injects the same faults at the
//! same job indices on every run, and the reconnect backoff schedule
//! ([`reconnect_backoff`]) is a pure function of `(seed, attempt)` —
//! chaos runs are reproducible byte-for-byte, which is what lets the
//! fleetS golden assert `store_byte_equal == 1` instead of "usually
//! recovers".
//!
//! Why stalls cannot corrupt the store: the PR-4 determinism contract
//! makes every measurement a pure function of its request via
//! [`crate::coordinator::worker::job_seed`], so when the leader
//! speculatively re-issues a straggler's job
//! ([`crate::coordinator::server`]) the duplicate completions are
//! bitwise identical — whichever arrives first lands, the loser is
//! dropped by the exactly-once queue, and the bytes are the same either
//! way.

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use crate::util::hash::Fnv1a;
use crate::util::rng::Pcg64;

/// What a stalling worker does once its stall triggers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stall {
    /// Sleep this long with the job in flight, then answer it and keep
    /// serving — a device that throttled and recovered.  The leader may
    /// have speculated the job elsewhere meanwhile; the late (bitwise
    /// identical) duplicate is dropped by exactly-once completion.
    Recover(Duration),
    /// Never answer again, but keep the socket open — no Disconnected
    /// event ever fires for this worker.  The worker still *reads* (so
    /// the OS buffers never push back on the leader) and exits quietly
    /// on `Shutdown` or leader hang-up.
    Hang,
}

/// A deterministic per-worker fault script, threaded into
/// [`crate::coordinator::DeviceWorker`] via
/// [`crate::coordinator::DeviceWorker::with_faults`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Trigger the stall upon *receiving* the `k+1`-th job (after `k`
    /// clean completions) — the same indexing as
    /// [`crate::coordinator::DeviceWorker::run_limited`]'s death fault.
    /// `None` = never stall.
    pub stall_after_jobs: Option<usize>,
    /// What the stall does; ignored unless `stall_after_jobs` is set.
    pub stall: Option<Stall>,
    /// Sleep this long before every `Result` write — a chronically slow
    /// writer whose results arrive late but intact.
    pub slow_write: Option<Duration>,
}

impl FaultPlan {
    /// A worker that completes `jobs` jobs, then hangs without
    /// disconnecting on the next one.
    pub fn hang_after(jobs: usize) -> Self {
        Self { stall_after_jobs: Some(jobs), stall: Some(Stall::Hang), ..Self::default() }
    }

    /// A worker that completes `jobs` jobs, stalls `stall` on the next
    /// one, then recovers and keeps serving.
    pub fn stall_after(jobs: usize, stall: Duration) -> Self {
        Self {
            stall_after_jobs: Some(jobs),
            stall: Some(Stall::Recover(stall)),
            ..Self::default()
        }
    }

    /// A worker whose every result write is preceded by `per_write` of
    /// dawdling.
    pub fn slow_writer(per_write: Duration) -> Self {
        Self { slow_write: Some(per_write), ..Self::default() }
    }

    /// Derive a plan from a seed: which fault, after how many jobs, and
    /// how long, all pure functions of `seed` — the randomized-stall
    /// property test draws its chaos from here so every failing case
    /// replays exactly.
    pub fn seeded(seed: u64) -> Self {
        let mut r = Pcg64::new(seed);
        let jobs = r.range_usize(1, 3);
        match r.range_usize(0, 2) {
            0 => Self::hang_after(jobs),
            1 => Self::stall_after(jobs, Duration::from_millis(r.range_usize(150, 500) as u64)),
            _ => Self::slow_writer(Duration::from_millis(r.range_usize(1, 20) as u64)),
        }
    }

    /// True when the plan injects nothing.
    pub fn is_noop(&self) -> bool {
        self.stall_after_jobs.is_none() && self.slow_write.is_none()
    }
}

/// Seeded exponential reconnect backoff: attempt `k` waits
/// `10ms · 2^min(k,6)` plus a seeded jitter of up to the same again —
/// deterministic per `(seed, attempt)`, so a reconnect schedule is as
/// replayable as the faults that caused it, while distinct seeds
/// decorrelate (no thundering herd when a fleet's workers all lose the
/// same leader).
pub fn reconnect_backoff(seed: u64, attempt: u32) -> Duration {
    let base_ms = 10u64 << attempt.min(6);
    let mut h = Fnv1a::new();
    h.write(&seed.to_le_bytes());
    h.write(&u64::from(attempt).to_le_bytes());
    Duration::from_millis(base_ms + h.finish() % base_ms)
}

/// Slow-loris a byte string into `stream`: one byte per write, sleeping
/// `per_byte` between writes.  Used against the estimation daemon to
/// assert that a trickling client is reaped at the line deadline
/// instead of holding a worker thread hostage (`rust/tests/serve.rs`).
/// Returns how many bytes were accepted before the peer gave up on us.
pub fn slow_loris_send(stream: &mut TcpStream, bytes: &[u8], per_byte: Duration) -> usize {
    for (i, b) in bytes.iter().enumerate() {
        if stream.write_all(std::slice::from_ref(b)).is_err() {
            return i;
        }
        let _ = stream.flush();
        std::thread::sleep(per_byte);
    }
    bytes.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_reproducible_and_vary_across_seeds() {
        for seed in 0..50u64 {
            assert_eq!(FaultPlan::seeded(seed), FaultPlan::seeded(seed), "seed {seed} not pure");
        }
        // The generator covers all three fault kinds over a small seed
        // sweep — a degenerate constant plan would make the randomized
        // chaos tests vacuous.
        let (mut hangs, mut recovers, mut slow) = (0, 0, 0);
        for seed in 0..50u64 {
            let p = FaultPlan::seeded(seed);
            match (p.stall, p.slow_write) {
                (Some(Stall::Hang), _) => hangs += 1,
                (Some(Stall::Recover(_)), _) => recovers += 1,
                (None, Some(_)) => slow += 1,
                other => panic!("seeded plan is neither stall nor slow-write: {other:?}"),
            }
        }
        assert!(hangs > 0 && recovers > 0 && slow > 0, "{hangs}/{recovers}/{slow}");
    }

    #[test]
    fn backoff_is_deterministic_grows_and_decorrelates() {
        for attempt in 0..10 {
            assert_eq!(reconnect_backoff(7, attempt), reconnect_backoff(7, attempt));
        }
        // Envelope: attempt k waits within [10·2^min(k,6), 2·10·2^min(k,6)) ms.
        for attempt in 0..10u32 {
            let ms = reconnect_backoff(7, attempt).as_millis() as u64;
            let base = 10u64 << attempt.min(6);
            assert!(ms >= base && ms < 2 * base, "attempt {attempt}: {ms}ms outside envelope");
        }
        // Different seeds land on different jitter somewhere in the
        // schedule (decorrelation, not a fixed offset).
        assert!(
            (0..10).any(|a| reconnect_backoff(1, a) != reconnect_backoff(2, a)),
            "seeds 1 and 2 share the whole backoff schedule"
        );
    }

    #[test]
    fn constructors_set_exactly_their_fault() {
        let h = FaultPlan::hang_after(2);
        assert_eq!(h.stall_after_jobs, Some(2));
        assert_eq!(h.stall, Some(Stall::Hang));
        assert!(h.slow_write.is_none());
        let s = FaultPlan::stall_after(1, Duration::from_millis(100));
        assert_eq!(s.stall, Some(Stall::Recover(Duration::from_millis(100))));
        let w = FaultPlan::slow_writer(Duration::from_millis(5));
        assert!(w.stall_after_jobs.is_none() && w.slow_write.is_some());
        assert!(FaultPlan::default().is_noop());
        assert!(!h.is_noop() && !s.is_noop() && !w.is_noop());
    }
}
