//! Readiness-driven serving core (`--io-model reactor`, the default):
//! one event thread owns **all** connections through non-blocking
//! sockets and a [`Poller`] (epoll on Linux, `poll(2)` elsewhere on
//! unix — see [`crate::util::sys::poll`]), and a fixed compute pool
//! answers decoded queries.  Compared to the thread-per-connection
//! model (kept as `--io-model threads`), connection count decouples
//! from thread count: 10 000 mostly-idle connections cost 10 000 fd
//! registrations and buffers, not 10 000 stacks.
//!
//! Data path: readable socket → per-connection [`FrameBuf`] reassembles
//! newline-delimited frames across arbitrary TCP segmentation → decoded
//! requests become [`Unit`]s on one shared pending queue → a compute
//! worker drains up to `coalesce_max` units in a micro-batch
//! ("whatever is queued now", zero added latency), snapshots the store
//! once, and answers the whole batch through
//! [`estimate_units_shared`] — so same-`(device, family)` queries from
//! *different clients* coalesce into single GP batch solves.  Replies
//! come back to the event thread over a completion list plus a
//! [`WakePipe`], and are written under write-readiness with vectored
//! writes; a client that stops draining gets a bounded write queue and
//! read gating, never a blocked thread.
//!
//! Correctness contract (pinned by the unit test here and by
//! `tests/serve.rs` running the whole suite under both io models):
//! every reply is **byte-identical** to what the blocking path would
//! have produced — coalescing composes through
//! `estimate_batch_shared`'s bit-identity guarantee (PR 6) and error
//! strings reuse the exact blocking-path formats.
//!
//! Deadlines are ported from [`ServeTuning`]: a partial line older than
//! `line_timeout` (slow loris) gets one `est_err` and a close; a
//! connection with nothing buffered, nothing in flight, and no bytes
//! for `idle_timeout` is reaped silently; a write queue stalled past
//! `write_timeout` is dropped.  Two new knobs bound memory per
//! connection: `write_highwater` (stop reading while the write queue
//! is that deep) and `max_inflight` (decoded-but-unanswered cap).
//!
//! Shutdown is cooperative and connection-free: the owner sets the
//! stop flag and writes one byte to the wake pipe (no dummy
//! `connect()`s — the fix for the thread model's shutdown idiom, and
//! why 100 start/stop cycles hold fd count flat; see `tests/serve.rs`).

use std::collections::{HashMap, VecDeque};
use std::io::{self, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::estimate_server::{ServeStats, ServeTuning, StoreSlot};
use crate::coordinator::protocol::{FrameBuf, FrameError, Msg};
use crate::model::spec::parse_spec;
use crate::model::ModelGraph;
use crate::thor::estimator::{estimate_units_shared, SharedEstimateCache};
use crate::thor::store::GpStore;
use crate::util::sys::poll::{fd_of, Event, Poller, WakePipe};

/// Token for the listening socket.
const LISTENER: u64 = 0;
/// Token for the wake pipe's read end.
const WAKE: u64 = 1;
/// First connection token; tokens increase monotonically and are never
/// reused, so a stale completion can never be delivered to a newer
/// connection that recycled the slot.
const FIRST_CONN: u64 = 2;

/// One decoded request, ready for the compute pool.
enum Query {
    Single { id: u64, device: String, model: String },
    Batch { id: u64, queries: Vec<(String, String)> },
}

/// A queued unit of work: one protocol request from one connection.
struct Unit {
    token: u64,
    query: Query,
}

/// One finished reply heading back to the event thread.
struct Completion {
    token: u64,
    bytes: Vec<u8>,
    /// The reply is an `EstimateError` (single-request path only; batch
    /// per-query errors are data, not protocol errors — blocking-path
    /// parity).
    errored: bool,
    /// This reply was computed in a micro-batch of ≥ 2 units.
    coalesced: bool,
}

/// State shared between the event thread and the compute pool.
struct Shared {
    pending: Mutex<VecDeque<Unit>>,
    available: Condvar,
    completed: Mutex<Vec<Completion>>,
    wake: WakePipe,
}

/// Per-connection state owned by the event thread.
struct Conn {
    stream: TcpStream,
    frame: FrameBuf,
    /// Outbound reply queue; front buffer partially written up to
    /// `wq_front_off`.
    wq: VecDeque<Vec<u8>>,
    wq_front_off: usize,
    wq_bytes: usize,
    idle_since: Instant,
    /// Set while a *partial* line is buffered — the slow-loris clock.
    /// Cleared on every completed line, so a pipelined client gated on
    /// `max_inflight` is never misread as a loris.
    line_start: Option<Instant>,
    /// Set when the write queue is non-empty and the last flush made no
    /// progress — the write-deadline clock.
    write_stalled_since: Option<Instant>,
    /// Decoded-but-unanswered requests (gates reading at `max_inflight`).
    inflight: usize,
    /// Graceful close requested: stop reading, flush owed replies, then
    /// close once `inflight == 0` and the write queue drains.
    closing: bool,
    interest_r: bool,
    interest_w: bool,
}

impl Conn {
    fn new(stream: TcpStream, max_line_bytes: usize, now: Instant) -> Self {
        Conn {
            stream,
            frame: FrameBuf::new(max_line_bytes),
            wq: VecDeque::new(),
            wq_front_off: 0,
            wq_bytes: 0,
            idle_since: now,
            line_start: None,
            write_stalled_since: None,
            inflight: 0,
            closing: false,
            interest_r: true,
            interest_w: false,
        }
    }
}

fn enqueue(c: &mut Conn, bytes: Vec<u8>) {
    c.wq_bytes += bytes.len();
    c.wq.push_back(bytes);
}

fn est_err(id: u64, error: String) -> Vec<u8> {
    Msg::EstimateError { id, error }.encode().into_bytes()
}

/// Start the reactor: one event thread plus `compute_threads` workers.
/// Fails up front (before any thread spawns) if the host has no
/// readiness primitive — `--io-model threads` remains available there.
pub(crate) fn spawn(
    listener: TcpListener,
    slot: StoreSlot,
    cache: Arc<SharedEstimateCache>,
    stop: Arc<AtomicBool>,
    tuning: ServeTuning,
    compute_threads: usize,
    coalesce_max: usize,
) -> Result<ReactorHandle> {
    listener.set_nonblocking(true)?;
    let mut poller = Poller::new()?;
    let wake = WakePipe::new()?;
    poller.register(fd_of(&listener), LISTENER, true, false)?;
    poller.register(wake.read_fd(), WAKE, true, false)?;
    let shared = Arc::new(Shared {
        pending: Mutex::new(VecDeque::new()),
        available: Condvar::new(),
        completed: Mutex::new(Vec::new()),
        wake,
    });
    let coalesce_max = coalesce_max.max(1);
    let mut computes = Vec::with_capacity(compute_threads);
    for _ in 0..compute_threads {
        let (shared, slot, cache, stop) =
            (shared.clone(), slot.clone(), cache.clone(), stop.clone());
        computes.push(std::thread::spawn(move || {
            compute_loop(&shared, &slot, &cache, &stop, coalesce_max)
        }));
    }
    let event = {
        let (shared, stop) = (shared.clone(), stop.clone());
        std::thread::spawn(move || event_loop(listener, poller, &shared, &stop, &tuning))
    };
    Ok(ReactorHandle { shared, stop, event, computes })
}

/// Owner's handle to a running reactor (wrapped by
/// [`crate::coordinator::estimate_server::EstimateServerHandle`]).
pub(crate) struct ReactorHandle {
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    event: JoinHandle<ServeStats>,
    computes: Vec<JoinHandle<()>>,
}

impl ReactorHandle {
    /// Stop-flag + wake-pipe shutdown: no dummy connections, no fd
    /// churn.  Joins every thread and returns the accumulated stats.
    pub(crate) fn shutdown(self) -> ServeStats {
        self.stop.store(true, Ordering::Relaxed);
        self.shared.wake.wake();
        {
            // Take the lock so a worker that checked the flag just
            // before the store cannot park and miss the notify.
            let _q = self.shared.pending.lock().unwrap_or_else(|e| e.into_inner());
            self.shared.available.notify_all();
        }
        for h in self.computes {
            let _ = h.join();
        }
        self.event.join().unwrap_or_default()
    }

    /// Serve-forever mode: block until the event thread exits (an
    /// external stop signal), then wind down the compute pool.
    pub(crate) fn join(self) -> ServeStats {
        let stats = self.event.join().unwrap_or_default();
        self.stop.store(true, Ordering::Relaxed);
        {
            let _q = self.shared.pending.lock().unwrap_or_else(|e| e.into_inner());
            self.shared.available.notify_all();
        }
        for h in self.computes {
            let _ = h.join();
        }
        stats
    }
}

// ---------------------------------------------------------------------------
// Compute pool: drain micro-batches, answer them coalesced.
// ---------------------------------------------------------------------------

fn compute_loop(
    shared: &Shared,
    slot: &StoreSlot,
    cache: &SharedEstimateCache,
    stop: &AtomicBool,
    coalesce_max: usize,
) {
    loop {
        let units: Vec<Unit> = {
            let mut q = shared.pending.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                if !q.is_empty() {
                    break;
                }
                q = shared.available.wait(q).unwrap_or_else(|e| e.into_inner());
            }
            let n = q.len().min(coalesce_max);
            q.drain(..n).collect()
        };
        // One immutable store snapshot per micro-batch: a concurrent
        // `swap_store` lands between batches, never inside one, so no
        // unit ever sees a torn mix of fits.
        let store: Arc<GpStore> = slot.read().unwrap_or_else(|e| e.into_inner()).clone();
        let done = answer_units(&store, cache, units);
        {
            let mut c = shared.completed.lock().unwrap_or_else(|e| e.into_inner());
            c.extend(done);
        }
        shared.wake.wake();
    }
}

/// Parse state for one unit, kept so replies reassemble in request
/// order after the coalesced solve.
struct Prep {
    token: u64,
    id: u64,
    batch: bool,
    devices: Vec<String>,
    parsed: Vec<Result<ModelGraph, String>>,
}

/// Answer a micro-batch of units with **one**
/// [`estimate_units_shared`] call, so same-family queries across
/// connections share GP batch solves.  Reply bytes and error strings
/// are byte-identical to the blocking path's `serve_one`/`serve_batch`
/// (pinned by [`tests::answer_units_matches_blocking_serve_helpers_byte_for_byte`]).
fn answer_units(
    store: &GpStore,
    cache: &SharedEstimateCache,
    units: Vec<Unit>,
) -> Vec<Completion> {
    let coalesced = units.len() > 1;
    let preps: Vec<Prep> = units
        .into_iter()
        .map(|Unit { token, query }| match query {
            Query::Single { id, device, model } => Prep {
                token,
                id,
                batch: false,
                parsed: vec![parse_spec(&model).map_err(|e| e.to_string())],
                devices: vec![device],
            },
            Query::Batch { id, queries } => {
                let parsed =
                    queries.iter().map(|(_, m)| parse_spec(m).map_err(|e| e.to_string())).collect();
                let devices = queries.into_iter().map(|(d, _)| d).collect();
                Prep { token, id, batch: true, devices, parsed }
            }
        })
        .collect();
    let unit_queries: Vec<Vec<(&str, &ModelGraph)>> = preps
        .iter()
        .map(|p| {
            p.devices
                .iter()
                .zip(&p.parsed)
                .filter_map(|(d, g)| g.as_ref().ok().map(|g| (d.as_str(), g)))
                .collect()
        })
        .collect();
    let unit_answers = estimate_units_shared(store, &unit_queries, cache);
    preps
        .into_iter()
        .zip(unit_answers)
        .map(|(p, answers)| {
            let mut answers = answers.into_iter();
            if !p.batch {
                let (msg, errored) = match p.parsed.into_iter().next().expect("single has 1 slot") {
                    Err(e) => (Msg::EstimateError { id: p.id, error: e }, true),
                    Ok(_) => match answers.next().expect("one answer per valid parse") {
                        Ok(e) => (
                            Msg::EstimateReply {
                                id: p.id,
                                energy_per_iter: e.energy_per_iter,
                                variance: e.variance,
                            },
                            false,
                        ),
                        Err(e) => (Msg::EstimateError { id: p.id, error: e.to_string() }, true),
                    },
                };
                Completion { token: p.token, bytes: msg.encode().into_bytes(), errored, coalesced }
            } else {
                let results: Vec<Result<(f64, f64), String>> = p
                    .parsed
                    .into_iter()
                    .map(|pr| match pr {
                        Err(e) => Err(e),
                        Ok(_) => answers
                            .next()
                            .expect("one answer per valid parse")
                            .map(|e| (e.energy_per_iter, e.variance))
                            .map_err(|e| e.to_string()),
                    })
                    .collect();
                let msg = Msg::EstimateBatchReply { id: p.id, results };
                Completion {
                    token: p.token,
                    bytes: msg.encode().into_bytes(),
                    errored: false,
                    coalesced,
                }
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Event loop.
// ---------------------------------------------------------------------------

fn event_loop(
    listener: TcpListener,
    mut poller: Poller,
    shared: &Shared,
    stop: &AtomicBool,
    tuning: &ServeTuning,
) -> ServeStats {
    let mut stats = ServeStats::default();
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token = FIRST_CONN;
    let mut events: Vec<Event> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let mut new_units: Vec<Unit> = Vec::new();
    let mut to_close: Vec<u64> = Vec::new();

    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let now = Instant::now();
        let timeout = wait_timeout(&conns, tuning, now);
        if poller.wait(&mut events, Some(timeout)).is_err() {
            break;
        }
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // Always drain the wake pipe (level-triggered: leftover bytes
        // would spin the loop).
        shared.wake.drain();
        let now = Instant::now();

        for ev in events.drain(..) {
            match ev.token {
                LISTENER => loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if stop.load(Ordering::Relaxed) {
                                break;
                            }
                            if stream.set_nonblocking(true).is_err() {
                                continue;
                            }
                            let token = next_token;
                            next_token += 1;
                            if poller.register(fd_of(&stream), token, true, false).is_err() {
                                continue;
                            }
                            stats.connections += 1;
                            conns.insert(token, Conn::new(stream, tuning.max_line_bytes, now));
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                        // Transient accept failure (EMFILE, aborted
                        // handshake): keep serving.
                        Err(_) => break,
                    }
                },
                WAKE => {}
                token => {
                    if let Some(c) = conns.get_mut(&token) {
                        if ev.readable
                            && c.interest_r
                            && handle_readable(
                                c,
                                token,
                                &mut scratch,
                                tuning,
                                now,
                                &mut stats,
                                &mut new_units,
                            )
                        {
                            to_close.push(token);
                        }
                        // Writable readiness is consumed by the
                        // maintenance flush below.
                    }
                }
            }
        }

        // Hard-broken connections go away before replies are routed, so
        // their completions (if any) are dropped, not mis-delivered.
        close_all(&mut conns, &mut poller, &mut to_close);

        publish(shared, &mut new_units);

        // Route finished replies into per-connection write queues.
        let done: Vec<Completion> = {
            let mut c = shared.completed.lock().unwrap_or_else(|e| e.into_inner());
            std::mem::take(&mut *c)
        };
        for completion in done {
            if completion.errored {
                stats.errors += 1;
            }
            if completion.coalesced {
                stats.coalesced += 1;
            }
            if let Some(c) = conns.get_mut(&completion.token) {
                c.inflight = c.inflight.saturating_sub(1);
                c.idle_since = now;
                enqueue(c, completion.bytes);
            }
            // else: the client vanished mid-request; drop the reply.
        }

        // Maintenance: timers, gated-line catch-up, flushing, interest.
        for (&token, c) in conns.iter_mut() {
            // Slow-loris: a partial line outlived the read deadline.
            if let Some(started) = c.line_start {
                if now.duration_since(started) >= tuning.line_timeout {
                    stats.errors += 1;
                    let deadline = tuning.line_timeout;
                    enqueue(
                        c,
                        est_err(
                            0,
                            format!("request line stalled past the {deadline:?} read deadline"),
                        ),
                    );
                    c.line_start = None;
                    c.closing = true;
                }
            }
            // Idle reap: nothing buffered, nothing owed, no bytes.
            if !c.closing
                && c.inflight == 0
                && c.wq.is_empty()
                && c.line_start.is_none()
                && now.duration_since(c.idle_since) >= tuning.idle_timeout
            {
                stats.reaped += 1;
                to_close.push(token);
                continue;
            }
            // Catch-up: complete lines can sit in the frame buffer when
            // the inflight gate paused decoding — no socket event will
            // resume them, so this pass must.
            if !c.closing
                && c.inflight < tuning.max_inflight
                && !drain_lines(c, token, tuning, now, &mut stats, &mut new_units)
            {
                to_close.push(token);
                continue;
            }
            if !c.wq.is_empty() {
                match flush(c) {
                    Ok(progressed) => {
                        if c.wq.is_empty() {
                            c.write_stalled_since = None;
                            c.idle_since = now;
                        } else if progressed || c.write_stalled_since.is_none() {
                            c.write_stalled_since = Some(now);
                        }
                    }
                    Err(_) => {
                        to_close.push(token);
                        continue;
                    }
                }
            }
            if let Some(stalled) = c.write_stalled_since {
                if !c.wq.is_empty() && now.duration_since(stalled) >= tuning.write_timeout {
                    to_close.push(token);
                    continue;
                }
            }
            if c.closing && c.inflight == 0 && c.wq.is_empty() {
                to_close.push(token);
                continue;
            }
            // Reconcile poller interest with what this connection can
            // actually make progress on: reading is gated by graceful
            // close, the inflight cap, and write-queue backpressure.
            let want_r = !c.closing
                && c.inflight < tuning.max_inflight
                && c.wq_bytes < tuning.write_highwater;
            let want_w = !c.wq.is_empty();
            if (want_r, want_w) != (c.interest_r, c.interest_w) {
                if poller.reregister(fd_of(&c.stream), token, want_r, want_w).is_err() {
                    to_close.push(token);
                    continue;
                }
                c.interest_r = want_r;
                c.interest_w = want_w;
            }
        }

        close_all(&mut conns, &mut poller, &mut to_close);
        // The catch-up drain may have decoded more requests.
        publish(shared, &mut new_units);
    }
    stats
}

fn publish(shared: &Shared, new_units: &mut Vec<Unit>) {
    if new_units.is_empty() {
        return;
    }
    let mut q = shared.pending.lock().unwrap_or_else(|e| e.into_inner());
    q.extend(new_units.drain(..));
    drop(q);
    shared.available.notify_all();
}

fn close_all(conns: &mut HashMap<u64, Conn>, poller: &mut Poller, to_close: &mut Vec<u64>) {
    for token in to_close.drain(..) {
        if let Some(c) = conns.remove(&token) {
            let _ = poller.deregister(fd_of(&c.stream));
        }
    }
}

/// Smallest pending deadline across all connections, capped at the
/// tuning poll tick (the worst-case latency for noticing shutdown).
fn wait_timeout(conns: &HashMap<u64, Conn>, tuning: &ServeTuning, now: Instant) -> Duration {
    let mut t = tuning.poll;
    for c in conns.values() {
        if let Some(started) = c.line_start {
            t = t.min((started + tuning.line_timeout).saturating_duration_since(now));
        }
        if !c.closing && c.inflight == 0 && c.wq.is_empty() && c.line_start.is_none() {
            t = t.min((c.idle_since + tuning.idle_timeout).saturating_duration_since(now));
        }
        if !c.wq.is_empty() {
            if let Some(stalled) = c.write_stalled_since {
                t = t.min((stalled + tuning.write_timeout).saturating_duration_since(now));
            }
        }
    }
    t
}

/// Drain the socket into the frame buffer and decode complete lines.
/// Returns `true` to force-close (hard socket error or broken framing).
fn handle_readable(
    c: &mut Conn,
    token: u64,
    scratch: &mut [u8],
    tuning: &ServeTuning,
    now: Instant,
    stats: &mut ServeStats,
    new_units: &mut Vec<Unit>,
) -> bool {
    loop {
        if c.closing || c.inflight >= tuning.max_inflight {
            return false;
        }
        match (&c.stream).read(scratch) {
            Ok(0) => {
                // Clean EOF: any decoded-but-unanswered requests still
                // get their replies flushed before the close.
                c.closing = true;
                return false;
            }
            Ok(n) => {
                c.frame.push(&scratch[..n]);
                if !drain_lines(c, token, tuning, now, stats, new_units) {
                    return true;
                }
                if c.closing {
                    return false;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return false,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return true,
        }
    }
}

/// Decode buffered complete lines into units, maintaining the
/// slow-loris clock: it runs only while a *partial* line is buffered.
/// Returns `false` to force-close (invalid UTF-8 — the blocking path's
/// silent `Broken`).
fn drain_lines(
    c: &mut Conn,
    token: u64,
    tuning: &ServeTuning,
    now: Instant,
    stats: &mut ServeStats,
    new_units: &mut Vec<Unit>,
) -> bool {
    loop {
        if c.closing || c.inflight >= tuning.max_inflight {
            // Gated: leave remaining lines buffered (the maintenance
            // pass resumes them); the loris clock is untouched — it was
            // cleared by the last complete line, so a gated pipeline is
            // never mistaken for a loris.
            return true;
        }
        match c.frame.next_line() {
            Ok(Some(line)) => {
                c.line_start = None;
                c.idle_since = now;
                on_line(c, token, &line, stats, new_units);
            }
            Ok(None) => {
                c.line_start =
                    if c.frame.has_partial() { Some(c.line_start.unwrap_or(now)) } else { None };
                return true;
            }
            Err(FrameError::TooLong) => {
                stats.errors += 1;
                enqueue(
                    c,
                    est_err(0, format!("request line exceeds {} bytes", tuning.max_line_bytes)),
                );
                c.line_start = None;
                c.closing = true;
                return true;
            }
            Err(FrameError::Utf8) => return false,
        }
    }
}

/// Handle one complete request line — the reactor twin of the blocking
/// path's per-message match, with identical error strings and
/// keep-open/close decisions.
fn on_line(c: &mut Conn, token: u64, line: &str, stats: &mut ServeStats, new_units: &mut Vec<Unit>) {
    if line.trim().is_empty() {
        return;
    }
    let Some(msg) = Msg::decode(line) else {
        stats.errors += 1;
        enqueue(c, est_err(0, "malformed request line".into()));
        c.closing = true;
        return;
    };
    match msg {
        Msg::EstimateRequest { id, device, model } => {
            stats.requests += 1;
            c.inflight += 1;
            new_units.push(Unit { token, query: Query::Single { id, device, model } });
        }
        Msg::EstimateBatch { id, queries } => {
            stats.requests += 1;
            c.inflight += 1;
            new_units.push(Unit { token, query: Query::Batch { id, queries } });
        }
        // A polite client close: flush anything owed, then hang up.
        Msg::Shutdown => c.closing = true,
        other => {
            stats.errors += 1;
            enqueue(
                c,
                est_err(0, format!("unsupported message on an estimate connection: {other:?}")),
            );
            // Connection stays open — blocking-path parity.
        }
    }
}

/// Write as much of the queue as the socket accepts, vectored (up to 16
/// buffers per syscall).  Returns whether any bytes moved.
fn flush(c: &mut Conn) -> io::Result<bool> {
    let mut progressed = false;
    while !c.wq.is_empty() {
        let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(16.min(c.wq.len()));
        for (i, buf) in c.wq.iter().take(16).enumerate() {
            let start = if i == 0 { c.wq_front_off } else { 0 };
            slices.push(IoSlice::new(&buf[start..]));
        }
        match (&c.stream).write_vectored(&slices) {
            Ok(0) => {
                return Err(io::Error::new(io::ErrorKind::WriteZero, "socket accepted 0 bytes"))
            }
            Ok(mut n) => {
                progressed = true;
                c.wq_bytes -= n;
                while n > 0 {
                    let front_left = c.wq.front().expect("bytes imply a buffer").len()
                        - c.wq_front_off;
                    if n >= front_left {
                        n -= front_left;
                        c.wq.pop_front();
                        c.wq_front_off = 0;
                    } else {
                        c.wq_front_off += n;
                        n = 0;
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(progressed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::estimate_server::{serve_batch, serve_one};
    use crate::model::zoo;

    fn profiled_store(device: &str, seed: u64) -> GpStore {
        let profile = crate::simdevice::devices::by_name(device).unwrap();
        let mut dev = crate::simdevice::Device::new(profile, seed);
        let mut thor = crate::thor::Thor::new(crate::thor::ThorConfig::quick());
        thor.profile_local(&mut dev, &zoo::cnn5(&[32, 64, 128, 256], 16, 10));
        thor.store
    }

    /// The coalescing contract: a micro-batch mixing valid singles, a
    /// mixed batch, a parse error, and an unknown device produces
    /// replies byte-identical to the blocking path's helpers, in unit
    /// order, with the error/coalesced flags the stats layer expects.
    #[test]
    fn answer_units_matches_blocking_serve_helpers_byte_for_byte() {
        let store = profiled_store("xavier", 11);
        let cache = SharedEstimateCache::default();
        let good = "cnn5:8,16,32,64:16";
        let batch_queries: Vec<(String, String)> = vec![
            ("xavier".into(), "cnn5:4,8,16,32:16".into()),
            ("xavier".into(), "nope:1".into()),
            ("oppo".into(), good.into()),
        ];
        let units = vec![
            Unit {
                token: 10,
                query: Query::Single { id: 1, device: "xavier".into(), model: good.into() },
            },
            Unit { token: 11, query: Query::Batch { id: 2, queries: batch_queries.clone() } },
            Unit {
                token: 12,
                query: Query::Single { id: 3, device: "xavier".into(), model: "nope:1".into() },
            },
            Unit {
                token: 13,
                query: Query::Single { id: 4, device: "oppo".into(), model: good.into() },
            },
        ];
        let done = answer_units(&store, &cache, units);
        assert_eq!(done.len(), 4);
        assert!(done.iter().all(|c| c.coalesced), "micro-batch of 4 is coalesced");
        assert_eq!([done[0].token, done[1].token, done[2].token, done[3].token], [10, 11, 12, 13]);

        let fresh = SharedEstimateCache::default();
        let (e, v) = serve_one(&store, "xavier", good, &fresh).unwrap();
        let expect0 =
            Msg::EstimateReply { id: 1, energy_per_iter: e, variance: v }.encode().into_bytes();
        assert_eq!(done[0].bytes, expect0);
        assert!(!done[0].errored);

        let expect1 = Msg::EstimateBatchReply {
            id: 2,
            results: serve_batch(&store, &batch_queries, &fresh),
        }
        .encode()
        .into_bytes();
        assert_eq!(done[1].bytes, expect1);
        assert!(!done[1].errored, "batch per-query errors are data, not protocol errors");

        let parse_err = serve_one(&store, "xavier", "nope:1", &fresh).unwrap_err();
        let expect2 = Msg::EstimateError { id: 3, error: parse_err }.encode().into_bytes();
        assert_eq!(done[2].bytes, expect2);
        assert!(done[2].errored);

        let device_err = serve_one(&store, "oppo", good, &fresh).unwrap_err();
        assert!(device_err.contains("no fitted GP"), "{device_err}");
        let expect3 = Msg::EstimateError { id: 4, error: device_err }.encode().into_bytes();
        assert_eq!(done[3].bytes, expect3);
        assert!(done[3].errored);
    }

    /// A singleton unit must not be flagged coalesced (the stat counts
    /// genuine cross-request micro-batches).
    #[test]
    fn singleton_units_are_not_counted_as_coalesced() {
        let store = profiled_store("xavier", 11);
        let cache = SharedEstimateCache::default();
        let units = vec![Unit {
            token: 2,
            query: Query::Single {
                id: 1,
                device: "xavier".into(),
                model: "cnn5:8,16,32,64:16".into(),
            },
        }];
        let done = answer_units(&store, &cache, units);
        assert_eq!(done.len(), 1);
        assert!(!done[0].coalesced);
        assert!(!done[0].errored);
    }
}
