//! Fitting leader: accepts device workers over TCP and exposes them to
//! the profiling pipeline as one [`FleetMeasurer`] backend.  The leader
//! runs the *same* acquisition code as a local run
//! ([`crate::thor::pipeline::Thor::profile`]) — the paper's
//! client/server split (the device only trains, the server only fits)
//! with none of the fit logic duplicated server-side.  Each batched
//! acquisition round fans its requests across the fleet as jobs; the
//! [`crate::coordinator::scheduler::JobQueue`] provides class-scoped
//! affinity routing, exactly-once completion and requeue-on-death.
//!
//! # Heterogeneous fleets
//!
//! One leader can serve a **mixed** fleet ([`FleetSpec`]): workers
//! declare their device class in `Hello`, jobs are tagged with the
//! class they must run on, and [`JobQueue::assign`] routes same-class
//! only.  The pipeline interleaves the classes' acquisition rounds, so
//! a single `serve` emits one multi-device store with every class
//! measured on its own silicon.  [`Measurer::occupancy`] reports live
//! per-class worker counts for `Batch::Auto` sizing.
//!
//! Concurrency model: one accept loop; per-connection reader threads
//! push (worker, msg) events into an mpsc channel; the leader thread
//! owns all state (queue + pipeline) — no shared-state locking beyond
//! the channel.
//!
//! Determinism: batch requests are submitted with a same-class worker
//! affinity (per-class request index modulo live class peers, sorted
//! ids) and only issued once every expected worker has said Hello (or
//! [`FORMATION_GRACE`] expires), so with per-job-seeded workers
//! ([`crate::coordinator::worker::job_seed`], class-derived via
//! [`crate::thor::profiler::class_seed`] in mixed fleets) the final
//! store is a pure function of (reference, config, base seed) —
//! independent of OS scheduling, and byte-identical to
//! [`crate::thor::measure::LocalMeasurer`] per-job runs at *any* worker
//! count (`rust/tests/backend_equiv.rs`).  On a worker death its jobs
//! re-queue with affinity cleared onto same-class peers, trading count
//! determinism for liveness.  Under a `Fixed` batch the store stays
//! byte-identical across deaths (per-request seeding makes the
//! re-measurement reproduce the lost one); under `Batch::Auto` a death
//! shrinks the class's occupancy and therefore its *proposal* stream,
//! so the store is a pure function of (reference, config, base seed,
//! death pattern) — healthy runs remain byte-reproducible, degraded
//! ones legitimately diverge from healthy ones.  If an entire
//! scheduled class dies, `serve` errors instead of emitting a
//! class-less store.
//!
//! # Elasticity (worker rejoin, leader checkpoint/resume)
//!
//! The accept loop never stops: a worker may connect (or reconnect)
//! at any point of the run.  A rejoining worker is simply a **new
//! connection id** whose `Hello` folds it into its declared class —
//! `live_of`, [`Measurer::occupancy`] (feeding `Batch::Auto`) and the
//! batch-affinity routing all pick it up from the next event on, and
//! the [`JobQueue`]'s class-scoped assignment admits the new id without
//! special cases.  The dead id stays retired (its in-flight jobs were
//! requeued on disconnect), so the exactly-once ledgers never conflate
//! incarnations.
//!
//! A leader can additionally persist its progress
//! ([`ServeOptions::checkpointer`]) and a successor can resume from the
//! checkpoint ([`ServeOptions::resume`]): completed families load into
//! the store, in-flight acquisition machines replay bit-identically
//! from their journals (see [`crate::thor::checkpoint`]), so the
//! resumed final store is byte-identical to an uninterrupted run's.
//!
//! # Stragglers (deadlines + speculative re-issue)
//!
//! Death is not the only failure: a worker can *stall* — stay
//! connected, never answer (thermal throttling, DVFS collapse, a wedged
//! runtime).  With a [`FleetSpec::with_deadline`] the leader watches
//! every in-flight job; a job still unanswered at its deadline marks
//! its holder a **suspect** (no new work, queued pins cleared) and is
//! speculatively re-issued to an idle live same-class peer
//! ([`JobQueue::speculate`]).  First result wins, the loser is dropped
//! by exactly-once completion — and because per-job seeding makes both
//! results bitwise identical, speculation can never perturb the store:
//! the post-chaos store is byte-equal to a healthy run's (the fleetS
//! golden).  A suspect that answers anything is healthy again; if every
//! live worker of a class is suspect with an expired job and no peer to
//! speculate to, `serve` errors rather than waiting forever.  Without a
//! deadline (the default) behavior is exactly the pre-straggler
//! blocking wait.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::protocol::{read_line_capped, Msg, MAX_LINE_BYTES};
use crate::coordinator::scheduler::{Job, JobQueue, JobState};
use crate::model::ModelGraph;
use crate::thor::checkpoint::{Checkpoint, Checkpointer};
use crate::thor::measure::{AbortAfter, MeasureError, MeasureRequest, Measurement, Measurer};
use crate::thor::pipeline::{ProfileOptions, ThorConfig};
use crate::thor::store::GpStore;
use crate::thor::Thor;

enum Event {
    Connected(usize, TcpStream),
    Message(usize, Msg),
    Disconnected(usize),
}

/// What a leader expects of its fleet before issuing jobs.
#[derive(Clone, Debug)]
pub struct FleetSpec {
    /// Expected (device class, worker count) pairs.  Empty = untyped
    /// legacy mode: a single-class fleet whose class is learned from
    /// the first `Hello` (PR-4 behavior, bit-compatible).
    pub classes: Vec<(String, usize)>,
    /// Expected initial fleet size (= sum of class counts when typed)
    /// — the formation quorum, *not* an accept cap: the leader keeps
    /// accepting connections after formation so workers can late-join
    /// or rejoin mid-run.
    pub total: usize,
    /// Formation window (see [`FORMATION_GRACE`]); tests shrink it.
    pub grace: Duration,
    /// Per-job straggler deadline: a job unanswered this long after
    /// assignment marks its worker suspect and is speculatively
    /// re-issued to a live same-class peer.  `None` (default) waits
    /// forever — the pre-straggler behavior, byte-compatible.  Pick a
    /// deadline comfortably above the slowest honest job: an honest
    /// worker that merely crosses it is treated as a straggler (its
    /// late result is still accepted if it wins the race).
    pub job_deadline: Option<Duration>,
}

impl FleetSpec {
    /// Untyped single-class fleet of `total` workers (legacy mode).
    pub fn untyped(total: usize) -> Self {
        Self { classes: Vec::new(), total, grace: FORMATION_GRACE, job_deadline: None }
    }

    /// Typed mixed fleet: `count` workers expected per named class.
    pub fn mixed(classes: &[(&str, usize)]) -> Self {
        let classes: Vec<(String, usize)> =
            classes.iter().map(|(c, n)| (c.to_string(), *n)).collect();
        let total = classes.iter().map(|(_, n)| n).sum();
        Self { classes, total, grace: FORMATION_GRACE, job_deadline: None }
    }

    /// Override the formation window (tests).
    pub fn with_grace(mut self, grace: Duration) -> Self {
        self.grace = grace;
        self
    }

    /// Arm the per-job straggler deadline (see
    /// [`FleetSpec::job_deadline`]).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.job_deadline = Some(deadline);
        self
    }
}

/// Outcome of one fleet profiling run (see
/// [`BoundFleetServer::serve`]).
pub struct FleetRun {
    pub store: GpStore,
    /// Jobs ever submitted by the leader.
    pub jobs_submitted: usize,
    /// Jobs completed (each exactly once; duplicates are dropped).
    pub jobs_done: usize,
    /// Completed jobs per worker index (connection order).  Starts at
    /// the spec's total and grows when workers late-join or rejoin —
    /// a rejoining worker is a fresh connection id, so its two
    /// incarnations occupy two slots.  Deterministic for healthy
    /// homogeneous fleets; under churn the split is timing-dependent,
    /// so reports should aggregate [`FleetRun::per_class`] instead.
    pub per_worker: Vec<usize>,
    /// Completed jobs per device class, sorted by class name — a pure
    /// function of the config even for mixed fleets.
    pub per_class: Vec<(String, usize)>,
    /// In-flight jobs re-queued because their worker disconnected.
    pub requeued: usize,
    /// Speculative duplicates issued for jobs that crossed their
    /// deadline (straggler recovery; zero without
    /// [`FleetSpec::with_deadline`]).
    pub speculated: usize,
}

/// The fleet fitting server.
pub struct FleetServer {
    pub cfg: ThorConfig,
}

/// How long the leader waits for the full fleet to say Hello before
/// proceeding with whoever showed up.  Within the window, job issue is
/// gated on all expected Hellos (deterministic affinity); after it,
/// liveness wins — a worker that never connects or dies before Hello no
/// longer hangs `thor serve` forever.  Exception: a typed
/// ([`FleetSpec::mixed`]) class with **zero** Hellos is a hard error,
/// not a degraded fleet — proceeding would silently emit a store with
/// that class missing.  In-process fleets (fleet1/fleetN/fleetH, tests)
/// form in milliseconds, so the degraded path never fires there and
/// wall-clock never influences their reports.
const FORMATION_GRACE: Duration = Duration::from_secs(30);

/// A fleet server bound to a local address but not yet serving — lets
/// callers bind to an ephemeral port (`127.0.0.1:0`), read
/// [`BoundFleetServer::local_addr`], hand it to workers, then
/// [`BoundFleetServer::serve`].
pub struct BoundFleetServer {
    cfg: ThorConfig,
    listener: TcpListener,
    addr: SocketAddr,
}

impl FleetServer {
    pub fn new(cfg: ThorConfig) -> Self {
        Self { cfg }
    }

    /// Bind `addr` (supports port 0 for an OS-assigned port).
    pub fn bind(&self, addr: &str) -> Result<BoundFleetServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(BoundFleetServer { cfg: self.cfg, listener, addr })
    }

    /// Serve on `addr` until every family of `reference` is fitted for
    /// `expect_workers` single-class workers, then shut workers down.
    /// Convenience wrapper over [`FleetServer::bind`] +
    /// [`BoundFleetServer::serve`] for the CLI.
    pub fn run(&self, addr: &str, reference: &ModelGraph, expect_workers: usize) -> Result<GpStore> {
        Ok(self.bind(addr)?.serve(reference, expect_workers)?.store)
    }

    /// [`FleetServer::run`] for an explicit (possibly mixed) fleet
    /// spec: one leader, one serve, one multi-device store.
    pub fn run_spec(&self, addr: &str, reference: &ModelGraph, spec: FleetSpec) -> Result<GpStore> {
        Ok(self.bind(addr)?.serve_spec(reference, spec)?.store)
    }
}

impl BoundFleetServer {
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve an untyped single-class fleet (legacy mode, PR-4
    /// bit-compatible): all workers must expose the same device type.
    /// Heterogeneous fleets use [`BoundFleetServer::serve_spec`].
    pub fn serve(self, reference: &ModelGraph, expect_workers: usize) -> Result<FleetRun> {
        self.serve_spec(reference, FleetSpec::untyped(expect_workers))
    }

    /// Serve until every family of `reference` is fitted for every
    /// device class of `spec`, then shut workers down.
    ///
    /// Errors when a typed class never forms (no Hello within the
    /// grace window) or when every worker of a class with outstanding
    /// jobs disconnects — there is no partial-store fallback: a store
    /// must be a complete pure function of the config or nothing.
    pub fn serve_spec(self, reference: &ModelGraph, spec: FleetSpec) -> Result<FleetRun> {
        self.serve_spec_with(reference, spec, ServeOptions::default())
    }

    /// [`BoundFleetServer::serve_spec`] with elasticity options:
    /// resume from a leader checkpoint, write checkpoints as the run
    /// progresses, and (tests/chaos only) die at a deterministic
    /// joint-batch boundary.
    pub fn serve_spec_with(
        self,
        reference: &ModelGraph,
        spec: FleetSpec,
        opts: ServeOptions<'_>,
    ) -> Result<FleetRun> {
        let BoundFleetServer { cfg, listener, addr: _ } = self;
        let grace = spec.grace;
        let mut fleet = FleetMeasurer::accept(listener, spec, cfg.iterations);
        fleet.form(grace).map_err(|e| anyhow!("fleet formation failed: {e}"))?;
        let mut thor = Thor::new(cfg);
        let mut popts = ProfileOptions::default();
        if let Some(ck) = opts.resume {
            // Completed families skip via store idempotency; in-flight
            // machines replay from their journals at stage activation.
            thor.store = ck.store;
            popts.resume = ck.inflight;
        }
        popts.checkpointer = opts.checkpointer;
        match opts.abort_after_rounds {
            Some(limit) => {
                let mut dying = AbortAfter::new(&mut fleet, limit);
                thor.profile_with(&mut dying, reference, popts)
            }
            None => thor.profile_with(&mut fleet, reference, popts),
        }
        .map_err(|e| anyhow!("fleet profiling failed: {e}"))?;
        fleet.shutdown();
        let per_class: Vec<(String, usize)> = fleet
            .queue
            .classes_submitted()
            .into_iter()
            .map(|c| {
                let n = fleet.queue.done_for(&c);
                (c, n)
            })
            .collect();
        Ok(FleetRun {
            store: thor.store,
            jobs_submitted: fleet.queue.submitted(),
            jobs_done: fleet.queue.done(),
            per_worker: std::mem::take(&mut fleet.per_worker),
            per_class,
            requeued: fleet.requeued,
            speculated: fleet.speculated,
        })
    }
}

/// Elasticity knobs for [`BoundFleetServer::serve_spec_with`].
#[derive(Default)]
pub struct ServeOptions<'a> {
    /// Resume from a previous leader's checkpoint: its store seeds this
    /// run (completed families are never re-measured) and its journals
    /// replay the in-flight acquisition machines bit-identically.
    pub resume: Option<Checkpoint>,
    /// Write an atomic checkpoint every k absorbed rounds (see
    /// [`Checkpointer`]).
    pub checkpointer: Option<&'a mut Checkpointer>,
    /// Fault injection: after this many joint batches have been
    /// measured and absorbed, the next one errors before any of its
    /// jobs are submitted — the leader-kill analogue of
    /// [`crate::coordinator::DeviceWorker::run_limited`], landing
    /// exactly "between absorbs" so chaos tests kill leaders at a
    /// deterministic, checkpointable boundary.
    pub abort_after_rounds: Option<usize>,
}

/// The fleet as a measurement backend: a batch of requests (possibly
/// spanning device classes) becomes a batch of class-routed jobs fanned
/// across the live workers; `measure_batch` returns when every job of
/// the batch has resolved (requeue-on-death included), in request
/// order.
pub struct FleetMeasurer {
    rx: mpsc::Receiver<Event>,
    /// Keeps the channel open even after the accept/reader threads end.
    _tx: mpsc::Sender<Event>,
    writers: HashMap<usize, TcpStream>,
    helloed: BTreeSet<usize>,
    /// Worker id → device class, learned from `Hello`.
    class_of: BTreeMap<usize, String>,
    queue: JobQueue,
    /// Completed measurements awaiting pickup, by job id.
    done: HashMap<u64, Measurement>,
    per_worker: Vec<usize>,
    requeued: usize,
    /// Straggler bookkeeping (armed by [`FleetSpec::job_deadline`]):
    /// job id → when its current watch started (assignment or the last
    /// speculation).  Entries leave on completion or requeue.
    watch: HashMap<u64, Instant>,
    /// Workers whose job crossed its deadline without an answer: they
    /// get no new work and no affinity pins until they show a sign of
    /// life (any message clears the suspicion; disconnect retires it).
    suspects: BTreeSet<usize>,
    /// Speculative duplicates issued (reported in
    /// [`FleetRun::speculated`]).
    speculated: usize,
    /// First Hello's class — the untyped mode's single class.
    device_name: String,
    spec: FleetSpec,
    started: Instant,
    /// Jobs carry this iteration count (the leader's ThorConfig) — kept
    /// here so the measurer can sanity-check request batches.
    iterations: usize,
    /// Signals the accept thread to exit (see
    /// [`FleetMeasurer::stop_accept`]).
    accept_stop: Arc<AtomicBool>,
    /// The listener's bound address — the stop path connects to it once
    /// to unblock the accept thread.
    local_addr: Option<SocketAddr>,
}

impl FleetMeasurer {
    /// Start accepting connections on `listener` — indefinitely, not
    /// capped at `spec.total`: elasticity means a worker may connect
    /// (late-join) or reconnect (rejoin, a fresh id) at any point of
    /// the run.  The thread exits when [`FleetMeasurer::stop_accept`]
    /// fires or the event channel's receiver is gone.
    fn accept(listener: TcpListener, spec: FleetSpec, iterations: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Event>();
        let accept_tx = tx.clone();
        let expect_workers = spec.total;
        let accept_stop = Arc::new(AtomicBool::new(false));
        let stop = accept_stop.clone();
        let local_addr = listener.local_addr().ok();
        std::thread::spawn(move || {
            for (i, stream) in listener.incoming().enumerate() {
                if stop.load(Ordering::SeqCst) {
                    break; // the wake-up connect itself lands here
                }
                let Ok(stream) = stream else { break };
                if accept_tx.send(Event::Connected(i, stream)).is_err() {
                    break; // measurer dropped: nobody left to serve
                }
            }
        });
        Self {
            rx,
            _tx: tx,
            writers: HashMap::new(),
            helloed: BTreeSet::new(),
            class_of: BTreeMap::new(),
            queue: JobQueue::new(),
            done: HashMap::new(),
            per_worker: vec![0; expect_workers],
            requeued: 0,
            watch: HashMap::new(),
            suspects: BTreeSet::new(),
            speculated: 0,
            device_name: String::new(),
            spec,
            started: Instant::now(),
            iterations,
            accept_stop,
            local_addr,
        }
    }

    /// Stop the endless accept loop: raise the flag, then poke the
    /// listener with one dummy connection so the blocking `accept`
    /// returns and observes it (the estimate daemon's shutdown idiom).
    /// Idempotent; called from [`FleetMeasurer::shutdown`] and `Drop`
    /// so an erroring serve never leaks the thread or the port.
    fn stop_accept(&mut self) {
        if !self.accept_stop.swap(true, Ordering::SeqCst) {
            if let Some(addr) = self.local_addr {
                let _ = TcpStream::connect(addr);
            }
        }
    }

    /// Helloed-and-alive workers of one class, sorted by id.
    fn live_of(&self, class: &str) -> Vec<usize> {
        self.class_of
            .iter()
            .filter(|(w, c)| c.as_str() == class && self.writers.contains_key(w) && self.helloed.contains(w))
            .map(|(w, _)| *w)
            .collect()
    }

    /// Typed classes with an unmet quota (count of helloed workers of
    /// that class, dead or alive — formation is about who showed up).
    fn unformed_classes(&self) -> Vec<(String, usize, usize)> {
        self.spec
            .classes
            .iter()
            .map(|(c, n)| {
                let have = self.class_of.values().filter(|cc| cc.as_str() == c.as_str()).count();
                (c.clone(), have, *n)
            })
            .filter(|(_, have, want)| have < want)
            .collect()
    }

    /// Wait for the fleet to form: every expected Hello (all
    /// `spec.total` in untyped mode, every class quota in typed mode),
    /// or — once `grace` has expired — proceed with a partial fleet
    /// (liveness over count determinism).  Exception, the hard error:
    /// a typed class with **zero** Hellos after the grace window (a
    /// heterogeneous serve must never silently emit a class-less
    /// store).
    fn form(&mut self, grace: Duration) -> Result<(), MeasureError> {
        loop {
            let formed = if self.spec.classes.is_empty() {
                self.helloed.len() >= self.spec.total
            } else {
                self.unformed_classes().is_empty()
            };
            if formed {
                return Ok(());
            }
            let elapsed = self.started.elapsed();
            // Untyped mode keeps PR-4 semantics: with zero Hellos it
            // waits indefinitely (an operator watching `thor serve`).
            // Typed mode must resolve at the grace boundary either way —
            // a missing class is an error even if nobody joined.
            if elapsed >= grace && (!self.helloed.is_empty() || !self.spec.classes.is_empty()) {
                let missing = self.unformed_classes();
                if let Some((c, _, want)) =
                    missing.iter().find(|(_, have, _)| *have == 0).cloned()
                {
                    return Err(MeasureError(format!(
                        "device class '{c}' ({want} worker(s) requested) never said Hello \
                         within {grace:?}; refusing to serve a store missing a requested class"
                    )));
                }
                eprintln!(
                    "fleet leader: only {}/{} workers joined within {grace:?}; \
                     proceeding with the partial fleet",
                    self.helloed.len(),
                    self.spec.total
                );
                return Ok(());
            }
            let wait = grace.checked_sub(elapsed).unwrap_or(Duration::from_millis(50));
            match self.rx.recv_timeout(wait) {
                Ok(ev) => self.on_event(ev),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(MeasureError("fleet event channel closed during formation".into()))
                }
            }
        }
    }

    /// Process one event (connection, hello, result, disconnect).
    fn on_event(&mut self, ev: Event) {
        match ev {
            Event::Connected(w, stream) => {
                let reader_tx = self._tx.clone();
                let read_stream = match stream.try_clone() {
                    Ok(s) => s,
                    Err(e) => {
                        // Never registered as a writer, so accounting
                        // treats it like a worker that never connected;
                        // say so instead of stalling silently.
                        eprintln!("fleet leader: dropping worker {w}: stream clone failed: {e}");
                        return;
                    }
                };
                self.writers.insert(w, stream);
                std::thread::spawn(move || {
                    let mut reader = BufReader::new(read_stream);
                    loop {
                        let mut line = String::new();
                        // Capped read: a worker streaming bytes without
                        // a newline is a broken peer — disconnect it
                        // (requeueing its jobs) instead of buffering
                        // its stream without bound.
                        match read_line_capped(&mut reader, &mut line, MAX_LINE_BYTES) {
                            Ok(0) | Err(_) => {
                                let _ = reader_tx.send(Event::Disconnected(w));
                                break;
                            }
                            Ok(_) => {
                                if let Some(m) = Msg::decode(&line) {
                                    if reader_tx.send(Event::Message(w, m)).is_err() {
                                        break;
                                    }
                                }
                            }
                        }
                    }
                });
            }
            Event::Message(w, msg) => {
                // Any message is a sign of life: a suspected straggler
                // that answers (even with a stale duplicate) is healthy
                // again and may take new work.
                self.suspects.remove(&w);
                match msg {
                    Msg::Hello { device } => {
                        // A rejoining worker arrives here as a brand-new
                        // id: this insert is the whole re-admission path
                        // — from the next `live_of`/`occupancy`/affinity
                        // computation on, the id serves its declared
                        // class like any founder.
                        self.helloed.insert(w);
                        if self.device_name.is_empty() {
                            self.device_name = device.clone();
                        }
                        self.class_of.entry(w).or_insert(device);
                    }
                    Msg::Result { job_id, energy_per_iter, device_seconds } => {
                        // exactly-once: stale/duplicate completions are
                        // dropped (a straggler's late duplicate of a
                        // speculated job lands here — bitwise identical
                        // to the winner, so dropping it is byte-neutral)
                        if self.queue.complete(job_id, w) {
                            // Late joiners/rejoiners have ids past the
                            // spec's total: grow the ledger instead of
                            // dropping them.
                            if w >= self.per_worker.len() {
                                self.per_worker.resize(w + 1, 0);
                            }
                            self.per_worker[w] += 1;
                            self.watch.remove(&job_id);
                            self.done.insert(job_id, Measurement { energy_per_iter, device_seconds });
                        }
                    }
                    _ => {}
                }
            }
            Event::Disconnected(w) => {
                // Re-queue the dead worker's in-flight jobs (affinity
                // cleared, class kept — only same-class peers can take
                // them): they keep their ids, so completion by another
                // worker still resolves the original request.  A job
                // whose dead primary had a speculative runner stays in
                // flight under that runner (queue-level promotion).
                self.requeued += self.queue.requeue_worker(w);
                self.writers.remove(&w);
                self.suspects.remove(&w);
                // Re-queued jobs leave the deadline watch (they rejoin
                // it on reassignment); promoted speculations keep their
                // watch running.
                let queue = &self.queue;
                self.watch.retain(|id, _| {
                    matches!(queue.get(*id).map(|j| &j.state), Some(JobState::Assigned { .. }))
                });
            }
        }
    }

    /// Send queued jobs to idle workers (sorted ids for determinism);
    /// each worker only receives jobs of its own class.  Suspected
    /// stragglers are skipped until they show a sign of life.
    fn pump_assign(&mut self) {
        let untyped = self.spec.classes.is_empty();
        let mut worker_ids: Vec<usize> = self.writers.keys().copied().collect();
        worker_ids.sort_unstable();
        for w in worker_ids {
            if self.suspects.contains(&w) {
                continue;
            }
            // Untyped legacy mode treats every connection as the single
            // fleet class (jobs are tagged with it too) — exactly the
            // PR-4 routing, so a mis-declared or not-yet-helloed worker
            // can still serve the fleet instead of stranding a job
            // pinned to it.  Typed mode routes strictly by Hello class;
            // a class-less connection gets nothing.
            let class = if untyped {
                if self.device_name.is_empty() {
                    continue; // no Hello yet anywhere: nothing to route
                }
                self.device_name.clone()
            } else {
                match self.class_of.get(&w) {
                    Some(c) => c.clone(),
                    None => continue,
                }
            };
            if let Some(job) = self.queue.assign(w, &class) {
                self.watch.insert(job.id, Instant::now());
                self.send_job(w, &job);
            }
        }
    }

    /// Write one Job message to a worker.  A failed write surfaces as a
    /// reader-side Disconnected event, which requeues the job.
    fn send_job(&mut self, w: usize, job: &Job) {
        let msg = Msg::Job {
            job_id: job.id,
            family: job.family.clone(),
            channels: job.channels.clone(),
            iterations: job.iterations,
        };
        if let Some(stream) = self.writers.get_mut(&w) {
            let _ = stream.write_all(msg.encode().as_bytes());
        }
    }

    /// Speculation candidates for a job of `class`: the same worker set
    /// the assignment pump would route that class to, sorted by id.
    fn peers_of(&self, class: &str) -> Vec<usize> {
        if self.spec.classes.is_empty() {
            let mut v: Vec<usize> = self
                .writers
                .keys()
                .copied()
                .filter(|w| self.helloed.contains(w))
                .collect();
            v.sort_unstable();
            v
        } else {
            self.live_of(class)
        }
    }

    /// How long the deadline-armed select loop may block: until the
    /// nearest watched job crosses `deadline` (floored so a crossed
    /// deadline cannot spin the loop hot), or one full `deadline` when
    /// nothing is in flight.
    fn next_deadline_wait(&self, deadline: Duration) -> Duration {
        let now = Instant::now();
        self.watch
            .values()
            .map(|t| (*t + deadline).saturating_duration_since(now))
            .min()
            .unwrap_or(deadline)
            .max(Duration::from_millis(10))
    }

    /// Deadline expiry without a disconnect: mark the holders of every
    /// expired job as suspects (no new work, pins cleared) and re-issue
    /// each expired job speculatively to an idle live same-class peer.
    /// When no peer is free *yet*, the watch re-arms and the job is
    /// retried at the next expiry; when every live worker of the class
    /// is itself a suspect, the class can never finish — hard error,
    /// mirroring the dead-class rule.
    fn reissue_stragglers(&mut self, deadline: Duration) -> Result<(), MeasureError> {
        let now = Instant::now();
        let expired: Vec<u64> = self
            .watch
            .iter()
            .filter(|(_, t)| now.duration_since(**t) >= deadline)
            .map(|(id, _)| *id)
            .collect();
        for id in expired {
            let (primary, spec_runner, class) = match self.queue.get(id) {
                Some(Job { state: JobState::Assigned { worker }, speculated, device, .. }) => {
                    (*worker, *speculated, device.clone())
                }
                _ => {
                    self.watch.remove(&id);
                    continue;
                }
            };
            // Suspect the straggling holder(s); unpin their queued jobs
            // so healthy peers can take them.
            if self.suspects.insert(primary) {
                self.queue.clear_affinity(primary);
            }
            if let Some(s) = spec_runner {
                if self.suspects.insert(s) {
                    self.queue.clear_affinity(s);
                }
            }
            let peers = self.peers_of(&class);
            let target = peers
                .iter()
                .copied()
                .find(|w| !self.suspects.contains(w) && !self.queue.busy(*w));
            match target {
                Some(w2) => {
                    if let Some(job) = self.queue.speculate(id, w2, &class) {
                        self.speculated += 1;
                        self.watch.insert(id, now);
                        self.send_job(w2, &job);
                    }
                }
                None => {
                    if !peers.is_empty() && peers.iter().all(|w| self.suspects.contains(w)) {
                        return Err(MeasureError(format!(
                            "every live worker of device class '{class}' stalled past the \
                             {deadline:?} job deadline with no healthy peer to speculate to"
                        )));
                    }
                    // Healthy peers exist but are busy (or formation is
                    // still settling): re-arm and retry at next expiry.
                    self.watch.insert(id, now);
                }
            }
        }
        Ok(())
    }

    /// A scheduled class whose last live worker is gone, if any —
    /// checked against the classes with unresolved jobs so `serve`
    /// errors instead of spinning forever.
    fn dead_class_with_work(&self) -> Option<String> {
        self.queue.classes_outstanding().into_iter().find(|c| self.live_of(c).is_empty())
    }

    /// Tell every remaining worker to exit and stop accepting new ones.
    pub fn shutdown(&mut self) {
        for (_, s) in self.writers.iter_mut() {
            let _ = s.write_all(Msg::Shutdown.encode().as_bytes());
        }
        self.writers.clear();
        self.stop_accept();
    }
}

impl Drop for FleetMeasurer {
    /// The accept loop is endless by design; make sure an erroring or
    /// aborted serve (e.g. the chaos experiments' injected leader
    /// death) still releases the thread and the listening port.
    fn drop(&mut self) {
        self.stop_accept();
    }
}

impl Measurer for FleetMeasurer {
    fn devices(&self) -> Vec<String> {
        if self.spec.classes.is_empty() {
            // Untyped legacy mode: the single class learned from the
            // first Hello (formation guarantees it exists).
            vec![self.device_name.clone()]
        } else {
            let mut cs: Vec<String> = self.spec.classes.iter().map(|(c, _)| c.clone()).collect();
            cs.sort_unstable();
            cs.dedup();
            cs
        }
    }

    fn occupancy(&self, device: &str) -> usize {
        // Untyped mode: every worker is the single class regardless of
        // its Hello string (PR-4 treated the fleet as one class).
        if self.spec.classes.is_empty() {
            self.writers.len()
        } else {
            self.live_of(device).len()
        }
    }

    fn measure_batch(&mut self, reqs: &[MeasureRequest]) -> Result<Vec<Measurement>, MeasureError> {
        debug_assert!(
            reqs.iter().all(|r| r.iterations == self.iterations),
            "request iterations diverge from the leader config"
        );
        // Deterministic class-scoped fan-out: the i-th request *of a
        // class* is pinned to that class's i-th live worker (sorted
        // ids, round-robin).  With hello-gated formation the live set
        // is the full fleet from the first batch on, so per-worker job
        // counts are a pure function of the config in a healthy
        // homogeneous run (mixed fleets aggregate per class instead:
        // the id ↔ class mapping follows connection order).
        let untyped = self.spec.classes.is_empty();
        let mut live_by_class: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut seen_by_class: BTreeMap<String, usize> = BTreeMap::new();
        let ids: Vec<u64> = reqs
            .iter()
            .map(|r| {
                let live = live_by_class.entry(r.device.clone()).or_insert_with(|| {
                    // Suspected stragglers take no pins: a job pinned to
                    // a worker the pump skips would strand forever.
                    if untyped {
                        let mut v: Vec<usize> = self
                            .writers
                            .keys()
                            .copied()
                            .filter(|w| !self.suspects.contains(w))
                            .collect();
                        v.sort_unstable();
                        v
                    } else {
                        self.live_of(&r.device)
                            .into_iter()
                            .filter(|w| !self.suspects.contains(w))
                            .collect()
                    }
                });
                let i = seen_by_class.entry(r.device.clone()).or_insert(0);
                let affinity = if live.is_empty() { None } else { Some(live[*i % live.len()]) };
                *i += 1;
                // Untyped jobs are tagged with the single fleet class so
                // class-scoped assignment stays a no-op filter there.
                let class = if untyped { self.device_name.clone() } else { r.device.clone() };
                self.queue.submit_to(&class, &r.family, r.channels.clone(), r.iterations, affinity)
            })
            .collect();
        loop {
            self.pump_assign();
            if ids.iter().all(|id| self.done.contains_key(id)) {
                break;
            }
            if self.writers.is_empty() {
                return Err(MeasureError(format!(
                    "all fleet workers disconnected with {} job(s) outstanding",
                    ids.iter().filter(|id| !self.done.contains_key(id)).count()
                )));
            }
            if !untyped {
                if let Some(c) = self.dead_class_with_work() {
                    return Err(MeasureError(format!(
                        "all workers of device class '{c}' disconnected with jobs outstanding; \
                         a heterogeneous store cannot be completed without that class"
                    )));
                }
            }
            match self.spec.job_deadline {
                // No deadline: the pre-straggler blocking wait.
                None => match self.rx.recv() {
                    Ok(ev) => self.on_event(ev),
                    Err(_) => {
                        return Err(MeasureError("fleet event channel closed".into()));
                    }
                },
                // Deadline armed: wait only until the nearest watched
                // job would expire, then run straggler recovery.
                Some(d) => {
                    let wait = self.next_deadline_wait(d);
                    match self.rx.recv_timeout(wait) {
                        Ok(ev) => self.on_event(ev),
                        Err(mpsc::RecvTimeoutError::Timeout) => self.reissue_stragglers(d)?,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            return Err(MeasureError("fleet event channel closed".into()));
                        }
                    }
                }
            }
        }
        Ok(ids.iter().map(|id| self.done.remove(id).expect("checked above")).collect())
    }
}
