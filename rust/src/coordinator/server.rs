//! Fitting leader: accepts device workers over TCP and exposes them to
//! the profiling pipeline as one [`FleetMeasurer`] backend.  The leader
//! runs the *same* acquisition code as a local run
//! ([`crate::thor::pipeline::Thor::profile`]) — the paper's
//! client/server split (the device only trains, the server only fits)
//! with none of the fit logic duplicated server-side.  Each batched
//! acquisition round fans its requests across the fleet as jobs; the
//! [`crate::coordinator::scheduler::JobQueue`] provides affinity
//! routing, exactly-once completion and requeue-on-death.
//!
//! Concurrency model: one accept loop; per-connection reader threads
//! push (worker, msg) events into an mpsc channel; the leader thread
//! owns all state (queue + pipeline) — no shared-state locking beyond
//! the channel.
//!
//! Determinism: batch requests are submitted with a worker affinity
//! (request index modulo live workers, sorted ids) and only issued once
//! every expected worker has said Hello (or [`FORMATION_GRACE`]
//! expires), so with per-job-seeded workers
//! ([`crate::coordinator::worker::job_seed`]) the final store *and* the
//! per-worker job counts are pure functions of (reference, config, base
//! seed) — independent of OS scheduling, and byte-identical to a
//! [`crate::thor::measure::LocalMeasurer::per_job`] run at any worker
//! count (`rust/tests/backend_equiv.rs`).  On a worker death its jobs
//! re-queue with affinity cleared, trading count determinism for
//! liveness (the store stays deterministic either way).

use std::collections::{BTreeSet, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::protocol::Msg;
use crate::coordinator::scheduler::JobQueue;
use crate::model::ModelGraph;
use crate::thor::measure::{MeasureError, MeasureRequest, Measurement, Measurer};
use crate::thor::pipeline::ThorConfig;
use crate::thor::store::GpStore;
use crate::thor::Thor;

enum Event {
    Connected(usize, TcpStream),
    Message(usize, Msg),
    Disconnected(usize),
}

/// Outcome of one fleet profiling run (see
/// [`BoundFleetServer::serve`]).
pub struct FleetRun {
    pub store: GpStore,
    /// Jobs ever submitted by the leader.
    pub jobs_submitted: usize,
    /// Jobs completed (each exactly once; duplicates are dropped).
    pub jobs_done: usize,
    /// Completed jobs per worker index (connection order), length =
    /// `expect_workers`.
    pub per_worker: Vec<usize>,
    /// In-flight jobs re-queued because their worker disconnected.
    pub requeued: usize,
}

/// The fleet fitting server.
pub struct FleetServer {
    pub cfg: ThorConfig,
}

/// How long the leader waits for the full fleet to say Hello before
/// proceeding with whoever showed up.  Within the window, job issue is
/// gated on all `expect_workers` Hellos (deterministic affinity); after
/// it, liveness wins — a worker that never connects or dies before
/// Hello no longer hangs `thor serve` forever.  In-process fleets
/// (fleet1/fleetN, tests) form in milliseconds, so the degraded path
/// never fires there and wall-clock never influences their reports.
const FORMATION_GRACE: Duration = Duration::from_secs(30);

/// A fleet server bound to a local address but not yet serving — lets
/// callers bind to an ephemeral port (`127.0.0.1:0`), read
/// [`BoundFleetServer::local_addr`], hand it to workers, then
/// [`BoundFleetServer::serve`].
pub struct BoundFleetServer {
    cfg: ThorConfig,
    listener: TcpListener,
    addr: SocketAddr,
}

impl FleetServer {
    pub fn new(cfg: ThorConfig) -> Self {
        Self { cfg }
    }

    /// Bind `addr` (supports port 0 for an OS-assigned port).
    pub fn bind(&self, addr: &str) -> Result<BoundFleetServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(BoundFleetServer { cfg: self.cfg, listener, addr })
    }

    /// Serve on `addr` until every family of `reference` is fitted for
    /// `expect_workers` workers' devices, then shut workers down.
    /// Convenience wrapper over [`FleetServer::bind`] +
    /// [`BoundFleetServer::serve`] for the CLI.
    pub fn run(&self, addr: &str, reference: &ModelGraph, expect_workers: usize) -> Result<GpStore> {
        Ok(self.bind(addr)?.serve(reference, expect_workers)?.store)
    }
}

impl BoundFleetServer {
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until every family of `reference` is fitted, then shut
    /// workers down.
    ///
    /// Single-device fleet: all workers must expose the same device type
    /// (heterogeneous fleets run one server per device type — matching
    /// the paper, where GPs never transfer across devices; the `fleetN`
    /// experiment does exactly that).
    ///
    /// Errors when the whole fleet disconnects with jobs outstanding —
    /// there is no partial-store fallback anymore: a store must be a
    /// complete pure function of the config or nothing.
    pub fn serve(self, reference: &ModelGraph, expect_workers: usize) -> Result<FleetRun> {
        let BoundFleetServer { cfg, listener, addr: _ } = self;
        let mut fleet = FleetMeasurer::accept(listener, expect_workers, cfg.iterations);
        fleet.form(FORMATION_GRACE);
        let mut thor = Thor::new(cfg);
        thor.profile(&mut fleet, reference).map_err(|e| anyhow!("fleet profiling failed: {e}"))?;
        fleet.shutdown();
        Ok(FleetRun {
            store: thor.store,
            jobs_submitted: fleet.queue.submitted(),
            jobs_done: fleet.queue.done(),
            per_worker: fleet.per_worker,
            requeued: fleet.requeued,
        })
    }
}

/// The fleet as a measurement backend: a batch of requests becomes a
/// batch of jobs fanned across the live workers; `measure_batch`
/// returns when every job of the batch has resolved (requeue-on-death
/// included), in request order.
pub struct FleetMeasurer {
    rx: mpsc::Receiver<Event>,
    /// Keeps the channel open even after the accept/reader threads end.
    _tx: mpsc::Sender<Event>,
    writers: HashMap<usize, TcpStream>,
    helloed: BTreeSet<usize>,
    queue: JobQueue,
    /// Completed measurements awaiting pickup, by job id.
    done: HashMap<u64, Measurement>,
    per_worker: Vec<usize>,
    requeued: usize,
    device_name: String,
    expect_workers: usize,
    started: Instant,
    /// Jobs carry this iteration count (the leader's ThorConfig) — kept
    /// here so the measurer can sanity-check request batches.
    iterations: usize,
}

impl FleetMeasurer {
    /// Start accepting up to `expect_workers` connections on `listener`.
    fn accept(listener: TcpListener, expect_workers: usize, iterations: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Event>();
        let accept_tx = tx.clone();
        std::thread::spawn(move || {
            for (i, stream) in listener.incoming().enumerate() {
                let Ok(stream) = stream else { break };
                let _ = accept_tx.send(Event::Connected(i, stream));
                if i + 1 >= expect_workers {
                    break;
                }
            }
        });
        Self {
            rx,
            _tx: tx,
            writers: HashMap::new(),
            helloed: BTreeSet::new(),
            queue: JobQueue::new(),
            done: HashMap::new(),
            per_worker: vec![0; expect_workers],
            requeued: 0,
            device_name: String::new(),
            expect_workers,
            started: Instant::now(),
            iterations,
        }
    }

    /// Wait for the fleet to form: all `expect_workers` Hellos, or at
    /// least one Hello once `grace` has expired (partial fleet proceeds
    /// instead of hanging — liveness over count determinism).
    fn form(&mut self, grace: Duration) {
        loop {
            if self.helloed.len() >= self.expect_workers {
                return;
            }
            let elapsed = self.started.elapsed();
            if !self.helloed.is_empty() && elapsed >= grace {
                eprintln!(
                    "fleet leader: only {}/{} workers joined within {grace:?}; \
                     proceeding with the partial fleet",
                    self.helloed.len(),
                    self.expect_workers
                );
                return;
            }
            let wait = grace.checked_sub(elapsed).unwrap_or(Duration::from_millis(50));
            match self.rx.recv_timeout(wait) {
                Ok(ev) => self.on_event(ev),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
    }

    /// Process one event (connection, hello, result, disconnect).
    fn on_event(&mut self, ev: Event) {
        match ev {
            Event::Connected(w, stream) => {
                let reader_tx = self._tx.clone();
                let read_stream = match stream.try_clone() {
                    Ok(s) => s,
                    Err(e) => {
                        // Never registered as a writer, so accounting
                        // treats it like a worker that never connected;
                        // say so instead of stalling silently.
                        eprintln!("fleet leader: dropping worker {w}: stream clone failed: {e}");
                        return;
                    }
                };
                self.writers.insert(w, stream);
                std::thread::spawn(move || {
                    let mut reader = BufReader::new(read_stream);
                    loop {
                        let mut line = String::new();
                        match reader.read_line(&mut line) {
                            Ok(0) | Err(_) => {
                                let _ = reader_tx.send(Event::Disconnected(w));
                                break;
                            }
                            Ok(_) => {
                                if let Some(m) = Msg::decode(&line) {
                                    if reader_tx.send(Event::Message(w, m)).is_err() {
                                        break;
                                    }
                                }
                            }
                        }
                    }
                });
            }
            Event::Message(w, Msg::Hello { device }) => {
                self.helloed.insert(w);
                if self.device_name.is_empty() {
                    self.device_name = device;
                }
            }
            Event::Message(w, Msg::Result { job_id, energy_per_iter, device_seconds }) => {
                // exactly-once: stale/duplicate completions are dropped
                if self.queue.complete(job_id, w) {
                    if w < self.per_worker.len() {
                        self.per_worker[w] += 1;
                    }
                    self.done.insert(job_id, Measurement { energy_per_iter, device_seconds });
                }
            }
            Event::Message(_, _) => {}
            Event::Disconnected(w) => {
                // Re-queue the dead worker's in-flight jobs (affinity
                // cleared): they keep their ids, so completion by another
                // worker still resolves the original request.
                self.requeued += self.queue.requeue_worker(w);
                self.writers.remove(&w);
            }
        }
    }

    /// Send queued jobs to idle workers (sorted ids for determinism).
    fn pump_assign(&mut self) {
        let mut worker_ids: Vec<usize> = self.writers.keys().copied().collect();
        worker_ids.sort_unstable();
        for w in worker_ids {
            if let Some(job) = self.queue.assign(w) {
                let msg = Msg::Job {
                    job_id: job.id,
                    family: job.family.clone(),
                    channels: job.channels.clone(),
                    iterations: job.iterations,
                };
                if let Some(stream) = self.writers.get_mut(&w) {
                    // A failed write surfaces as a reader-side
                    // Disconnected event, which requeues the job.
                    let _ = stream.write_all(msg.encode().as_bytes());
                }
            }
        }
    }

    /// Tell every remaining worker to exit.
    pub fn shutdown(&mut self) {
        for (_, s) in self.writers.iter_mut() {
            let _ = s.write_all(Msg::Shutdown.encode().as_bytes());
        }
        self.writers.clear();
    }
}

impl Measurer for FleetMeasurer {
    fn device(&self) -> &str {
        &self.device_name
    }

    fn measure_batch(&mut self, reqs: &[MeasureRequest]) -> Result<Vec<Measurement>, MeasureError> {
        // Deterministic fan-out: request i of the batch is pinned to the
        // i-th live worker (sorted ids, round-robin).  With hello-gated
        // formation the live set is the full fleet from the first batch
        // on, so per-worker job counts are a pure function of the
        // config in a healthy run.
        let live: Vec<usize> = {
            let mut v: Vec<usize> = self.writers.keys().copied().collect();
            v.sort_unstable();
            v
        };
        debug_assert!(
            reqs.iter().all(|r| r.iterations == self.iterations),
            "request iterations diverge from the leader config"
        );
        let ids: Vec<u64> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let affinity = if live.is_empty() { None } else { Some(live[i % live.len()]) };
                self.queue.submit_to(&r.family, r.channels.clone(), r.iterations, affinity)
            })
            .collect();
        loop {
            self.pump_assign();
            if ids.iter().all(|id| self.done.contains_key(id)) {
                break;
            }
            if self.writers.is_empty() {
                return Err(MeasureError(format!(
                    "all fleet workers disconnected with {} job(s) outstanding",
                    ids.iter().filter(|id| !self.done.contains_key(id)).count()
                )));
            }
            match self.rx.recv() {
                Ok(ev) => self.on_event(ev),
                Err(_) => {
                    return Err(MeasureError("fleet event channel closed".into()));
                }
            }
        }
        Ok(ids.iter().map(|id| self.done.remove(id).expect("checked above")).collect())
    }
}
