//! Fitting leader: accepts device workers over TCP, drives each
//! family's active-learning loop by issuing measurement jobs, fits the
//! GPs server-side (the paper's client/server split: the device only
//! trains, the server only fits), and returns a populated
//! [`crate::thor::store::GpStore`].
//!
//! Concurrency model: one accept loop; per-connection reader threads
//! push (worker, msg) events into an mpsc channel; the leader thread
//! owns all state (queue + fit loops) — no shared-state locking beyond
//! the channel.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

use anyhow::Result;

use crate::coordinator::protocol::Msg;
use crate::coordinator::scheduler::JobQueue;
use crate::model::ModelGraph;
use crate::thor::fit::FitConfig;
use crate::thor::parse::{parse, Position};
use crate::thor::pipeline::{log_channel, ThorConfig};
use crate::thor::profiler::{fc_in_after, ranges};
use crate::thor::store::{GpStore, StoredGp};
use crate::gp::acquisition::{max_variance, Acquire, CandidateGrid};
use crate::gp::GpModel;

enum Event {
    Connected(usize, TcpStream),
    Message(usize, Msg),
    Disconnected(usize),
}

/// Per-family sequential fit state driven by remote measurements.
struct FamilyFit {
    family: String,
    dim: usize,
    x_max: Vec<f64>,
    /// Pending start points not yet issued.
    start_queue: Vec<Vec<f64>>,
    /// (normalized point, energy, device seconds).
    points: Vec<(Vec<f64>, f64, f64)>,
    /// Outstanding job (job id, normalized point, subtraction terms).
    outstanding: Option<(u64, Vec<f64>, f64)>,
    converged: bool,
    device_seconds: f64,
    /// Families whose GPs must exist before this one can run
    /// (subtractivity ordering: out → in → hidden).
    stage: usize,
}

/// The fleet fitting server.
pub struct FleetServer {
    pub cfg: ThorConfig,
}

impl FleetServer {
    pub fn new(cfg: ThorConfig) -> Self {
        Self { cfg }
    }

    /// Serve on `addr` until every family of `reference` is fitted for
    /// `expect_workers` workers' devices, then shut workers down.
    ///
    /// Single-device fleet: all workers must expose the same device type
    /// (heterogeneous fleets run one server per device type — matching
    /// the paper, where GPs never transfer across devices).
    pub fn run(&self, addr: &str, reference: &ModelGraph, expect_workers: usize) -> Result<GpStore> {
        let listener = TcpListener::bind(addr)?;
        let real_addr = listener.local_addr()?;
        let (tx, rx) = mpsc::channel::<Event>();

        // accept loop
        let accept_tx = tx.clone();
        let accept_handle = std::thread::spawn(move || {
            for (i, stream) in listener.incoming().enumerate() {
                let Ok(stream) = stream else { break };
                let _ = accept_tx.send(Event::Connected(i, stream));
                if i + 1 >= expect_workers {
                    break;
                }
            }
        });
        let _ = real_addr;

        // leader state
        let parsed = parse(reference);
        let rg = ranges(&parsed);
        let out_tmpl = parsed.output_groups().next().unwrap().clone();
        let in_tmpl = parsed.input_groups().next().unwrap().clone();
        let fit_cfg_1 = self.fit_cfg(1);
        let fit_cfg_2 = self.fit_cfg(2);

        let mut fits: Vec<FamilyFit> = Vec::new();
        fits.push(FamilyFit {
            family: out_tmpl.key.id(),
            dim: 1,
            x_max: vec![rg.out_max as f64],
            start_queue: vec![vec![0.0], vec![1.0], vec![0.5]],
            points: Vec::new(),
            outstanding: None,
            converged: false,
            device_seconds: 0.0,
            stage: 0,
        });
        fits.push(FamilyFit {
            family: in_tmpl.key.id(),
            dim: 1,
            x_max: vec![rg.in_max as f64],
            start_queue: vec![vec![0.0], vec![1.0], vec![0.5]],
            points: Vec::new(),
            outstanding: None,
            converged: false,
            device_seconds: 0.0,
            stage: 1,
        });
        for (fi, fam) in parsed.families.iter().enumerate() {
            if fam.position != Position::Hidden {
                continue;
            }
            let (a, b) = rg.hidden_max[fi];
            fits.push(FamilyFit {
                family: fam.id(),
                dim: 2,
                x_max: vec![a.max(2) as f64, b.max(2) as f64],
                start_queue: vec![
                    vec![0.0, 0.0],
                    vec![0.0, 1.0],
                    vec![1.0, 0.0],
                    vec![1.0, 1.0],
                    vec![0.5, 0.5],
                ],
                points: Vec::new(),
                outstanding: None,
                converged: false,
                device_seconds: 0.0,
                stage: 2,
            });
        }

        let mut queue = JobQueue::new();
        let mut job_meta: HashMap<u64, usize> = HashMap::new(); // job -> fit index
        let mut writers: HashMap<usize, TcpStream> = HashMap::new();
        let mut device_name = String::new();
        let mut store = GpStore::new();

        // Helper: (re)fit a family GP from its points; store when done.
        let finalize = |fit: &FamilyFit, store: &mut GpStore, dev: &str, cfg: &FitConfig| {
            let xs: Vec<Vec<f64>> = fit.points.iter().map(|p| p.0.clone()).collect();
            let ys: Vec<f64> = fit.points.iter().map(|p| p.1.max(1e-15).ln()).collect();
            if let Some(gp) = GpModel::fit(cfg.kind, xs, &ys) {
                store.insert(
                    dev,
                    &fit.family,
                    StoredGp {
                        gp,
                        x_max: fit.x_max.clone(),
                        log_x: true,
                        log_y: true,
                        device_seconds: fit.device_seconds,
                        fit_seconds: 0.0,
                        converged: fit.converged,
                    },
                );
            }
        };

        loop {
            // issue next probes for ready, unconverged families
            // (stage gating: out → in → hidden, per subtractivity)
            if !device_name.is_empty() {
                for (fi, fit) in fits.iter_mut().enumerate() {
                    if fit.converged || fit.outstanding.is_some() {
                        continue;
                    }
                    if !stage_ready_impl(&store, &device_name, fit.stage, &stage_gate_names(fit.stage, &out_tmpl, &in_tmpl)) {
                        continue;
                    }
                    let cfg = if fit.dim == 1 { &fit_cfg_1 } else { &fit_cfg_2 };
                    let next = next_probe(fit, cfg);
                    match next {
                        Some(p) => {
                            let channels: Vec<usize> =
                                p.iter().zip(&fit.x_max).map(|(v, m)| log_channel(*v, *m)).collect();
                            // subtraction terms computed server-side from stored GPs
                            let subtract = subtraction_for(
                                &store,
                                &device_name,
                                fit.stage,
                                &channels,
                                &out_tmpl,
                                &in_tmpl,
                                &parsed,
                                &fit.family,
                            );
                            let id = queue.submit(&fit.family, channels, self.cfg.iterations);
                            job_meta.insert(id, fi);
                            fit.outstanding = Some((id, p, subtract));
                        }
                        None => {
                            fit.converged = true;
                            finalize(fit, &mut store, &device_name, cfg);
                        }
                    }
                }
            }

            // assign queued jobs to idle workers
            let worker_ids: Vec<usize> = writers.keys().copied().collect();
            for w in worker_ids {
                if let Some(job) = queue.assign(w) {
                    let msg = Msg::Job {
                        job_id: job.id,
                        family: job.family.clone(),
                        channels: job.channels.clone(),
                        iterations: job.iterations,
                    };
                    if let Some(stream) = writers.get_mut(&w) {
                        let _ = stream.write_all(msg.encode().as_bytes());
                    }
                }
            }

            // done?
            if !device_name.is_empty() && fits.iter().all(|f| f.converged) {
                break;
            }

            // wait for events
            match rx.recv() {
                Err(_) => break,
                Ok(Event::Connected(w, stream)) => {
                    let reader_tx = tx.clone();
                    let read_stream = stream.try_clone()?;
                    writers.insert(w, stream);
                    std::thread::spawn(move || {
                        let mut reader = BufReader::new(read_stream);
                        loop {
                            let mut line = String::new();
                            match reader.read_line(&mut line) {
                                Ok(0) | Err(_) => {
                                    let _ = reader_tx.send(Event::Disconnected(w));
                                    break;
                                }
                                Ok(_) => {
                                    if let Some(m) = Msg::decode(&line) {
                                        if reader_tx.send(Event::Message(w, m)).is_err() {
                                            break;
                                        }
                                    }
                                }
                            }
                        }
                    });
                }
                Ok(Event::Message(w, Msg::Hello { device })) => {
                    if device_name.is_empty() {
                        device_name = device;
                    }
                    let _ = w;
                }
                Ok(Event::Message(w, Msg::Result { job_id, energy_per_iter, device_seconds })) => {
                    if queue.complete(job_id, w) {
                        if let Some(&fi) = job_meta.get(&job_id) {
                            let fit = &mut fits[fi];
                            if let Some((oid, p, subtract)) = fit.outstanding.take() {
                                debug_assert_eq!(oid, job_id);
                                let e = (energy_per_iter - subtract).max(1e-12);
                                fit.points.push((p, e, device_seconds));
                                fit.device_seconds += device_seconds;
                            }
                        }
                    }
                }
                Ok(Event::Message(_, _)) => {}
                Ok(Event::Disconnected(w)) => {
                    queue.requeue_worker(w);
                    // drop outstanding markers pointing at requeued jobs
                    for fit in fits.iter_mut() {
                        if let Some((id, _, _)) = &fit.outstanding {
                            if queue.get(*id).map(|j| j.state == crate::coordinator::scheduler::JobState::Queued).unwrap_or(false) {
                                // leave outstanding: job will be re-assigned under same id
                                let _ = id;
                            }
                        }
                    }
                    writers.remove(&w);
                    if writers.is_empty() && queue.pending() > 0 {
                        // no workers left: abort
                        break;
                    }
                }
            }
        }

        // finalize any unconverged-but-budgeted fits
        for fit in &fits {
            if !store.contains(&device_name, &fit.family) && !fit.points.is_empty() {
                let cfg = if fit.dim == 1 { &fit_cfg_1 } else { &fit_cfg_2 };
                finalize(fit, &mut store, &device_name, cfg);
            }
        }

        // shut down workers
        for (_, mut s) in writers {
            let _ = s.write_all(Msg::Shutdown.encode().as_bytes());
        }
        drop(accept_handle);
        Ok(store)
    }

    fn fit_cfg(&self, dim: usize) -> FitConfig {
        FitConfig {
            kind: self.cfg.kind,
            max_points: if dim == 1 { self.cfg.max_points_1d } else { self.cfg.max_points_2d },
            threshold_frac: self.cfg.threshold_frac,
            grid_n: if dim == 1 { self.cfg.grid_n_1d } else { self.cfg.grid_n_2d },
            time_surrogate: self.cfg.time_surrogate,
            random_sampling: self.cfg.random_sampling,
            log_targets: true,
            seed: self.cfg.seed,
        }
    }
}

fn stage_gate_names(
    stage: usize,
    out_tmpl: &crate::thor::parse::Group,
    in_tmpl: &crate::thor::parse::Group,
) -> Vec<String> {
    match stage {
        0 => vec![],
        1 => vec![out_tmpl.key.id()],
        _ => vec![out_tmpl.key.id(), in_tmpl.key.id()],
    }
}

fn stage_ready_impl(store: &GpStore, dev: &str, _stage: usize, gates: &[String]) -> bool {
    gates.iter().all(|g| store.contains(dev, g))
}

/// Server-side subtraction terms (eqs. 1–2) for a probe.
#[allow(clippy::too_many_arguments)]
fn subtraction_for(
    store: &GpStore,
    dev: &str,
    stage: usize,
    channels: &[usize],
    out_tmpl: &crate::thor::parse::Group,
    in_tmpl: &crate::thor::parse::Group,
    parsed: &crate::thor::parse::ParsedModel,
    family: &str,
) -> f64 {
    match stage {
        0 => 0.0,
        1 => {
            let gi = in_tmpl.with_channels(in_tmpl.anchor.c_in, channels[0].max(1));
            let fc_in = fc_in_after(&gi).max(1);
            store
                .get(dev, &out_tmpl.key.id())
                .map(|g| g.predict_raw(&[fc_in as f64]).0.max(0.0))
                .unwrap_or(0.0)
        }
        _ => {
            let tmpl = parsed
                .groups
                .iter()
                .find(|g| g.key.id() == family)
                .expect("family template");
            let gh = tmpl.with_channels(channels[0].max(1), channels[1].max(1));
            let fc_in = fc_in_after(&gh).max(1);
            let e_in = store
                .get(dev, &in_tmpl.key.id())
                .map(|g| g.predict_raw(&[1.0]).0.max(0.0))
                .unwrap_or(0.0);
            let e_out = store
                .get(dev, &out_tmpl.key.id())
                .map(|g| g.predict_raw(&[fc_in as f64]).0.max(0.0))
                .unwrap_or(0.0);
            e_in + e_out
        }
    }
}

/// Next probe for a family fit (start points, then max-variance).
fn next_probe(fit: &mut FamilyFit, cfg: &FitConfig) -> Option<Vec<f64>> {
    if let Some(p) = fit.start_queue.pop() {
        return Some(p);
    }
    if fit.points.len() >= cfg.max_points {
        return None;
    }
    let xs: Vec<Vec<f64>> = fit.points.iter().map(|p| p.0.clone()).collect();
    let ys: Vec<f64> = fit.points.iter().map(|p| p.1.max(1e-15).ln()).collect();
    let gp = GpModel::fit(cfg.kind, xs, &ys)?;
    let grid = if fit.dim == 1 {
        CandidateGrid::dim1(0.0, 1.0, cfg.grid_n)
    } else {
        CandidateGrid::dim2(0.0, 1.0, cfg.grid_n)
    };
    match max_variance(&gp, &grid, cfg.threshold_frac, 1.0) {
        Acquire::Next(p, _) => Some(p),
        Acquire::Converged(_) => None,
    }
}
