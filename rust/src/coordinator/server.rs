//! Fitting leader: accepts device workers over TCP, drives each
//! family's active-learning loop by issuing measurement jobs, fits the
//! GPs server-side (the paper's client/server split: the device only
//! trains, the server only fits), and returns a populated
//! [`crate::thor::store::GpStore`].
//!
//! Concurrency model: one accept loop; per-connection reader threads
//! push (worker, msg) events into an mpsc channel; the leader thread
//! owns all state (queue + fit loops) — no shared-state locking beyond
//! the channel.
//!
//! Determinism: jobs are submitted with a worker affinity (fit index
//! modulo live workers) and only issued once every expected worker has
//! said Hello (or [`FORMATION_GRACE`] expires), so with per-job-seeded
//! workers ([`crate::coordinator::worker::job_seed`]) the final store
//! *and* the per-worker job counts are pure functions of (reference,
//! config, base seed) — independent of OS scheduling.  On a worker
//! death its jobs re-queue with affinity cleared, trading count
//! determinism for liveness (the store stays deterministic either way).

use std::collections::{BTreeSet, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::protocol::Msg;
use crate::coordinator::scheduler::JobQueue;
use crate::gp::acquisition::{max_variance, Acquire, CandidateGrid};
use crate::gp::GpModel;
use crate::model::ModelGraph;
use crate::thor::fit::FitConfig;
use crate::thor::parse::{parse, Position};
use crate::thor::pipeline::{log_channel, ThorConfig};
use crate::thor::profiler::{fc_in_after, ranges};
use crate::thor::store::{GpStore, StoredGp};

enum Event {
    Connected(usize, TcpStream),
    Message(usize, Msg),
    Disconnected(usize),
}

/// Per-family sequential fit state driven by remote measurements.
struct FamilyFit {
    family: String,
    dim: usize,
    x_max: Vec<f64>,
    /// Pending start points not yet issued.
    start_queue: Vec<Vec<f64>>,
    /// (normalized point, energy, device seconds).
    points: Vec<(Vec<f64>, f64, f64)>,
    /// Outstanding job (job id, normalized point, subtraction terms).
    outstanding: Option<(u64, Vec<f64>, f64)>,
    converged: bool,
    device_seconds: f64,
    /// Families whose GPs must exist before this one can run
    /// (subtractivity ordering: out → in → hidden).
    stage: usize,
}

/// Outcome of one fleet profiling run (see
/// [`BoundFleetServer::serve`]).
pub struct FleetRun {
    pub store: GpStore,
    /// Jobs ever submitted by the leader.
    pub jobs_submitted: usize,
    /// Jobs completed (each exactly once; duplicates are dropped).
    pub jobs_done: usize,
    /// Completed jobs per worker index (connection order), length =
    /// `expect_workers`.
    pub per_worker: Vec<usize>,
    /// In-flight jobs re-queued because their worker disconnected.
    pub requeued: usize,
}

/// The fleet fitting server.
pub struct FleetServer {
    pub cfg: ThorConfig,
}

/// How long the leader waits for the full fleet to say Hello before
/// proceeding with whoever showed up.  Within the window, job issue is
/// gated on all `expect_workers` Hellos (deterministic affinity); after
/// it, liveness wins — a worker that never connects or dies before
/// Hello no longer hangs `thor serve` forever.  In-process fleets
/// (fleet1, tests) form in milliseconds, so the degraded path never
/// fires there and wall-clock never influences their reports.
const FORMATION_GRACE: Duration = Duration::from_secs(30);

/// A fleet server bound to a local address but not yet serving — lets
/// callers bind to an ephemeral port (`127.0.0.1:0`), read
/// [`BoundFleetServer::local_addr`], hand it to workers, then
/// [`BoundFleetServer::serve`].
pub struct BoundFleetServer {
    cfg: ThorConfig,
    listener: TcpListener,
    addr: SocketAddr,
}

impl FleetServer {
    pub fn new(cfg: ThorConfig) -> Self {
        Self { cfg }
    }

    /// Bind `addr` (supports port 0 for an OS-assigned port).
    pub fn bind(&self, addr: &str) -> Result<BoundFleetServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(BoundFleetServer { cfg: self.cfg, listener, addr })
    }

    /// Serve on `addr` until every family of `reference` is fitted for
    /// `expect_workers` workers' devices, then shut workers down.
    /// Convenience wrapper over [`FleetServer::bind`] +
    /// [`BoundFleetServer::serve`] for the CLI.
    pub fn run(&self, addr: &str, reference: &ModelGraph, expect_workers: usize) -> Result<GpStore> {
        Ok(self.bind(addr)?.serve(reference, expect_workers)?.store)
    }
}

impl BoundFleetServer {
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until every family of `reference` is fitted, then shut
    /// workers down.
    ///
    /// Single-device fleet: all workers must expose the same device type
    /// (heterogeneous fleets run one server per device type — matching
    /// the paper, where GPs never transfer across devices).
    pub fn serve(self, reference: &ModelGraph, expect_workers: usize) -> Result<FleetRun> {
        let BoundFleetServer { cfg, listener, addr: _ } = self;
        let (tx, rx) = mpsc::channel::<Event>();

        // accept loop
        let accept_tx = tx.clone();
        std::thread::spawn(move || {
            for (i, stream) in listener.incoming().enumerate() {
                let Ok(stream) = stream else { break };
                let _ = accept_tx.send(Event::Connected(i, stream));
                if i + 1 >= expect_workers {
                    break;
                }
            }
        });

        // leader state
        let parsed = parse(reference);
        let rg = ranges(&parsed);
        let out_tmpl = parsed.output_groups().next().unwrap().clone();
        let in_tmpl = parsed.input_groups().next().unwrap().clone();
        let fit_cfg_1 = fit_cfg(&cfg, 1);
        let fit_cfg_2 = fit_cfg(&cfg, 2);

        let mut fits: Vec<FamilyFit> = Vec::new();
        fits.push(FamilyFit {
            family: out_tmpl.key.id(),
            dim: 1,
            x_max: vec![rg.out_max as f64],
            start_queue: vec![vec![0.0], vec![1.0], vec![0.5]],
            points: Vec::new(),
            outstanding: None,
            converged: false,
            device_seconds: 0.0,
            stage: 0,
        });
        fits.push(FamilyFit {
            family: in_tmpl.key.id(),
            dim: 1,
            x_max: vec![rg.in_max as f64],
            start_queue: vec![vec![0.0], vec![1.0], vec![0.5]],
            points: Vec::new(),
            outstanding: None,
            converged: false,
            device_seconds: 0.0,
            stage: 1,
        });
        for (fi, fam) in parsed.families.iter().enumerate() {
            if fam.position != Position::Hidden {
                continue;
            }
            let (a, b) = rg.hidden_max[fi];
            fits.push(FamilyFit {
                family: fam.id(),
                dim: 2,
                x_max: vec![a.max(2) as f64, b.max(2) as f64],
                start_queue: vec![
                    vec![0.0, 0.0],
                    vec![0.0, 1.0],
                    vec![1.0, 0.0],
                    vec![1.0, 1.0],
                    vec![0.5, 0.5],
                ],
                points: Vec::new(),
                outstanding: None,
                converged: false,
                device_seconds: 0.0,
                stage: 2,
            });
        }

        let mut queue = JobQueue::new();
        let mut job_meta: HashMap<u64, usize> = HashMap::new(); // job -> fit index
        let mut writers: HashMap<usize, TcpStream> = HashMap::new();
        let mut helloed: BTreeSet<usize> = BTreeSet::new();
        let mut device_name = String::new();
        let mut store = GpStore::new();
        let mut per_worker = vec![0usize; expect_workers];
        let mut requeued = 0usize;
        let started = Instant::now();
        let mut gate_open = false;

        // Helper: (re)fit a family GP from its points; store when done.
        let finalize = |fit: &FamilyFit, store: &mut GpStore, dev: &str, cfg: &FitConfig| {
            let xs: Vec<Vec<f64>> = fit.points.iter().map(|p| p.0.clone()).collect();
            let ys: Vec<f64> = fit.points.iter().map(|p| p.1.max(1e-15).ln()).collect();
            if let Some(gp) = GpModel::fit(cfg.kind, xs, &ys) {
                store.insert(
                    dev,
                    &fit.family,
                    StoredGp {
                        gp,
                        x_max: fit.x_max.clone(),
                        log_x: true,
                        log_y: true,
                        device_seconds: fit.device_seconds,
                        fit_seconds: 0.0,
                        converged: fit.converged,
                    },
                );
            }
        };

        loop {
            // Job issue is gated until the whole fleet has said Hello,
            // so job → worker affinity is deterministic from the first
            // job on; after FORMATION_GRACE, proceed with the partial
            // fleet rather than hanging forever (liveness over count
            // determinism — the store stays deterministic either way).
            if !gate_open
                && !device_name.is_empty()
                && (helloed.len() >= expect_workers
                    || (!helloed.is_empty() && started.elapsed() >= FORMATION_GRACE))
            {
                gate_open = true;
                if helloed.len() < expect_workers {
                    eprintln!(
                        "fleet leader: only {}/{} workers joined within {FORMATION_GRACE:?}; \
                         proceeding with the partial fleet",
                        helloed.len(),
                        expect_workers
                    );
                }
            }

            // issue next probes for ready, unconverged families
            // (stage gating: out → in → hidden, per subtractivity)
            if gate_open {
                let live: Vec<usize> = {
                    let mut v: Vec<usize> = writers.keys().copied().collect();
                    v.sort_unstable();
                    v
                };
                for (fi, fit) in fits.iter_mut().enumerate() {
                    if fit.converged || fit.outstanding.is_some() {
                        continue;
                    }
                    if !stage_ready_impl(
                        &store,
                        &device_name,
                        fit.stage,
                        &stage_gate_names(fit.stage, &out_tmpl, &in_tmpl),
                    ) {
                        continue;
                    }
                    let fcfg = if fit.dim == 1 { &fit_cfg_1 } else { &fit_cfg_2 };
                    match next_probe(fit, fcfg) {
                        Some(p) => {
                            let channels: Vec<usize> =
                                p.iter().zip(&fit.x_max).map(|(v, m)| log_channel(*v, *m)).collect();
                            // subtraction terms computed server-side from stored GPs
                            let subtract = subtraction_for(
                                &store,
                                &device_name,
                                fit.stage,
                                &channels,
                                &out_tmpl,
                                &in_tmpl,
                                &parsed,
                                &fit.family,
                            );
                            let affinity = if live.is_empty() {
                                None
                            } else {
                                Some(live[fi % live.len()])
                            };
                            let id =
                                queue.submit_to(&fit.family, channels, cfg.iterations, affinity);
                            job_meta.insert(id, fi);
                            fit.outstanding = Some((id, p, subtract));
                        }
                        None => {
                            fit.converged = true;
                            finalize(fit, &mut store, &device_name, fcfg);
                        }
                    }
                }
            }

            // assign queued jobs to idle workers (sorted for determinism)
            let mut worker_ids: Vec<usize> = writers.keys().copied().collect();
            worker_ids.sort_unstable();
            for w in worker_ids {
                if let Some(job) = queue.assign(w) {
                    let msg = Msg::Job {
                        job_id: job.id,
                        family: job.family.clone(),
                        channels: job.channels.clone(),
                        iterations: job.iterations,
                    };
                    if let Some(stream) = writers.get_mut(&w) {
                        let _ = stream.write_all(msg.encode().as_bytes());
                    }
                }
            }

            // done?
            if !device_name.is_empty() && fits.iter().all(|f| f.converged) {
                break;
            }

            // wait for events; before the gate opens, wake up at the
            // formation deadline so a partial fleet can proceed
            let event = if gate_open {
                match rx.recv() {
                    Ok(e) => e,
                    Err(_) => break,
                }
            } else {
                let wait = FORMATION_GRACE
                    .checked_sub(started.elapsed())
                    .unwrap_or(Duration::from_millis(50));
                match rx.recv_timeout(wait) {
                    Ok(e) => e,
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            };
            match event {
                Event::Connected(w, stream) => {
                    let reader_tx = tx.clone();
                    let read_stream = stream.try_clone()?;
                    writers.insert(w, stream);
                    std::thread::spawn(move || {
                        let mut reader = BufReader::new(read_stream);
                        loop {
                            let mut line = String::new();
                            match reader.read_line(&mut line) {
                                Ok(0) | Err(_) => {
                                    let _ = reader_tx.send(Event::Disconnected(w));
                                    break;
                                }
                                Ok(_) => {
                                    if let Some(m) = Msg::decode(&line) {
                                        if reader_tx.send(Event::Message(w, m)).is_err() {
                                            break;
                                        }
                                    }
                                }
                            }
                        }
                    });
                }
                Event::Message(w, Msg::Hello { device }) => {
                    helloed.insert(w);
                    if device_name.is_empty() {
                        device_name = device;
                    }
                }
                Event::Message(w, Msg::Result { job_id, energy_per_iter, device_seconds }) => {
                    if queue.complete(job_id, w) {
                        if w < per_worker.len() {
                            per_worker[w] += 1;
                        }
                        if let Some(&fi) = job_meta.get(&job_id) {
                            let fit = &mut fits[fi];
                            if let Some((oid, p, subtract)) = fit.outstanding.take() {
                                debug_assert_eq!(oid, job_id);
                                let e = (energy_per_iter - subtract).max(1e-12);
                                fit.points.push((p, e, device_seconds));
                                fit.device_seconds += device_seconds;
                            }
                        }
                    }
                }
                Event::Message(_, _) => {}
                Event::Disconnected(w) => {
                    // Re-queue the dead worker's in-flight jobs (affinity
                    // cleared): they keep their ids, so the outstanding
                    // markers stay valid and completion by another worker
                    // matches.
                    requeued += queue.requeue_worker(w);
                    writers.remove(&w);
                    if writers.is_empty() && queue.pending() > 0 {
                        // no workers left: abort with what we have
                        break;
                    }
                }
            }
        }

        // finalize any unconverged-but-budgeted fits
        for fit in &fits {
            if !store.contains(&device_name, &fit.family) && !fit.points.is_empty() {
                let fcfg = if fit.dim == 1 { &fit_cfg_1 } else { &fit_cfg_2 };
                finalize(fit, &mut store, &device_name, fcfg);
            }
        }

        // shut down workers
        for (_, mut s) in writers {
            let _ = s.write_all(Msg::Shutdown.encode().as_bytes());
        }
        Ok(FleetRun {
            store,
            jobs_submitted: queue.submitted(),
            jobs_done: queue.done(),
            per_worker,
            requeued,
        })
    }
}

fn fit_cfg(cfg: &ThorConfig, dim: usize) -> FitConfig {
    FitConfig {
        kind: cfg.kind,
        max_points: if dim == 1 { cfg.max_points_1d } else { cfg.max_points_2d },
        threshold_frac: cfg.threshold_frac,
        grid_n: if dim == 1 { cfg.grid_n_1d } else { cfg.grid_n_2d },
        time_surrogate: cfg.time_surrogate,
        random_sampling: cfg.random_sampling,
        log_targets: true,
        seed: cfg.seed,
    }
}

fn stage_gate_names(
    stage: usize,
    out_tmpl: &crate::thor::parse::Group,
    in_tmpl: &crate::thor::parse::Group,
) -> Vec<String> {
    match stage {
        0 => vec![],
        1 => vec![out_tmpl.key.id()],
        _ => vec![out_tmpl.key.id(), in_tmpl.key.id()],
    }
}

fn stage_ready_impl(store: &GpStore, dev: &str, _stage: usize, gates: &[String]) -> bool {
    gates.iter().all(|g| store.contains(dev, g))
}

/// Server-side subtraction terms (eqs. 1–2) for a probe.
#[allow(clippy::too_many_arguments)]
fn subtraction_for(
    store: &GpStore,
    dev: &str,
    stage: usize,
    channels: &[usize],
    out_tmpl: &crate::thor::parse::Group,
    in_tmpl: &crate::thor::parse::Group,
    parsed: &crate::thor::parse::ParsedModel,
    family: &str,
) -> f64 {
    match stage {
        0 => 0.0,
        1 => {
            let gi = in_tmpl.with_channels(in_tmpl.anchor.c_in, channels[0].max(1));
            let fc_in = fc_in_after(&gi).max(1);
            store
                .get(dev, &out_tmpl.key.id())
                .map(|g| g.predict_raw(&[fc_in as f64]).0.max(0.0))
                .unwrap_or(0.0)
        }
        _ => {
            let tmpl = parsed
                .groups
                .iter()
                .find(|g| g.key.id() == family)
                .expect("family template");
            let gh = tmpl.with_channels(channels[0].max(1), channels[1].max(1));
            let fc_in = fc_in_after(&gh).max(1);
            let e_in = store
                .get(dev, &in_tmpl.key.id())
                .map(|g| g.predict_raw(&[1.0]).0.max(0.0))
                .unwrap_or(0.0);
            let e_out = store
                .get(dev, &out_tmpl.key.id())
                .map(|g| g.predict_raw(&[fc_in as f64]).0.max(0.0))
                .unwrap_or(0.0);
            e_in + e_out
        }
    }
}

/// Next probe for a family fit (start points, then max-variance).
fn next_probe(fit: &mut FamilyFit, cfg: &FitConfig) -> Option<Vec<f64>> {
    if let Some(p) = fit.start_queue.pop() {
        return Some(p);
    }
    if fit.points.len() >= cfg.max_points {
        return None;
    }
    let xs: Vec<Vec<f64>> = fit.points.iter().map(|p| p.0.clone()).collect();
    let ys: Vec<f64> = fit.points.iter().map(|p| p.1.max(1e-15).ln()).collect();
    let gp = GpModel::fit(cfg.kind, xs, &ys)?;
    let grid = if fit.dim == 1 {
        CandidateGrid::dim1(0.0, 1.0, cfg.grid_n)
    } else {
        CandidateGrid::dim2(0.0, 1.0, cfg.grid_n)
    };
    match max_variance(&gp, &grid, cfg.threshold_frac, 1.0) {
        Acquire::Next(p, _) => Some(p),
        Acquire::Converged(_) => None,
    }
}
