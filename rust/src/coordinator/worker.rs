//! Device worker: connects to the fitting server, receives variant jobs,
//! runs them on its (simulated) device, streams results back.
//!
//! Variant reconstruction ([`VariantBuilder`]) and the per-job seed
//! derivation ([`job_seed`]) live in [`crate::thor::profiler`] — they
//! are shared with the in-process [`crate::thor::measure::LocalMeasurer`]
//! so a fleet worker and a local per-job run execute the *same* code on
//! the same request, which is what makes the backends byte-equivalent.
//!
//! Rejoin needs no protocol: a worker that died (or was restarted)
//! simply connects again and sends a fresh `Hello` — the leader files
//! the new connection under a new id and folds it back into its
//! declared class (see the elasticity notes in
//! [`crate::coordinator::server`]).  [`DeviceWorker::run_phases`]
//! scripts such lifetimes for the chaos tests and the fleetE
//! experiment; [`DeviceWorker::run_reconnecting`] automates the same
//! loop with seeded exponential backoff
//! ([`crate::coordinator::faults::reconnect_backoff`]).
//!
//! Fault injection: a [`FaultPlan`] scripts stragglers — stalls that
//! recover, hangs that never disconnect, chronically slow writes — so
//! the leader's deadline/speculation machinery can be pinned against
//! reproducible chaos (`rust/tests/fleet.rs`, the fleetS experiment).

use std::io::{BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, Result};

use crate::coordinator::faults::{reconnect_backoff, FaultPlan, Stall};
use crate::coordinator::protocol::{read_line_capped, Msg, MAX_LINE_BYTES};
use crate::model::ModelGraph;
use crate::simdevice::Device;
use crate::thor::profiler;

pub use crate::thor::profiler::{class_seed, job_seed, VariantBuilder};

/// How one connection ended — drives [`DeviceWorker::run_reconnecting`]:
/// only an explicit `Shutdown` stops the reconnect loop; a hang-up (or
/// connect error) schedules a backed-off retry.
enum Exit {
    Shutdown,
    HungUp,
}

/// A worker process bound to one simulated device.
pub struct DeviceWorker {
    pub device: Device,
    pub builder: VariantBuilder,
    /// When set, each job is measured on a *fresh* device seeded from
    /// [`job_seed`] of this base — scheduling-independent results.  When
    /// unset (default), the one stateful device carries DVFS/thermal
    /// state across jobs, like a physical device would.
    per_job_seed: Option<u64>,
    /// Injected straggler faults (default: none).  The plan applies per
    /// connection: a reconnecting worker re-arms its stall counter,
    /// like a rebooted device re-entering the same thermal envelope.
    faults: FaultPlan,
}

impl DeviceWorker {
    pub fn new(device: Device, reference: &ModelGraph) -> Self {
        Self {
            device,
            builder: VariantBuilder::from_reference(reference),
            per_job_seed: None,
            faults: FaultPlan::default(),
        }
    }

    /// Switch to deterministic per-job measurement seeds (fleet
    /// experiments and tests; see [`job_seed`]).
    pub fn with_per_job_seed(mut self, base_seed: u64) -> Self {
        self.per_job_seed = Some(base_seed);
        self
    }

    /// [`DeviceWorker::with_per_job_seed`] for heterogeneous fleets:
    /// the per-job base is [`class_seed`]`(base_seed, <own class>)`, so
    /// workers of different classes sharing one fleet base seed never
    /// share a measurement seed — and each class's measurements match a
    /// [`crate::thor::measure::LocalMeasurer::per_job_fleet`] run of
    /// the same base bit-for-bit (`rust/tests/backend_equiv.rs`).
    pub fn with_class_seed(self, base_seed: u64) -> Self {
        let class = self.device.profile.name.to_string();
        self.with_per_job_seed(class_seed(base_seed, &class))
    }

    /// Inject a straggler [`FaultPlan`] (chaos tests, fleetS).
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Connect and serve until Shutdown.  Returns jobs completed.
    pub fn run(&mut self, addr: &str) -> Result<usize> {
        self.run_conn(addr, None).map(|(n, _)| n)
    }

    /// Connect and serve, but drop the connection upon *receiving* the
    /// `max_jobs + 1`-th job, leaving it unanswered — fault injection for
    /// the re-queue path (`rust/tests/fleet.rs`).  Returns jobs completed.
    pub fn run_limited(&mut self, addr: &str, max_jobs: usize) -> Result<usize> {
        self.run_conn(addr, Some(max_jobs)).map(|(n, _)| n)
    }

    /// Scripted elastic lifetime, phase by phase: `Some(k)` dies with
    /// the `k+1`-th job in flight ([`DeviceWorker::run_limited`]),
    /// `None` serves until Shutdown or leader hang-up
    /// ([`DeviceWorker::run`]).  A phase whose leader is already gone
    /// (connection refused, reset mid-serve) is skipped rather than an
    /// error — a chaos schedule cannot assume its leaders outlive the
    /// script.  Returns total jobs completed across phases.
    pub fn run_phases(&mut self, phases: &[(String, Option<usize>)]) -> usize {
        let mut total = 0;
        for (addr, limit) in phases {
            let r = match limit {
                Some(k) => self.run_limited(addr, *k),
                None => self.run(addr),
            };
            if let Ok(n) = r {
                total += n;
            }
        }
        total
    }

    /// Serve `addr`, reconnecting after connection loss (leader
    /// hang-up, reset, refused connect) with seeded exponential backoff
    /// — only an explicit `Shutdown` ends the loop early.  At most
    /// `max_reconnects` reconnect attempts are spent; the wait before
    /// retry `k` is [`reconnect_backoff`]`(backoff_seed, k)`, so the
    /// whole retry schedule is a pure function of the seed.  Returns
    /// total jobs completed across incarnations.
    pub fn run_reconnecting(
        &mut self,
        addr: &str,
        max_reconnects: usize,
        backoff_seed: u64,
    ) -> usize {
        let mut total = 0;
        for attempt in 0..=max_reconnects {
            match self.run_conn(addr, None) {
                Ok((n, Exit::Shutdown)) => return total + n,
                Ok((n, Exit::HungUp)) => total += n,
                Err(_) => {} // connect refused / reset: retry like a hang-up
            }
            if attempt < max_reconnects {
                std::thread::sleep(reconnect_backoff(backoff_seed, attempt as u32));
            }
        }
        total
    }

    fn run_conn(&mut self, addr: &str, max_jobs: Option<usize>) -> Result<(usize, Exit)> {
        let stream = TcpStream::connect(addr)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        writer.write_all(Msg::Hello { device: self.device.profile.name.to_string() }.encode().as_bytes())?;
        let mut done = 0;
        let mut stalled = false;
        loop {
            let mut line = String::new();
            if read_line_capped(&mut reader, &mut line, MAX_LINE_BYTES)? == 0 {
                return Ok((done, Exit::HungUp)); // server hung up
            }
            match Msg::decode(&line) {
                Some(Msg::Job { job_id, family, channels, iterations }) => {
                    if max_jobs.map_or(false, |m| done >= m) {
                        // injected fault: die with the job in flight
                        return Ok((done, Exit::HungUp));
                    }
                    if !stalled && self.faults.stall_after_jobs == Some(done) {
                        stalled = true;
                        match self.faults.stall {
                            Some(Stall::Hang) => {
                                // Hang without disconnecting: hold the
                                // job, keep the socket open, never
                                // answer again.  From the leader's side
                                // this is pure silence — no Disconnected
                                // event — which is exactly the straggler
                                // shape the deadline layer must survive.
                                return self.hang_until_closed(&mut reader, done);
                            }
                            Some(Stall::Recover(d)) => std::thread::sleep(d),
                            None => {}
                        }
                    }
                    let g = self.builder.build(&family, &channels)?;
                    let (e, dt) = match self.per_job_seed {
                        Some(base) => {
                            let seed = job_seed(base, &family, &channels, iterations);
                            let mut dev = Device::new(self.device.profile.clone(), seed);
                            profiler::measure(&mut dev, &g, iterations)
                        }
                        None => profiler::measure(&mut self.device, &g, iterations),
                    };
                    if let Some(d) = self.faults.slow_write {
                        std::thread::sleep(d);
                    }
                    writer.write_all(
                        Msg::Result { job_id, energy_per_iter: e, device_seconds: dt }
                            .encode()
                            .as_bytes(),
                    )?;
                    done += 1;
                }
                Some(Msg::Idle) => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    writer.write_all(Msg::Hello { device: self.device.profile.name.to_string() }.encode().as_bytes())?;
                }
                Some(Msg::Shutdown) => return Ok((done, Exit::Shutdown)),
                _ => return Err(anyhow!("unexpected message: {line}")),
            }
        }
    }

    /// The hang-without-disconnect fault: keep reading (so the leader's
    /// writes never block) but never reply; exit quietly on Shutdown,
    /// hang-up, or any read error.  The leader only ever learns about
    /// this worker again through its own deadline machinery.
    fn hang_until_closed(&self, reader: &mut BufReader<TcpStream>, done: usize) -> Result<(usize, Exit)> {
        loop {
            let mut line = String::new();
            match read_line_capped(reader, &mut line, MAX_LINE_BYTES) {
                Ok(0) | Err(_) => return Ok((done, Exit::HungUp)),
                Ok(_) => {
                    if matches!(Msg::decode(&line), Some(Msg::Shutdown)) {
                        return Ok((done, Exit::Shutdown));
                    }
                }
            }
        }
    }
}
