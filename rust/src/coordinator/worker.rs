//! Device worker: connects to the fitting server, receives variant jobs,
//! runs them on its (simulated) device, streams results back.
//!
//! Variant reconstruction ([`VariantBuilder`]) and the per-job seed
//! derivation ([`job_seed`]) live in [`crate::thor::profiler`] — they
//! are shared with the in-process [`crate::thor::measure::LocalMeasurer`]
//! so a fleet worker and a local per-job run execute the *same* code on
//! the same request, which is what makes the backends byte-equivalent.
//!
//! Rejoin needs no protocol: a worker that died (or was restarted)
//! simply connects again and sends a fresh `Hello` — the leader files
//! the new connection under a new id and folds it back into its
//! declared class (see the elasticity notes in
//! [`crate::coordinator::server`]).  [`DeviceWorker::run_phases`]
//! scripts such lifetimes for the chaos tests and the fleetE
//! experiment.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, Result};

use crate::coordinator::protocol::Msg;
use crate::model::ModelGraph;
use crate::simdevice::Device;
use crate::thor::profiler;

pub use crate::thor::profiler::{class_seed, job_seed, VariantBuilder};

/// A worker process bound to one simulated device.
pub struct DeviceWorker {
    pub device: Device,
    pub builder: VariantBuilder,
    /// When set, each job is measured on a *fresh* device seeded from
    /// [`job_seed`] of this base — scheduling-independent results.  When
    /// unset (default), the one stateful device carries DVFS/thermal
    /// state across jobs, like a physical device would.
    per_job_seed: Option<u64>,
}

impl DeviceWorker {
    pub fn new(device: Device, reference: &ModelGraph) -> Self {
        Self { device, builder: VariantBuilder::from_reference(reference), per_job_seed: None }
    }

    /// Switch to deterministic per-job measurement seeds (fleet
    /// experiments and tests; see [`job_seed`]).
    pub fn with_per_job_seed(mut self, base_seed: u64) -> Self {
        self.per_job_seed = Some(base_seed);
        self
    }

    /// [`DeviceWorker::with_per_job_seed`] for heterogeneous fleets:
    /// the per-job base is [`class_seed`]`(base_seed, <own class>)`, so
    /// workers of different classes sharing one fleet base seed never
    /// share a measurement seed — and each class's measurements match a
    /// [`crate::thor::measure::LocalMeasurer::per_job_fleet`] run of
    /// the same base bit-for-bit (`rust/tests/backend_equiv.rs`).
    pub fn with_class_seed(self, base_seed: u64) -> Self {
        let class = self.device.profile.name.to_string();
        self.with_per_job_seed(class_seed(base_seed, &class))
    }

    /// Connect and serve until Shutdown.  Returns jobs completed.
    pub fn run(&mut self, addr: &str) -> Result<usize> {
        self.run_inner(addr, None)
    }

    /// Connect and serve, but drop the connection upon *receiving* the
    /// `max_jobs + 1`-th job, leaving it unanswered — fault injection for
    /// the re-queue path (`rust/tests/fleet.rs`).  Returns jobs completed.
    pub fn run_limited(&mut self, addr: &str, max_jobs: usize) -> Result<usize> {
        self.run_inner(addr, Some(max_jobs))
    }

    /// Scripted elastic lifetime, phase by phase: `Some(k)` dies with
    /// the `k+1`-th job in flight ([`DeviceWorker::run_limited`]),
    /// `None` serves until Shutdown or leader hang-up
    /// ([`DeviceWorker::run`]).  A phase whose leader is already gone
    /// (connection refused, reset mid-serve) is skipped rather than an
    /// error — a chaos schedule cannot assume its leaders outlive the
    /// script.  Returns total jobs completed across phases.
    pub fn run_phases(&mut self, phases: &[(String, Option<usize>)]) -> usize {
        let mut total = 0;
        for (addr, limit) in phases {
            let r = match limit {
                Some(k) => self.run_limited(addr, *k),
                None => self.run(addr),
            };
            if let Ok(n) = r {
                total += n;
            }
        }
        total
    }

    fn run_inner(&mut self, addr: &str, max_jobs: Option<usize>) -> Result<usize> {
        let stream = TcpStream::connect(addr)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        writer.write_all(Msg::Hello { device: self.device.profile.name.to_string() }.encode().as_bytes())?;
        let mut done = 0;
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                break; // server hung up
            }
            match Msg::decode(&line) {
                Some(Msg::Job { job_id, family, channels, iterations }) => {
                    if max_jobs.map_or(false, |m| done >= m) {
                        break; // injected fault: die with the job in flight
                    }
                    let g = self.builder.build(&family, &channels)?;
                    let (e, dt) = match self.per_job_seed {
                        Some(base) => {
                            let seed = job_seed(base, &family, &channels, iterations);
                            let mut dev = Device::new(self.device.profile.clone(), seed);
                            profiler::measure(&mut dev, &g, iterations)
                        }
                        None => profiler::measure(&mut self.device, &g, iterations),
                    };
                    writer.write_all(
                        Msg::Result { job_id, energy_per_iter: e, device_seconds: dt }
                            .encode()
                            .as_bytes(),
                    )?;
                    done += 1;
                }
                Some(Msg::Idle) => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    writer.write_all(Msg::Hello { device: self.device.profile.name.to_string() }.encode().as_bytes())?;
                }
                Some(Msg::Shutdown) => break,
                _ => return Err(anyhow!("unexpected message: {line}")),
            }
        }
        Ok(done)
    }
}
