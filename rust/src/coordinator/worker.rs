//! Device worker: connects to the fitting server, receives variant jobs,
//! runs them on its (simulated) device, streams results back.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, Result};

use crate::coordinator::protocol::Msg;
use crate::model::ModelGraph;
use crate::simdevice::Device;
use crate::thor::parse::Group;
use crate::thor::profiler;

/// Rebuilds variant graphs from (family, channels) using the templates
/// of a reference model — the worker and server share the reference
/// architecture, so only channels travel on the wire.
pub struct VariantBuilder {
    input: Group,
    output: Group,
    hidden: Vec<Group>,
}

impl VariantBuilder {
    pub fn from_reference(reference: &ModelGraph) -> Self {
        let parsed = crate::thor::parse::parse(reference);
        let input = parsed.input_groups().next().expect("input group").clone();
        let output = parsed.output_groups().next().expect("output group").clone();
        let hidden: Vec<Group> = parsed.hidden_groups().cloned().collect();
        Self { input, output, hidden }
    }

    /// Build the variant graph for a family id + raw channels.
    pub fn build(&self, family: &str, channels: &[usize]) -> Result<ModelGraph> {
        if family == self.output.key.id() {
            return Ok(profiler::output_variant(&self.output, channels[0]));
        }
        if family == self.input.key.id() {
            return Ok(profiler::input_variant(&self.input, &self.output, channels[0]).0);
        }
        for h in &self.hidden {
            if family == h.key.id() {
                let (g, _, _) =
                    profiler::hidden_variant(&self.input, h, &self.output, channels[0], channels[1]);
                return Ok(g);
            }
        }
        Err(anyhow!("unknown family '{family}'"))
    }
}

/// Deterministic per-job device seed: FNV-1a ([`crate::util::hash`]) over
/// (base seed ‖ family ‖ channels ‖ iterations).  Any worker measuring
/// the same job with the same base seed gets the same result, which
/// makes a whole fleet run a pure function of the job stream —
/// independent of which worker ran what, in what order (see
/// `rust/tests/fleet.rs`).
pub fn job_seed(base_seed: u64, family: &str, channels: &[usize], iterations: usize) -> u64 {
    let mut h = crate::util::hash::Fnv1a::new();
    h.write(&base_seed.to_le_bytes());
    h.write(family.as_bytes());
    for c in channels {
        h.write(&(*c as u64).to_le_bytes());
    }
    h.write(&(iterations as u64).to_le_bytes());
    h.finish()
}

/// A worker process bound to one simulated device.
pub struct DeviceWorker {
    pub device: Device,
    pub builder: VariantBuilder,
    /// When set, each job is measured on a *fresh* device seeded from
    /// [`job_seed`] of this base — scheduling-independent results.  When
    /// unset (default), the one stateful device carries DVFS/thermal
    /// state across jobs, like a physical device would.
    per_job_seed: Option<u64>,
}

impl DeviceWorker {
    pub fn new(device: Device, reference: &ModelGraph) -> Self {
        Self { device, builder: VariantBuilder::from_reference(reference), per_job_seed: None }
    }

    /// Switch to deterministic per-job measurement seeds (fleet
    /// experiments and tests; see [`job_seed`]).
    pub fn with_per_job_seed(mut self, base_seed: u64) -> Self {
        self.per_job_seed = Some(base_seed);
        self
    }

    /// Connect and serve until Shutdown.  Returns jobs completed.
    pub fn run(&mut self, addr: &str) -> Result<usize> {
        self.run_inner(addr, None)
    }

    /// Connect and serve, but drop the connection upon *receiving* the
    /// `max_jobs + 1`-th job, leaving it unanswered — fault injection for
    /// the re-queue path (`rust/tests/fleet.rs`).  Returns jobs completed.
    pub fn run_limited(&mut self, addr: &str, max_jobs: usize) -> Result<usize> {
        self.run_inner(addr, Some(max_jobs))
    }

    fn run_inner(&mut self, addr: &str, max_jobs: Option<usize>) -> Result<usize> {
        let stream = TcpStream::connect(addr)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        writer.write_all(Msg::Hello { device: self.device.profile.name.to_string() }.encode().as_bytes())?;
        let mut done = 0;
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                break; // server hung up
            }
            match Msg::decode(&line) {
                Some(Msg::Job { job_id, family, channels, iterations }) => {
                    if max_jobs.map_or(false, |m| done >= m) {
                        break; // injected fault: die with the job in flight
                    }
                    let g = self.builder.build(&family, &channels)?;
                    let (e, dt) = match self.per_job_seed {
                        Some(base) => {
                            let seed = job_seed(base, &family, &channels, iterations);
                            let mut dev = Device::new(self.device.profile.clone(), seed);
                            profiler::measure(&mut dev, &g, iterations)
                        }
                        None => profiler::measure(&mut self.device, &g, iterations),
                    };
                    writer.write_all(
                        Msg::Result { job_id, energy_per_iter: e, device_seconds: dt }
                            .encode()
                            .as_bytes(),
                    )?;
                    done += 1;
                }
                Some(Msg::Idle) => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    writer.write_all(Msg::Hello { device: self.device.profile.name.to_string() }.encode().as_bytes())?;
                }
                Some(Msg::Shutdown) => break,
                _ => return Err(anyhow!("unexpected message: {line}")),
            }
        }
        Ok(done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::simdevice::devices;

    #[test]
    fn builder_covers_all_families() {
        let reference = zoo::cnn5(&[16, 32, 64, 128], 16, 10);
        let parsed = crate::thor::parse::parse(&reference);
        let b = VariantBuilder::from_reference(&reference);
        for fam in &parsed.families {
            let dim = if fam.position == crate::thor::Position::Hidden { 2 } else { 1 };
            let chans = vec![4; dim];
            let g = b.build(&fam.id(), &chans).unwrap();
            assert!(!g.layers.is_empty());
        }
        assert!(b.build("nonexistent", &[1]).is_err());
    }

    #[test]
    fn job_seed_is_stable_and_content_sensitive() {
        let base = job_seed(42, "fam", &[4, 8], 60);
        assert_eq!(base, job_seed(42, "fam", &[4, 8], 60));
        assert_ne!(base, job_seed(43, "fam", &[4, 8], 60));
        assert_ne!(base, job_seed(42, "maf", &[4, 8], 60));
        assert_ne!(base, job_seed(42, "fam", &[8, 4], 60));
        assert_ne!(base, job_seed(42, "fam", &[4, 8], 61));
    }

    #[test]
    fn built_variant_measurable() {
        let reference = zoo::cnn5(&[16, 32, 64, 128], 16, 10);
        let b = VariantBuilder::from_reference(&reference);
        let parsed = crate::thor::parse::parse(&reference);
        let fam = parsed.families[1].id();
        let g = b.build(&fam, &[4, 8]).unwrap();
        let mut dev = Device::new(devices::tx2(), 5);
        let (e, t) = profiler::measure(&mut dev, &g, 30);
        assert!(e > 0.0 && t > 0.0);
    }
}
