//! Job queue + assignment state machine for the fitting leader.
//!
//! The leader serializes GP acquisition (one probe per family at a
//! time — max-variance acquisition is sequential by nature) but keeps
//! every *worker* busy by interleaving jobs from different families and
//! devices.  Workers can die at any time: their in-flight jobs re-queue.

use std::collections::BTreeMap;

/// Lifecycle of one measurement job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Assigned { worker: usize },
    Done,
}

#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub family: String,
    pub channels: Vec<usize>,
    pub iterations: usize,
    pub state: JobState,
    /// Routing preference: only this worker may take the job while it
    /// lives (deterministic per-worker job counts for the fleet
    /// experiment).  Cleared when the worker dies, so pinned jobs never
    /// strand.
    pub affinity: Option<usize>,
}

/// FIFO queue with at-most-one-outstanding-job-per-worker routing.
#[derive(Default)]
pub struct JobQueue {
    jobs: BTreeMap<u64, Job>,
    next_id: u64,
}

impl JobQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn submit(&mut self, family: &str, channels: Vec<usize>, iterations: usize) -> u64 {
        self.submit_to(family, channels, iterations, None)
    }

    /// Submit with an optional worker affinity.
    pub fn submit_to(
        &mut self,
        family: &str,
        channels: Vec<usize>,
        iterations: usize,
        affinity: Option<usize>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.insert(
            id,
            Job {
                id,
                family: family.to_string(),
                channels,
                iterations,
                state: JobState::Queued,
                affinity,
            },
        );
        id
    }

    /// Assign the oldest queued job routable to `worker` (no affinity, or
    /// affinity to it) unless it already holds one
    /// (at-most-one-outstanding invariant).
    pub fn assign(&mut self, worker: usize) -> Option<Job> {
        if self.jobs.values().any(|j| j.state == (JobState::Assigned { worker })) {
            return None;
        }
        let id = self
            .jobs
            .values()
            .find(|j| {
                j.state == JobState::Queued && j.affinity.map_or(true, |a| a == worker)
            })
            .map(|j| j.id)?;
        let job = self.jobs.get_mut(&id).unwrap();
        job.state = JobState::Assigned { worker };
        Some(job.clone())
    }

    /// Record completion; returns false if the job was not assigned to
    /// this worker (stale/duplicate results are dropped).
    pub fn complete(&mut self, id: u64, worker: usize) -> bool {
        match self.jobs.get_mut(&id) {
            Some(j) if j.state == (JobState::Assigned { worker }) => {
                j.state = JobState::Done;
                true
            }
            _ => false,
        }
    }

    /// A worker died: re-queue its in-flight jobs and strip its affinity
    /// from every live job (pinned-but-unassigned jobs would otherwise
    /// strand forever).  Returns the number of re-queued jobs.
    pub fn requeue_worker(&mut self, worker: usize) -> usize {
        let mut n = 0;
        for j in self.jobs.values_mut() {
            if j.state == (JobState::Assigned { worker }) {
                j.state = JobState::Queued;
                n += 1;
            }
            if j.affinity == Some(worker) {
                j.affinity = None;
            }
        }
        n
    }

    pub fn pending(&self) -> usize {
        self.jobs.values().filter(|j| j.state != JobState::Done).count()
    }

    pub fn done(&self) -> usize {
        self.jobs.values().filter(|j| j.state == JobState::Done).count()
    }

    /// Total jobs ever submitted.
    pub fn submitted(&self) -> usize {
        self.jobs.len()
    }

    pub fn get(&self, id: u64) -> Option<&Job> {
        self.jobs.get(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    #[test]
    fn fifo_assignment() {
        let mut q = JobQueue::new();
        let a = q.submit("f", vec![1], 10);
        let b = q.submit("f", vec![2], 10);
        assert_eq!(q.assign(0).unwrap().id, a);
        assert_eq!(q.assign(1).unwrap().id, b);
    }

    #[test]
    fn at_most_one_outstanding_per_worker() {
        let mut q = JobQueue::new();
        q.submit("f", vec![1], 10);
        q.submit("f", vec![2], 10);
        assert!(q.assign(0).is_some());
        assert!(q.assign(0).is_none(), "worker 0 double-assigned");
    }

    #[test]
    fn stale_results_dropped() {
        let mut q = JobQueue::new();
        let id = q.submit("f", vec![1], 10);
        let j = q.assign(0).unwrap();
        assert_eq!(j.id, id);
        assert!(!q.complete(id, 1), "result from wrong worker accepted");
        assert!(q.complete(id, 0));
        assert!(!q.complete(id, 0), "duplicate completion accepted");
    }

    #[test]
    fn affinity_routes_to_pinned_worker_only() {
        let mut q = JobQueue::new();
        let pinned = q.submit_to("f", vec![1], 10, Some(1));
        let free = q.submit("f", vec![2], 10);
        // worker 0 must skip the pinned job and take the free one
        assert_eq!(q.assign(0).unwrap().id, free);
        assert_eq!(q.assign(1).unwrap().id, pinned);
    }

    #[test]
    fn affinity_cleared_when_pinned_worker_dies() {
        let mut q = JobQueue::new();
        let a = q.submit_to("f", vec![1], 10, Some(1));
        let b = q.submit_to("f", vec![2], 10, Some(1));
        assert_eq!(q.assign(1).unwrap().id, a);
        // worker 1 dies holding `a`, with `b` still queued-and-pinned
        assert_eq!(q.requeue_worker(1), 1);
        // both jobs are now routable to worker 0
        assert_eq!(q.assign(0).unwrap().id, a);
        assert!(q.complete(a, 0));
        assert_eq!(q.assign(0).unwrap().id, b);
        assert!(q.complete(b, 0));
        assert_eq!(q.pending(), 0);
        assert_eq!(q.submitted(), 2);
    }

    #[test]
    fn requeue_counts_only_inflight_jobs() {
        // Queued-but-unassigned jobs are not "re-queued": the count is
        // exactly the in-flight jobs of the dead worker (what FleetRun
        // reports as `requeued`).
        let mut q = JobQueue::new();
        q.submit("f", vec![1], 10);
        q.submit("f", vec![2], 10);
        q.submit("f", vec![3], 10);
        q.assign(0).unwrap();
        assert_eq!(q.requeue_worker(0), 1, "only the held job counts");
        assert_eq!(q.requeue_worker(0), 0, "repeat requeue finds nothing in flight");
        assert_eq!(q.requeue_worker(5), 0, "idle/unknown worker requeues nothing");
        assert_eq!(q.pending(), 3);
    }

    #[test]
    fn complete_from_dead_worker_after_requeue_is_stale() {
        // Exactly-once across death: the old worker's late result for a
        // re-queued job must be dropped, and the re-measurement by the
        // new worker is the one that lands.
        let mut q = JobQueue::new();
        let id = q.submit("f", vec![1], 10);
        q.assign(0).unwrap();
        q.requeue_worker(0);
        assert!(!q.complete(id, 0), "late result from dead worker accepted");
        assert_eq!(q.assign(1).unwrap().id, id);
        assert!(q.complete(id, 1));
        assert!(!q.complete(id, 1), "duplicate completion accepted");
        assert_eq!(q.done(), 1);
    }

    #[test]
    fn affinity_cleared_even_for_unassigned_pinned_jobs() {
        // A job pinned to a worker that dies before ever taking it must
        // become routable to the survivors (no stranding).
        let mut q = JobQueue::new();
        let id = q.submit_to("f", vec![1], 10, Some(2));
        assert!(q.assign(0).is_none(), "pinned job leaked to the wrong worker");
        assert_eq!(q.requeue_worker(2), 0, "nothing was in flight");
        assert_eq!(q.assign(0).unwrap().id, id, "affinity not cleared on death");
    }

    #[test]
    fn requeue_on_worker_death() {
        let mut q = JobQueue::new();
        let id = q.submit("f", vec![1], 10);
        q.assign(0).unwrap();
        assert_eq!(q.requeue_worker(0), 1);
        // the job can be assigned to another worker now
        assert_eq!(q.assign(1).unwrap().id, id);
        assert!(q.complete(id, 1));
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn prop_every_job_resolves_exactly_once() {
        // Random interleaving of submit/assign/complete/death; at the end
        // drain everything and verify each job completed exactly once.
        check(
            "jobs resolve exactly once",
            Config { cases: 64, seed: 77 },
            |r| {
                let ops: Vec<u8> = (0..r.range_usize(10, 60)).map(|_| r.range_usize(0, 3) as u8).collect();
                (ops, r.range_usize(1, 4))
            },
            |(ops, n_workers)| {
                let mut q = JobQueue::new();
                let mut completions: BTreeMap<u64, usize> = BTreeMap::new();
                let mut inflight: Vec<(u64, usize)> = Vec::new();
                let mut submitted = 0u64;
                for (i, op) in ops.iter().enumerate() {
                    match op {
                        0 => {
                            q.submit("f", vec![i], 10);
                            submitted += 1;
                        }
                        1 => {
                            let w = i % n_workers;
                            if let Some(j) = q.assign(w) {
                                inflight.push((j.id, w));
                            }
                        }
                        2 => {
                            if let Some((id, w)) = inflight.pop() {
                                if q.complete(id, w) {
                                    *completions.entry(id).or_insert(0) += 1;
                                }
                            }
                        }
                        _ => {
                            let w = i % n_workers;
                            q.requeue_worker(w);
                            inflight.retain(|&(_, iw)| iw != w);
                        }
                    }
                }
                // drain: first release any jobs still held by workers from
                // the random phase (a held worker can't take a new one)
                for w in 0..*n_workers {
                    q.requeue_worker(w);
                }
                inflight.clear();
                let mut guard = 0;
                while q.pending() > 0 {
                    guard += 1;
                    crate::prop_assert!(guard < 100_000, "drain did not terminate");
                    for w in 0..*n_workers {
                        if let Some(j) = q.assign(w) {
                            crate::prop_assert!(q.complete(j.id, w), "drain completion rejected");
                            *completions.entry(j.id).or_insert(0) += 1;
                        }
                    }
                }
                crate::prop_assert!(completions.len() as u64 == submitted, "{} != {submitted}", completions.len());
                crate::prop_assert!(completions.values().all(|&c| c == 1), "double completion: {completions:?}");
                Ok(())
            },
        );
    }
}
