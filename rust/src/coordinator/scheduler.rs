//! Job queue + assignment state machine for the fitting leader.
//!
//! The leader serializes GP acquisition (one probe per family at a
//! time — max-variance acquisition is sequential by nature) but keeps
//! every *worker* busy by interleaving jobs from different families and
//! devices.  Workers can die at any time: their in-flight jobs re-queue.
//!
//! Every job is tagged with the **device class** it must run on and
//! [`JobQueue::assign`] filters by the asking worker's class, so a
//! heterogeneous fleet never measures a job on the wrong silicon: a
//! dead worker's jobs re-queue, but only same-class peers can pick them
//! up (class-scoped requeue falls out of class-scoped assignment).
//!
//! Worker ids are opaque here: the queue never enumerates workers, it
//! only answers `assign(worker, class)` — which is what makes the fleet
//! *elastic* for free.  A late-joining or rejoining worker (a fresh
//! connection id the leader admits mid-round) starts taking same-class
//! work on its first `assign`, and the exactly-once / class-affinity
//! invariants hold under arbitrary join/death/rejoin schedules
//! (property-tested in `rust/tests/properties.rs`).

use std::collections::BTreeMap;

/// Lifecycle of one measurement job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Assigned { worker: usize },
    Done,
}

#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    /// Device class this job must be measured on ([`JobQueue::assign`]
    /// only hands it to a worker of the same class).
    pub device: String,
    pub family: String,
    pub channels: Vec<usize>,
    pub iterations: usize,
    pub state: JobState,
    /// Routing preference: only this worker may take the job while it
    /// lives (deterministic per-worker job counts for the fleet
    /// experiment).  Cleared when the worker dies, so pinned jobs never
    /// strand — they fall back to any same-class peer.
    pub affinity: Option<usize>,
    /// Straggler speculation: a second worker also running this job
    /// ([`JobQueue::speculate`]).  Invariant: `Some` only while the job
    /// is `Assigned` — completion and requeue both clear it.  Either
    /// runner's result completes the job (first wins); with per-job
    /// seeding the two results are bitwise identical, so which one wins
    /// never shows in the store.
    pub speculated: Option<usize>,
}

/// FIFO queue with class-scoped, at-most-one-outstanding-job-per-worker
/// routing.
#[derive(Default)]
pub struct JobQueue {
    jobs: BTreeMap<u64, Job>,
    next_id: u64,
}

impl JobQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn submit(
        &mut self,
        device: &str,
        family: &str,
        channels: Vec<usize>,
        iterations: usize,
    ) -> u64 {
        self.submit_to(device, family, channels, iterations, None)
    }

    /// Submit with an optional worker affinity (the pinned worker must
    /// be of the job's class — the caller routes same-class only).
    pub fn submit_to(
        &mut self,
        device: &str,
        family: &str,
        channels: Vec<usize>,
        iterations: usize,
        affinity: Option<usize>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.insert(
            id,
            Job {
                id,
                device: device.to_string(),
                family: family.to_string(),
                channels,
                iterations,
                state: JobState::Queued,
                affinity,
                speculated: None,
            },
        );
        id
    }

    /// Assign the oldest queued job of `class` routable to `worker` (no
    /// affinity, or affinity to it) unless it already holds one
    /// (at-most-one-outstanding invariant).  A worker never receives a
    /// job of another device class.
    pub fn assign(&mut self, worker: usize, class: &str) -> Option<Job> {
        if self.busy(worker) {
            return None;
        }
        let id = self
            .jobs
            .values()
            .find(|j| {
                j.state == JobState::Queued
                    && j.device == class
                    && j.affinity.map_or(true, |a| a == worker)
            })
            .map(|j| j.id)?;
        let job = self.jobs.get_mut(&id).unwrap();
        job.state = JobState::Assigned { worker };
        Some(job.clone())
    }

    /// A worker holding any job, primary or speculative — the
    /// at-most-one-outstanding invariant counts both kinds of hold.
    pub fn busy(&self, worker: usize) -> bool {
        self.jobs
            .values()
            .any(|j| j.state == (JobState::Assigned { worker }) || j.speculated == Some(worker))
    }

    /// Issue a speculative duplicate of in-flight job `id` to a second
    /// worker of the same class (straggler recovery): either runner's
    /// result now completes the job, first wins.  Refused — `None` —
    /// when the job is not in flight, the worker is its primary runner,
    /// the class does not match, or the worker is busy.  An existing
    /// speculative assignee is *replaced* (the leader re-speculates when
    /// the first speculation stalled too); its late result becomes
    /// stale, which is harmless because duplicates are bitwise
    /// identical and dropped anyway.
    pub fn speculate(&mut self, id: u64, worker: usize, class: &str) -> Option<Job> {
        if self.busy(worker) {
            return None;
        }
        let j = self.jobs.get_mut(&id)?;
        match j.state {
            JobState::Assigned { worker: primary } if primary != worker && j.device == class => {
                j.speculated = Some(worker);
                Some(j.clone())
            }
            _ => None,
        }
    }

    /// Record completion; returns false if the job was not held by this
    /// worker — primary or speculative — (stale/duplicate results are
    /// dropped).  First result wins: completion retires both holds.
    pub fn complete(&mut self, id: u64, worker: usize) -> bool {
        match self.jobs.get_mut(&id) {
            Some(j)
                if j.state == (JobState::Assigned { worker })
                    || (matches!(j.state, JobState::Assigned { .. })
                        && j.speculated == Some(worker)) =>
            {
                j.state = JobState::Done;
                j.speculated = None;
                true
            }
            _ => false,
        }
    }

    /// A worker died: re-queue its in-flight jobs and strip its affinity
    /// from every live job (pinned-but-unassigned jobs would otherwise
    /// strand forever).  Re-queued jobs keep their device class, so only
    /// same-class survivors can take them.  A job whose dead primary
    /// had a live speculative runner is not re-queued — the speculative
    /// runner is *promoted* to primary (the job never left flight);
    /// conversely a dead speculative runner just loses its hold.
    /// Returns the number of re-queued jobs (promotions don't count —
    /// nothing went back to the queue).
    pub fn requeue_worker(&mut self, worker: usize) -> usize {
        let mut n = 0;
        for j in self.jobs.values_mut() {
            if j.state == (JobState::Assigned { worker }) {
                match j.speculated.take() {
                    Some(spec) if spec != worker => {
                        j.state = JobState::Assigned { worker: spec };
                    }
                    _ => {
                        j.state = JobState::Queued;
                        n += 1;
                    }
                }
            } else if j.speculated == Some(worker) {
                j.speculated = None;
            }
            if j.affinity == Some(worker) {
                j.affinity = None;
            }
        }
        n
    }

    /// Strip `worker`'s affinity from every job without touching its
    /// holds — the leader calls this when it marks a still-connected
    /// worker as a suspected straggler, so jobs pinned to it fall back
    /// to healthy same-class peers instead of stranding behind a worker
    /// the assignment pump now skips.  Returns affinities cleared.
    pub fn clear_affinity(&mut self, worker: usize) -> usize {
        let mut n = 0;
        for j in self.jobs.values_mut() {
            if j.affinity == Some(worker) {
                j.affinity = None;
                n += 1;
            }
        }
        n
    }

    pub fn pending(&self) -> usize {
        self.jobs.values().filter(|j| j.state != JobState::Done).count()
    }

    pub fn done(&self) -> usize {
        self.jobs.values().filter(|j| j.state == JobState::Done).count()
    }

    /// Total jobs ever submitted.
    pub fn submitted(&self) -> usize {
        self.jobs.len()
    }

    /// Jobs completed for one device class.
    pub fn done_for(&self, class: &str) -> usize {
        self.jobs.values().filter(|j| j.state == JobState::Done && j.device == class).count()
    }

    /// Jobs ever submitted for one device class.
    pub fn submitted_for(&self, class: &str) -> usize {
        self.jobs.values().filter(|j| j.device == class).count()
    }

    /// Sorted, deduplicated device classes any job was ever submitted
    /// for.
    pub fn classes_submitted(&self) -> Vec<String> {
        let set: std::collections::BTreeSet<&str> =
            self.jobs.values().map(|j| j.device.as_str()).collect();
        set.into_iter().map(str::to_string).collect()
    }

    /// Sorted device classes with unresolved (non-Done) jobs — the
    /// leader checks these against the live fleet to turn
    /// "all workers of a scheduled class died" into a hard error.
    pub fn classes_outstanding(&self) -> Vec<String> {
        let mut cs: Vec<String> = self
            .jobs
            .values()
            .filter(|j| j.state != JobState::Done)
            .map(|j| j.device.clone())
            .collect();
        cs.sort_unstable();
        cs.dedup();
        cs
    }

    pub fn get(&self, id: u64) -> Option<&Job> {
        self.jobs.get(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    /// Single-class convenience used by the legacy-shaped tests.
    fn submit1(q: &mut JobQueue, channels: Vec<usize>) -> u64 {
        q.submit("xavier", "f", channels, 10)
    }

    fn assign1(q: &mut JobQueue, worker: usize) -> Option<Job> {
        q.assign(worker, "xavier")
    }

    #[test]
    fn fifo_assignment() {
        let mut q = JobQueue::new();
        let a = submit1(&mut q, vec![1]);
        let b = submit1(&mut q, vec![2]);
        assert_eq!(assign1(&mut q, 0).unwrap().id, a);
        assert_eq!(assign1(&mut q, 1).unwrap().id, b);
    }

    #[test]
    fn at_most_one_outstanding_per_worker() {
        let mut q = JobQueue::new();
        submit1(&mut q, vec![1]);
        submit1(&mut q, vec![2]);
        assert!(assign1(&mut q, 0).is_some());
        assert!(assign1(&mut q, 0).is_none(), "worker 0 double-assigned");
    }

    #[test]
    fn stale_results_dropped() {
        let mut q = JobQueue::new();
        let id = submit1(&mut q, vec![1]);
        let j = assign1(&mut q, 0).unwrap();
        assert_eq!(j.id, id);
        assert!(!q.complete(id, 1), "result from wrong worker accepted");
        assert!(q.complete(id, 0));
        assert!(!q.complete(id, 0), "duplicate completion accepted");
    }

    #[test]
    fn affinity_routes_to_pinned_worker_only() {
        let mut q = JobQueue::new();
        let pinned = q.submit_to("xavier", "f", vec![1], 10, Some(1));
        let free = submit1(&mut q, vec![2]);
        // worker 0 must skip the pinned job and take the free one
        assert_eq!(assign1(&mut q, 0).unwrap().id, free);
        assert_eq!(assign1(&mut q, 1).unwrap().id, pinned);
    }

    #[test]
    fn affinity_cleared_when_pinned_worker_dies() {
        let mut q = JobQueue::new();
        let a = q.submit_to("xavier", "f", vec![1], 10, Some(1));
        let b = q.submit_to("xavier", "f", vec![2], 10, Some(1));
        assert_eq!(assign1(&mut q, 1).unwrap().id, a);
        // worker 1 dies holding `a`, with `b` still queued-and-pinned
        assert_eq!(q.requeue_worker(1), 1);
        // both jobs are now routable to worker 0
        assert_eq!(assign1(&mut q, 0).unwrap().id, a);
        assert!(q.complete(a, 0));
        assert_eq!(assign1(&mut q, 0).unwrap().id, b);
        assert!(q.complete(b, 0));
        assert_eq!(q.pending(), 0);
        assert_eq!(q.submitted(), 2);
    }

    #[test]
    fn requeue_counts_only_inflight_jobs() {
        // Queued-but-unassigned jobs are not "re-queued": the count is
        // exactly the in-flight jobs of the dead worker (what FleetRun
        // reports as `requeued`).
        let mut q = JobQueue::new();
        submit1(&mut q, vec![1]);
        submit1(&mut q, vec![2]);
        submit1(&mut q, vec![3]);
        assign1(&mut q, 0).unwrap();
        assert_eq!(q.requeue_worker(0), 1, "only the held job counts");
        assert_eq!(q.requeue_worker(0), 0, "repeat requeue finds nothing in flight");
        assert_eq!(q.requeue_worker(5), 0, "idle/unknown worker requeues nothing");
        assert_eq!(q.pending(), 3);
    }

    #[test]
    fn complete_from_dead_worker_after_requeue_is_stale() {
        // Exactly-once across death: the old worker's late result for a
        // re-queued job must be dropped, and the re-measurement by the
        // new worker is the one that lands.
        let mut q = JobQueue::new();
        let id = submit1(&mut q, vec![1]);
        assign1(&mut q, 0).unwrap();
        q.requeue_worker(0);
        assert!(!q.complete(id, 0), "late result from dead worker accepted");
        assert_eq!(assign1(&mut q, 1).unwrap().id, id);
        assert!(q.complete(id, 1));
        assert!(!q.complete(id, 1), "duplicate completion accepted");
        assert_eq!(q.done(), 1);
    }

    #[test]
    fn affinity_cleared_even_for_unassigned_pinned_jobs() {
        // A job pinned to a worker that dies before ever taking it must
        // become routable to the survivors (no stranding).
        let mut q = JobQueue::new();
        let id = q.submit_to("xavier", "f", vec![1], 10, Some(2));
        assert!(assign1(&mut q, 0).is_none(), "pinned job leaked to the wrong worker");
        assert_eq!(q.requeue_worker(2), 0, "nothing was in flight");
        assert_eq!(assign1(&mut q, 0).unwrap().id, id, "affinity not cleared on death");
    }

    #[test]
    fn requeue_on_worker_death() {
        let mut q = JobQueue::new();
        let id = submit1(&mut q, vec![1]);
        assign1(&mut q, 0).unwrap();
        assert_eq!(q.requeue_worker(0), 1);
        // the job can be assigned to another worker now
        assert_eq!(assign1(&mut q, 1).unwrap().id, id);
        assert!(q.complete(id, 1));
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn mixed_class_queue_never_assigns_across_classes() {
        // A tx2 worker asking first must NOT receive the older xavier
        // job; each class drains only its own jobs.
        let mut q = JobQueue::new();
        let jx = q.submit("xavier", "f", vec![1], 10);
        let jt = q.submit("tx2", "f", vec![2], 10);
        let js = q.submit("server", "f", vec![3], 10);
        let got_t = q.assign(0, "tx2").unwrap();
        assert_eq!((got_t.id, got_t.device.as_str()), (jt, "tx2"));
        let got_x = q.assign(1, "xavier").unwrap();
        assert_eq!((got_x.id, got_x.device.as_str()), (jx, "xavier"));
        assert!(q.assign(2, "oppo").is_none(), "unscheduled class got a job");
        let got_s = q.assign(3, "server").unwrap();
        assert_eq!(got_s.id, js);
        // nothing queued is left for any class
        for c in ["xavier", "tx2", "server"] {
            assert!(q.assign(9, c).is_none(), "{c} job assigned twice");
        }
    }

    #[test]
    fn dead_tx2_worker_requeues_onto_surviving_tx2_only() {
        // Mid-stream death of one tx2 worker: its in-flight job must go
        // to the surviving tx2 worker and never to the (idle!) xavier.
        let mut q = JobQueue::new();
        let jt = q.submit_to("tx2", "f", vec![1], 10, Some(1));
        assert_eq!(q.assign(1, "tx2").unwrap().id, jt);
        assert_eq!(q.requeue_worker(1), 1);
        assert!(q.assign(0, "xavier").is_none(), "tx2 job leaked to a xavier worker");
        assert_eq!(q.assign(2, "tx2").unwrap().id, jt, "surviving tx2 peer skipped");
        assert!(q.complete(jt, 2));
        assert_eq!(q.done_for("tx2"), 1);
    }

    #[test]
    fn per_class_done_equals_submitted_exactly_once() {
        // Drain a mixed-class queue with one worker per class and check
        // the per-class ledgers: done == submitted for every class, and
        // duplicate completions never inflate them.
        let mut q = JobQueue::new();
        let classes = ["xavier", "tx2", "server"];
        for (ci, c) in classes.iter().enumerate() {
            for k in 0..=ci {
                q.submit(c, "f", vec![k], 10);
            }
        }
        assert!(!q.classes_outstanding().is_empty());
        for (w, c) in classes.iter().enumerate() {
            while let Some(j) = q.assign(w, c) {
                assert_eq!(&j.device, c);
                assert!(q.complete(j.id, w));
                assert!(!q.complete(j.id, w), "duplicate completion accepted");
            }
        }
        for (ci, c) in classes.iter().enumerate() {
            assert_eq!(q.submitted_for(c), ci + 1);
            assert_eq!(q.done_for(c), ci + 1, "{c}: done != submitted");
        }
        assert_eq!(q.done(), q.submitted());
        assert!(q.classes_outstanding().is_empty());
        assert_eq!(
            q.classes_submitted(),
            vec!["server".to_string(), "tx2".to_string(), "xavier".to_string()],
            "classes_submitted must be sorted and deduplicated"
        );
    }

    #[test]
    fn classes_outstanding_tracks_unresolved_jobs() {
        let mut q = JobQueue::new();
        let jx = q.submit("xavier", "f", vec![1], 10);
        q.submit("tx2", "f", vec![2], 10);
        assert_eq!(q.classes_outstanding(), vec!["tx2".to_string(), "xavier".to_string()]);
        q.assign(0, "xavier").unwrap();
        assert_eq!(
            q.classes_outstanding(),
            vec!["tx2".to_string(), "xavier".to_string()],
            "in-flight jobs are still outstanding"
        );
        q.complete(jx, 0);
        assert_eq!(q.classes_outstanding(), vec!["tx2".to_string()]);
    }

    #[test]
    fn speculation_first_result_wins_exactly_once() {
        let mut q = JobQueue::new();
        let id = submit1(&mut q, vec![1]);
        assign1(&mut q, 0).unwrap();
        // speculate to an idle same-class peer
        let j = q.speculate(id, 1, "xavier").expect("speculation refused");
        assert_eq!(j.id, id);
        assert!(q.busy(0) && q.busy(1), "both runners hold the job");
        // the speculative runner answers first; the straggler's late
        // duplicate is stale
        assert!(q.complete(id, 1));
        assert!(!q.complete(id, 0), "duplicate completion accepted");
        assert_eq!(q.done(), 1);
        assert!(!q.busy(0) && !q.busy(1));
    }

    #[test]
    fn speculation_primary_can_still_win() {
        let mut q = JobQueue::new();
        let id = submit1(&mut q, vec![1]);
        assign1(&mut q, 0).unwrap();
        q.speculate(id, 1, "xavier").unwrap();
        assert!(q.complete(id, 0), "recovered straggler's first result rejected");
        assert!(!q.complete(id, 1), "speculative duplicate accepted");
        assert_eq!(q.done(), 1);
    }

    #[test]
    fn speculate_refuses_bad_targets() {
        let mut q = JobQueue::new();
        let id = submit1(&mut q, vec![1]);
        assert!(q.speculate(id, 1, "xavier").is_none(), "speculated a queued job");
        assign1(&mut q, 0).unwrap();
        assert!(q.speculate(id, 0, "xavier").is_none(), "speculated onto the primary");
        assert!(q.speculate(id, 1, "tx2").is_none(), "speculated across classes");
        assert!(q.speculate(9999, 1, "xavier").is_none(), "speculated a ghost job");
        // a busy worker can't take a speculative copy either
        submit1(&mut q, vec![2]);
        assign1(&mut q, 1).unwrap();
        assert!(q.speculate(id, 1, "xavier").is_none(), "busy worker took a speculation");
        // and a speculative hold blocks regular assignment
        q.complete(1, 1);
        q.speculate(id, 1, "xavier").unwrap();
        submit1(&mut q, vec![3]);
        assert!(assign1(&mut q, 1).is_none(), "speculating worker double-assigned");
    }

    #[test]
    fn dead_primary_promotes_speculative_runner() {
        let mut q = JobQueue::new();
        let id = submit1(&mut q, vec![1]);
        assign1(&mut q, 0).unwrap();
        q.speculate(id, 1, "xavier").unwrap();
        // the hung primary finally disconnects: nothing re-queues (the
        // speculative runner still has it) and its result completes
        assert_eq!(q.requeue_worker(0), 0, "promoted job counted as re-queued");
        assert!(q.complete(id, 1));
        assert!(!q.complete(id, 0), "dead primary's late result accepted");
    }

    #[test]
    fn dead_speculative_runner_leaves_primary_in_flight() {
        let mut q = JobQueue::new();
        let id = submit1(&mut q, vec![1]);
        assign1(&mut q, 0).unwrap();
        q.speculate(id, 1, "xavier").unwrap();
        assert_eq!(q.requeue_worker(1), 0);
        assert!(!q.busy(1), "dead speculative runner still holds the job");
        assert!(q.complete(id, 0));
        assert_eq!(q.done(), 1);
    }

    #[test]
    fn respeculation_replaces_a_stalled_speculative_runner() {
        let mut q = JobQueue::new();
        let id = submit1(&mut q, vec![1]);
        assign1(&mut q, 0).unwrap();
        q.speculate(id, 1, "xavier").unwrap();
        // the first speculation stalled too; move it to worker 2
        q.speculate(id, 2, "xavier").unwrap();
        assert!(!q.busy(1), "replaced runner still counted busy");
        assert!(!q.complete(id, 1), "replaced runner's result accepted");
        assert!(q.complete(id, 2));
        assert_eq!(q.done(), 1);
    }

    #[test]
    fn clear_affinity_unpins_without_touching_holds() {
        let mut q = JobQueue::new();
        let held = q.submit_to("xavier", "f", vec![1], 10, Some(0));
        let pinned = q.submit_to("xavier", "f", vec![2], 10, Some(0));
        assert_eq!(q.assign(0, "xavier").unwrap().id, held);
        // worker 0 is now suspected: unpin its queued jobs so peers can
        // take them, but its in-flight hold stays in place
        assert_eq!(q.clear_affinity(0), 2);
        assert_eq!(q.assign(1, "xavier").unwrap().id, pinned, "unpinned job not routable");
        assert!(q.busy(0), "clear_affinity dropped an in-flight hold");
        assert!(q.complete(held, 0));
    }

    #[test]
    fn prop_every_job_resolves_exactly_once() {
        // Random interleaving of submit/assign/complete/death across two
        // device classes; at the end drain everything and verify each
        // job completed exactly once, each on its own class's workers.
        check(
            "jobs resolve exactly once",
            Config { cases: 64, seed: 77 },
            |r| {
                let ops: Vec<u8> = (0..r.range_usize(10, 60)).map(|_| r.range_usize(0, 3) as u8).collect();
                (ops, r.range_usize(1, 4))
            },
            |(ops, n_workers)| {
                // worker w serves class CLASSES[w % 2]
                const CLASSES: [&str; 2] = ["xavier", "tx2"];
                let class_of = |w: usize| CLASSES[w % 2];
                let mut q = JobQueue::new();
                let mut completions: BTreeMap<u64, usize> = BTreeMap::new();
                let mut inflight: Vec<(u64, usize)> = Vec::new();
                let mut submitted = 0u64;
                for (i, op) in ops.iter().enumerate() {
                    match op {
                        0 => {
                            q.submit(CLASSES[i % 2], "f", vec![i], 10);
                            submitted += 1;
                        }
                        1 => {
                            let w = i % n_workers;
                            if let Some(j) = q.assign(w, class_of(w)) {
                                crate::prop_assert!(j.device == class_of(w), "cross-class assignment");
                                inflight.push((j.id, w));
                            }
                        }
                        2 => {
                            if let Some((id, w)) = inflight.pop() {
                                if q.complete(id, w) {
                                    *completions.entry(id).or_insert(0) += 1;
                                }
                            }
                        }
                        _ => {
                            let w = i % n_workers;
                            q.requeue_worker(w);
                            inflight.retain(|&(_, iw)| iw != w);
                        }
                    }
                }
                // drain: first release any jobs still held by workers from
                // the random phase (a held worker can't take a new one)
                for w in 0..*n_workers {
                    q.requeue_worker(w);
                }
                inflight.clear();
                let mut guard = 0;
                while q.pending() > 0 {
                    guard += 1;
                    crate::prop_assert!(guard < 100_000, "drain did not terminate");
                    // two drain workers, one per class, beyond the random
                    // phase's ids so both classes always have a taker
                    for (w, c) in [(1000usize, CLASSES[0]), (1001, CLASSES[1])] {
                        if let Some(j) = q.assign(w, c) {
                            crate::prop_assert!(j.device == c, "cross-class assignment in drain");
                            crate::prop_assert!(q.complete(j.id, w), "drain completion rejected");
                            *completions.entry(j.id).or_insert(0) += 1;
                        }
                    }
                }
                crate::prop_assert!(completions.len() as u64 == submitted, "{} != {submitted}", completions.len());
                crate::prop_assert!(completions.values().all(|&c| c == 1), "double completion: {completions:?}");
                crate::prop_assert!(
                    q.done_for(CLASSES[0]) + q.done_for(CLASSES[1]) == q.done(),
                    "per-class ledgers do not add up"
                );
                Ok(())
            },
        );
    }
}
