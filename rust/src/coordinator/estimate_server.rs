//! Estimation-serving daemon (`thor serve-estimates`): the query-heavy,
//! fit-rarely half of the paper's value proposition.  Profiling pays for
//! measurements once; after that, estimation is a few GP posteriors per
//! model — cheap enough to serve at high rate to schedulers and fleet
//! scorers.  This server loads fitted [`GpStore`] artifacts as shared
//! immutable state (posterior factors α and K⁻¹ are precomputed once at
//! load, via the store's workspace-threaded `from_json`) and answers
//! `EstimateRequest` / `EstimateBatch` messages over the same
//! newline-delimited JSON protocol the fleet uses
//! ([`crate::coordinator::protocol`]).
//!
//! Two concurrency models, selected by [`IoModel`] (`--io-model`):
//!
//! * **Reactor** (default): one readiness-driven event thread owns all
//!   connections via non-blocking sockets and epoll/`poll(2)`
//!   ([`crate::coordinator::reactor`]); decoded requests flow to a
//!   fixed compute pool that drains pending queries in micro-batches,
//!   coalescing same-`(device, family)` queries *across connections*
//!   into single GP batch solves.  Connection count decouples from
//!   thread count, and a slow reader costs a bounded buffer, not a
//!   thread.
//! * **Threads** (`--io-model threads`, kept for one release): the
//!   original thread-per-connection accept/worker loop — N worker
//!   threads share one `TcpListener` (via `try_clone`) and each
//!   `accept`s its own connections, so a connection is handled
//!   start-to-finish by one thread with zero cross-thread handoff.
//!
//! Both models share one [`SharedEstimateCache`] (sharded `RwLock`
//! read-through memo) and one hot-swappable store slot, and answer
//! byte-identically (the serve test suite runs under both).  A client
//! disconnect — clean, mid-line, or mid-request — only ends that
//! connection; it can never wedge the daemon or poison a cache shard
//! (the cache recovers poisoned locks by design).
//!
//! Responses are **bit-identical** to a local [`crate::thor::estimate`]
//! call against the same store: the batch path coalesces same-family GP
//! queries across a request but each point's posterior is computed
//! independently (`estimate_batch_shared`'s contract, pinned by tests
//! here and in `tests/serve.rs`).
//!
//! Hot reload: [`EstimateServerHandle::swap_store`] atomically replaces
//! the store snapshot; in-flight requests finish against the snapshot
//! they started with, and the generation-stamped cache lazily discards
//! entries from older snapshots (see [`crate::thor::store`]).
//!
//! Deadline hardening ([`ServeTuning`]): every connection reads under a
//! short socket poll, so a worker thread can never block indefinitely
//! on one client.  A connection idle between requests past
//! `idle_timeout` is reaped silently; a request line that trickles in
//! slower than `line_timeout` (the slow-loris shape) or grows past
//! `max_line_bytes` gets one `est_err` and the connection is dropped;
//! writes carry `write_timeout` so a client that stops draining cannot
//! pin a worker either.  One misbehaving client costs one bounded
//! buffer and one error line — never a thread.  The reactor adds
//! `write_highwater` (read gating under write backpressure) and
//! `max_inflight` (a cap on decoded-but-unanswered pipelined requests
//! per connection).

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::protocol::{Msg, MAX_LINE_BYTES};
use crate::coordinator::reactor;
use crate::model::spec::parse_spec;
use crate::model::ModelGraph;
use crate::thor::estimator::{estimate_batch_shared, estimate_shared, SharedEstimateCache};
use crate::thor::store::GpStore;

/// The hot-swappable store slot: workers clone the inner `Arc` per
/// request (an atomic refcount bump under a briefly-held read lock), so
/// every request serves against one immutable snapshot while
/// [`EstimateServerHandle::swap_store`] can replace it at any time.
pub(crate) type StoreSlot = Arc<RwLock<Arc<GpStore>>>;

/// Which serving core owns the sockets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoModel {
    /// Thread-per-connection (the pre-reactor model; kept for one
    /// release as `--io-model threads`).
    Threads,
    /// Readiness-driven event loop + compute pool (the default).
    Reactor,
}

impl IoModel {
    /// Parse the `--io-model` flag value.
    pub fn parse(s: &str) -> Result<IoModel> {
        match s {
            "threads" => Ok(IoModel::Threads),
            "reactor" => Ok(IoModel::Reactor),
            other => Err(anyhow!("unknown io model {other:?} (expected reactor|threads)")),
        }
    }
}

/// Counters one serving thread accumulates; summed at shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    /// Connections accepted (shutdown-unblocking dummies excluded).
    pub connections: u64,
    /// Estimate requests served (an `EstimateBatch` counts once).
    pub requests: u64,
    /// Requests answered with an error (plus malformed lines).
    pub errors: u64,
    /// Connections reaped for idling past [`ServeTuning::idle_timeout`].
    pub reaped: u64,
    /// Requests answered inside a cross-connection micro-batch of ≥ 2
    /// (reactor only; always 0 under `IoModel::Threads`).
    pub coalesced: u64,
}

impl ServeStats {
    fn absorb(&mut self, other: ServeStats) {
        self.connections += other.connections;
        self.requests += other.requests;
        self.errors += other.errors;
        self.reaped += other.reaped;
        self.coalesced += other.coalesced;
    }
}

/// Per-connection deadline knobs (see the module docs).  The defaults
/// are generous — they exist to bound damage from misbehaving clients,
/// not to police healthy ones; tests tighten them to milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct ServeTuning {
    /// Reap a connection with no request in progress after this long.
    pub idle_timeout: Duration,
    /// A request line must arrive (first byte to newline) within this
    /// long, or the client is answered `est_err` and dropped — the
    /// slow-loris bound.
    pub line_timeout: Duration,
    /// Socket write timeout: a client that stops draining its replies
    /// errors the write instead of blocking the worker.
    pub write_timeout: Duration,
    /// Socket read-poll granularity — the worst-case extra latency for
    /// noticing shutdown, idle expiry, or a stalled line.
    pub poll: Duration,
    /// Hard cap on one request line (bounds per-connection memory).
    pub max_line_bytes: usize,
    /// Reactor only: stop reading from a connection while its buffered
    /// unsent replies exceed this many bytes (backpressure for clients
    /// that pipeline requests without draining replies).
    pub write_highwater: usize,
    /// Reactor only: cap on decoded-but-unanswered requests per
    /// connection; further pipelined requests wait in the read buffer.
    pub max_inflight: usize,
}

impl Default for ServeTuning {
    fn default() -> Self {
        Self {
            idle_timeout: Duration::from_secs(60),
            line_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            poll: Duration::from_millis(250),
            max_line_bytes: MAX_LINE_BYTES,
            write_highwater: 1 << 20,
            max_inflight: 1024,
        }
    }
}

/// Entry point: bind, then [`BoundEstimateServer::start`].
pub struct EstimateServer;

impl EstimateServer {
    /// Bind `addr` (supports port 0 for an OS-assigned port) with the
    /// store to serve.  The store should come from
    /// [`GpStore::load`]/`from_json`, which precompute every family's
    /// posterior factors at load — nothing is fitted per request.
    pub fn bind(addr: &str, store: GpStore) -> Result<BoundEstimateServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(BoundEstimateServer {
            listener,
            addr,
            store: Arc::new(RwLock::new(Arc::new(store))),
            cache: Arc::new(SharedEstimateCache::default()),
            tuning: ServeTuning::default(),
            io_model: IoModel::Reactor,
            coalesce_max: 32,
        })
    }
}

/// Bound but not yet serving — read [`BoundEstimateServer::local_addr`]
/// first when bound to an ephemeral port (the fleet-server idiom).
pub struct BoundEstimateServer {
    listener: TcpListener,
    addr: SocketAddr,
    store: StoreSlot,
    cache: Arc<SharedEstimateCache>,
    tuning: ServeTuning,
    io_model: IoModel,
    coalesce_max: usize,
}

impl BoundEstimateServer {
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Override the connection deadlines (tests tighten these).
    pub fn with_tuning(mut self, tuning: ServeTuning) -> Self {
        self.tuning = tuning;
        self
    }

    /// Bound the shared estimate cache to roughly `cap` entries total
    /// (LRU per shard; `0` = unbounded, the default).  `thor
    /// serve-estimates --cache-cap N`.
    pub fn with_cache_cap(mut self, cap: usize) -> Self {
        self.cache = Arc::new(SharedEstimateCache::bounded(cap));
        self
    }

    /// Select the serving core (`thor serve-estimates --io-model`).
    pub fn with_io_model(mut self, io_model: IoModel) -> Self {
        self.io_model = io_model;
        self
    }

    /// Cap a reactor compute worker's micro-batch: it drains at most
    /// this many pending requests per coalesced solve (`--coalesce-max`;
    /// `1` disables cross-request coalescing, ignored under threads).
    pub fn with_coalesce_max(mut self, coalesce_max: usize) -> Self {
        self.coalesce_max = coalesce_max.max(1);
        self
    }

    /// Start serving.  `threads == 0` means one per available core
    /// (min 2).  Under [`IoModel::Threads`] that many workers each
    /// `accept` and own whole connections, so at most `threads`
    /// connections are served concurrently; under [`IoModel::Reactor`]
    /// it sizes the compute pool while one event thread multiplexes any
    /// number of connections.
    pub fn start(self, threads: usize) -> Result<EstimateServerHandle> {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).max(2)
        } else {
            threads
        };
        let stop = Arc::new(AtomicBool::new(false));
        let tuning = self.tuning;
        let inner = match self.io_model {
            IoModel::Threads => {
                let mut workers = Vec::with_capacity(threads);
                for _ in 0..threads {
                    let listener = self.listener.try_clone()?;
                    let (slot, cache, stop) =
                        (self.store.clone(), self.cache.clone(), stop.clone());
                    workers.push(std::thread::spawn(move || {
                        worker_loop(listener, slot, cache, stop, tuning)
                    }));
                }
                HandleInner::Threads { workers }
            }
            IoModel::Reactor => HandleInner::Reactor(reactor::spawn(
                self.listener,
                self.store.clone(),
                self.cache.clone(),
                stop.clone(),
                tuning,
                threads,
                self.coalesce_max,
            )?),
        };
        Ok(EstimateServerHandle {
            addr: self.addr,
            store: self.store,
            cache: self.cache,
            stop,
            inner,
        })
    }
}

/// Model-specific running state behind [`EstimateServerHandle`].
enum HandleInner {
    Threads { workers: Vec<JoinHandle<ServeStats>> },
    Reactor(reactor::ReactorHandle),
}

/// A running daemon: the owner's handle for reload and shutdown.
pub struct EstimateServerHandle {
    addr: SocketAddr,
    store: StoreSlot,
    cache: Arc<SharedEstimateCache>,
    stop: Arc<AtomicBool>,
    inner: HandleInner,
}

impl EstimateServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared cache statistics (hits/misses/entries).
    pub fn cache(&self) -> &SharedEstimateCache {
        &self.cache
    }

    /// Hot-reload: atomically replace the served store.  In-flight
    /// requests finish on the snapshot they started with; the next
    /// request (or reactor micro-batch) sees the new one, and the
    /// generation-stamped cache invalidates lazily — no stale estimate
    /// can ever be served.
    pub fn swap_store(&self, store: GpStore) {
        *self.store.write().unwrap_or_else(|e| e.into_inner()) = Arc::new(store);
    }

    /// Stop serving, unblock every thread, and join them.  The thread
    /// model wakes blocked `accept()`s with dummy connections; the
    /// reactor needs only its stop flag and wake pipe (no fd churn —
    /// `tests/serve.rs` pins fd-count stability across 100 cycles).
    pub fn shutdown(self) -> ServeStats {
        self.stop.store(true, Ordering::Relaxed);
        match self.inner {
            HandleInner::Threads { workers } => {
                // Each blocked accept() needs one connection to wake up;
                // extras sit in the backlog and die with the listener.
                for _ in 0..workers.len() {
                    let _ = TcpStream::connect(self.addr);
                }
                let mut total = ServeStats::default();
                for h in workers {
                    if let Ok(s) = h.join() {
                        total.absorb(s);
                    }
                }
                total
            }
            HandleInner::Reactor(r) => r.shutdown(),
        }
    }

    /// Block until the serving threads exit (the CLI's serve-forever
    /// mode; only an external `shutdown`-style signal ends it).
    pub fn join(self) -> ServeStats {
        match self.inner {
            HandleInner::Threads { workers } => {
                let mut total = ServeStats::default();
                for h in workers {
                    if let Ok(s) = h.join() {
                        total.absorb(s);
                    }
                }
                total
            }
            HandleInner::Reactor(r) => r.join(),
        }
    }
}

fn worker_loop(
    listener: TcpListener,
    slot: StoreSlot,
    cache: Arc<SharedEstimateCache>,
    stop: Arc<AtomicBool>,
    tuning: ServeTuning,
) -> ServeStats {
    let mut stats = ServeStats::default();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if stop.load(Ordering::Relaxed) {
                    break; // shutdown-unblocking dummy connection
                }
                stats.connections += 1;
                handle_conn(stream, &slot, &cache, &stop, &tuning, &mut stats);
            }
            // Transient accept failure (e.g. EMFILE, aborted handshake):
            // keep the loop alive; only the stop flag ends a worker.
            Err(_) => continue,
        }
    }
    stats
}

/// How one [`read_request_line`] call resolved.
enum LineRead {
    /// A complete request line landed in the buffer.
    Line,
    /// Clean EOF between requests.
    Eof,
    /// No request started within [`ServeTuning::idle_timeout`] — reap
    /// silently (a pooled client going quiet is not an error).
    Idle,
    /// A line started but did not finish within
    /// [`ServeTuning::line_timeout`] — the slow-loris shape.
    SlowLine,
    /// The line outgrew [`ServeTuning::max_line_bytes`].
    TooLong,
    /// The daemon is shutting down.
    Stopped,
    /// Mid-line EOF, invalid UTF-8, or a hard socket error.
    Broken,
}

/// Read one `\n`-terminated line under the connection deadlines.  The
/// socket carries a [`ServeTuning::poll`] read timeout, so this loop
/// wakes every poll tick to check the stop flag and the idle/line
/// clocks — a worker thread is never parked on a client for longer
/// than one tick.  On `Line` the text (sans enforcement) is in `line`.
fn read_request_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    tuning: &ServeTuning,
    stop: &AtomicBool,
) -> LineRead {
    line.clear();
    let mut pending: Vec<u8> = Vec::new();
    let opened = Instant::now();
    let mut line_start: Option<Instant> = None;
    loop {
        if stop.load(Ordering::Relaxed) {
            return LineRead::Stopped;
        }
        let consumed = match reader.fill_buf() {
            Ok([]) => {
                return if pending.is_empty() { LineRead::Eof } else { LineRead::Broken };
            }
            Ok(chunk) => {
                if line_start.is_none() {
                    line_start = Some(Instant::now());
                }
                match chunk.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        if pending.len() + i + 1 > tuning.max_line_bytes {
                            return LineRead::TooLong;
                        }
                        pending.extend_from_slice(&chunk[..=i]);
                        i + 1
                    }
                    None => {
                        if pending.len() + chunk.len() > tuning.max_line_bytes {
                            return LineRead::TooLong;
                        }
                        pending.extend_from_slice(chunk);
                        chunk.len()
                    }
                }
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                // One poll tick elapsed with no bytes: check the clocks.
                match line_start {
                    None if opened.elapsed() >= tuning.idle_timeout => return LineRead::Idle,
                    Some(started) if started.elapsed() >= tuning.line_timeout => {
                        return LineRead::SlowLine;
                    }
                    _ => continue,
                }
            }
            Err(_) => return LineRead::Broken,
        };
        reader.consume(consumed);
        if pending.last() == Some(&b'\n') {
            return match String::from_utf8(std::mem::take(&mut pending)) {
                Ok(s) => {
                    line.push_str(&s);
                    LineRead::Line
                }
                Err(_) => LineRead::Broken,
            };
        }
    }
}

/// Serve one connection until the client disconnects or trips a
/// deadline.  Every exit path returns to the caller's accept loop — a
/// half-written line, a dropped socket, a malformed request, or a
/// deadline expiry only ends *this* connection.
fn handle_conn(
    stream: TcpStream,
    slot: &StoreSlot,
    cache: &SharedEstimateCache,
    stop: &AtomicBool,
    tuning: &ServeTuning,
    stats: &mut ServeStats,
) {
    // try_clone shares the underlying file description, so the
    // read/write timeouts below govern both halves; set them once.
    if stream.set_read_timeout(Some(tuning.poll)).is_err()
        || stream.set_write_timeout(Some(tuning.write_timeout)).is_err()
    {
        return;
    }
    let Ok(read_half) = stream.try_clone() else { return };
    let mut writer = stream;
    let mut reader = BufReader::new(read_half);
    let mut line = String::new();
    loop {
        match read_request_line(&mut reader, &mut line, tuning, stop) {
            LineRead::Line => {}
            LineRead::Eof | LineRead::Broken | LineRead::Stopped => return,
            LineRead::Idle => {
                stats.reaped += 1;
                return;
            }
            LineRead::SlowLine => {
                stats.errors += 1;
                let err = Msg::EstimateError {
                    id: 0,
                    error: format!(
                        "request line stalled past the {:?} read deadline",
                        tuning.line_timeout
                    ),
                };
                let _ = writer.write_all(err.encode().as_bytes());
                return;
            }
            LineRead::TooLong => {
                stats.errors += 1;
                let err = Msg::EstimateError {
                    id: 0,
                    error: format!("request line exceeds {} bytes", tuning.max_line_bytes),
                };
                let _ = writer.write_all(err.encode().as_bytes());
                return;
            }
        }
        if line.trim().is_empty() {
            continue;
        }
        let Some(msg) = Msg::decode(&line) else {
            // Framing is broken; answer once, then drop the connection
            // rather than guessing at message alignment.
            stats.errors += 1;
            let err = Msg::EstimateError { id: 0, error: "malformed request line".into() };
            let _ = writer.write_all(err.encode().as_bytes());
            return;
        };
        // One immutable snapshot per request (Arc clone, not a copy).
        let store: Arc<GpStore> = slot.read().unwrap_or_else(|e| e.into_inner()).clone();
        let reply = match msg {
            Msg::EstimateRequest { id, device, model } => {
                stats.requests += 1;
                match serve_one(&store, &device, &model, cache) {
                    Ok((energy_per_iter, variance)) => {
                        Msg::EstimateReply { id, energy_per_iter, variance }
                    }
                    Err(error) => {
                        stats.errors += 1;
                        Msg::EstimateError { id, error }
                    }
                }
            }
            Msg::EstimateBatch { id, queries } => {
                stats.requests += 1;
                Msg::EstimateBatchReply { id, results: serve_batch(&store, &queries, cache) }
            }
            // A polite client close; also lets `nc`-style probes exit.
            Msg::Shutdown => return,
            other => {
                stats.errors += 1;
                Msg::EstimateError {
                    id: 0,
                    error: format!("unsupported message on an estimate connection: {other:?}"),
                }
            }
        };
        if writer.write_all(reply.encode().as_bytes()).is_err() {
            return;
        }
    }
}

pub(crate) fn serve_one(
    store: &GpStore,
    device: &str,
    model_spec: &str,
    cache: &SharedEstimateCache,
) -> Result<(f64, f64), String> {
    let g = parse_spec(model_spec).map_err(|e| e.to_string())?;
    estimate_shared(store, device, &g, cache)
        .map(|e| (e.energy_per_iter, e.variance))
        .map_err(|e| e.to_string())
}

/// Per-query outcomes in query order; spec parse failures consume only
/// their own slot, and the valid remainder still coalesces through one
/// [`estimate_batch_shared`] call.
pub(crate) fn serve_batch(
    store: &GpStore,
    queries: &[(String, String)],
    cache: &SharedEstimateCache,
) -> Vec<Result<(f64, f64), String>> {
    let parsed: Vec<Result<ModelGraph, String>> =
        queries.iter().map(|(_, m)| parse_spec(m).map_err(|e| e.to_string())).collect();
    let valid: Vec<(usize, (&str, &ModelGraph))> = queries
        .iter()
        .zip(&parsed)
        .enumerate()
        .filter_map(|(i, ((device, _), p))| p.as_ref().ok().map(|g| (i, (device.as_str(), g))))
        .collect();
    let sub: Vec<(&str, &ModelGraph)> = valid.iter().map(|(_, q)| *q).collect();
    let answers = estimate_batch_shared(store, &sub, cache);
    let mut out: Vec<Result<(f64, f64), String>> =
        parsed.into_iter().map(|p| p.map(|_| (0.0, 0.0))).collect();
    for ((i, _), a) in valid.into_iter().zip(answers) {
        out[i] = a.map(|e| (e.energy_per_iter, e.variance)).map_err(|e| e.to_string());
    }
    out
}

/// Blocking client for the estimate protocol — used by the `serve1`
/// experiment, the tests, and scriptable from the CLI.  The
/// [`EstimateClient::estimate`] / [`EstimateClient::estimate_batch`]
/// methods keep one request in flight at a time; `id`s are still
/// checked so a desynced server is an error, not a wrong answer.  For
/// pipelining, pair [`EstimateClient::submit`] (fire as many requests
/// as you like) with [`EstimateClient::recv_single`] (collect replies,
/// matching by correlation id — the reactor answers in completion
/// order, not necessarily send order).
pub struct EstimateClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl EstimateClient {
    pub fn connect(addr: &SocketAddr) -> Result<Self> {
        let writer = TcpStream::connect(addr)?;
        let reader = BufReader::new(writer.try_clone()?);
        Ok(Self { reader, writer, next_id: 1 })
    }

    fn roundtrip(&mut self, msg: Msg) -> Result<Msg> {
        self.writer.write_all(msg.encode().as_bytes())?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("server closed the connection"));
        }
        Msg::decode(&line).ok_or_else(|| anyhow!("undecodable reply: {line:?}"))
    }

    fn take_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Estimate one model (a [`crate::model::spec`] string) on one
    /// device class; returns (energy J/iter, variance).
    pub fn estimate(&mut self, device: &str, model: &str) -> Result<(f64, f64)> {
        let id = self.take_id();
        let req =
            Msg::EstimateRequest { id, device: device.to_string(), model: model.to_string() };
        match self.roundtrip(req)? {
            Msg::EstimateReply { id: rid, energy_per_iter, variance } if rid == id => {
                Ok((energy_per_iter, variance))
            }
            Msg::EstimateError { id: rid, error } if rid == id => Err(anyhow!(error)),
            other => Err(anyhow!("out-of-sync reply: {other:?}")),
        }
    }

    /// Estimate a batch of `(device, model-spec)` queries in one
    /// round-trip; per-query outcomes in query order.
    pub fn estimate_batch(
        &mut self,
        queries: &[(String, String)],
    ) -> Result<Vec<Result<(f64, f64), String>>> {
        let id = self.take_id();
        match self.roundtrip(Msg::EstimateBatch { id, queries: queries.to_vec() })? {
            Msg::EstimateBatchReply { id: rid, results } if rid == id => Ok(results),
            Msg::EstimateError { id: rid, error } if rid == id => Err(anyhow!(error)),
            other => Err(anyhow!("out-of-sync reply: {other:?}")),
        }
    }

    /// Pipelined send: write one `EstimateRequest` without waiting for
    /// the reply; returns the correlation id to match against
    /// [`EstimateClient::recv_single`].  Any number may be in flight.
    pub fn submit(&mut self, device: &str, model: &str) -> Result<u64> {
        let id = self.take_id();
        let req =
            Msg::EstimateRequest { id, device: device.to_string(), model: model.to_string() };
        self.writer.write_all(req.encode().as_bytes())?;
        Ok(id)
    }

    /// Read one single-request reply (success or per-request error),
    /// returning `(id, outcome)` so the caller can match pipelined
    /// replies by correlation id in whatever order they complete.
    pub fn recv_single(&mut self) -> Result<(u64, Result<(f64, f64), String>)> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("server closed the connection"));
        }
        match Msg::decode(&line) {
            Some(Msg::EstimateReply { id, energy_per_iter, variance }) => {
                Ok((id, Ok((energy_per_iter, variance))))
            }
            Some(Msg::EstimateError { id, error }) => Ok((id, Err(error))),
            other => Err(anyhow!("unexpected reply on a pipelined connection: {other:?}")),
        }
    }

    /// Write raw bytes (tests: malformed lines, partial requests).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.writer.write_all(bytes)?;
        Ok(())
    }

    /// Read one reply line (tests, paired with [`EstimateClient::send_raw`]).
    pub fn read_reply(&mut self) -> Result<Msg> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("server closed the connection"));
        }
        Msg::decode(&line).ok_or_else(|| anyhow!("undecodable reply: {line:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::thor::estimator::estimate;
    use crate::thor::store::GpStore;

    /// A deterministic fitted store covering the cnn5 reference families
    /// on `device` (quick profile — seconds, not minutes).
    fn profiled_store(device: &str, seed: u64) -> GpStore {
        let profile = crate::simdevice::devices::by_name(device).unwrap();
        let mut dev = crate::simdevice::Device::new(profile, seed);
        let mut thor =
            crate::thor::Thor::new(crate::thor::ThorConfig::quick());
        thor.profile_local(&mut dev, &zoo::cnn5(&[32, 64, 128, 256], 16, 10));
        thor.store
    }

    fn start_daemon(store: GpStore, threads: usize) -> EstimateServerHandle {
        EstimateServer::bind("127.0.0.1:0", store).unwrap().start(threads).unwrap()
    }

    #[test]
    fn serves_single_requests_bit_identical_to_local_estimate() {
        let store = profiled_store("xavier", 11);
        let spec = "cnn5:8,16,32,64:16";
        let expect = estimate(&store, "xavier", &parse_spec(spec).unwrap()).unwrap();
        let handle = start_daemon(store, 2);
        let mut client = EstimateClient::connect(&handle.addr()).unwrap();
        for _ in 0..3 {
            let (e, v) = client.estimate("xavier", spec).unwrap();
            assert_eq!(e.to_bits(), expect.energy_per_iter.to_bits());
            assert_eq!(v.to_bits(), expect.variance.to_bits());
        }
        drop(client);
        let stats = handle.shutdown();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn batch_replies_match_local_estimates_with_per_query_errors() {
        let store = profiled_store("xavier", 11);
        let specs = ["cnn5:8,16,32,64:16", "cnn5:4,8,16,32:16", "nope:1", "cnn5:16,32,64,128:16"];
        let expected: Vec<Option<(u64, u64)>> = specs
            .iter()
            .map(|s| {
                parse_spec(s).ok().map(|g| {
                    let e = estimate(&store, "xavier", &g).unwrap();
                    (e.energy_per_iter.to_bits(), e.variance.to_bits())
                })
            })
            .collect();
        let handle = start_daemon(store, 2);
        let mut client = EstimateClient::connect(&handle.addr()).unwrap();
        let queries: Vec<(String, String)> =
            specs.iter().map(|s| ("xavier".to_string(), s.to_string())).collect();
        let got = client.estimate_batch(&queries).unwrap();
        assert_eq!(got.len(), specs.len());
        for (g, e) in got.iter().zip(&expected) {
            match (g, e) {
                (Ok((ge, gv)), Some((ee, ev))) => {
                    assert_eq!(ge.to_bits(), *ee);
                    assert_eq!(gv.to_bits(), *ev);
                }
                (Err(msg), None) => assert!(msg.contains("unknown model family"), "{msg}"),
                other => panic!("mismatched outcome: {other:?}"),
            }
        }
        drop(client);
        handle.shutdown();
    }

    #[test]
    fn unknown_device_and_malformed_lines_answer_errors() {
        let store = profiled_store("xavier", 11);
        let handle = start_daemon(store, 2);
        let mut client = EstimateClient::connect(&handle.addr()).unwrap();
        let err = client.estimate("oppo", "cnn5").unwrap_err();
        assert!(err.to_string().contains("no fitted GP"), "{err}");
        // Malformed line: one error reply, then the server drops the
        // connection — and keeps serving new ones.
        let mut bad = EstimateClient::connect(&handle.addr()).unwrap();
        bad.send_raw(b"this is not json\n").unwrap();
        match bad.read_reply().unwrap() {
            Msg::EstimateError { id: 0, .. } => {}
            other => panic!("expected EstimateError, got {other:?}"),
        }
        assert!(bad.read_reply().is_err(), "connection should be closed after framing break");
        let (e, _) = client.estimate("xavier", "cnn5:8,16,32,64:16").unwrap();
        assert!(e > 0.0);
        drop(client);
        drop(bad);
        let stats = handle.shutdown();
        assert!(stats.errors >= 2);
    }

    #[test]
    fn overlong_request_lines_get_one_error_then_drop() {
        let store = profiled_store("xavier", 11);
        let tuning = ServeTuning { max_line_bytes: 256, ..ServeTuning::default() };
        let handle =
            EstimateServer::bind("127.0.0.1:0", store).unwrap().with_tuning(tuning).start(2).unwrap();
        let mut bad = EstimateClient::connect(&handle.addr()).unwrap();
        // No newline at all: the cap must bound buffered bytes, not just
        // completed lines.
        bad.send_raw(&[b'x'; 512]).unwrap();
        match bad.read_reply().unwrap() {
            Msg::EstimateError { id: 0, error } => assert!(error.contains("exceeds"), "{error}"),
            other => panic!("expected EstimateError, got {other:?}"),
        }
        assert!(bad.read_reply().is_err(), "connection should be closed after the cap trips");
        // The daemon still serves well-formed clients afterwards.
        let mut client = EstimateClient::connect(&handle.addr()).unwrap();
        assert!(client.estimate("xavier", "cnn5:8,16,32,64:16").unwrap().0 > 0.0);
        drop(client);
        drop(bad);
        let stats = handle.shutdown();
        assert!(stats.errors >= 1);
    }

    #[test]
    fn sparse_backend_store_loads_and_serves_bit_identically() {
        // PR 9 serving contract: a `--gp sparse:<m>` profiled artifact
        // reloads through the same workspace-threaded `from_json` (the
        // daemon's load path — posterior factors over the inducing basis
        // precomputed once) and serves bit-identically to a local
        // estimate against the reloaded store.
        let profile = crate::simdevice::devices::by_name("xavier").unwrap();
        let mut dev = crate::simdevice::Device::new(profile, 11);
        let mut cfg = crate::thor::ThorConfig::quick();
        cfg.gp_backend = crate::gp::GpBackend::Sparse { m: 6 };
        let mut thor = crate::thor::Thor::new(cfg);
        thor.profile_local(&mut dev, &zoo::cnn5(&[32, 64, 128, 256], 16, 10));
        let json = thor.store.to_json().to_string();
        assert!(json.contains("\"backend\":\"sparse\""), "quick fits exceed m=6, so at least one family must go sparse");
        let store = GpStore::from_json(&crate::util::json::Json::parse(&json).unwrap()).unwrap();
        let spec = "cnn5:8,16,32,64:16";
        let expect = estimate(&store, "xavier", &parse_spec(spec).unwrap()).unwrap();
        let handle = start_daemon(store, 2);
        let mut client = EstimateClient::connect(&handle.addr()).unwrap();
        let (e, v) = client.estimate("xavier", spec).unwrap();
        assert_eq!(e.to_bits(), expect.energy_per_iter.to_bits());
        assert_eq!(v.to_bits(), expect.variance.to_bits());
        drop(client);
        let stats = handle.shutdown();
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn swap_store_serves_the_new_fit_immediately() {
        let store_a = profiled_store("xavier", 11);
        let store_b = profiled_store("xavier", 99); // different profiling seed
        let spec = "cnn5:8,16,32,64:16";
        let g = parse_spec(spec).unwrap();
        let ea = estimate(&store_a, "xavier", &g).unwrap().energy_per_iter;
        let eb = estimate(&store_b, "xavier", &g).unwrap().energy_per_iter;
        assert_ne!(ea.to_bits(), eb.to_bits(), "seeds must produce different fits");
        let handle = start_daemon(store_a, 2);
        let mut client = EstimateClient::connect(&handle.addr()).unwrap();
        assert_eq!(client.estimate("xavier", spec).unwrap().0.to_bits(), ea.to_bits());
        handle.swap_store(store_b);
        assert_eq!(
            client.estimate("xavier", spec).unwrap().0.to_bits(),
            eb.to_bits(),
            "hot reload must not serve stale cache entries"
        );
        drop(client);
        handle.shutdown();
    }
}
