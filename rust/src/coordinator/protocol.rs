//! Wire protocol: one JSON object per line (newline-delimited JSON —
//! **not** length-prefixed; framing is the `\n` terminator and nothing
//! else).
//!
//! Floats travel through [`crate::util::json`], whose f64 formatting is
//! shortest-roundtrip — a `Result`'s energy reaches the leader with the
//! exact bit pattern the worker measured, which the cross-backend store
//! byte-equality (`rust/tests/backend_equiv.rs`) depends on; the
//! roundtrip property below pins the transport to `to_bits()` equality.
//! Integer ids are a separate concern: an f64 only holds integers
//! exactly up to 2^53, so ids travel as JSON numbers in the safe range
//! and as decimal strings beyond it ([`id_to_json`]) — a u64 id
//! roundtrips losslessly at any magnitude.
//!
//! Batched acquisition needs no protocol change: a batch is just
//! several in-flight `Job`s at once.  Heterogeneous fleets need none
//! either: `Hello::device` **is** the worker's device class — the
//! leader's routing key ([`crate::coordinator::scheduler::JobQueue`]
//! assigns same-class only), so a `Job` never names a device (the
//! receiving worker is, by routing, of the right class).  Neither does
//! worker rejoin: a restarted worker reconnects and re-`Hello`s, and
//! the leader treats the new connection as a fresh worker id of the
//! declared class — there is no resume token, because jobs lost with
//! the old connection were already requeued on its disconnect.
//!
//! The estimation-serving daemon
//! ([`crate::coordinator::estimate_server`]) shares this codec: an
//! `EstimateRequest`/`EstimateBatch` carries a client-chosen correlation
//! id (echoed in the reply so clients can pipeline), a device class and
//! a model spec string ([`crate::model::spec`]).

use std::io::{self, BufRead};

use crate::util::json::Json;

/// Ceiling on one protocol line.  Every legitimate message is a few
/// hundred bytes (the largest, a wide `EstimateBatch`, stays well under
/// a megabyte), so a line still growing past this is a broken or
/// hostile peer streaming bytes without a newline — readers bail out
/// instead of buffering its stream forever ([`read_line_capped`]).
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Read one `\n`-terminated line like `BufRead::read_line`, but refuse
/// to buffer more than `max` bytes: both coordinator tiers use this so
/// a newline-less stream costs a bounded buffer and one
/// `InvalidData` error, not unbounded memory.  On the cap (or invalid
/// UTF-8) the offending bytes stay unconsumed — callers drop the
/// connection, they never resynchronize.  Returns bytes read, newline
/// included; `Ok(0)` is clean EOF.
pub fn read_line_capped<R: BufRead>(r: &mut R, line: &mut String, max: usize) -> io::Result<usize> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (done, used) = {
            let chunk = r.fill_buf()?;
            if chunk.is_empty() {
                break; // EOF (mid-line EOF returns what arrived, like read_line)
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    if buf.len() + i + 1 > max {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("protocol line exceeds {max} bytes"),
                        ));
                    }
                    buf.extend_from_slice(&chunk[..=i]);
                    (true, i + 1)
                }
                None => {
                    if buf.len() + chunk.len() > max {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("protocol line exceeds {max} bytes"),
                        ));
                    }
                    buf.extend_from_slice(chunk);
                    (false, chunk.len())
                }
            }
        };
        r.consume(used);
        if done {
            break;
        }
    }
    let s = std::str::from_utf8(&buf)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
    line.push_str(s);
    Ok(buf.len())
}

/// How a [`FrameBuf`] line extraction failed.  Both are connection-fatal
/// for the reactor: `TooLong` earns one `est_err` then the drop (the
/// same answer the blocking reader's cap gives), `Utf8` is a silent
/// drop (framing is unrecoverable, matching the blocking path's
/// `Broken`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// A line (terminated or still growing) exceeded the byte cap.
    TooLong,
    /// A complete line was not valid UTF-8.
    Utf8,
}

/// Incremental newline framing over a per-connection byte buffer — the
/// non-blocking counterpart of [`read_line_capped`]: the reactor feeds
/// whatever `read()` returned via [`FrameBuf::push`] and pulls complete
/// lines with [`FrameBuf::next_line`].  Cap semantics match the
/// blocking reader exactly (a line errors when its bytes *including*
/// the newline would exceed `max`, and a still-unterminated tail errors
/// as soon as it alone exceeds `max`), so the two io models answer
/// oversize abuse identically.
///
/// The caller must drain `next_line` until `Ok(None)` after each push;
/// the buffer then holds at most one partial line, bounded by `max` —
/// per-connection memory stays capped no matter what the peer streams.
pub struct FrameBuf {
    buf: Vec<u8>,
    /// Bytes already scanned for `\n` (avoids rescanning a long partial
    /// line on every push).
    scanned: usize,
    max: usize,
}

impl FrameBuf {
    pub fn new(max: usize) -> Self {
        Self { buf: Vec::new(), scanned: 0, max }
    }

    /// Append bytes from the socket.  Infallible: caps are enforced in
    /// [`FrameBuf::next_line`], which sees line boundaries.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Extract the next complete line, newline included (the shape
    /// [`Msg::decode`] expects; it trims).  `Ok(None)` means no full
    /// line is buffered yet.
    pub fn next_line(&mut self) -> Result<Option<String>, FrameError> {
        match self.buf[self.scanned..].iter().position(|&b| b == b'\n') {
            Some(rel) => {
                let end = self.scanned + rel; // index of the newline
                if end + 1 > self.max {
                    return Err(FrameError::TooLong);
                }
                let line_bytes: Vec<u8> = self.buf.drain(..=end).collect();
                self.scanned = 0;
                match String::from_utf8(line_bytes) {
                    Ok(s) => Ok(Some(s)),
                    Err(_) => Err(FrameError::Utf8),
                }
            }
            None => {
                self.scanned = self.buf.len();
                if self.buf.len() > self.max {
                    return Err(FrameError::TooLong);
                }
                Ok(None)
            }
        }
    }

    /// Whether an unterminated line is buffered (drives the reactor's
    /// slow-loris clock: a partial line that stops growing times out).
    pub fn has_partial(&self) -> bool {
        !self.buf.is_empty()
    }
}

/// Largest integer an f64 represents exactly (2^53).  Ids above this
/// must not travel as JSON numbers: the `u64 → f64` cast would round,
/// silently corrupting the id on roundtrip.
const MAX_SAFE_INT: u64 = 1 << 53;

/// Encode a u64 id losslessly: a JSON number within the f64-exact range,
/// a decimal string beyond it.
fn id_to_json(id: u64) -> Json {
    if id <= MAX_SAFE_INT {
        Json::Num(id as f64)
    } else {
        Json::Str(id.to_string())
    }
}

/// Decode an id written by [`id_to_json`].  A JSON number outside the
/// f64-exact integer range is rejected rather than rounded — a peer that
/// encodes big ids as numbers corrupted them before they hit the wire.
fn id_from_json(j: &Json) -> Option<u64> {
    match j {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= MAX_SAFE_INT as f64 => {
            Some(*n as u64)
        }
        Json::Str(s) => s.parse().ok(),
        _ => None,
    }
}

/// Protocol messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker → server: registration; `device` is the worker's device
    /// class — the leader's class-scoped routing key.
    Hello { device: String },
    /// server → worker: measure a variant (channels on the *raw* scale).
    Job { job_id: u64, family: String, channels: Vec<usize>, iterations: usize },
    /// worker → server: measurement result.
    Result { job_id: u64, energy_per_iter: f64, device_seconds: f64 },
    /// server → worker: nothing to do right now.
    Idle,
    /// server → worker: profiling finished; worker exits.
    Shutdown,
    /// client → daemon: estimate one model on one device class.  `id` is
    /// a client-chosen correlation id, echoed verbatim in the reply;
    /// `model` is a spec string parsed by [`crate::model::spec`].
    EstimateRequest { id: u64, device: String, model: String },
    /// client → daemon: estimate several `(device, model)` pairs in one
    /// round-trip; the daemon coalesces same-family GP queries across
    /// the whole batch.
    EstimateBatch { id: u64, queries: Vec<(String, String)> },
    /// daemon → client: successful single estimate (mean J/iter and
    /// predictive variance), bit-identical to a local
    /// [`crate::thor::estimate`] call against the same store.
    EstimateReply { id: u64, energy_per_iter: f64, variance: f64 },
    /// daemon → client: per-query outcomes for an `EstimateBatch`, in
    /// query order; each entry is `Ok((energy, variance))` or a
    /// per-query error string (one bad query does not fail the batch).
    EstimateBatchReply { id: u64, results: Vec<Result<(f64, f64), String>> },
    /// daemon → client: the request (or the whole connection's framing)
    /// could not be served; `id` is 0 when the request id was unreadable.
    EstimateError { id: u64, error: String },
}

impl Msg {
    pub fn to_json(&self) -> Json {
        match self {
            Msg::Hello { device } => Json::obj(vec![
                ("type", Json::str("hello")),
                ("device", Json::str(device)),
            ]),
            Msg::Job { job_id, family, channels, iterations } => Json::obj(vec![
                ("type", Json::str("job")),
                ("job_id", id_to_json(*job_id)),
                ("family", Json::str(family)),
                ("channels", Json::arr_f64(&channels.iter().map(|&c| c as f64).collect::<Vec<_>>())),
                ("iterations", Json::Num(*iterations as f64)),
            ]),
            Msg::Result { job_id, energy_per_iter, device_seconds } => Json::obj(vec![
                ("type", Json::str("result")),
                ("job_id", id_to_json(*job_id)),
                ("energy_per_iter", Json::Num(*energy_per_iter)),
                ("device_seconds", Json::Num(*device_seconds)),
            ]),
            Msg::Idle => Json::obj(vec![("type", Json::str("idle"))]),
            Msg::Shutdown => Json::obj(vec![("type", Json::str("shutdown"))]),
            Msg::EstimateRequest { id, device, model } => Json::obj(vec![
                ("type", Json::str("est")),
                ("id", id_to_json(*id)),
                ("device", Json::str(device)),
                ("model", Json::str(model)),
            ]),
            Msg::EstimateBatch { id, queries } => Json::obj(vec![
                ("type", Json::str("est_batch")),
                ("id", id_to_json(*id)),
                (
                    "queries",
                    Json::Arr(
                        queries
                            .iter()
                            .map(|(d, m)| {
                                Json::obj(vec![("device", Json::str(d)), ("model", Json::str(m))])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Msg::EstimateReply { id, energy_per_iter, variance } => Json::obj(vec![
                ("type", Json::str("est_ok")),
                ("id", id_to_json(*id)),
                ("energy_per_iter", Json::Num(*energy_per_iter)),
                ("variance", Json::Num(*variance)),
            ]),
            Msg::EstimateBatchReply { id, results } => Json::obj(vec![
                ("type", Json::str("est_batch_ok")),
                ("id", id_to_json(*id)),
                (
                    "results",
                    Json::Arr(
                        results
                            .iter()
                            .map(|r| match r {
                                Ok((e, v)) => Json::obj(vec![
                                    ("energy_per_iter", Json::Num(*e)),
                                    ("variance", Json::Num(*v)),
                                ]),
                                Err(msg) => Json::obj(vec![("error", Json::str(msg))]),
                            })
                            .collect(),
                    ),
                ),
            ]),
            Msg::EstimateError { id, error } => Json::obj(vec![
                ("type", Json::str("est_err")),
                ("id", id_to_json(*id)),
                ("error", Json::str(error)),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Option<Msg> {
        match j.get("type")?.as_str()? {
            "hello" => Some(Msg::Hello { device: j.get("device")?.as_str()?.to_string() }),
            "job" => Some(Msg::Job {
                job_id: id_from_json(j.get("job_id")?)?,
                family: j.get("family")?.as_str()?.to_string(),
                channels: j.get("channels")?.as_f64_vec()?.iter().map(|&c| c as usize).collect(),
                iterations: j.get("iterations")?.as_usize()?,
            }),
            "result" => Some(Msg::Result {
                job_id: id_from_json(j.get("job_id")?)?,
                energy_per_iter: j.get("energy_per_iter")?.as_f64()?,
                device_seconds: j.get("device_seconds")?.as_f64()?,
            }),
            "idle" => Some(Msg::Idle),
            "shutdown" => Some(Msg::Shutdown),
            "est" => Some(Msg::EstimateRequest {
                id: id_from_json(j.get("id")?)?,
                device: j.get("device")?.as_str()?.to_string(),
                model: j.get("model")?.as_str()?.to_string(),
            }),
            "est_batch" => Some(Msg::EstimateBatch {
                id: id_from_json(j.get("id")?)?,
                queries: j
                    .get("queries")?
                    .as_arr()?
                    .iter()
                    .map(|q| {
                        Some((
                            q.get("device")?.as_str()?.to_string(),
                            q.get("model")?.as_str()?.to_string(),
                        ))
                    })
                    .collect::<Option<Vec<_>>>()?,
            }),
            "est_ok" => Some(Msg::EstimateReply {
                id: id_from_json(j.get("id")?)?,
                energy_per_iter: j.get("energy_per_iter")?.as_f64()?,
                variance: j.get("variance")?.as_f64()?,
            }),
            "est_batch_ok" => Some(Msg::EstimateBatchReply {
                id: id_from_json(j.get("id")?)?,
                results: j
                    .get("results")?
                    .as_arr()?
                    .iter()
                    .map(|r| match r.get("error") {
                        Some(e) => Some(Err(e.as_str()?.to_string())),
                        None => Some(Ok((
                            r.get("energy_per_iter")?.as_f64()?,
                            r.get("variance")?.as_f64()?,
                        ))),
                    })
                    .collect::<Option<Vec<_>>>()?,
            }),
            "est_err" => Some(Msg::EstimateError {
                id: id_from_json(j.get("id")?)?,
                error: j.get("error")?.as_str()?.to_string(),
            }),
            _ => None,
        }
    }

    pub fn encode(&self) -> String {
        format!("{}\n", self.to_json())
    }

    pub fn decode(line: &str) -> Option<Msg> {
        Json::parse(line.trim()).ok().and_then(|j| Msg::from_json(&j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::rng::Pcg64;

    /// Ids across the whole u64 range: small, near the 2^53 boundary,
    /// and far beyond it — the magnitudes that flushed out the old
    /// `as f64` corruption.
    fn arbitrary_id(r: &mut Pcg64) -> u64 {
        match r.range_usize(0, 3) {
            0 => r.next_u64() % 1_000_000,
            1 => (1u64 << 53).wrapping_add(r.next_u64() % 8).wrapping_sub(4),
            _ => r.next_u64(),
        }
    }

    fn arbitrary_msg(r: &mut Pcg64) -> Msg {
        match r.range_usize(0, 9) {
            0 => Msg::Hello { device: format!("dev{}", r.range_usize(0, 9)) },
            1 => Msg::Job {
                job_id: arbitrary_id(r),
                family: "hid:conv3s1p:h14w14b10:bn-r-mp2".into(),
                channels: (0..r.range_usize(1, 2)).map(|_| r.range_usize(1, 512)).collect(),
                iterations: r.range_usize(1, 1000),
            },
            2 => Msg::Result {
                job_id: arbitrary_id(r),
                energy_per_iter: r.range_f64(1e-6, 10.0),
                device_seconds: r.range_f64(0.0, 100.0),
            },
            3 => Msg::Idle,
            4 => Msg::EstimateRequest {
                id: arbitrary_id(r),
                device: format!("dev{}", r.range_usize(0, 9)),
                model: "cnn5:8,16,32,64".into(),
            },
            5 => Msg::EstimateBatch {
                id: arbitrary_id(r),
                queries: (0..r.range_usize(0, 4))
                    .map(|i| (format!("dev{}", r.range_usize(0, 9)), format!("m{i}")))
                    .collect(),
            },
            6 => Msg::EstimateReply {
                id: arbitrary_id(r),
                energy_per_iter: r.range_f64(1e-6, 10.0),
                variance: r.range_f64(0.0, 1.0),
            },
            7 => Msg::EstimateBatchReply {
                id: arbitrary_id(r),
                results: (0..r.range_usize(0, 4))
                    .map(|i| {
                        if r.range_usize(0, 4) == 0 {
                            Err(format!("no family for query {i}"))
                        } else {
                            Ok((r.range_f64(1e-6, 10.0), r.range_f64(0.0, 1.0)))
                        }
                    })
                    .collect(),
            },
            8 => Msg::EstimateError { id: arbitrary_id(r), error: "boom".into() },
            _ => Msg::Shutdown,
        }
    }

    /// Structural equality with every f64 compared by `to_bits()` — the
    /// contract the module doc promises (shortest-roundtrip bit-exact
    /// transport), strictly stronger than the derived `PartialEq`.
    fn bits_eq(a: &Msg, b: &Msg) -> bool {
        let fe = |x: f64, y: f64| x.to_bits() == y.to_bits();
        match (a, b) {
            (
                Msg::Result { job_id: ai, energy_per_iter: ae, device_seconds: ad },
                Msg::Result { job_id: bi, energy_per_iter: be, device_seconds: bd },
            ) => ai == bi && fe(*ae, *be) && fe(*ad, *bd),
            (
                Msg::EstimateReply { id: ai, energy_per_iter: ae, variance: av },
                Msg::EstimateReply { id: bi, energy_per_iter: be, variance: bv },
            ) => ai == bi && fe(*ae, *be) && fe(*av, *bv),
            (
                Msg::EstimateBatchReply { id: ai, results: ar },
                Msg::EstimateBatchReply { id: bi, results: br },
            ) => {
                ai == bi
                    && ar.len() == br.len()
                    && ar.iter().zip(br).all(|(x, y)| match (x, y) {
                        (Ok((xe, xv)), Ok((ye, yv))) => fe(*xe, *ye) && fe(*xv, *yv),
                        (Err(xm), Err(ym)) => xm == ym,
                        _ => false,
                    })
            }
            _ => a == b,
        }
    }

    #[test]
    fn prop_roundtrip() {
        check("msg json roundtrip", Config { cases: 400, seed: 31 }, arbitrary_msg, |m| {
            let line = m.encode();
            let back = Msg::decode(&line).ok_or("decode failed")?;
            // every id exactly, every float bit-for-bit
            crate::prop_assert!(bits_eq(m, &back), "{m:?} vs {back:?}");
            Ok(())
        });
    }

    #[test]
    fn large_job_ids_roundtrip_exactly() {
        for id in [0, 1, (1 << 53) - 1, 1 << 53, (1 << 53) + 1, u64::MAX - 1, u64::MAX] {
            let m = Msg::Result { job_id: id, energy_per_iter: 1.0, device_seconds: 2.0 };
            let back = Msg::decode(&m.encode()).expect("decode");
            match back {
                Msg::Result { job_id, .. } => assert_eq!(job_id, id, "id corrupted on the wire"),
                other => panic!("wrong variant: {other:?}"),
            }
        }
    }

    #[test]
    fn id_codec_rejects_unsafe_numbers() {
        // A number past 2^53 was rounded before it hit the wire; decoding
        // it would silently alias some other job. Hard error instead.
        assert_eq!(id_from_json(&Json::Num(((1u64 << 53) + 2) as f64)), None);
        assert_eq!(id_from_json(&Json::Num(-1.0)), None);
        assert_eq!(id_from_json(&Json::Num(1.5)), None);
        assert_eq!(id_from_json(&Json::Str("not a number".into())), None);
        // In-range numbers and decimal strings both decode.
        assert_eq!(id_from_json(&Json::Num(42.0)), Some(42));
        assert_eq!(id_from_json(&Json::Str(u64::MAX.to_string())), Some(u64::MAX));
        // Small ids stay plain JSON numbers (wire-compatible with old peers).
        assert!(matches!(id_to_json(7), Json::Num(_)));
        assert!(matches!(id_to_json(u64::MAX), Json::Str(_)));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Msg::decode("{}").is_none());
        assert!(Msg::decode("not json").is_none());
        assert!(Msg::decode(r#"{"type":"job"}"#).is_none()); // missing fields
        assert!(Msg::decode(r#"{"type":"est","id":1,"device":"xavier"}"#).is_none());
    }

    #[test]
    fn capped_reader_matches_read_line_and_rejects_overlong() {
        use std::io::Cursor;
        // Ordinary lines behave exactly like read_line.
        let mut r = Cursor::new(b"hello\nworld\n".to_vec());
        let mut line = String::new();
        assert_eq!(read_line_capped(&mut r, &mut line, 64).unwrap(), 6);
        assert_eq!(line, "hello\n");
        line.clear();
        assert_eq!(read_line_capped(&mut r, &mut line, 64).unwrap(), 6);
        assert_eq!(line, "world\n");
        line.clear();
        assert_eq!(read_line_capped(&mut r, &mut line, 64).unwrap(), 0, "EOF");
        // Mid-line EOF returns the partial line (read_line parity).
        let mut r = Cursor::new(b"partial".to_vec());
        line.clear();
        assert_eq!(read_line_capped(&mut r, &mut line, 64).unwrap(), 7);
        assert_eq!(line, "partial");
        // A newline-less stream past the cap errors instead of buffering.
        let mut r = Cursor::new(vec![b'x'; 1000]);
        line.clear();
        let err = read_line_capped(&mut r, &mut line, 100).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // A line whose newline lands past the cap errors too.
        let mut long = vec![b'y'; 200];
        long.push(b'\n');
        let mut r = Cursor::new(long);
        line.clear();
        assert!(read_line_capped(&mut r, &mut line, 100).is_err());
        // Invalid UTF-8 is a framing error, not a panic.
        let mut r = Cursor::new(vec![0xff, 0xfe, b'\n']);
        line.clear();
        assert!(read_line_capped(&mut r, &mut line, 64).is_err());
    }

    #[test]
    fn frame_buf_reassembles_lines_across_arbitrary_splits() {
        // Every split point of a two-line stream must yield the same
        // two lines — the whole point of incremental framing.
        let stream = b"{\"type\":\"idle\"}\n{\"type\":\"shutdown\"}\n";
        for cut in 0..=stream.len() {
            let mut fb = FrameBuf::new(MAX_LINE_BYTES);
            fb.push(&stream[..cut]);
            let mut lines = Vec::new();
            while let Some(l) = fb.next_line().unwrap() {
                lines.push(l);
            }
            fb.push(&stream[cut..]);
            while let Some(l) = fb.next_line().unwrap() {
                lines.push(l);
            }
            assert_eq!(lines.len(), 2, "cut at {cut}");
            assert_eq!(lines[0], "{\"type\":\"idle\"}\n");
            assert_eq!(lines[1], "{\"type\":\"shutdown\"}\n");
            assert!(!fb.has_partial());
        }
    }

    #[test]
    fn frame_buf_cap_matches_blocking_reader_semantics() {
        // Terminated line whose bytes incl. newline exceed the cap.
        let mut fb = FrameBuf::new(8);
        fb.push(b"123456789\n");
        assert_eq!(fb.next_line(), Err(FrameError::TooLong));
        // Exactly at the cap is fine (7 chars + newline = 8).
        let mut fb = FrameBuf::new(8);
        fb.push(b"1234567\n");
        assert_eq!(fb.next_line().unwrap().as_deref(), Some("1234567\n"));
        // A newline-less tail trips the cap as soon as it alone exceeds
        // it — bounded memory even if the newline never comes.
        let mut fb = FrameBuf::new(8);
        fb.push(b"12345");
        assert_eq!(fb.next_line(), Ok(None));
        assert!(fb.has_partial());
        fb.push(b"6789");
        assert_eq!(fb.next_line(), Err(FrameError::TooLong));
    }

    #[test]
    fn frame_buf_rejects_invalid_utf8_lines() {
        let mut fb = FrameBuf::new(64);
        fb.push(&[0xff, 0xfe, b'\n', b'o', b'k', b'\n']);
        assert_eq!(fb.next_line(), Err(FrameError::Utf8));
        // The bad line was consumed; the connection would be dropped
        // anyway, but the buffer stays coherent.
        assert_eq!(fb.next_line().unwrap().as_deref(), Some("ok\n"));
    }

    #[test]
    fn frame_buf_pipelined_burst_decodes_in_order() {
        // Many messages in one push — the pipelined-client shape.
        let mut fb = FrameBuf::new(MAX_LINE_BYTES);
        let mut wire = String::new();
        for id in 0..64u64 {
            wire.push_str(&Msg::EstimateRequest {
                id,
                device: "xavier".into(),
                model: "cnn5:8,16,32,64:16".into(),
            }
            .encode());
        }
        fb.push(wire.as_bytes());
        for id in 0..64u64 {
            let line = fb.next_line().unwrap().expect("a full line per message");
            match Msg::decode(&line) {
                Some(Msg::EstimateRequest { id: got, .. }) => assert_eq!(got, id),
                other => panic!("bad decode: {other:?}"),
            }
        }
        assert_eq!(fb.next_line(), Ok(None));
    }

    #[test]
    fn estimate_request_wire_shape() {
        let m = Msg::EstimateRequest { id: 3, device: "xavier".into(), model: "cnn5".into() };
        let line = m.encode();
        assert!(line.contains(r#""type":"est""#), "{line}");
        assert!(line.ends_with('\n'), "newline-delimited framing");
        assert_eq!(Msg::decode(&line), Some(m));
    }
}
