//! Wire protocol: one JSON object per line.
//!
//! Numbers travel through [`crate::util::json`], whose f64 formatting
//! is shortest-roundtrip — a `Result`'s energy reaches the leader with
//! the exact bit pattern the worker measured, which the cross-backend
//! store byte-equality (`rust/tests/backend_equiv.rs`) depends on.
//! Batched acquisition needs no protocol change: a batch is just
//! several in-flight `Job`s at once.  Heterogeneous fleets need none
//! either: `Hello::device` **is** the worker's device class — the
//! leader's routing key ([`crate::coordinator::scheduler::JobQueue`]
//! assigns same-class only), so a `Job` never names a device (the
//! receiving worker is, by routing, of the right class).

use crate::util::json::Json;

/// Protocol messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker → server: registration; `device` is the worker's device
    /// class — the leader's class-scoped routing key.
    Hello { device: String },
    /// server → worker: measure a variant (channels on the *raw* scale).
    Job { job_id: u64, family: String, channels: Vec<usize>, iterations: usize },
    /// worker → server: measurement result.
    Result { job_id: u64, energy_per_iter: f64, device_seconds: f64 },
    /// server → worker: nothing to do right now.
    Idle,
    /// server → worker: profiling finished; worker exits.
    Shutdown,
}

impl Msg {
    pub fn to_json(&self) -> Json {
        match self {
            Msg::Hello { device } => Json::obj(vec![
                ("type", Json::str("hello")),
                ("device", Json::str(device)),
            ]),
            Msg::Job { job_id, family, channels, iterations } => Json::obj(vec![
                ("type", Json::str("job")),
                ("job_id", Json::Num(*job_id as f64)),
                ("family", Json::str(family)),
                ("channels", Json::arr_f64(&channels.iter().map(|&c| c as f64).collect::<Vec<_>>())),
                ("iterations", Json::Num(*iterations as f64)),
            ]),
            Msg::Result { job_id, energy_per_iter, device_seconds } => Json::obj(vec![
                ("type", Json::str("result")),
                ("job_id", Json::Num(*job_id as f64)),
                ("energy_per_iter", Json::Num(*energy_per_iter)),
                ("device_seconds", Json::Num(*device_seconds)),
            ]),
            Msg::Idle => Json::obj(vec![("type", Json::str("idle"))]),
            Msg::Shutdown => Json::obj(vec![("type", Json::str("shutdown"))]),
        }
    }

    pub fn from_json(j: &Json) -> Option<Msg> {
        match j.get("type")?.as_str()? {
            "hello" => Some(Msg::Hello { device: j.get("device")?.as_str()?.to_string() }),
            "job" => Some(Msg::Job {
                job_id: j.get("job_id")?.as_f64()? as u64,
                family: j.get("family")?.as_str()?.to_string(),
                channels: j.get("channels")?.as_f64_vec()?.iter().map(|&c| c as usize).collect(),
                iterations: j.get("iterations")?.as_usize()?,
            }),
            "result" => Some(Msg::Result {
                job_id: j.get("job_id")?.as_f64()? as u64,
                energy_per_iter: j.get("energy_per_iter")?.as_f64()?,
                device_seconds: j.get("device_seconds")?.as_f64()?,
            }),
            "idle" => Some(Msg::Idle),
            "shutdown" => Some(Msg::Shutdown),
            _ => None,
        }
    }

    pub fn encode(&self) -> String {
        format!("{}\n", self.to_json())
    }

    pub fn decode(line: &str) -> Option<Msg> {
        Json::parse(line.trim()).ok().and_then(|j| Msg::from_json(&j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::rng::Pcg64;

    fn arbitrary_msg(r: &mut Pcg64) -> Msg {
        match r.range_usize(0, 4) {
            0 => Msg::Hello { device: format!("dev{}", r.range_usize(0, 9)) },
            1 => Msg::Job {
                job_id: r.next_u64() % 1_000_000,
                family: "hid:conv3s1p:h14w14b10:bn-r-mp2".into(),
                channels: (0..r.range_usize(1, 2)).map(|_| r.range_usize(1, 512)).collect(),
                iterations: r.range_usize(1, 1000),
            },
            2 => Msg::Result {
                job_id: r.next_u64() % 1_000_000,
                energy_per_iter: r.range_f64(1e-6, 10.0),
                device_seconds: r.range_f64(0.0, 100.0),
            },
            3 => Msg::Idle,
            _ => Msg::Shutdown,
        }
    }

    #[test]
    fn prop_roundtrip() {
        check("msg json roundtrip", Config { cases: 200, seed: 31 }, arbitrary_msg, |m| {
            let line = m.encode();
            let back = Msg::decode(&line).ok_or("decode failed")?;
            // floats survive with full precision through our writer
            match (m, &back) {
                (Msg::Result { energy_per_iter: a, .. }, Msg::Result { energy_per_iter: b, .. }) => {
                    crate::prop_assert!((a - b).abs() < 1e-12 * a.abs().max(1.0), "{a} vs {b}");
                }
                _ => crate::prop_assert!(m == &back, "{m:?} vs {back:?}"),
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_malformed() {
        assert!(Msg::decode("{}").is_none());
        assert!(Msg::decode("not json").is_none());
        assert!(Msg::decode(r#"{"type":"job"}"#).is_none()); // missing fields
    }
}
