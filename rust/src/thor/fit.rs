//! Active-learning fitting loop (paper §3.3): start from the channel
//! bounds, then repeatedly profile the candidates with the largest GP
//! posterior variance, until the paper's end conditions fire: point
//! budget exhausted, or max posterior std < 5 % of the data scale.
//!
//! On devices without real-time energy readout the paper uses *time*
//! uncertainty as the acquisition surrogate (justified by the Fig-6
//! time↔energy correlation); `FitConfig::time_surrogate` enables that
//! path — the energy GP is still the estimation output.
//!
//! # Batched acquisition
//!
//! Each GP round proposes the top-`FitConfig::batch` candidates by
//! acquisition value (posterior std, descending) instead of one, so a
//! parallel backend (the fleet) runs `batch` measurement jobs
//! concurrently.  Results fold back into the point set in proposal
//! (declaration) order, so the fitted GP is a pure function of the
//! config — and at `batch = Fixed(1)` the whole loop is
//! **bit-identical** to the sequential pre-refactor loop (asserted by a
//! reference implementation in this module's tests).
//!
//! [`Batch::Auto`] sizes each round from the backend's live same-class
//! worker count instead of a fixed k (occupancy-adaptive batching): a
//! heterogeneous fleet keeps every class saturated without the caller
//! pre-computing per-class batch sizes.  While occupancy holds constant
//! at k, `Auto` is bit-identical to `Fixed(k)` (asserted below).
//!
//! # Resumable engine
//!
//! The loop is implemented as the [`FamilyFit`] state machine
//! (`propose` → `absorb` → … → `finish`) so a multi-device driver
//! ([`crate::thor::pipeline::Thor::profile`]) can interleave the
//! acquisition rounds of *several* (device, family) fits into joint
//! measurement batches — one class need not finish before another
//! starts.  [`fit_family_with`] is the single-fit driver over the same
//! machine and is bit-identical to the pre-machine loop.

use crate::gp::acquisition::{top_k_variance, AcquireBatch, CandidateGrid};
use crate::gp::{FitWorkspace, GpBackend, GpHyper, GpModel, KernelKind};
use crate::thor::measure::MeasureError;

/// Acquisition batch sizing policy (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Batch {
    /// Exactly this many proposals per GP round (min 1).
    Fixed(usize),
    /// Size each round from the measuring backend's live same-class
    /// worker count ([`crate::thor::measure::Measurer::occupancy`]).
    /// Backends without a worker notion (scalar closures, the local
    /// simulator) resolve to 1.
    Auto,
}

impl Batch {
    /// Proposals for one round at the given occupancy (both floored
    /// at 1 — a live fleet never has occupancy 0 for a scheduled
    /// class, and a zero batch would stall the loop).
    pub fn size(self, occupancy: usize) -> usize {
        match self {
            Batch::Fixed(k) => k.max(1),
            Batch::Auto => occupancy.max(1),
        }
    }

    /// Parse a CLI value: `auto` or a positive integer.
    pub fn parse(s: &str) -> Result<Self, String> {
        if s.eq_ignore_ascii_case("auto") {
            return Ok(Batch::Auto);
        }
        s.parse::<usize>()
            .map(|k| Batch::Fixed(k.max(1)))
            .map_err(|_| format!("invalid batch '{s}' (expected a positive integer or 'auto')"))
    }
}

impl Default for Batch {
    fn default() -> Self {
        Batch::Fixed(1)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct FitConfig {
    pub kind: KernelKind,
    /// Point budget (end condition 1).
    pub max_points: usize,
    /// Convergence threshold as a fraction of mean |y| (end condition 2,
    /// the paper's 5 %).
    pub threshold_frac: f64,
    /// Candidate grid resolution per dimension.
    pub grid_n: usize,
    /// Use time variance to steer acquisition (phones).
    pub time_surrogate: bool,
    /// Select points randomly instead of by max variance (the A15
    /// "Random" ablation arm).
    pub random_sampling: bool,
    /// Fit the GP on ln(energy) (and ln(time)).  Energy spans orders of
    /// magnitude across the channel range with curvature concentrated at
    /// the narrow end; log targets make GP residuals *relative* errors
    /// and stop mean-reversion from inflating narrow-layer estimates.
    /// Convergence then reads `threshold_frac` as an absolute log-std,
    /// i.e. directly as the paper's 5 % relative criterion.
    pub log_targets: bool,
    /// Measurement requests proposed per GP round (top-k acquisition).
    /// `Fixed(1)` reproduces the sequential loop bit-for-bit; fleet runs
    /// want `Fixed(worker count)` or `Auto` so every worker stays busy.
    pub batch: Batch,
    /// GP fit backend: exact Cholesky, sparse inducing-point, or the
    /// default `Auto` crossover (exact below its n-threshold, so per-family
    /// acquisition fits — capped at `gp::MAX_POINTS` — stay bit-identical
    /// to the historical exact path).
    pub backend: GpBackend,
    pub seed: u64,
}

impl Default for FitConfig {
    fn default() -> Self {
        Self {
            kind: KernelKind::Matern52,
            max_points: 24,
            threshold_frac: 0.05,
            grid_n: 17,
            time_surrogate: false,
            random_sampling: false,
            log_targets: true,
            batch: Batch::Fixed(1),
            backend: GpBackend::default(),
            seed: 17,
        }
    }
}

/// Outcome of fitting one layer family.
pub struct FitOutcome {
    /// Energy GP over normalized features (targets in ln(J) when
    /// `log_targets` was set — [`crate::thor::store::StoredGp`] records
    /// the transform).
    pub gp: GpModel,
    /// Profiled (normalized point, energy, time) observations.
    pub points: Vec<(Vec<f64>, f64, f64)>,
    /// Simulated device-seconds spent profiling (Table 1 numerator).
    pub device_seconds: f64,
    /// Leader-side fitting wall-clock seconds (Table 1 addend).
    pub fit_seconds: f64,
    pub converged: bool,
}

/// Fit one family over a *scalar* measurement closure:
/// `measure(normalized_point) -> (energy_per_iter J, device_seconds)`;
/// `dim` is 1 or 2.  Thin wrapper over [`fit_family_with`] — batched
/// proposals are measured by calling the closure once per point in
/// proposal order, so a stateful closure sees the exact request stream
/// the sequential loop produced at `batch = 1`.
pub fn fit_family(
    mut measure: impl FnMut(&[f64]) -> (f64, f64),
    dim: usize,
    cfg: &FitConfig,
) -> FitOutcome {
    fit_family_with(
        |ps: &[Vec<f64>]| Ok(ps.iter().map(|p| measure(p)).collect()),
        dim,
        cfg,
    )
    .expect("scalar measurement closures are infallible")
}

/// Fit one family over a *batch* measurement function:
/// `measure_batch(normalized_points) -> one (energy J/iter,
/// device_seconds) per point, in request order`.  Single-fit driver
/// over the [`FamilyFit`] state machine — the engine single-backend
/// callers run; it errors only when the backend does.  Occupancy is
/// pinned at 1 (a closure has no worker notion), so `Batch::Auto`
/// behaves like `Fixed(1)` here; multi-device drivers feed live
/// occupancy per round instead.
pub fn fit_family_with<F>(mut measure_batch: F, dim: usize, cfg: &FitConfig) -> Result<FitOutcome, MeasureError>
where
    F: FnMut(&[Vec<f64>]) -> Result<Vec<(f64, f64)>, MeasureError>,
{
    let mut fit = FamilyFit::new(dim, cfg);
    while let Some(ps) = fit.propose(1) {
        let results = measure_batch(&ps)?;
        assert_eq!(results.len(), ps.len(), "backend returned wrong batch size");
        fit.absorb(&results);
    }
    Ok(fit.finish())
}

/// Resumable acquisition state machine for one (device, family) fit.
///
/// Protocol: alternate [`FamilyFit::propose`] (get the next batch of
/// normalized points to measure — the starts first, then one GP round
/// per call) with [`FamilyFit::absorb`] (fold the measurements back, in
/// proposal order).  When `propose` returns `None` the fit has hit an
/// end condition; [`FamilyFit::finish`] then fits the final energy GP.
///
/// The machine performs *exactly* the operation sequence of the
/// pre-refactor closed loop — same RNG draws, same workspace reuse,
/// same warm-start keys — so driving it with `occupancy = 1` and a
/// `Fixed` batch is bit-identical to the code it replaced (asserted
/// against a verbatim reference copy in this module's tests).  Several
/// machines for *different* devices can be advanced in lock-step and
/// their proposals measured in one joint batch: each machine's stream
/// depends only on its own absorbed results, so interleaving classes
/// never perturbs a class's fit
/// ([`crate::thor::pipeline::Thor::profile`] relies on this for
/// heterogeneous fleets).
pub struct FamilyFit {
    cfg: FitConfig,
    grid: CandidateGrid,
    pts: Vec<(Vec<f64>, f64, f64)>,
    device_seconds: f64,
    rng: crate::util::rng::Pcg64,
    // §Perf: one workspace carries the pairwise-distance cache and the
    // gram/Cholesky buffers across every refit of this fit; after the
    // first full multi-start fit, each round does a warm single-start
    // refit seeded from the previous round's hypers.
    ws: FitWorkspace,
    prev_hyper: Option<GpHyper>,
    converged: bool,
    /// Proposals handed out by the last `propose`, awaiting `absorb`.
    pending: Option<Vec<Vec<f64>>>,
    /// Occupancy passed to the `propose` that produced `pending`.
    pending_occ: usize,
    /// Absorbed-round history `(occupancy, folded results)` — with
    /// `(dim, cfg)` a complete serializable description of the machine's
    /// state, because every internal bit (RNG stream, warm-start chain,
    /// workspace caches, acquired points) is a pure function of the
    /// occupancy and result sequences.  See [`FamilyFit::replay`].
    journal: Vec<(usize, Vec<(f64, f64)>)>,
    started: bool,
    ended: bool,
    t0: std::time::Instant,
}

impl FamilyFit {
    /// `dim` is 1 or 2.
    pub fn new(dim: usize, cfg: &FitConfig) -> Self {
        let grid = match dim {
            1 => CandidateGrid::dim1(0.0, 1.0, cfg.grid_n),
            2 => CandidateGrid::dim2(0.0, 1.0, cfg.grid_n),
            d => panic!("unsupported family dim {d}"),
        };
        Self {
            cfg: *cfg,
            grid,
            pts: Vec::new(),
            device_seconds: 0.0,
            rng: crate::util::rng::Pcg64::new(cfg.seed),
            ws: FitWorkspace::new(),
            prev_hyper: None,
            converged: false,
            pending: None,
            pending_occ: 1,
            journal: Vec::new(),
            started: false,
            ended: false,
            t0: std::time::Instant::now(),
        }
    }

    fn dim(&self) -> usize {
        self.grid.points.first().map_or(1, |p| p.len())
    }

    fn tf(&self, v: f64) -> f64 {
        if self.cfg.log_targets {
            v.max(1e-15).ln()
        } else {
            v
        }
    }

    /// Normalized points to measure next, or `None` once an end
    /// condition fired (budget, convergence, degenerate GP).  The first
    /// call returns the starting points (the channel bounds + center —
    /// one natural batch needing no GP round between them); later calls
    /// run one GP round and propose up to `batch.size(occupancy)`
    /// top-variance candidates, clamped to the remaining point budget.
    /// Must not be called with an un-`absorb`ed batch outstanding.
    pub fn propose(&mut self, occupancy: usize) -> Option<Vec<Vec<f64>>> {
        assert!(self.pending.is_none(), "propose() with measurements outstanding");
        if self.ended {
            return None;
        }
        self.pending_occ = occupancy;
        if !self.started {
            self.started = true;
            // Starting points: the bounds (paper: "we use the upper and
            // lower bounds as the starting points") plus one center
            // point so the first GP fit has curvature signal.
            let dim = self.dim();
            let mut starts: Vec<Vec<f64>> = match dim {
                1 => vec![vec![0.0], vec![1.0]],
                _ => vec![vec![0.0, 0.0], vec![0.0, 1.0], vec![1.0, 0.0], vec![1.0, 1.0]],
            };
            starts.push(vec![0.5; dim]);
            self.pending = Some(starts.clone());
            return Some(starts);
        }
        let cfg = self.cfg;
        if self.pts.len() >= cfg.max_points {
            self.ended = true;
            return None;
        }
        let xs: Vec<Vec<f64>> = self.pts.iter().map(|p| p.0.clone()).collect();
        let es: Vec<f64> = self.pts.iter().map(|p| self.tf(p.1)).collect();
        let ts: Vec<f64> = self.pts.iter().map(|p| self.tf(p.2)).collect();

        // Acquisition target: energy GP, or the time GP surrogate.
        let acq_ys = if cfg.time_surrogate { &ts } else { &es };
        let fitted = match self.prev_hyper {
            Some(h) => GpModel::fit_warm_b(&mut self.ws, cfg.kind, xs.clone(), acq_ys, h, cfg.backend),
            None => GpModel::fit_b(&mut self.ws, cfg.kind, xs.clone(), acq_ys, cfg.backend),
        };
        let Some(acq_gp) = fitted else {
            self.ended = true;
            return None;
        };
        self.prev_hyper = Some(acq_gp.hyper);
        // With log targets, a posterior std of s is a relative error of
        // ~s, so the 5 % criterion compares the std against 1.0.
        let y_abs = if cfg.log_targets {
            1.0
        } else {
            crate::util::stats::mean(&acq_ys.iter().map(|y| y.abs()).collect::<Vec<_>>())
        };

        // Up to one batch of proposals this round, clamped to the
        // remaining point budget.
        let k = cfg.batch.size(occupancy).min(cfg.max_points - self.pts.len());
        let next: Vec<Vec<f64>> = if cfg.random_sampling {
            // A15 ablation arm: uniform-random unprofiled grid points
            // (indices only; clone just the drawn points).
            let mut free: Vec<usize> = self
                .grid
                .points
                .iter()
                .enumerate()
                .filter(|(_, q)| !xs.iter().any(|x| crate::gp::kernel::dist(x, q) < 1e-9))
                .map(|(i, _)| i)
                .collect();
            if free.is_empty() {
                self.converged = true;
                self.ended = true;
                return None;
            }
            let draws = k.min(free.len());
            (0..draws)
                .map(|_| {
                    let i = free.swap_remove(self.rng.range_usize(0, free.len() - 1));
                    self.grid.points[i].clone()
                })
                .collect()
        } else {
            match top_k_variance(&acq_gp, &self.grid, cfg.threshold_frac, y_abs, k) {
                AcquireBatch::Next(ps) => ps.into_iter().map(|(p, _)| p).collect(),
                AcquireBatch::Converged(_) => {
                    self.converged = true;
                    self.ended = true;
                    return None;
                }
            }
        };
        if next.is_empty() {
            self.ended = true;
            return None;
        }
        self.pending = Some(next.clone());
        Some(next)
    }

    /// Fold one batch of measurements — `results[i]` answers point `i`
    /// of the last [`FamilyFit::propose`] — in proposal order.
    pub fn absorb(&mut self, results: &[(f64, f64)]) {
        let ps = self.pending.take().expect("absorb() without a proposed batch");
        assert_eq!(results.len(), ps.len(), "backend returned wrong batch size");
        self.journal.push((self.pending_occ, results.to_vec()));
        for (p, &(e, dt)) in ps.into_iter().zip(results) {
            self.device_seconds += dt;
            self.pts.push((p, e, dt));
        }
    }

    /// The absorbed-round history: one `(occupancy, folded results)`
    /// entry per absorbed batch, in order.  Proposed-but-unabsorbed
    /// points are deliberately *not* recorded: after a crash they are
    /// re-proposed identically by the replayed machine, so they are the
    /// only measurements a resumed run repeats.
    pub fn journal(&self) -> &[(usize, Vec<(f64, f64)>)] {
        &self.journal
    }

    /// Reconstruct a machine bit-identically from an absorbed-round
    /// journal (the leader-checkpoint resume path): a fresh machine is
    /// driven through the recorded `(occupancy, results)` sequence, which
    /// regenerates the proposals — and with them the RNG stream, the
    /// warm-start hyper chain, and the workspace caches — exactly as the
    /// original run produced them.  The next `propose` of the returned
    /// machine is bit-identical to what the original machine would have
    /// proposed (pinned in this module's tests).
    ///
    /// Panics if the journal is inconsistent with `(dim, cfg)` — e.g. a
    /// round whose result count does not match the re-proposed batch, or
    /// more rounds than the machine's end conditions admit.  A journal
    /// produced by [`FamilyFit::journal`] under the same config never is.
    pub fn replay(dim: usize, cfg: &FitConfig, journal: &[(usize, Vec<(f64, f64)>)]) -> Self {
        let mut fit = Self::new(dim, cfg);
        for (occ, results) in journal {
            let ps = fit
                .propose(*occ)
                .expect("checkpoint journal extends past the machine's end conditions");
            assert_eq!(
                ps.len(),
                results.len(),
                "checkpoint journal round does not match the re-proposed batch"
            );
            fit.absorb(results);
        }
        fit
    }

    /// Fit the final energy GP over everything absorbed.
    pub fn finish(mut self) -> FitOutcome {
        assert!(self.pending.is_none(), "finish() with measurements outstanding");
        let cfg = self.cfg;
        let xs: Vec<Vec<f64>> = self.pts.iter().map(|p| p.0.clone()).collect();
        let es: Vec<f64> = self.pts.iter().map(|p| self.tf(p.1)).collect();
        // Final energy GP: warm from the loop's last energy-GP hypers.
        // In surrogate mode the loop fitted the *time* GP, so the energy
        // surface gets a full multi-start search instead.
        let gp = match self.prev_hyper {
            Some(h) if !cfg.time_surrogate => {
                GpModel::fit_warm_b(&mut self.ws, cfg.kind, xs, &es, h, cfg.backend)
            }
            _ => GpModel::fit_b(&mut self.ws, cfg.kind, xs, &es, cfg.backend),
        }
        .expect("final GP fit failed");
        FitOutcome {
            gp,
            points: self.pts,
            device_seconds: self.device_seconds,
            fit_seconds: self.t0.elapsed().as_secs_f64(),
            converged: self.converged,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smooth synthetic energy surface with a plateau (mimicking the
    /// occupancy shapes the simulator produces).
    fn surface_1d(x: f64) -> f64 {
        100.0 + 60.0 * (x * 3.0).min(1.2) + 25.0 * (4.0 * x).sin().max(0.0)
    }

    #[test]
    fn converges_on_smooth_surface() {
        let mut n = 0;
        let out = fit_family(
            |p| {
                n += 1;
                (surface_1d(p[0]), 0.5)
            },
            1,
            &FitConfig { max_points: 32, grid_n: 33, ..Default::default() },
        );
        assert!(out.points.len() >= 3);
        // prediction error small on a dense check grid
        let mut worst: f64 = 0.0;
        for i in 0..=20 {
            let x = i as f64 / 20.0;
            // default FitConfig fits ln(energy): exponentiate back
            let (m, _) = out.gp.predict(&[x]);
            worst = worst.max(((m.exp() - surface_1d(x)) / surface_1d(x)).abs());
        }
        assert!(worst < 0.15, "worst rel err {worst}");
        assert_eq!(n, out.points.len());
    }

    #[test]
    fn respects_point_budget() {
        let out = fit_family(
            |p| (surface_1d(p[0]) + p[0].sin() * 57.0, 0.1), // wiggly: won't converge fast
            1,
            &FitConfig { max_points: 8, threshold_frac: 0.0001, ..Default::default() },
        );
        assert!(out.points.len() <= 8);
        assert!(!out.converged);
    }

    #[test]
    fn guided_beats_random_on_budget() {
        // The A15 claim: guided profiling fits better than random
        // selection at equal budget (averaged over seeds).
        let surface = |p: &[f64]| 50.0 + 100.0 / (1.0 + (-12.0 * (p[0] - 0.7)).exp());
        let eval = |cfg: &FitConfig| {
            let out = fit_family(|p| (surface(p), 0.1), 1, cfg);
            let mut err = 0.0;
            for i in 0..=40 {
                let x = i as f64 / 40.0;
                err += (out.gp.predict(&[x]).0.exp() - surface(&[x])).abs();
            }
            err
        };
        let mut guided = 0.0;
        let mut random = 0.0;
        for seed in 0..5 {
            let base = FitConfig { max_points: 10, threshold_frac: 0.0, grid_n: 41, seed, ..Default::default() };
            guided += eval(&base);
            random += eval(&FitConfig { random_sampling: true, ..base });
        }
        assert!(guided < random, "guided {guided} vs random {random}");
    }

    #[test]
    fn dim2_fits_separable_surface() {
        let f = |p: &[f64]| 10.0 + 5.0 * p[0] + 3.0 * p[1] * p[1];
        let out = fit_family(|p| (f(p), 0.2), 2, &FitConfig { max_points: 30, grid_n: 9, ..Default::default() });
        let (m, _) = out.gp.predict(&[0.5, 0.5]);
        assert!((m.exp() - f(&[0.5, 0.5])).abs() < 1.0, "{}", m.exp());
    }

    #[test]
    fn fit_family_is_deterministic() {
        // Warm-start refits are pure functions of the observed points:
        // two identical runs must agree bit-for-bit (the suite-JSON
        // byte-identity contract leans on this).
        let run = || {
            fit_family(
                |p| (surface_1d(p[0]), 0.5),
                1,
                &FitConfig { max_points: 12, grid_n: 17, ..Default::default() },
            )
        };
        let (a, b) = (run(), run());
        assert_eq!(a.points.len(), b.points.len());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.0, pb.0);
            assert_eq!(pa.1.to_bits(), pb.1.to_bits());
        }
        for i in 0..=10 {
            let q = [i as f64 / 10.0];
            let (m1, v1) = a.gp.predict(&q);
            let (m2, v2) = b.gp.predict(&q);
            assert_eq!((m1.to_bits(), v1.to_bits()), (m2.to_bits(), v2.to_bits()));
        }
    }

    #[test]
    fn device_seconds_accumulate() {
        let out = fit_family(|_| (100.0, 2.5), 1, &FitConfig { max_points: 6, threshold_frac: 0.0, ..Default::default() });
        assert!((out.device_seconds - 2.5 * out.points.len() as f64).abs() < 1e-9);
    }

    /// Verbatim copy of the *pre-refactor* sequential acquisition loop
    /// (one max-variance proposal per round, scalar measure calls) — the
    /// oracle proving `fit_family` at `batch = 1` is bit-identical to
    /// the code it replaced.
    fn scalar_reference_fit(
        mut measure: impl FnMut(&[f64]) -> (f64, f64),
        dim: usize,
        cfg: &FitConfig,
    ) -> FitOutcome {
        use crate::gp::acquisition::{max_variance, Acquire};
        let grid = match dim {
            1 => CandidateGrid::dim1(0.0, 1.0, cfg.grid_n),
            2 => CandidateGrid::dim2(0.0, 1.0, cfg.grid_n),
            d => panic!("unsupported family dim {d}"),
        };
        let mut starts: Vec<Vec<f64>> = match dim {
            1 => vec![vec![0.0], vec![1.0]],
            _ => vec![vec![0.0, 0.0], vec![0.0, 1.0], vec![1.0, 0.0], vec![1.0, 1.0]],
        };
        starts.push(vec![0.5; dim]);
        let mut pts: Vec<(Vec<f64>, f64, f64)> = Vec::new();
        let mut device_seconds = 0.0;
        for p in starts {
            let (e, dt) = measure(&p);
            device_seconds += dt;
            pts.push((p, e, dt));
        }
        let mut rng = crate::util::rng::Pcg64::new(cfg.seed);
        let mut converged = false;
        let mut ws = FitWorkspace::new();
        let mut prev_hyper: Option<GpHyper> = None;
        loop {
            if pts.len() >= cfg.max_points {
                break;
            }
            let xs: Vec<Vec<f64>> = pts.iter().map(|p| p.0.clone()).collect();
            let tf = |v: f64| if cfg.log_targets { v.max(1e-15).ln() } else { v };
            let es: Vec<f64> = pts.iter().map(|p| tf(p.1)).collect();
            let ts: Vec<f64> = pts.iter().map(|p| tf(p.2)).collect();
            let acq_ys = if cfg.time_surrogate { &ts } else { &es };
            let fitted = match prev_hyper {
                Some(h) => GpModel::fit_warm(&mut ws, cfg.kind, xs.clone(), acq_ys, h),
                None => GpModel::fit_with(&mut ws, cfg.kind, xs.clone(), acq_ys),
            };
            let Some(acq_gp) = fitted else { break };
            prev_hyper = Some(acq_gp.hyper);
            let y_abs = if cfg.log_targets {
                1.0
            } else {
                crate::util::stats::mean(&acq_ys.iter().map(|y| y.abs()).collect::<Vec<_>>())
            };
            let next = if cfg.random_sampling {
                let free: Vec<&Vec<f64>> = grid
                    .points
                    .iter()
                    .filter(|q| !xs.iter().any(|x| crate::gp::kernel::dist(x, q) < 1e-9))
                    .collect();
                if free.is_empty() {
                    converged = true;
                    break;
                }
                Some(free[rng.range_usize(0, free.len() - 1)].clone())
            } else {
                match max_variance(&acq_gp, &grid, cfg.threshold_frac, y_abs) {
                    Acquire::Next(p, _) => Some(p),
                    Acquire::Converged(_) => {
                        converged = true;
                        break;
                    }
                }
            };
            let Some(p) = next else { break };
            let (e, dt) = measure(&p);
            device_seconds += dt;
            pts.push((p, e, dt));
        }
        let xs: Vec<Vec<f64>> = pts.iter().map(|p| p.0.clone()).collect();
        let tf = |v: f64| if cfg.log_targets { v.max(1e-15).ln() } else { v };
        let es: Vec<f64> = pts.iter().map(|p| tf(p.1)).collect();
        let gp = match prev_hyper {
            Some(h) if !cfg.time_surrogate => GpModel::fit_warm(&mut ws, cfg.kind, xs, &es, h),
            _ => GpModel::fit_with(&mut ws, cfg.kind, xs, &es),
        }
        .expect("final GP fit failed");
        FitOutcome { gp, points: pts, device_seconds, fit_seconds: 0.0, converged }
    }

    fn assert_outcomes_bit_equal(a: &FitOutcome, b: &FitOutcome, dim: usize) {
        assert_eq!(a.points.len(), b.points.len());
        assert_eq!(a.converged, b.converged);
        assert_eq!(a.device_seconds.to_bits(), b.device_seconds.to_bits());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.0, pb.0);
            assert_eq!(pa.1.to_bits(), pb.1.to_bits());
            assert_eq!(pa.2.to_bits(), pb.2.to_bits());
        }
        for i in 0..=10 {
            let q = vec![i as f64 / 10.0; dim];
            let (m1, v1) = a.gp.predict(&q);
            let (m2, v2) = b.gp.predict(&q);
            assert_eq!((m1.to_bits(), v1.to_bits()), (m2.to_bits(), v2.to_bits()), "q {q:?}");
        }
    }

    #[test]
    fn batch_size_1_is_bit_identical_to_prerefactor_scalar_loop() {
        // Guided, random, and time-surrogate arms, 1-D and 2-D — every
        // path must reproduce the sequential loop exactly at batch = 1.
        let surface = |p: &[f64]| {
            100.0 + 60.0 * (p[0] * 3.0).min(1.2) + 25.0 * (4.0 * p[0]).sin().max(0.0)
                + p.get(1).map_or(0.0, |y| 12.0 * y * y)
        };
        let configs = [
            (1usize, FitConfig { max_points: 12, grid_n: 17, ..Default::default() }),
            (1, FitConfig { max_points: 10, grid_n: 17, random_sampling: true, threshold_frac: 0.0, ..Default::default() }),
            (1, FitConfig { max_points: 12, grid_n: 17, time_surrogate: true, ..Default::default() }),
            (2, FitConfig { max_points: 14, grid_n: 7, ..Default::default() }),
        ];
        for (dim, cfg) in configs {
            assert_eq!(cfg.batch, Batch::Fixed(1));
            let batched = fit_family(|p| (surface(p), surface(p) / 3.0), dim, &cfg);
            let reference = scalar_reference_fit(|p| (surface(p), surface(p) / 3.0), dim, &cfg);
            assert_outcomes_bit_equal(&batched, &reference, dim);
        }
    }

    #[test]
    fn batched_rounds_respect_budget_and_fold_in_proposal_order() {
        // batch = 3 with threshold 0: rounds of 3 until the budget.
        let mut calls: Vec<usize> = Vec::new();
        let out = fit_family_with(
            |ps| {
                calls.push(ps.len());
                Ok(ps.iter().map(|p| (surface_1d(p[0]), 0.5)).collect())
            },
            1,
            &FitConfig { max_points: 11, threshold_frac: 0.0, batch: Batch::Fixed(3), grid_n: 33, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.points.len(), 11);
        // 3 starts, then 3+3, then a final round clamped to 2
        assert_eq!(calls, vec![3, 3, 3, 2]);
        assert!((out.device_seconds - 0.5 * 11.0).abs() < 1e-9);
    }

    #[test]
    fn batched_fit_is_deterministic() {
        let run = || {
            fit_family(
                |p| (surface_1d(p[0]), 0.5),
                1,
                &FitConfig { max_points: 12, grid_n: 17, batch: Batch::Fixed(4), ..Default::default() },
            )
        };
        let (a, b) = (run(), run());
        assert_outcomes_bit_equal(&a, &b, 1);
    }

    /// Drive a [`FamilyFit`] to completion with a constant occupancy
    /// (what the multi-device pipeline does for a healthy class).
    fn drive_machine(
        cfg: &FitConfig,
        occupancy: usize,
        mut measure: impl FnMut(&[f64]) -> (f64, f64),
    ) -> FitOutcome {
        let mut fit = FamilyFit::new(1, cfg);
        while let Some(ps) = fit.propose(occupancy) {
            let results: Vec<(f64, f64)> = ps.iter().map(|p| measure(p)).collect();
            fit.absorb(&results);
        }
        fit.finish()
    }

    #[test]
    fn auto_batch_is_bit_identical_to_fixed_k_at_constant_occupancy() {
        // The occupancy-adaptive contract: while k same-class workers
        // stay alive the whole run, `Auto` must equal `Fixed(k)`
        // bit-for-bit — every proposal, measurement and the final GP.
        for k in [1usize, 2, 3] {
            let base = FitConfig { max_points: 13, threshold_frac: 0.0, grid_n: 33, ..Default::default() };
            let auto = drive_machine(
                &FitConfig { batch: Batch::Auto, ..base },
                k,
                |p| (surface_1d(p[0]), 0.5),
            );
            // Fixed(k) ignores occupancy by definition; feed a wrong one
            // to prove it.
            let fixed = drive_machine(
                &FitConfig { batch: Batch::Fixed(k), ..base },
                7,
                |p| (surface_1d(p[0]), 0.5),
            );
            assert_outcomes_bit_equal(&auto, &fixed, 1);
        }
    }

    #[test]
    fn machine_driver_matches_closure_driver() {
        // fit_family_with is a thin driver over FamilyFit; the two entry
        // points must agree bit-for-bit.
        let cfg = FitConfig { max_points: 12, grid_n: 17, batch: Batch::Fixed(2), ..Default::default() };
        let a = fit_family(|p| (surface_1d(p[0]), 0.5), 1, &cfg);
        let b = drive_machine(&cfg, 1, |p| (surface_1d(p[0]), 0.5));
        assert_outcomes_bit_equal(&a, &b, 1);
    }

    #[test]
    fn replayed_machine_continues_bit_identically() {
        // The leader-checkpoint contract: interrupt a machine after any
        // absorbed round, replay its journal into a fresh machine, and
        // the continuation — every remaining proposal and the final GP —
        // must be bit-identical to the uninterrupted machine's.
        let cfg = FitConfig { max_points: 13, threshold_frac: 0.0, grid_n: 33, batch: Batch::Fixed(2), ..Default::default() };
        let measure = |p: &[f64]| (surface_1d(p[0]), 0.5);
        let uninterrupted = drive_machine(&cfg, 1, measure);
        for kill_after in 1..5usize {
            // Drive the "doomed leader" for `kill_after` absorbed rounds.
            let mut doomed = FamilyFit::new(1, &cfg);
            for _ in 0..kill_after {
                let ps = doomed.propose(1).expect("machine ended before the kill point");
                let results: Vec<(f64, f64)> = ps.iter().map(|p| measure(p)).collect();
                doomed.absorb(&results);
            }
            // The resumed leader sees only the serializable journal.
            let journal: Vec<(usize, Vec<(f64, f64)>)> = doomed.journal().to_vec();
            let mut resumed = FamilyFit::replay(1, &cfg, &journal);
            // Lock-step comparison from the kill point onward.
            loop {
                let a = doomed.propose(1);
                let b = resumed.propose(1);
                assert_eq!(a, b, "kill_after={kill_after}: proposals diverged after replay");
                let Some(ps) = a else { break };
                let results: Vec<(f64, f64)> = ps.iter().map(|p| measure(p)).collect();
                doomed.absorb(&results);
                resumed.absorb(&results);
            }
            assert_outcomes_bit_equal(&resumed.finish(), &uninterrupted, 1);
        }
    }

    #[test]
    fn sparse_backend_machine_replays_bit_identically() {
        // PR 9 replay contract: the inducing selection is a pure function
        // of (xs, m), so a journal replay under the sparse backend must
        // re-derive the identical inducing set and continue bit-for-bit —
        // no journal format change carries the selection.
        use crate::gp::GpBackend;
        let cfg = FitConfig {
            max_points: 13,
            threshold_frac: 0.0,
            grid_n: 33,
            batch: Batch::Fixed(2),
            backend: GpBackend::Sparse { m: 6 },
            ..Default::default()
        };
        let measure = |p: &[f64]| (surface_1d(p[0]), 0.5);
        let uninterrupted = drive_machine(&cfg, 1, measure);
        assert_eq!(
            uninterrupted.gp.inducing().len(),
            6,
            "final fit (13 points) must actually exercise the sparse path"
        );
        let mut doomed = FamilyFit::new(1, &cfg);
        for _ in 0..3 {
            let ps = doomed.propose(1).expect("machine ended before the kill point");
            let results: Vec<(f64, f64)> = ps.iter().map(|p| measure(p)).collect();
            doomed.absorb(&results);
        }
        let journal: Vec<(usize, Vec<(f64, f64)>)> = doomed.journal().to_vec();
        let mut resumed = FamilyFit::replay(1, &cfg, &journal);
        loop {
            let a = doomed.propose(1);
            let b = resumed.propose(1);
            assert_eq!(a, b, "sparse proposals diverged after replay");
            let Some(ps) = a else { break };
            let results: Vec<(f64, f64)> = ps.iter().map(|p| measure(p)).collect();
            doomed.absorb(&results);
            resumed.absorb(&results);
        }
        let out = resumed.finish();
        assert_eq!(out.gp.inducing(), uninterrupted.gp.inducing());
        assert_outcomes_bit_equal(&out, &uninterrupted, 1);
    }

    #[test]
    fn default_backend_config_is_bit_identical_to_exact_backend() {
        // The crossover guarantee at fit-loop scale: every fit in a
        // default-config run sits far below DEFAULT_SPARSE_THRESHOLD, so
        // `Auto` (the default) and a forced `Exact` produce byte-equal
        // outcomes.
        use crate::gp::GpBackend;
        let base = FitConfig { max_points: 12, grid_n: 17, ..Default::default() };
        let auto = fit_family(|p| (surface_1d(p[0]), 0.5), 1, &base);
        let exact = fit_family(
            |p| (surface_1d(p[0]), 0.5),
            1,
            &FitConfig { backend: GpBackend::Exact, ..base },
        );
        assert_outcomes_bit_equal(&auto, &exact, 1);
        assert!(auto.gp.inducing().is_empty());
    }

    #[test]
    fn journal_records_occupancies_and_results_verbatim() {
        let cfg = FitConfig { max_points: 9, threshold_frac: 0.0, grid_n: 17, batch: Batch::Auto, ..Default::default() };
        let mut fit = FamilyFit::new(1, &cfg);
        let mut occ = 3usize;
        let mut expect = Vec::new();
        while let Some(ps) = fit.propose(occ) {
            let results: Vec<(f64, f64)> = ps.iter().map(|p| (surface_1d(p[0]), 0.25)).collect();
            fit.absorb(&results);
            expect.push((occ, results));
            occ = if occ == 3 { 2 } else { 3 }; // churn: occupancy varies round to round
        }
        assert_eq!(fit.journal(), expect.as_slice());
        // Replaying a varying-occupancy journal also lands bit-identically.
        let replayed = FamilyFit::replay(1, &cfg, &expect);
        assert_eq!(replayed.journal(), expect.as_slice());
        assert_outcomes_bit_equal(&replayed.finish(), &fit.finish(), 1);
    }

    #[test]
    fn batch_parse_accepts_auto_and_integers() {
        assert_eq!(Batch::parse("auto").unwrap(), Batch::Auto);
        assert_eq!(Batch::parse("AUTO").unwrap(), Batch::Auto);
        assert_eq!(Batch::parse("3").unwrap(), Batch::Fixed(3));
        assert_eq!(Batch::parse("0").unwrap(), Batch::Fixed(1), "batch floors at 1");
        assert!(Batch::parse("three").is_err());
        assert_eq!(Batch::Auto.size(4), 4);
        assert_eq!(Batch::Auto.size(0), 1, "occupancy floors at 1");
        assert_eq!(Batch::Fixed(2).size(9), 2);
    }

    #[test]
    fn backend_error_propagates() {
        let r = fit_family_with(
            |_ps: &[Vec<f64>]| Err(crate::thor::measure::MeasureError("boom".into())),
            1,
            &FitConfig::default(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn time_surrogate_still_fits_energy() {
        // time = energy/3 (perfectly correlated): surrogate acquisition
        // must yield an equally good energy GP.
        let out = fit_family(
            |p| (surface_1d(p[0]), surface_1d(p[0]) / 3.0),
            1,
            &FitConfig { time_surrogate: true, max_points: 24, grid_n: 33, ..Default::default() },
        );
        let (m, _) = out.gp.predict(&[0.35]);
        assert!(((m.exp() - surface_1d(0.35)) / surface_1d(0.35)).abs() < 0.2, "{}", m.exp());
    }
}
