//! Persistence of fitted per-(device, family) GPs.  The paper's fitting
//! is a "one-time endeavor as the resulted models are reusable" — the
//! store is that reuse boundary, serialized as JSON so the decoupled
//! server (coordinator) can ship models across the wire and to disk.
//!
//! Every store carries a process-local **generation** stamp, refreshed
//! from a global counter on each mutation (insert/merge/load).  Caches
//! that memoize per-store predictions ([`crate::thor::EstimateCache`],
//! [`crate::thor::SharedEstimateCache`]) validate against it, so
//! re-profiling a family or hot-reloading a daemon's store invalidates
//! stale entries automatically.  The stamp never enters the serialized
//! artifact — store JSON stays byte-stable across runs.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::gp::{FitWorkspace, GpModel};
use crate::util::json::Json;

/// Process-wide mutation counter: every store mutation gets a stamp no
/// other store instance has ever held, so a cache validated against one
/// store can never alias a hit from another.
static NEXT_GENERATION: AtomicU64 = AtomicU64::new(1);

fn next_generation() -> u64 {
    NEXT_GENERATION.fetch_add(1, Ordering::Relaxed)
}

/// A fitted family model plus its feature normalizers.
#[derive(Clone, Debug)]
pub struct StoredGp {
    pub gp: GpModel,
    /// Feature scale: raw channels are divided by these before prediction
    /// (profiling normalized features to [0, 1]).
    pub x_max: Vec<f64>,
    /// Features were profiled on a log grid: x = ln(c)/ln(c_max).
    pub log_x: bool,
    /// Targets were fitted as ln(E); predictions are exponentiated back.
    pub log_y: bool,
    /// Simulated device-seconds spent profiling this family (Table 1).
    pub device_seconds: f64,
    pub fit_seconds: f64,
    pub converged: bool,
}

impl StoredGp {
    /// Raw channel features → the GP's normalized input space.
    fn normalize(&self, raw: &[f64]) -> Vec<f64> {
        raw.iter()
            .zip(&self.x_max)
            .map(|(v, m)| {
                if self.log_x {
                    v.max(1.0).ln() / m.max(1.0 + 1e-9).ln()
                } else {
                    v / m
                }
            })
            .collect()
    }

    /// Map a normalized-space (mean, var) back to linear joules (delta
    /// method on the variance when `log_y`).
    fn to_linear(&self, m: f64, v: f64) -> (f64, f64) {
        if self.log_y {
            let mean = m.exp();
            (mean, v * mean * mean)
        } else {
            (m, v)
        }
    }

    /// Predict at raw channel features, in linear joules regardless of
    /// the internal transforms.  The returned variance is mapped back to
    /// linear units via the delta method when `log_y`.
    pub fn predict_raw(&self, raw: &[f64]) -> (f64, f64) {
        let q = self.normalize(raw);
        let (m, v) = self.gp.predict(&q);
        self.to_linear(m, v)
    }

    /// Batched [`StoredGp::predict_raw`]: one `GpModel::predict_batch`
    /// call for the whole query set (bit-identical to the scalar path —
    /// the estimator's per-family batching relies on that).
    pub fn predict_raw_batch(&self, raws: &[Vec<f64>]) -> Vec<(f64, f64)> {
        let qs: Vec<Vec<f64>> = raws.iter().map(|r| self.normalize(r)).collect();
        let (ms, vs) = self.gp.predict_batch(&qs);
        ms.into_iter().zip(vs).map(|(m, v)| self.to_linear(m, v)).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("gp", self.gp.to_json()),
            ("x_max", Json::arr_f64(&self.x_max)),
            ("log_x", Json::Bool(self.log_x)),
            ("log_y", Json::Bool(self.log_y)),
            ("device_seconds", Json::Num(self.device_seconds)),
            ("fit_seconds", Json::Num(self.fit_seconds)),
            ("converged", Json::Bool(self.converged)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        Self::from_json_with(&mut FitWorkspace::new(), j)
    }

    /// [`StoredGp::from_json`] through a caller-owned fit workspace, so a
    /// whole-store load shares one scratch across every family's
    /// posterior (α, K⁻¹) reconstruction.
    pub fn from_json_with(ws: &mut FitWorkspace, j: &Json) -> Option<Self> {
        Some(Self {
            gp: GpModel::from_json_with(ws, j.get("gp")?)?,
            x_max: j.get("x_max")?.as_f64_vec()?,
            log_x: j.get("log_x")?.as_bool()?,
            log_y: j.get("log_y")?.as_bool()?,
            device_seconds: j.get("device_seconds")?.as_f64()?,
            fit_seconds: j.get("fit_seconds")?.as_f64()?,
            converged: j.get("converged")?.as_bool()?,
        })
    }
}

/// (device, family-id) → fitted GP.
pub struct GpStore {
    map: BTreeMap<String, StoredGp>,
    /// See the module doc: refreshed on every mutation, never serialized.
    generation: u64,
}

impl Default for GpStore {
    fn default() -> Self {
        Self { map: BTreeMap::new(), generation: next_generation() }
    }
}

fn key(device: &str, family: &str) -> String {
    format!("{device}|{family}")
}

impl GpStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// The current mutation stamp.  Unique across all live stores in
    /// this process; compare-and-clear caches against it.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn insert(&mut self, device: &str, family: &str, gp: StoredGp) {
        self.map.insert(key(device, family), gp);
        self.generation = next_generation();
    }

    pub fn get(&self, device: &str, family: &str) -> Option<&StoredGp> {
        self.map.get(&key(device, family))
    }

    pub fn contains(&self, device: &str, family: &str) -> bool {
        self.map.contains_key(&key(device, family))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Absorb another store (e.g. merge per-class stores into one
    /// fleet artifact).  Key collisions resolve to `other`'s entry.
    pub fn merge(&mut self, other: GpStore) {
        self.map.extend(other.map);
        self.generation = next_generation();
    }

    /// Fitted families for one device class.
    pub fn len_for(&self, device: &str) -> usize {
        let prefix = format!("{device}|");
        self.map.keys().filter(|k| k.starts_with(&prefix)).count()
    }

    /// Total profiling + fitting cost per device (Table 1 rows).
    pub fn cost_seconds(&self, device: &str) -> (f64, f64) {
        let prefix = format!("{device}|");
        self.map
            .iter()
            .filter(|(k, _)| k.starts_with(&prefix))
            .fold((0.0, 0.0), |(d, f), (_, g)| (d + g.device_seconds, f + g.fit_seconds))
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.map.iter().map(|(k, v)| (k.clone(), v.to_json())).collect())
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        // One workspace across all families: each entry's posterior
        // (α, K⁻¹) is rebuilt exactly once at load through the
        // scratch-free `chol_inverse_into` path.
        let mut ws = FitWorkspace::new();
        let mut map = BTreeMap::new();
        for (k, v) in j.as_obj()? {
            map.insert(k.clone(), StoredGp::from_json_with(&mut ws, v)?);
        }
        Some(Self { map, generation: next_generation() })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Option<Self>> {
        let s = std::fs::read_to_string(path)?;
        Ok(Json::parse(&s).ok().and_then(|j| Self::from_json(&j)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::KernelKind;

    fn toy_stored() -> StoredGp {
        let xs: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64 / 7.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 5.0 + 3.0 * x[0]).collect();
        StoredGp {
            gp: GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap(),
            x_max: vec![128.0],
            log_x: false,
            log_y: false,
            device_seconds: 12.5,
            fit_seconds: 0.5,
            converged: true,
        }
    }

    #[test]
    fn predict_raw_normalizes() {
        let s = toy_stored();
        let (m_raw, _) = s.predict_raw(&[64.0]);
        let (m_norm, _) = s.gp.predict(&[0.5]);
        assert_eq!(m_raw, m_norm);
    }

    #[test]
    fn predict_raw_batch_matches_scalar_bitwise() {
        let mut s = toy_stored();
        for (log_x, log_y) in [(false, false), (true, false), (false, true), (true, true)] {
            s.log_x = log_x;
            s.log_y = log_y;
            let raws: Vec<Vec<f64>> = (0..9).map(|i| vec![1.0 + 15.0 * i as f64]).collect();
            let batch = s.predict_raw_batch(&raws);
            for (raw, (bm, bv)) in raws.iter().zip(&batch) {
                let (m, v) = s.predict_raw(raw);
                assert_eq!((m.to_bits(), v.to_bits()), (bm.to_bits(), bv.to_bits()));
            }
        }
    }

    #[test]
    fn store_roundtrip_through_json() {
        let mut st = GpStore::new();
        st.insert("xavier", "hid:conv3s1p:h14w14b10:bn-r-mp2", toy_stored());
        st.insert("oppo", "out:fc:h1w1b10:sm", toy_stored());
        let j = st.to_json().to_string();
        let back = GpStore::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.len(), 2);
        let a = st.get("xavier", "hid:conv3s1p:h14w14b10:bn-r-mp2").unwrap();
        let b = back.get("xavier", "hid:conv3s1p:h14w14b10:bn-r-mp2").unwrap();
        assert!((a.predict_raw(&[40.0]).0 - b.predict_raw(&[40.0]).0).abs() < 1e-6);
    }

    #[test]
    fn sparse_stored_gp_roundtrip_is_byte_idempotent() {
        // PR 9: a sparse-backend store entry serializes its inducing set
        // ("backend":"sparse") and reloads with the identical posterior —
        // byte-idempotent JSON, bit-equal predictions through the raw
        // (normalize + delta-method) path.
        use crate::gp::{FitWorkspace, GpBackend};
        let xs: Vec<Vec<f64>> = (0..40).map(|i| vec![i as f64 / 39.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (5.0 + 3.0 * (3.0 * x[0]).sin()).ln()).collect();
        let mut ws = FitWorkspace::new();
        let gp = GpModel::fit_b(&mut ws, KernelKind::Matern52, xs, &ys, GpBackend::Sparse { m: 9 })
            .unwrap();
        assert_eq!(gp.inducing().len(), 9);
        let s = StoredGp {
            gp,
            x_max: vec![128.0],
            log_x: true,
            log_y: true,
            device_seconds: 3.0,
            fit_seconds: 0.0,
            converged: true,
        };
        let j1 = s.to_json().to_string();
        assert!(j1.contains("\"backend\":\"sparse\""), "{j1}");
        let back = StoredGp::from_json(&Json::parse(&j1).unwrap()).unwrap();
        let j2 = back.to_json().to_string();
        assert_eq!(j1, j2, "sparse StoredGp JSON must be byte-idempotent");
        for i in 0..9 {
            let raw = [1.0 + 14.0 * i as f64];
            let (m1, v1) = s.predict_raw(&raw);
            let (m2, v2) = back.predict_raw(&raw);
            assert_eq!((m1.to_bits(), v1.to_bits()), (m2.to_bits(), v2.to_bits()));
        }
    }

    #[test]
    fn merge_and_len_for_cover_multi_device_stores() {
        let mut a = GpStore::new();
        a.insert("xavier", "f1", toy_stored());
        a.insert("xavier", "f2", toy_stored());
        let mut b = GpStore::new();
        b.insert("tx2", "f1", toy_stored());
        a.merge(b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.len_for("xavier"), 2);
        assert_eq!(a.len_for("tx2"), 1);
        assert_eq!(a.len_for("server"), 0);
    }

    #[test]
    fn cost_seconds_sums_per_device() {
        let mut st = GpStore::new();
        st.insert("xavier", "f1", toy_stored());
        st.insert("xavier", "f2", toy_stored());
        st.insert("oppo", "f1", toy_stored());
        let (d, f) = st.cost_seconds("xavier");
        assert!((d - 25.0).abs() < 1e-9);
        assert!((f - 1.0).abs() < 1e-9);
    }

    #[test]
    fn every_mutation_bumps_generation_and_instances_never_share() {
        let mut a = GpStore::new();
        let b = GpStore::new();
        assert_ne!(a.generation(), b.generation(), "fresh stores must not alias");
        let g0 = a.generation();
        a.insert("xavier", "f1", toy_stored());
        let g1 = a.generation();
        assert_ne!(g0, g1, "insert must restamp");
        let mut other = GpStore::new();
        other.insert("tx2", "f1", toy_stored());
        a.merge(other);
        assert_ne!(a.generation(), g1, "merge must restamp");
        // Reload from JSON is a new logical store: new stamp too.
        let back = GpStore::from_json(&Json::parse(&a.to_json().to_string()).unwrap()).unwrap();
        assert_ne!(back.generation(), a.generation());
    }

    #[test]
    fn generation_never_enters_the_artifact() {
        let mut st = GpStore::new();
        st.insert("xavier", "f1", toy_stored());
        let before = st.to_json().to_string();
        st.insert("xavier", "f1", toy_stored()); // same content, new stamp
        assert_eq!(before, st.to_json().to_string(), "artifact must stay byte-stable");
    }

    #[test]
    fn save_load_file() {
        let mut st = GpStore::new();
        st.insert("tx2", "fam", toy_stored());
        let dir = std::env::temp_dir().join("thor_store_test.json");
        st.save(&dir).unwrap();
        let back = GpStore::load(&dir).unwrap().unwrap();
        assert!(back.contains("tx2", "fam"));
        std::fs::remove_file(dir).ok();
    }
}
