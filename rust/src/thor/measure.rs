//! Measurement-backend abstraction: every profiling backend — the
//! in-process simulator, the TCP fleet, the (stubbed) PJRT runtime —
//! exposes the same surface: submit a *batch* of variant-measurement
//! requests, get one [`Measurement`] per request back.  The whole
//! pipeline ([`crate::thor::pipeline::Thor::profile`],
//! [`crate::thor::fit`]) is written against [`Measurer`], so the
//! active-learning loop itself — not just a replayed job list — runs
//! over whichever backend is plugged in.
//!
//! # Determinism contract
//!
//! A deterministic backend must make each [`Measurement`] a **pure
//! function of its request alone** (per-request seeding, see
//! [`crate::thor::profiler::job_seed`]) — independent of batch
//! composition, submission order, concurrency, worker count, and which
//! backend ran it.  Under that contract the profiled
//! [`crate::thor::store::GpStore`] is a pure function of (reference,
//! config, base seed): a [`LocalMeasurer::per_job`] run and a
//! [`crate::coordinator::FleetMeasurer`] run at *any* worker count are
//! byte-identical (asserted by `rust/tests/backend_equiv.rs`).
//!
//! [`LocalMeasurer::sequential`] deliberately breaks the contract the
//! way a physical device does: one stateful device carries DVFS /
//! thermal / meter state across requests.  It is still deterministic
//! run-to-run at batch size 1 (requests arrive in declaration order),
//! and is the bit-compatible continuation of the pre-refactor
//! `&mut Device` pipeline.

use crate::model::ModelGraph;
use crate::simdevice::{Device, DeviceProfile};
use crate::thor::profiler::{self, job_seed, VariantBuilder};

/// One variant-network measurement request: the family id plus the raw
/// channel widths identify the variant (the backend rebuilds the graph
/// from the shared reference architecture, so only channels travel).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MeasureRequest {
    pub family: String,
    pub channels: Vec<usize>,
    /// Training iterations for this measurement (paper: 500).
    pub iterations: usize,
}

/// What a backend returns per request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measurement {
    /// Net energy per training iteration, joules.
    pub energy_per_iter: f64,
    /// Simulated device-seconds the measurement cost (Table 1).
    pub device_seconds: f64,
}

/// A measurement backend failed in a way the acquisition loop cannot
/// recover from (e.g. every fleet worker disconnected mid-batch).
#[derive(Debug, thiserror::Error)]
#[error("measurement backend failed: {0}")]
pub struct MeasureError(pub String);

/// A profiling backend.  Object-safe on purpose: the pipeline takes
/// `&mut dyn Measurer` so local, fleet and PJRT runs share one code
/// path.
pub trait Measurer {
    /// Device name the measurements come from — the
    /// [`crate::thor::store::GpStore`] key.
    fn device(&self) -> &str;

    /// Measure a batch; `result[i]` answers `reqs[i]`.  Backends may run
    /// the requests concurrently (the fleet does), but must return them
    /// in request order.  See the module docs for the determinism
    /// contract.
    fn measure_batch(&mut self, reqs: &[MeasureRequest]) -> Result<Vec<Measurement>, MeasureError>;
}

enum LocalMode<'d> {
    /// One stateful device shared across requests, measured in request
    /// order — bit-compatible with the pre-refactor `&mut Device`
    /// pipeline at batch size 1.
    Sequential(&'d mut Device),
    /// Fresh per-request-seeded device per request ([`job_seed`]) — the
    /// mode whose stores are byte-equal to a fleet run at any worker
    /// count (the fleet worker's `with_per_job_seed` path runs this
    /// exact code).
    PerJob { profile: DeviceProfile, base_seed: u64 },
}

/// In-process backend over the device simulator.
pub struct LocalMeasurer<'d> {
    mode: LocalMode<'d>,
    builder: VariantBuilder,
    name: String,
}

impl<'d> LocalMeasurer<'d> {
    /// Wrap an existing stateful device (DVFS/thermal/meter state carries
    /// across requests, like a physical device).
    pub fn sequential(dev: &'d mut Device, reference: &ModelGraph) -> Self {
        let name = dev.profile.name.to_string();
        Self { mode: LocalMode::Sequential(dev), builder: VariantBuilder::from_reference(reference), name }
    }
}

impl LocalMeasurer<'static> {
    /// Fresh per-request-seeded device per request: fleet-equivalent
    /// measurements (see the module docs).
    pub fn per_job(profile: DeviceProfile, base_seed: u64, reference: &ModelGraph) -> Self {
        let name = profile.name.to_string();
        Self {
            mode: LocalMode::PerJob { profile, base_seed },
            builder: VariantBuilder::from_reference(reference),
            name,
        }
    }
}

impl Measurer for LocalMeasurer<'_> {
    fn device(&self) -> &str {
        &self.name
    }

    fn measure_batch(&mut self, reqs: &[MeasureRequest]) -> Result<Vec<Measurement>, MeasureError> {
        let mut out = Vec::with_capacity(reqs.len());
        for r in reqs {
            let g = self
                .builder
                .build(&r.family, &r.channels)
                .map_err(|e| MeasureError(e.to_string()))?;
            let (e, dt) = match &mut self.mode {
                LocalMode::Sequential(dev) => profiler::measure(dev, &g, r.iterations),
                LocalMode::PerJob { profile, base_seed } => {
                    let seed = job_seed(*base_seed, &r.family, &r.channels, r.iterations);
                    let mut dev = Device::new(profile.clone(), seed);
                    profiler::measure(&mut dev, &g, r.iterations)
                }
            };
            out.push(Measurement { energy_per_iter: e, device_seconds: dt });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::simdevice::devices;

    fn reference() -> ModelGraph {
        zoo::cnn5(&[8, 16, 32, 64], 16, 10)
    }

    fn out_family() -> String {
        crate::thor::parse::parse(&reference()).output_groups().next().unwrap().key.id()
    }

    #[test]
    fn per_job_is_pure_per_request() {
        // Same request in different batch shapes → bit-identical result.
        let fam = out_family();
        let req = MeasureRequest { family: fam.clone(), channels: vec![32], iterations: 40 };
        let other = MeasureRequest { family: fam, channels: vec![8], iterations: 40 };
        let mut m = LocalMeasurer::per_job(devices::xavier(), 42, &reference());
        let alone = m.measure_batch(std::slice::from_ref(&req)).unwrap()[0];
        let batched = m.measure_batch(&[other, req]).unwrap()[1];
        assert_eq!(alone.energy_per_iter.to_bits(), batched.energy_per_iter.to_bits());
        assert_eq!(alone.device_seconds.to_bits(), batched.device_seconds.to_bits());
    }

    #[test]
    fn per_job_matches_manual_seeded_device() {
        // The measurer must run the exact per-job path the fleet worker
        // runs: job_seed → fresh device → profiler::measure.
        let fam = out_family();
        let req = MeasureRequest { family: fam.clone(), channels: vec![16], iterations: 30 };
        let mut m = LocalMeasurer::per_job(devices::tx2(), 7, &reference());
        let got = m.measure_batch(std::slice::from_ref(&req)).unwrap()[0];
        let builder = VariantBuilder::from_reference(&reference());
        let g = builder.build(&fam, &[16]).unwrap();
        let seed = job_seed(7, &fam, &[16], 30);
        let mut dev = Device::new(devices::tx2(), seed);
        let (e, dt) = profiler::measure(&mut dev, &g, 30);
        assert_eq!(got.energy_per_iter.to_bits(), e.to_bits());
        assert_eq!(got.device_seconds.to_bits(), dt.to_bits());
    }

    #[test]
    fn sequential_matches_direct_device_stream() {
        // Sequential mode must consume the wrapped device's RNG stream
        // exactly like direct profiler::measure calls in the same order.
        let fam = out_family();
        let reqs: Vec<MeasureRequest> = [8usize, 32, 64]
            .iter()
            .map(|&c| MeasureRequest { family: fam.clone(), channels: vec![c], iterations: 25 })
            .collect();
        let mut dev_a = Device::new(devices::server(), 5);
        let mut m = LocalMeasurer::sequential(&mut dev_a, &reference());
        let got = m.measure_batch(&reqs).unwrap();

        let builder = VariantBuilder::from_reference(&reference());
        let mut dev_b = Device::new(devices::server(), 5);
        for (r, g_m) in reqs.iter().zip(&got) {
            let g = builder.build(&r.family, &r.channels).unwrap();
            let (e, dt) = profiler::measure(&mut dev_b, &g, r.iterations);
            assert_eq!(g_m.energy_per_iter.to_bits(), e.to_bits());
            assert_eq!(g_m.device_seconds.to_bits(), dt.to_bits());
        }
    }

    #[test]
    fn unknown_family_errors() {
        let mut m = LocalMeasurer::per_job(devices::xavier(), 1, &reference());
        let req = MeasureRequest { family: "nope".into(), channels: vec![1], iterations: 10 };
        assert!(m.measure_batch(&[req]).is_err());
    }

    #[test]
    fn device_name_comes_from_profile() {
        let m = LocalMeasurer::per_job(devices::xavier(), 1, &reference());
        assert_eq!(m.device(), "xavier");
    }
}
