//! Measurement-backend abstraction: every profiling backend — the
//! in-process simulator, the TCP fleet, the (stubbed) PJRT runtime —
//! exposes the same surface: submit a *batch* of variant-measurement
//! requests, get one [`Measurement`] per request back.  The whole
//! pipeline ([`crate::thor::pipeline::Thor::profile`],
//! [`crate::thor::fit`]) is written against [`Measurer`], so the
//! active-learning loop itself — not just a replayed job list — runs
//! over whichever backend is plugged in.
//!
//! # Device classes
//!
//! A backend serves one or more *device classes* ([`Measurer::devices`])
//! and every [`MeasureRequest`] names the class it must run on — one
//! heterogeneous backend (a mixed xavier/tx2/server fleet behind a
//! single leader, or a [`LocalMeasurer`] holding a map of per-class
//! seeded devices) profiles all of its classes in one pipeline run.
//! [`Measurer::occupancy`] reports the live worker count of a class so
//! the acquisition loop can size its batches adaptively
//! ([`crate::thor::fit::Batch::Auto`]).
//!
//! # Determinism contract
//!
//! A deterministic backend must make each [`Measurement`] a **pure
//! function of its request alone** (per-request seeding, see
//! [`crate::thor::profiler::job_seed`]) — independent of batch
//! composition, submission order, concurrency, worker count, and which
//! backend ran it.  In multi-class runs the per-job seed base of class
//! `c` is [`crate::thor::profiler::class_seed`]`(base, c)`, so requests
//! of different classes never share a seed while single-class runs keep
//! their legacy bit patterns.  Under that contract the profiled
//! [`crate::thor::store::GpStore`] is a pure function of (reference,
//! config, base seed): a [`LocalMeasurer::per_job`] run and a
//! [`crate::coordinator::FleetMeasurer`] run at *any* worker count are
//! byte-identical, and a heterogeneous fleet store is the byte-exact
//! merge of per-class local stores (both asserted by
//! `rust/tests/backend_equiv.rs`).
//!
//! [`LocalMeasurer::sequential`] deliberately breaks the contract the
//! way a physical device does: one stateful device carries DVFS /
//! thermal / meter state across requests.  It is still deterministic
//! run-to-run at batch size 1 (requests arrive in declaration order),
//! and is the bit-compatible continuation of the pre-refactor
//! `&mut Device` pipeline.

use std::collections::BTreeMap;

use crate::model::ModelGraph;
use crate::simdevice::{Device, DeviceProfile};
use crate::thor::profiler::{self, class_seed, job_seed, VariantBuilder};

/// One variant-network measurement request: the device class it must
/// run on, plus the family id and the raw channel widths identifying
/// the variant (the backend rebuilds the graph from the shared
/// reference architecture, so only channels travel).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MeasureRequest {
    /// Device class this measurement must run on (a
    /// [`Measurer::devices`] entry — also the
    /// [`crate::thor::store::GpStore`] key).
    pub device: String,
    pub family: String,
    pub channels: Vec<usize>,
    /// Training iterations for this measurement (paper: 500).
    pub iterations: usize,
}

/// What a backend returns per request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measurement {
    /// Net energy per training iteration, joules.
    pub energy_per_iter: f64,
    /// Simulated device-seconds the measurement cost (Table 1).
    pub device_seconds: f64,
}

/// A measurement backend failed in a way the acquisition loop cannot
/// recover from (e.g. every fleet worker of a scheduled device class
/// disconnected mid-batch).
#[derive(Debug, thiserror::Error)]
#[error("measurement backend failed: {0}")]
pub struct MeasureError(pub String);

/// A profiling backend.  Object-safe on purpose: the pipeline takes
/// `&mut dyn Measurer` so local, fleet and PJRT runs share one code
/// path.
pub trait Measurer {
    /// Device classes this backend measures on, sorted and deduplicated
    /// — the pipeline profiles every class, and each
    /// [`MeasureRequest::device`] must name one of them.  These are the
    /// [`crate::thor::store::GpStore`] keys.
    fn devices(&self) -> Vec<String>;

    /// Measure a batch; `result[i]` answers `reqs[i]`.  A batch may mix
    /// device classes; backends may run the requests concurrently (the
    /// fleet does), but must return them in request order.  See the
    /// module docs for the determinism contract.
    fn measure_batch(&mut self, reqs: &[MeasureRequest]) -> Result<Vec<Measurement>, MeasureError>;

    /// Live measurement parallelism for one device class (fleet: live
    /// same-class worker count).  Sizes `Batch::Auto` acquisition
    /// rounds; backends without a worker notion report 1.
    fn occupancy(&self, device: &str) -> usize {
        let _ = device;
        1
    }
}

enum LocalMode<'d> {
    /// One stateful device shared across requests, measured in request
    /// order — bit-compatible with the pre-refactor `&mut Device`
    /// pipeline at batch size 1.  Single-class by nature.
    Sequential(&'d mut Device),
    /// Fresh per-request-seeded device per request ([`job_seed`]) — the
    /// mode whose stores are byte-equal to a fleet run at any worker
    /// count (the fleet worker's `with_per_job_seed` path runs this
    /// exact code).  Class → (profile, per-job seed base): single-class
    /// via [`LocalMeasurer::per_job`] (base used verbatim, the legacy
    /// bit pattern) or multi-class via [`LocalMeasurer::per_job_fleet`]
    /// (per-class bases derived with [`class_seed`]).
    PerJob { seeds: BTreeMap<String, (DeviceProfile, u64)> },
}

/// In-process backend over the device simulator.
pub struct LocalMeasurer<'d> {
    mode: LocalMode<'d>,
    builder: VariantBuilder,
    name: String,
}

impl<'d> LocalMeasurer<'d> {
    /// Wrap an existing stateful device (DVFS/thermal/meter state carries
    /// across requests, like a physical device).
    pub fn sequential(dev: &'d mut Device, reference: &ModelGraph) -> Self {
        let name = dev.profile.name.to_string();
        Self { mode: LocalMode::Sequential(dev), builder: VariantBuilder::from_reference(reference), name }
    }
}

impl LocalMeasurer<'static> {
    /// Fresh per-request-seeded device per request: fleet-equivalent
    /// measurements (see the module docs).  Single class; `base_seed`
    /// feeds [`job_seed`] directly, bit-compatible with PR-4 stores.
    pub fn per_job(profile: DeviceProfile, base_seed: u64, reference: &ModelGraph) -> Self {
        let name = profile.name.to_string();
        let mut seeds = BTreeMap::new();
        seeds.insert(name.clone(), (profile, base_seed));
        Self {
            mode: LocalMode::PerJob { seeds },
            builder: VariantBuilder::from_reference(reference),
            name,
        }
    }

    /// Multi-class per-job backend: one seeded simulator class per
    /// profile, the in-process twin of a heterogeneous single-leader
    /// fleet.  Class `c` measures with per-job base
    /// [`class_seed`]`(base_seed, c)` — exactly what a fleet worker of
    /// class `c` started via
    /// [`crate::coordinator::DeviceWorker::with_class_seed`] uses, so
    /// the two backends produce byte-identical stores.
    pub fn per_job_fleet(
        profiles: Vec<DeviceProfile>,
        base_seed: u64,
        reference: &ModelGraph,
    ) -> Self {
        let mut seeds = BTreeMap::new();
        for p in profiles {
            let name = p.name.to_string();
            let seed = class_seed(base_seed, &name);
            seeds.insert(name, (p, seed));
        }
        let name = seeds.keys().next().cloned().unwrap_or_default();
        Self {
            mode: LocalMode::PerJob { seeds },
            builder: VariantBuilder::from_reference(reference),
            name,
        }
    }
}

impl Measurer for LocalMeasurer<'_> {
    fn devices(&self) -> Vec<String> {
        match &self.mode {
            LocalMode::Sequential(_) => vec![self.name.clone()],
            LocalMode::PerJob { seeds } => seeds.keys().cloned().collect(),
        }
    }

    fn measure_batch(&mut self, reqs: &[MeasureRequest]) -> Result<Vec<Measurement>, MeasureError> {
        let mut out = Vec::with_capacity(reqs.len());
        for r in reqs {
            let g = self
                .builder
                .build(&r.family, &r.channels)
                .map_err(|e| MeasureError(e.to_string()))?;
            let (e, dt) = match &mut self.mode {
                LocalMode::Sequential(dev) => {
                    if r.device != self.name {
                        return Err(MeasureError(format!(
                            "request targets device class '{}' but this sequential backend \
                             wraps '{}'",
                            r.device, self.name
                        )));
                    }
                    profiler::measure(dev, &g, r.iterations)
                }
                LocalMode::PerJob { seeds } => {
                    let (profile, base) = seeds.get(&r.device).ok_or_else(|| {
                        MeasureError(format!(
                            "request targets unknown device class '{}' (serving: {})",
                            r.device,
                            seeds.keys().cloned().collect::<Vec<_>>().join(", ")
                        ))
                    })?;
                    let seed = job_seed(*base, &r.family, &r.channels, r.iterations);
                    let mut dev = Device::new(profile.clone(), seed);
                    profiler::measure(&mut dev, &g, r.iterations)
                }
            };
            out.push(Measurement { energy_per_iter: e, device_seconds: dt });
        }
        Ok(out)
    }
}

/// Fault-injection wrapper: delegates to the inner backend but fails the
/// `limit+1`-th `measure_batch` *before* submitting it — the leader-side
/// analogue of [`crate::coordinator::DeviceWorker::run_limited`], used by
/// chaos tests and the fleetE experiment to kill a leader at a
/// deterministic joint-batch boundary ("between absorbs": everything
/// measured so far has been absorbed, nothing from the failed round was
/// issued).
pub struct AbortAfter<'m> {
    inner: &'m mut dyn Measurer,
    limit: usize,
    calls: usize,
}

impl<'m> AbortAfter<'m> {
    pub fn new(inner: &'m mut dyn Measurer, limit: usize) -> Self {
        Self { inner, limit, calls: 0 }
    }
}

impl Measurer for AbortAfter<'_> {
    fn devices(&self) -> Vec<String> {
        self.inner.devices()
    }

    fn measure_batch(&mut self, reqs: &[MeasureRequest]) -> Result<Vec<Measurement>, MeasureError> {
        self.calls += 1;
        if self.calls > self.limit {
            return Err(MeasureError(format!(
                "injected leader death before joint batch {} ({} requests unsent)",
                self.calls,
                reqs.len()
            )));
        }
        self.inner.measure_batch(reqs)
    }

    fn occupancy(&self, device: &str) -> usize {
        self.inner.occupancy(device)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::simdevice::devices;

    fn reference() -> ModelGraph {
        zoo::cnn5(&[8, 16, 32, 64], 16, 10)
    }

    fn out_family() -> String {
        crate::thor::parse::parse(&reference()).output_groups().next().unwrap().key.id()
    }

    fn req(device: &str, family: &str, channels: Vec<usize>, iterations: usize) -> MeasureRequest {
        MeasureRequest { device: device.into(), family: family.into(), channels, iterations }
    }

    #[test]
    fn per_job_is_pure_per_request() {
        // Same request in different batch shapes → bit-identical result.
        let fam = out_family();
        let r = req("xavier", &fam, vec![32], 40);
        let other = req("xavier", &fam, vec![8], 40);
        let mut m = LocalMeasurer::per_job(devices::xavier(), 42, &reference());
        let alone = m.measure_batch(std::slice::from_ref(&r)).unwrap()[0];
        let batched = m.measure_batch(&[other, r]).unwrap()[1];
        assert_eq!(alone.energy_per_iter.to_bits(), batched.energy_per_iter.to_bits());
        assert_eq!(alone.device_seconds.to_bits(), batched.device_seconds.to_bits());
    }

    #[test]
    fn per_job_matches_manual_seeded_device() {
        // The measurer must run the exact per-job path the fleet worker
        // runs: job_seed → fresh device → profiler::measure.
        let fam = out_family();
        let r = req("tx2", &fam, vec![16], 30);
        let mut m = LocalMeasurer::per_job(devices::tx2(), 7, &reference());
        let got = m.measure_batch(std::slice::from_ref(&r)).unwrap()[0];
        let builder = VariantBuilder::from_reference(&reference());
        let g = builder.build(&fam, &[16]).unwrap();
        let seed = job_seed(7, &fam, &[16], 30);
        let mut dev = Device::new(devices::tx2(), seed);
        let (e, dt) = profiler::measure(&mut dev, &g, 30);
        assert_eq!(got.energy_per_iter.to_bits(), e.to_bits());
        assert_eq!(got.device_seconds.to_bits(), dt.to_bits());
    }

    #[test]
    fn sequential_matches_direct_device_stream() {
        // Sequential mode must consume the wrapped device's RNG stream
        // exactly like direct profiler::measure calls in the same order.
        let fam = out_family();
        let reqs: Vec<MeasureRequest> =
            [8usize, 32, 64].iter().map(|&c| req("server", &fam, vec![c], 25)).collect();
        let mut dev_a = Device::new(devices::server(), 5);
        let mut m = LocalMeasurer::sequential(&mut dev_a, &reference());
        let got = m.measure_batch(&reqs).unwrap();

        let builder = VariantBuilder::from_reference(&reference());
        let mut dev_b = Device::new(devices::server(), 5);
        for (r, g_m) in reqs.iter().zip(&got) {
            let g = builder.build(&r.family, &r.channels).unwrap();
            let (e, dt) = profiler::measure(&mut dev_b, &g, r.iterations);
            assert_eq!(g_m.energy_per_iter.to_bits(), e.to_bits());
            assert_eq!(g_m.device_seconds.to_bits(), dt.to_bits());
        }
    }

    #[test]
    fn per_job_fleet_routes_by_class_with_class_derived_seeds() {
        // A mixed batch routes each request to its class; each class's
        // result is bit-identical to a single-class per_job measurer
        // seeded with class_seed(base, class) — the merge contract the
        // heterogeneous backend-equivalence test scales up.
        let fam = out_family();
        let mut m = LocalMeasurer::per_job_fleet(
            vec![devices::xavier(), devices::tx2()],
            42,
            &reference(),
        );
        assert_eq!(m.devices(), vec!["tx2".to_string(), "xavier".to_string()]);
        let rx = req("xavier", &fam, vec![16], 30);
        let rt = req("tx2", &fam, vec![16], 30);
        let got = m.measure_batch(&[rx.clone(), rt.clone()]).unwrap();
        assert_ne!(
            got[0].energy_per_iter.to_bits(),
            got[1].energy_per_iter.to_bits(),
            "classes measured identically"
        );
        for (r, g) in [(rx, got[0]), (rt, got[1])] {
            let profile = devices::by_name(&r.device).unwrap();
            let mut solo =
                LocalMeasurer::per_job(profile, class_seed(42, &r.device), &reference());
            let alone = solo.measure_batch(std::slice::from_ref(&r)).unwrap()[0];
            assert_eq!(g.energy_per_iter.to_bits(), alone.energy_per_iter.to_bits());
            assert_eq!(g.device_seconds.to_bits(), alone.device_seconds.to_bits());
        }
    }

    #[test]
    fn unknown_family_or_class_errors() {
        let mut m = LocalMeasurer::per_job(devices::xavier(), 1, &reference());
        assert!(m.measure_batch(&[req("xavier", "nope", vec![1], 10)]).is_err());
        let fam = out_family();
        assert!(
            m.measure_batch(&[req("tx2", &fam, vec![1], 10)]).is_err(),
            "request for an unserved class must error"
        );
        let mut dev = Device::new(devices::server(), 1);
        let mut seq = LocalMeasurer::sequential(&mut dev, &reference());
        assert!(
            seq.measure_batch(&[req("xavier", &fam, vec![1], 10)]).is_err(),
            "sequential backend must reject a foreign class"
        );
    }

    #[test]
    fn device_classes_come_from_profiles() {
        let m = LocalMeasurer::per_job(devices::xavier(), 1, &reference());
        assert_eq!(m.devices(), vec!["xavier".to_string()]);
        assert_eq!(m.occupancy("xavier"), 1);
    }
}
