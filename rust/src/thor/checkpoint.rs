//! Leader checkpoint/resume for fleet profiling.
//!
//! A killed leader used to re-measure everything.  A [`Checkpoint`] makes
//! the leader's progress durable: the completed families (the
//! [`GpStore`] as fitted so far) plus, for every family still being
//! acquired, the [`FamilyFit`] absorbed-round journal — the complete
//! serializable description of the in-flight machine (see
//! [`FamilyFit::replay`]).  On resume, completed families are skipped by
//! the pipeline's store idempotency and in-flight machines are replayed
//! bit-identically, so the **resumed final store is byte-identical to the
//! uninterrupted run's** (the correctness contract, pinned in
//! `tests/fleet.rs` and the fleetE chaos experiment).  The only work a
//! resume repeats is the one proposed-but-unabsorbed batch that was in
//! flight when the leader died — journals record absorbed rounds only.
//!
//! Byte-identity leans on two pins elsewhere:
//! - `Json::Num` printing is shortest-roundtrip (util::json), so every
//!   `f64` survives the file bit-exactly;
//! - `GpModel::to_json` serializes the raw fit targets verbatim, so a
//!   reloaded store's posteriors predict bit-identically (gp::model's
//!   `json_roundtrip_is_bit_exact_and_idempotent`) — the replayed
//!   machines' subtraction GPs therefore fold measurements into exactly
//!   the values the original run folded.
//!
//! Journals carry **no GP-backend state**: the sparse inducing selection
//! is a pure function of the absorbed points and the [`FitConfig`]'s
//! backend (`gp::select_inducing`), so a resume under `--gp sparse:<m>`
//! re-derives the identical inducing set from the replayed points — the
//! checkpoint schema did not change for PR 9.
//!
//! [`Checkpointer`] handles the durability side: atomic tmp-file +
//! rename writes every `k` absorbed rounds, so a crash mid-write leaves
//! the previous checkpoint intact, never a torn file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::thor::store::GpStore;
use crate::util::json::Json;

#[cfg(doc)]
use crate::thor::fit::{FamilyFit, FitConfig};

/// The serializable acquisition history of one in-flight [`FamilyFit`]:
/// the family dimension plus one `(occupancy, folded results)` entry per
/// absorbed round, exactly as [`FamilyFit::journal`] reports it.
#[derive(Clone, Debug, PartialEq)]
pub struct FitJournal {
    pub dim: usize,
    pub rounds: Vec<(usize, Vec<(f64, f64)>)>,
}

impl FitJournal {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dim", Json::Num(self.dim as f64)),
            (
                "rounds",
                Json::Arr(
                    self.rounds
                        .iter()
                        .map(|(occ, results)| {
                            Json::obj(vec![
                                ("occ", Json::Num(*occ as f64)),
                                (
                                    "results",
                                    Json::Arr(
                                        results.iter().map(|&(e, dt)| Json::arr_f64(&[e, dt])).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        let dim = j.get("dim")?.as_usize()?;
        let mut rounds = Vec::new();
        for r in j.get("rounds")?.as_arr()? {
            let occ = r.get("occ")?.as_usize()?;
            let mut results = Vec::new();
            for pair in r.get("results")?.as_arr()? {
                let v = pair.as_f64_vec()?;
                if v.len() != 2 {
                    return None;
                }
                results.push((v[0], v[1]));
            }
            rounds.push((occ, results));
        }
        Some(Self { dim, rounds })
    }
}

/// The key an in-flight journal is filed under — the same
/// `"{device}|{family}"` shape the store uses internally.
pub fn inflight_key(device: &str, family: &str) -> String {
    format!("{device}|{family}")
}

/// A durable snapshot of a profiling run: everything finished (the
/// store) and everything in flight (per-family journals).
#[derive(Default)]
pub struct Checkpoint {
    pub store: GpStore,
    /// Keyed by [`inflight_key`].
    pub inflight: BTreeMap<String, FitJournal>,
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("store", self.store.to_json()),
            (
                "inflight",
                Json::Obj(self.inflight.iter().map(|(k, v)| (k.clone(), v.to_json())).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        let store = GpStore::from_json(j.get("store")?)?;
        let mut inflight = BTreeMap::new();
        for (k, v) in j.get("inflight")?.as_obj()? {
            inflight.insert(k.clone(), FitJournal::from_json(v)?);
        }
        Some(Self { store, inflight })
    }

    /// `Ok(None)` when the file does not exist (a cold start, not an
    /// error — crash-loop operation passes the same path to `--resume`
    /// and `--checkpoint` from the first launch on).
    pub fn load(path: &Path) -> std::io::Result<Option<Self>> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e),
        };
        match Json::parse(&text).ok().as_ref().and_then(Self::from_json) {
            Some(ck) => Ok(Some(ck)),
            None => Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{path:?} is not a checkpoint artifact"),
            )),
        }
    }
}

/// Periodic atomic checkpoint writer: counts absorbed rounds and, every
/// `every`-th, serializes a [`Checkpoint`] to `<path>.tmp` and renames
/// it over `path` — a crash between absorbs (or mid-write) always leaves
/// the last complete checkpoint on disk.
///
/// With [`Checkpointer::with_keep`] the previous `keep` snapshots are
/// rotated to `<path>.1` (newest history) … `<path>.N` (oldest) before
/// each rename, so an operator can step back past a checkpoint that
/// captured a bad state.  Writes also **compact** the in-flight set:
/// journals with no absorbed rounds are omitted, since replaying an
/// empty journal is exactly a cold start for that family — byte-neutral
/// on resume, smaller on disk.
#[derive(Debug)]
pub struct Checkpointer {
    path: PathBuf,
    every: usize,
    pending: usize,
    /// History snapshots to retain (`0` = overwrite in place, default).
    keep: usize,
    /// Completed atomic writes (observability + tests).
    pub writes: usize,
}

impl Checkpointer {
    /// `every` floors at 1 (write after every absorbed round).
    pub fn new(path: impl Into<PathBuf>, every: usize) -> Self {
        Self { path: path.into(), every: every.max(1), keep: 0, writes: 0, pending: 0 }
    }

    /// Retain the previous `keep` checkpoints as `<path>.1..=<path>.N`
    /// (`thor serve --checkpoint-keep N`).
    pub fn with_keep(mut self, keep: usize) -> Self {
        self.keep = keep;
        self
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Record `rounds` freshly absorbed rounds; writes a checkpoint (and
    /// returns `true`) once the configured cadence is reached.
    pub fn absorbed(
        &mut self,
        rounds: usize,
        store: &GpStore,
        inflight: &[(String, FitJournal)],
    ) -> std::io::Result<bool> {
        self.pending += rounds;
        if self.pending < self.every {
            return Ok(false);
        }
        self.pending = 0;
        self.write_now(store, inflight)?;
        Ok(true)
    }

    /// Unconditional atomic write of the current state (compacted; see
    /// the type docs), rotating history first when `keep > 0`.
    pub fn write_now(
        &mut self,
        store: &GpStore,
        inflight: &[(String, FitJournal)],
    ) -> std::io::Result<()> {
        let ck = Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("store", store.to_json()),
            (
                "inflight",
                Json::Obj(
                    inflight
                        .iter()
                        .filter(|(_, v)| !v.rounds.is_empty())
                        .map(|(k, v)| (k.clone(), v.to_json()))
                        .collect(),
                ),
            ),
        ]);
        let mut tmp = self.path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        std::fs::write(&tmp, ck.to_string())?;
        self.rotate();
        std::fs::rename(&tmp, &self.path)?;
        self.writes += 1;
        Ok(())
    }

    fn history_path(&self, i: usize) -> PathBuf {
        let mut p = self.path.as_os_str().to_owned();
        p.push(format!(".{i}"));
        PathBuf::from(p)
    }

    /// Shift `<path>` → `<path>.1` → … → `<path>.keep`; the oldest
    /// falls off the end.  Best-effort: a rotation failure (e.g. a
    /// history file deleted underneath us) must never block the write
    /// of the *current* checkpoint, which is the one that matters.
    fn rotate(&self) {
        if self.keep == 0 {
            return;
        }
        for i in (1..self.keep).rev() {
            let _ = std::fs::rename(self.history_path(i), self.history_path(i + 1));
        }
        let _ = std::fs::rename(&self.path, self.history_path(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::thor::fit::{FamilyFit, FitConfig};

    fn surface(x: f64) -> f64 {
        80.0 + 40.0 * (x * 2.0).min(1.0) + 10.0 * (3.0 * x).sin()
    }

    /// Drive a machine for `rounds` absorbed rounds and return its journal.
    fn journal_after(cfg: &FitConfig, rounds: usize) -> FitJournal {
        let mut fit = FamilyFit::new(1, cfg);
        for _ in 0..rounds {
            let ps = fit.propose(2).expect("machine ended early");
            let results: Vec<(f64, f64)> = ps.iter().map(|p| (surface(p[0]), 0.5)).collect();
            fit.absorb(&results);
        }
        FitJournal { dim: 1, rounds: fit.journal().to_vec() }
    }

    #[test]
    fn journal_json_roundtrip_is_bit_exact() {
        let cfg = FitConfig { max_points: 11, threshold_frac: 0.0, grid_n: 17, ..Default::default() };
        let j = journal_after(&cfg, 3);
        let parsed = Json::parse(&j.to_json().to_string()).unwrap();
        let back = FitJournal::from_json(&parsed).unwrap();
        assert_eq!(j, back, "journal must survive serialization bit-exactly");
        // ...and a replay from the deserialized journal continues the
        // machine exactly (the f64s are bit-identical, so this is the
        // same guarantee fit.rs pins — here we pin the JSON hop).
        let a = FamilyFit::replay(1, &cfg, &j.rounds).propose(2);
        let b = FamilyFit::replay(1, &cfg, &back.rounds).propose(2);
        assert_eq!(a, b);
    }

    #[test]
    fn sparse_backend_journal_replays_identically_through_json() {
        // The schema-stability pin for PR 9: a journal written by a
        // sparse-backend run is byte-identical in shape to an exact one
        // (no inducing indices on disk), and replaying it under the same
        // sparse FitConfig proposes identically to the live machine.
        use crate::gp::GpBackend;
        let cfg = FitConfig {
            max_points: 13,
            threshold_frac: 0.0,
            grid_n: 33,
            backend: GpBackend::Sparse { m: 6 },
            ..Default::default()
        };
        // 8 absorbed points > m = 6, so the replayed fits actually run
        // the sparse path (below that the backend resolves exact).
        let j = journal_after(&cfg, 8);
        let back = FitJournal::from_json(&Json::parse(&j.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(j, back);
        let a = FamilyFit::replay(1, &cfg, &j.rounds).propose(2);
        let b = FamilyFit::replay(1, &cfg, &back.rounds).propose(2);
        assert_eq!(a, b, "sparse replay must re-derive the same proposals after the JSON hop");
        assert!(a.is_some(), "machine must still be mid-acquisition at 8 absorbed rounds");
    }

    #[test]
    fn checkpoint_roundtrips_and_missing_file_is_a_cold_start() {
        let cfg = FitConfig { max_points: 11, threshold_frac: 0.0, grid_n: 17, ..Default::default() };
        let dir = std::env::temp_dir();
        let path = dir.join(format!("thor_ckpt_test_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        assert!(Checkpoint::load(&path).unwrap().is_none(), "missing file must read as None");

        let mut w = Checkpointer::new(&path, 2);
        let store = GpStore::new();
        let inflight = vec![(inflight_key("xavier", "conv:f"), journal_after(&cfg, 2))];
        // Cadence: 1 round pending — no write yet; the second reaches it.
        assert!(!w.absorbed(1, &store, &inflight).unwrap());
        assert!(!path.exists());
        assert!(w.absorbed(1, &store, &inflight).unwrap());
        assert_eq!(w.writes, 1);

        let ck = Checkpoint::load(&path).unwrap().expect("checkpoint written");
        assert_eq!(ck.store.len(), 0);
        assert_eq!(ck.inflight.len(), 1);
        assert_eq!(ck.inflight["xavier|conv:f"], inflight[0].1);
        // No torn tmp file left behind.
        let tmp = path.with_file_name(format!("{}.tmp", path.file_name().unwrap().to_string_lossy()));
        assert!(!tmp.exists(), "atomic write must not leave {tmp:?}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rotation_keeps_a_bounded_history_of_loadable_snapshots() {
        let cfg = FitConfig { max_points: 11, threshold_frac: 0.0, grid_n: 17, ..Default::default() };
        let dir = std::env::temp_dir();
        let path = dir.join(format!("thor_ckpt_rot_{}.json", std::process::id()));
        let hist = |i: usize| {
            path.with_file_name(format!(
                "{}.{i}",
                path.file_name().unwrap().to_string_lossy()
            ))
        };
        for p in [path.clone(), hist(1), hist(2), hist(3)] {
            let _ = std::fs::remove_file(p);
        }

        let store = GpStore::new();
        let mut w = Checkpointer::new(&path, 1).with_keep(2);
        // Four distinguishable writes: the journal grows one round each.
        for rounds in 1..=4 {
            let inflight = vec![(inflight_key("xavier", "conv:f"), journal_after(&cfg, rounds))];
            w.write_now(&store, &inflight).unwrap();
        }
        assert_eq!(w.writes, 4);

        // Newest on `path`, then one and two writes back; nothing older.
        let rounds_at = |p: &Path| {
            Checkpoint::load(p).unwrap().expect("snapshot must load").inflight["xavier|conv:f"]
                .rounds
                .len()
        };
        assert_eq!(rounds_at(&path), 4);
        assert_eq!(rounds_at(&hist(1)), 3, "<path>.1 must be the previous snapshot");
        assert_eq!(rounds_at(&hist(2)), 2, "<path>.2 must be two snapshots back");
        assert!(!hist(3).exists(), "history beyond --checkpoint-keep must fall off");

        for p in [path.clone(), hist(1), hist(2)] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn empty_journals_are_compacted_out_of_the_checkpoint() {
        let cfg = FitConfig { max_points: 11, threshold_frac: 0.0, grid_n: 17, ..Default::default() };
        let path =
            std::env::temp_dir().join(format!("thor_ckpt_compact_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut w = Checkpointer::new(&path, 1);
        let inflight = vec![
            // Zero absorbed rounds: replay is identical to a cold start
            // for this family, so the entry is pure dead weight.
            (inflight_key("xavier", "conv:a"), FitJournal { dim: 1, rounds: Vec::new() }),
            (inflight_key("xavier", "conv:f"), journal_after(&cfg, 2)),
        ];
        w.write_now(&GpStore::new(), &inflight).unwrap();
        let ck = Checkpoint::load(&path).unwrap().unwrap();
        assert_eq!(ck.inflight.len(), 1, "empty journals must be compacted out");
        assert!(ck.inflight.contains_key("xavier|conv:f"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_checkpoint_is_an_error_not_a_silent_cold_start() {
        let path = std::env::temp_dir().join(format!("thor_ckpt_bad_{}.json", std::process::id()));
        std::fs::write(&path, "{ not json").unwrap();
        assert!(Checkpoint::load(&path).is_err(), "corrupt artifacts must not be ignored");
        let _ = std::fs::remove_file(&path);
    }
}
