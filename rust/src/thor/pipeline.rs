//! The end-to-end THOR pipeline per (device, reference model): profile
//! every family with guided active learning (subtractivity applied
//! between stages exactly as eqs. 1–2 prescribe: output first, then
//! input, then each hidden family), store the fitted GPs, and estimate
//! arbitrary models from the store.

use crate::gp::KernelKind;
use crate::model::ModelGraph;
use crate::simdevice::Device;
use crate::thor::estimator::{estimate, estimate_cached, Estimate, EstimateCache, EstimateError};
use crate::thor::fit::{fit_family, FitConfig};
use crate::thor::parse::{parse, Position};
use crate::thor::profiler::{self, ranges};
use crate::thor::store::{GpStore, StoredGp};

#[derive(Clone, Copy, Debug)]
pub struct ThorConfig {
    /// Training iterations per variant measurement (paper: 500).
    pub iterations: usize,
    pub kind: KernelKind,
    pub max_points_1d: usize,
    pub max_points_2d: usize,
    pub threshold_frac: f64,
    pub grid_n_1d: usize,
    pub grid_n_2d: usize,
    pub time_surrogate: bool,
    pub random_sampling: bool,
    pub seed: u64,
}

impl Default for ThorConfig {
    fn default() -> Self {
        Self {
            iterations: 500,
            kind: KernelKind::Matern52,
            max_points_1d: 16,
            max_points_2d: 28,
            threshold_frac: 0.05,
            grid_n_1d: 33,
            grid_n_2d: 13,
            time_surrogate: false,
            random_sampling: false,
            seed: 20_25,
        }
    }
}

impl ThorConfig {
    /// Cheap settings for tests / quick demo runs.
    pub fn quick() -> Self {
        Self {
            iterations: 60,
            max_points_1d: 10,
            max_points_2d: 14,
            grid_n_1d: 17,
            grid_n_2d: 7,
            ..Default::default()
        }
    }

    fn fit_cfg(&self, dim: usize) -> FitConfig {
        FitConfig {
            kind: self.kind,
            max_points: if dim == 1 { self.max_points_1d } else { self.max_points_2d },
            threshold_frac: self.threshold_frac,
            grid_n: if dim == 1 { self.grid_n_1d } else { self.grid_n_2d },
            time_surrogate: self.time_surrogate,
            random_sampling: self.random_sampling,
            log_targets: true,
            seed: self.seed,
        }
    }
}

/// Map a normalized grid coordinate p ∈ [0, 1] to a channel count on a
/// log grid: c = round(c_max^p).  Profiling resolution then concentrates
/// at the narrow end, where the energy surface curves hardest
/// (occupancy ramps + tile padding).
pub fn log_channel(p: f64, c_max: f64) -> usize {
    c_max.powf(p).round().max(1.0) as usize
}

/// Per-family profiling summary (feeds Table 1 and Fig A14).
#[derive(Clone, Debug)]
pub struct FamilyReport {
    pub family: String,
    pub points: usize,
    pub device_seconds: f64,
    pub fit_seconds: f64,
    pub converged: bool,
}

#[derive(Clone, Debug, Default)]
pub struct ProfileReport {
    pub families: Vec<FamilyReport>,
}

impl ProfileReport {
    pub fn device_seconds(&self) -> f64 {
        self.families.iter().map(|f| f.device_seconds).sum()
    }

    pub fn fit_seconds(&self) -> f64 {
        self.families.iter().map(|f| f.fit_seconds).sum()
    }

    pub fn total_points(&self) -> usize {
        self.families.iter().map(|f| f.points).sum()
    }
}

/// THOR instance: a GP store plus configuration.
pub struct Thor {
    pub store: GpStore,
    pub cfg: ThorConfig,
}

impl Thor {
    pub fn new(cfg: ThorConfig) -> Self {
        Self { store: GpStore::new(), cfg }
    }

    /// Profile every family of `reference` on `dev` (idempotent per
    /// family: already-profiled families are skipped, the paper's
    /// "one-time endeavor" reuse property).
    pub fn profile(&mut self, dev: &mut Device, reference: &ModelGraph) -> ProfileReport {
        let parsed = parse(reference);
        let rg = ranges(&parsed);
        let dev_name = dev.profile.name.to_string();
        let iterations = self.cfg.iterations;
        let mut report = ProfileReport::default();

        let out_tmpl = parsed.output_groups().next().expect("no output group").clone();
        let in_tmpl = parsed.input_groups().next().expect("no input group").clone();
        let out_fam = out_tmpl.key.id();
        let in_fam = in_tmpl.key.id();

        // --- stage 1: output family, measured directly -------------------
        if !self.store.contains(&dev_name, &out_fam) {
            let out_max = rg.out_max as f64;
            let outcome = fit_family(
                |p| {
                    let c = log_channel(p[0], out_max);
                    let g = profiler::output_variant(&out_tmpl, c);
                    profiler::measure(dev, &g, iterations)
                },
                1,
                &self.cfg.fit_cfg(1),
            );
            report.families.push(FamilyReport {
                family: out_fam.clone(),
                points: outcome.points.len(),
                device_seconds: outcome.device_seconds,
                fit_seconds: outcome.fit_seconds,
                converged: outcome.converged,
            });
            self.store.insert(
                &dev_name,
                &out_fam,
                StoredGp {
                    gp: outcome.gp,
                    x_max: vec![out_max],
                    log_x: true,
                    log_y: true,
                    device_seconds: outcome.device_seconds,
                    fit_seconds: outcome.fit_seconds,
                    converged: outcome.converged,
                },
            );
        }

        // --- stage 2: input family via eq. (1) ----------------------------
        if !self.store.contains(&dev_name, &in_fam) {
            let in_max = rg.in_max as f64;
            let out_gp = self.store.get(&dev_name, &out_fam).expect("stage order").clone();
            let outcome = fit_family(
                |p| {
                    let c = log_channel(p[0], in_max);
                    let (g, fc_in) = profiler::input_variant(&in_tmpl, &out_tmpl, c);
                    let (e_total, dt) = profiler::measure(dev, &g, iterations);
                    let (e_out, _) = out_gp.predict_raw(&[fc_in as f64]);
                    ((e_total - e_out.max(0.0)).max(1e-12), dt)
                },
                1,
                &self.cfg.fit_cfg(1),
            );
            report.families.push(FamilyReport {
                family: in_fam.clone(),
                points: outcome.points.len(),
                device_seconds: outcome.device_seconds,
                fit_seconds: outcome.fit_seconds,
                converged: outcome.converged,
            });
            self.store.insert(
                &dev_name,
                &in_fam,
                StoredGp {
                    gp: outcome.gp,
                    x_max: vec![in_max],
                    log_x: true,
                    log_y: true,
                    device_seconds: outcome.device_seconds,
                    fit_seconds: outcome.fit_seconds,
                    converged: outcome.converged,
                },
            );
        }

        // --- stage 3: each hidden family via eq. (2) ----------------------
        for (fi, fam) in parsed.families.iter().enumerate() {
            if fam.position != Position::Hidden {
                continue;
            }
            let fam_id = fam.id();
            if self.store.contains(&dev_name, &fam_id) {
                continue;
            }
            let tmpl = parsed.template(fam).unwrap().clone();
            let (a_max, b_max) = rg.hidden_max[fi];
            let (a_max, b_max) = (a_max.max(2) as f64, b_max.max(2) as f64);
            let in_gp = self.store.get(&dev_name, &in_fam).expect("stage order").clone();
            let out_gp = self.store.get(&dev_name, &out_fam).expect("stage order").clone();
            let outcome = fit_family(
                |p| {
                    let a = log_channel(p[0], a_max);
                    let b = log_channel(p[1], b_max);
                    let (g, thin, fc_in) = profiler::hidden_variant(&in_tmpl, &tmpl, &out_tmpl, a, b);
                    let (e_total, dt) = profiler::measure(dev, &g, iterations);
                    let (e_in, _) = in_gp.predict_raw(&[thin as f64]);
                    let (e_out, _) = out_gp.predict_raw(&[fc_in as f64]);
                    ((e_total - e_in.max(0.0) - e_out.max(0.0)).max(1e-12), dt)
                },
                2,
                &self.cfg.fit_cfg(2),
            );
            report.families.push(FamilyReport {
                family: fam_id.clone(),
                points: outcome.points.len(),
                device_seconds: outcome.device_seconds,
                fit_seconds: outcome.fit_seconds,
                converged: outcome.converged,
            });
            self.store.insert(
                &dev_name,
                &fam_id,
                StoredGp {
                    gp: outcome.gp,
                    x_max: vec![a_max, b_max],
                    log_x: true,
                    log_y: true,
                    device_seconds: outcome.device_seconds,
                    fit_seconds: outcome.fit_seconds,
                    converged: outcome.converged,
                },
            );
        }
        report
    }

    /// Estimate a model's per-iteration energy from the fitted store.
    pub fn estimate(&self, device: &str, model: &ModelGraph) -> Result<Estimate, EstimateError> {
        estimate(&self.store, device, model)
    }

    /// [`Thor::estimate`] with a caller-owned memo cache — thread one
    /// cache through a candidate sweep (e.g. the pruning search) so
    /// repeated family×width queries skip the GP.  Results are
    /// bit-identical to [`Thor::estimate`].  The cache memoizes this
    /// store's *current* GPs: drop it if [`Thor::profile`] runs again.
    pub fn estimate_cached(
        &self,
        device: &str,
        model: &ModelGraph,
        cache: &mut EstimateCache,
    ) -> Result<Estimate, EstimateError> {
        estimate_cached(&self.store, device, model, cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::simdevice::{devices, Device};
    use crate::util::stats::mape;
    use crate::workload::{fusion::fuse, lower::lower};

    /// End-to-end sanity: profile the cnn5 family set on Xavier, then
    /// estimate random variants and compare against the simulator ground
    /// truth.  This is a miniature of Fig 7/8 and the single most
    /// important integration test in the repo.
    #[test]
    fn thor_beats_trivial_on_cnn5_xavier() {
        // Full-size reference + default budgets: the quick() budgets are
        // for smoke tests; estimation quality needs the paper's scale.
        let reference = zoo::cnn5(&[16, 32, 64, 128], 28, 10);
        let mut dev = Device::new(devices::xavier(), 42);
        let mut thor = Thor::new(ThorConfig { iterations: 200, ..ThorConfig::default() });
        let report = thor.profile(&mut dev, &reference);
        assert!(report.total_points() > 10);
        assert_eq!(report.families.len(), 5); // out, in, 3 hidden conv sizes

        // estimate 12 random variants vs measured ground truth (the
        // paper's protocol: mean of repeated metered runs)
        let mut rng = crate::util::rng::Pcg64::new(7);
        let mut actual = Vec::new();
        let mut est = Vec::new();
        for _ in 0..12 {
            let ch = [
                rng.range_usize(1, 16),
                rng.range_usize(1, 32),
                rng.range_usize(1, 64),
                rng.range_usize(1, 128),
            ];
            let g = zoo::cnn5(&ch, 28, 10);
            let tr = fuse(&lower(&g));
            let truth = (dev.run(&tr, 200).energy_per_iter() + dev.run(&tr, 200).energy_per_iter()) / 2.0;
            let e = thor.estimate("xavier", &g).unwrap();
            actual.push(truth);
            est.push(e.energy_per_iter);
        }
        let m = mape(&actual, &est);
        assert!(m < 35.0, "THOR MAPE {m}% too high: actual {actual:?} est {est:?}");
    }

    #[test]
    fn profile_is_idempotent() {
        let reference = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let mut dev = Device::new(devices::tx2(), 1);
        let mut thor = Thor::new(ThorConfig::quick());
        let r1 = thor.profile(&mut dev, &reference);
        let r2 = thor.profile(&mut dev, &reference);
        assert!(!r1.families.is_empty());
        assert!(r2.families.is_empty(), "second profile should be a no-op");
    }

    #[test]
    fn store_reusable_across_models_of_same_family() {
        // Profiling cnn5 once covers every narrower cnn5 variant.
        let reference = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let mut dev = Device::new(devices::server(), 5);
        let mut thor = Thor::new(ThorConfig::quick());
        thor.profile(&mut dev, &reference);
        let narrow = zoo::cnn5(&[2, 5, 9, 30], 16, 10);
        assert!(thor.estimate("server", &narrow).is_ok());
    }
}
