//! The end-to-end THOR pipeline per (device, reference model): profile
//! every family with guided active learning (subtractivity applied
//! between stages exactly as eqs. 1–2 prescribe: output first, then
//! input, then each hidden family), store the fitted GPs, and estimate
//! arbitrary models from the store.
//!
//! The pipeline is backend-agnostic: [`Thor::profile`] drives any
//! [`Measurer`] — the in-process simulator
//! ([`crate::thor::measure::LocalMeasurer`]), the TCP fleet
//! ([`crate::coordinator::FleetMeasurer`]), or the PJRT runtime stub
//! ([`crate::runtime::PjrtMeasurer`]) — through the same acquisition
//! code, so a fleet-profiled store and a local per-job-seeded store are
//! byte-identical (see `rust/tests/backend_equiv.rs`).
//!
//! # Heterogeneous (multi-class) profiling
//!
//! A backend may serve several device classes at once
//! ([`Measurer::devices`]); the driver then runs one
//! [`crate::thor::fit::FamilyFit`] machine per device — stage order
//! (out → in → hidden, the eq. 1–2 dependency chain) preserved *within*
//! each device — and **interleaves the classes**: every round it
//! gathers each device's proposals into one joint `measure_batch`, so a
//! mixed fleet has jobs of every class in flight simultaneously instead
//! of profiling classes back to back.  Each class's request stream
//! depends only on its own absorbed results, so the per-class
//! subsequences — and therefore the per-class store entries — are
//! byte-identical to a solo single-class run at the same effective
//! batch size.  For a single-class backend the driver degenerates to
//! exactly the sequential per-family loop (bit-compatible with the
//! pre-refactor pipeline, including the stateful
//! [`LocalMeasurer::sequential`] device stream).

use std::collections::{BTreeMap, VecDeque};

use crate::gp::{GpBackend, KernelKind};
use crate::model::ModelGraph;
use crate::simdevice::Device;
use crate::thor::checkpoint::{inflight_key, Checkpointer, FitJournal};
use crate::thor::estimator::{estimate, estimate_cached, Estimate, EstimateCache, EstimateError};
use crate::thor::fit::{Batch, FamilyFit, FitConfig, FitOutcome};
use crate::thor::measure::{LocalMeasurer, MeasureError, MeasureRequest, Measurer};
use crate::thor::parse::{parse, Group, Position};
use crate::thor::profiler::{self, ranges};
use crate::thor::store::{GpStore, StoredGp};

#[derive(Clone, Copy, Debug)]
pub struct ThorConfig {
    /// Training iterations per variant measurement (paper: 500).
    pub iterations: usize,
    pub kind: KernelKind,
    pub max_points_1d: usize,
    pub max_points_2d: usize,
    pub threshold_frac: f64,
    pub grid_n_1d: usize,
    pub grid_n_2d: usize,
    pub time_surrogate: bool,
    pub random_sampling: bool,
    /// Measurement requests proposed per GP round per device (top-k
    /// batched acquisition; see [`crate::thor::fit`]).  `Fixed(1)`
    /// reproduces the sequential loop bit-for-bit; fleet runs want
    /// `Fixed(worker count)` or `Auto` (sized each round from the live
    /// same-class worker count).
    pub batch: Batch,
    /// GP fit backend ([`GpBackend`]): exact Cholesky, sparse
    /// inducing-point, or the default `Auto` crossover.  Default-config
    /// family fits (≤ `max_points_2d` points) sit far below the `Auto`
    /// n-threshold, so stores stay byte-identical to the exact path
    /// unless `sparse:<m>` is forced.
    pub gp_backend: GpBackend,
    pub seed: u64,
}

impl Default for ThorConfig {
    fn default() -> Self {
        Self {
            iterations: 500,
            kind: KernelKind::Matern52,
            max_points_1d: 16,
            max_points_2d: 28,
            threshold_frac: 0.05,
            grid_n_1d: 33,
            grid_n_2d: 13,
            time_surrogate: false,
            random_sampling: false,
            batch: Batch::Fixed(1),
            gp_backend: GpBackend::default(),
            seed: 20_25,
        }
    }
}

impl ThorConfig {
    /// Cheap settings for tests / quick demo runs.
    pub fn quick() -> Self {
        Self {
            iterations: 60,
            max_points_1d: 10,
            max_points_2d: 14,
            grid_n_1d: 17,
            grid_n_2d: 7,
            ..Default::default()
        }
    }

    fn fit_cfg(&self, dim: usize) -> FitConfig {
        FitConfig {
            kind: self.kind,
            max_points: if dim == 1 { self.max_points_1d } else { self.max_points_2d },
            threshold_frac: self.threshold_frac,
            grid_n: if dim == 1 { self.grid_n_1d } else { self.grid_n_2d },
            time_surrogate: self.time_surrogate,
            random_sampling: self.random_sampling,
            log_targets: true,
            batch: self.batch,
            backend: self.gp_backend,
            seed: self.seed,
        }
    }
}

/// Map a normalized grid coordinate p ∈ [0, 1] to a channel count on a
/// log grid: c = round(c_max^p).  Profiling resolution then concentrates
/// at the narrow end, where the energy surface curves hardest
/// (occupancy ramps + tile padding).
pub fn log_channel(p: f64, c_max: f64) -> usize {
    c_max.powf(p).round().max(1.0) as usize
}

/// Per-family profiling summary (feeds Table 1 and Fig A14).
#[derive(Clone, Debug)]
pub struct FamilyReport {
    pub family: String,
    pub points: usize,
    pub device_seconds: f64,
    pub fit_seconds: f64,
    pub converged: bool,
}

#[derive(Clone, Debug, Default)]
pub struct ProfileReport {
    pub families: Vec<FamilyReport>,
}

impl ProfileReport {
    pub fn device_seconds(&self) -> f64 {
        self.families.iter().map(|f| f.device_seconds).sum()
    }

    pub fn fit_seconds(&self) -> f64 {
        self.families.iter().map(|f| f.fit_seconds).sum()
    }

    pub fn total_points(&self) -> usize {
        self.families.iter().map(|f| f.points).sum()
    }
}

/// Which subtraction rule (eqs. 1–2) one profiling stage applies.
enum StageKind {
    /// Measured directly.
    Output,
    /// Eq. (1): subtract the predicted output-family energy.
    Input,
    /// Eq. (2): subtract predicted input- and output-family energies.
    Hidden { tmpl: Group },
}

/// One family's place in a device's profiling plan.
struct Stage {
    family: String,
    dim: usize,
    x_max: Vec<f64>,
    kind: StageKind,
}

/// A live (device, family) fit: the acquisition machine plus the
/// already-fitted GPs its subtraction rule needs (cloned at activation
/// — stage order within the device guarantees they exist).
struct ActiveFit {
    stage: Stage,
    fit: FamilyFit,
    in_gp: Option<StoredGp>,
    out_gp: Option<StoredGp>,
}

impl ActiveFit {
    /// Normalized proposal → measurement request (log channel grid,
    /// exactly the mapping the single-class closures used).
    fn request(&self, device: &str, p: &[f64], iterations: usize) -> MeasureRequest {
        let channels: Vec<usize> = p
            .iter()
            .zip(&self.stage.x_max)
            .map(|(&pi, &mx)| log_channel(pi, mx))
            .collect();
        MeasureRequest {
            device: device.to_string(),
            family: self.stage.family.clone(),
            channels,
            iterations,
        }
    }

    /// Apply this stage's subtraction rule to one batch of raw
    /// measurements.  The measured variant is rebuilt from the request
    /// channels to read off the widths the subtracted groups saw — the
    /// subtraction coordinates stay in lock-step with
    /// [`crate::thor::profiler::VariantBuilder`] by construction.
    fn fold(
        &self,
        in_tmpl: &Group,
        out_tmpl: &Group,
        reqs: &[MeasureRequest],
        ms: &[crate::thor::measure::Measurement],
    ) -> Vec<(f64, f64)> {
        match &self.stage.kind {
            StageKind::Output => {
                ms.iter().map(|r| (r.energy_per_iter, r.device_seconds)).collect()
            }
            StageKind::Input => {
                let out_gp = self.out_gp.as_ref().expect("stage order");
                reqs.iter()
                    .zip(ms)
                    .map(|(req, r)| {
                        let (_, fc_in) =
                            profiler::input_variant(in_tmpl, out_tmpl, req.channels[0]);
                        let (e_out, _) = out_gp.predict_raw(&[fc_in as f64]);
                        ((r.energy_per_iter - e_out.max(0.0)).max(1e-12), r.device_seconds)
                    })
                    .collect()
            }
            StageKind::Hidden { tmpl } => {
                let in_gp = self.in_gp.as_ref().expect("stage order");
                let out_gp = self.out_gp.as_ref().expect("stage order");
                reqs.iter()
                    .zip(ms)
                    .map(|(req, r)| {
                        let (_, thin, fc_in) = profiler::hidden_variant(
                            in_tmpl,
                            tmpl,
                            out_tmpl,
                            req.channels[0],
                            req.channels[1],
                        );
                        let (e_in, _) = in_gp.predict_raw(&[thin as f64]);
                        let (e_out, _) = out_gp.predict_raw(&[fc_in as f64]);
                        (
                            (r.energy_per_iter - e_in.max(0.0) - e_out.max(0.0)).max(1e-12),
                            r.device_seconds,
                        )
                    })
                    .collect()
            }
        }
    }
}

/// One device class's progress through its profiling plan.
struct DeviceRun {
    device: String,
    plan: VecDeque<Stage>,
    active: Option<ActiveFit>,
}

/// Elasticity knobs for [`Thor::profile_with`]: resume in-flight
/// acquisition machines from a [`crate::thor::checkpoint::Checkpoint`]'s
/// journals, and/or write checkpoints as the run progresses.  The plain
/// [`Thor::profile`] is `profile_with` at defaults.
#[derive(Default)]
pub struct ProfileOptions<'a> {
    /// In-flight journals to replay at stage activation, keyed by
    /// [`inflight_key`].  Completed families resume for free through the
    /// pipeline's store idempotency (set `Thor::store` from the
    /// checkpoint's store before calling).
    pub resume: BTreeMap<String, FitJournal>,
    /// Periodic atomic checkpoint writer (counts absorbed rounds across
    /// all devices).  A write failure fails the run: the operator asked
    /// for durability, so losing it silently is not an option.
    pub checkpointer: Option<&'a mut Checkpointer>,
}

/// THOR instance: a GP store plus configuration.
pub struct Thor {
    pub store: GpStore,
    pub cfg: ThorConfig,
}

impl Thor {
    pub fn new(cfg: ThorConfig) -> Self {
        Self { store: GpStore::new(), cfg }
    }

    /// Record one fitted family into the report and the store.  The
    /// store is a byte-stable artifact compared across backends and
    /// runs (`rust/tests/backend_equiv.rs`, `rust/tests/fleet.rs`), so
    /// wall-clock never enters it — fitting wall-clock stays in the
    /// [`ProfileReport`] (display only).
    fn record(
        &mut self,
        report: &mut ProfileReport,
        dev_name: &str,
        family: &str,
        x_max: Vec<f64>,
        outcome: FitOutcome,
    ) {
        report.families.push(FamilyReport {
            family: family.to_string(),
            points: outcome.points.len(),
            device_seconds: outcome.device_seconds,
            fit_seconds: outcome.fit_seconds,
            converged: outcome.converged,
        });
        self.store.insert(
            dev_name,
            family,
            StoredGp {
                gp: outcome.gp,
                x_max,
                log_x: true,
                log_y: true,
                device_seconds: outcome.device_seconds,
                fit_seconds: 0.0,
                converged: outcome.converged,
            },
        );
    }

    /// Profile every family of `reference` for every device class of
    /// the backend (idempotent per (device, family): already-profiled
    /// entries are skipped, the paper's "one-time endeavor" reuse
    /// property).
    ///
    /// The backend only measures; acquisition, subtractivity (eqs. 1–2)
    /// and GP fitting all run here, leader-side — which is what makes a
    /// local run and a fleet run of the same config produce the same
    /// store.  Multi-class backends are driven round-interleaved (see
    /// the module docs) so a heterogeneous fleet stays saturated.
    /// Errors only when the backend does (e.g. every worker of a
    /// scheduled class disconnected); the in-process [`LocalMeasurer`]
    /// is infallible on families of its own reference model.
    pub fn profile(
        &mut self,
        m: &mut dyn Measurer,
        reference: &ModelGraph,
    ) -> Result<ProfileReport, MeasureError> {
        self.profile_with(m, reference, ProfileOptions::default())
    }

    /// [`Thor::profile`] with elasticity: checkpoint journals to resume
    /// from and/or a periodic checkpoint writer (see [`ProfileOptions`]).
    ///
    /// Resume is bit-exact: a replayed machine regenerates the RNG
    /// stream, warm-start chain and proposals of the original run
    /// ([`FamilyFit::replay`]), and the reloaded store's subtraction GPs
    /// predict bit-identically (gp::model's roundtrip pin), so the final
    /// store is byte-identical to an uninterrupted run's.  The only
    /// repeated work is the joint batch that was proposed but not yet
    /// absorbed when the previous leader died.
    pub fn profile_with(
        &mut self,
        m: &mut dyn Measurer,
        reference: &ModelGraph,
        mut opts: ProfileOptions<'_>,
    ) -> Result<ProfileReport, MeasureError> {
        let parsed = parse(reference);
        let rg = ranges(&parsed);
        let iterations = self.cfg.iterations;
        let mut report = ProfileReport::default();

        let out_tmpl = parsed.output_groups().next().expect("no output group").clone();
        let in_tmpl = parsed.input_groups().next().expect("no input group").clone();
        let out_fam = out_tmpl.key.id();
        let in_fam = in_tmpl.key.id();

        // Identical per-device plan: the subtraction chain fixes the
        // stage order (out → in → hidden families in parsed order).
        let make_plan = || -> VecDeque<Stage> {
            let mut plan = VecDeque::new();
            plan.push_back(Stage {
                family: out_fam.clone(),
                dim: 1,
                x_max: vec![rg.out_max as f64],
                kind: StageKind::Output,
            });
            plan.push_back(Stage {
                family: in_fam.clone(),
                dim: 1,
                x_max: vec![rg.in_max as f64],
                kind: StageKind::Input,
            });
            for (fi, fam) in parsed.families.iter().enumerate() {
                if fam.position != Position::Hidden {
                    continue;
                }
                let tmpl = parsed.template(fam).unwrap().clone();
                let (a_max, b_max) = rg.hidden_max[fi];
                plan.push_back(Stage {
                    family: fam.id(),
                    dim: 2,
                    x_max: vec![a_max.max(2) as f64, b_max.max(2) as f64],
                    kind: StageKind::Hidden { tmpl },
                });
            }
            plan
        };

        let mut devs: Vec<DeviceRun> = m
            .devices()
            .into_iter()
            .map(|device| DeviceRun { device, plan: make_plan(), active: None })
            .collect();

        loop {
            // Gather one acquisition round per device into a joint
            // batch; (device index, proposal count, request offset).
            let mut reqs: Vec<MeasureRequest> = Vec::new();
            let mut spans: Vec<(usize, usize, usize)> = Vec::new();
            for di in 0..devs.len() {
                // Advance this device until it has proposals in flight
                // or its plan is exhausted; finishing one family
                // activates the next in the same round.
                loop {
                    if devs[di].active.is_none() {
                        let device = devs[di].device.clone();
                        let stage = loop {
                            match devs[di].plan.pop_front() {
                                // idempotency: skip already-fitted families
                                Some(s) if self.store.contains(&device, &s.family) => continue,
                                s => break s,
                            }
                        };
                        let Some(stage) = stage else { break };
                        // Resume path: an in-flight journal for this
                        // family replays the machine bit-identically to
                        // where the checkpointed leader left it.
                        let fit_cfg = self.cfg.fit_cfg(stage.dim);
                        let fit = match opts.resume.remove(&inflight_key(&device, &stage.family)) {
                            Some(j) => {
                                assert_eq!(
                                    j.dim, stage.dim,
                                    "checkpoint journal for {device}|{} disagrees with the \
                                     reference model's family dimension",
                                    stage.family
                                );
                                FamilyFit::replay(stage.dim, &fit_cfg, &j.rounds)
                            }
                            None => FamilyFit::new(stage.dim, &fit_cfg),
                        };
                        let (in_gp, out_gp) = match stage.kind {
                            StageKind::Output => (None, None),
                            StageKind::Input => (
                                None,
                                Some(self.store.get(&device, &out_fam).expect("stage order").clone()),
                            ),
                            StageKind::Hidden { .. } => (
                                Some(self.store.get(&device, &in_fam).expect("stage order").clone()),
                                Some(self.store.get(&device, &out_fam).expect("stage order").clone()),
                            ),
                        };
                        devs[di].active = Some(ActiveFit { stage, fit, in_gp, out_gp });
                    }
                    let occ = m.occupancy(&devs[di].device);
                    let device = devs[di].device.clone();
                    let active = devs[di].active.as_mut().unwrap();
                    match active.fit.propose(occ) {
                        Some(ps) => {
                            let off = reqs.len();
                            for p in &ps {
                                reqs.push(active.request(&device, p, iterations));
                            }
                            spans.push((di, ps.len(), off));
                            break;
                        }
                        None => {
                            let af = devs[di].active.take().unwrap();
                            let Stage { family, x_max, .. } = af.stage;
                            let outcome = af.fit.finish();
                            self.record(&mut report, &device, &family, x_max, outcome);
                        }
                    }
                }
            }
            if reqs.is_empty() {
                break; // every device exhausted its plan
            }
            let ms = m.measure_batch(&reqs)?;
            let n_rounds = spans.len();
            for (di, n, off) in spans {
                let active = devs[di].active.as_mut().unwrap();
                let results =
                    active.fold(&in_tmpl, &out_tmpl, &reqs[off..off + n], &ms[off..off + n]);
                active.fit.absorb(&results);
            }
            // Durability point: everything measured so far is absorbed,
            // nothing is outstanding — exactly the state a resumed
            // leader can replay to.  (A machine whose journal is already
            // complete checkpoints as in-flight and finishes on replay;
            // `finish()` is deterministic, so that's byte-equivalent.)
            if let Some(ck) = opts.checkpointer.as_deref_mut() {
                let inflight: Vec<(String, FitJournal)> = devs
                    .iter()
                    .filter_map(|d| {
                        d.active.as_ref().map(|af| {
                            (
                                inflight_key(&d.device, &af.stage.family),
                                FitJournal {
                                    dim: af.stage.dim,
                                    rounds: af.fit.journal().to_vec(),
                                },
                            )
                        })
                    })
                    .collect();
                ck.absorbed(n_rounds, &self.store, &inflight)
                    .map_err(|e| MeasureError(format!("checkpoint write failed: {e}")))?;
            }
        }
        Ok(report)
    }

    /// [`Thor::profile`] over one in-process stateful device — the
    /// bit-compatible continuation of the original `&mut Device`
    /// pipeline (same request order, same device RNG stream).
    pub fn profile_local(&mut self, dev: &mut Device, reference: &ModelGraph) -> ProfileReport {
        let mut m = LocalMeasurer::sequential(dev, reference);
        self.profile(&mut m, reference).expect("local measurement is infallible")
    }

    /// Estimate a model's per-iteration energy from the fitted store.
    pub fn estimate(&self, device: &str, model: &ModelGraph) -> Result<Estimate, EstimateError> {
        estimate(&self.store, device, model)
    }

    /// [`Thor::estimate`] with a caller-owned memo cache — thread one
    /// cache through a candidate sweep (e.g. the pruning search) so
    /// repeated family×width queries skip the GP.  Results are
    /// bit-identical to [`Thor::estimate`].  The cache validates
    /// against the store's generation stamp, so it self-invalidates if
    /// [`Thor::profile`] runs again between calls.
    pub fn estimate_cached(
        &self,
        device: &str,
        model: &ModelGraph,
        cache: &mut EstimateCache,
    ) -> Result<Estimate, EstimateError> {
        estimate_cached(&self.store, device, model, cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::simdevice::{devices, Device};
    use crate::util::stats::mape;
    use crate::workload::{fusion::fuse, lower::lower};

    /// End-to-end sanity: profile the cnn5 family set on Xavier, then
    /// estimate random variants and compare against the simulator ground
    /// truth.  This is a miniature of Fig 7/8 and the single most
    /// important integration test in the repo.
    #[test]
    fn thor_beats_trivial_on_cnn5_xavier() {
        // Full-size reference + default budgets: the quick() budgets are
        // for smoke tests; estimation quality needs the paper's scale.
        let reference = zoo::cnn5(&[16, 32, 64, 128], 28, 10);
        let mut dev = Device::new(devices::xavier(), 42);
        let mut thor = Thor::new(ThorConfig { iterations: 200, ..ThorConfig::default() });
        let report = thor.profile_local(&mut dev, &reference);
        assert!(report.total_points() > 10);
        assert_eq!(report.families.len(), 5); // out, in, 3 hidden conv sizes

        // estimate 12 random variants vs measured ground truth (the
        // paper's protocol: mean of repeated metered runs)
        let mut rng = crate::util::rng::Pcg64::new(7);
        let mut actual = Vec::new();
        let mut est = Vec::new();
        for _ in 0..12 {
            let ch = [
                rng.range_usize(1, 16),
                rng.range_usize(1, 32),
                rng.range_usize(1, 64),
                rng.range_usize(1, 128),
            ];
            let g = zoo::cnn5(&ch, 28, 10);
            let tr = fuse(&lower(&g));
            let truth = (dev.run(&tr, 200).energy_per_iter() + dev.run(&tr, 200).energy_per_iter()) / 2.0;
            let e = thor.estimate("xavier", &g).unwrap();
            actual.push(truth);
            est.push(e.energy_per_iter);
        }
        let m = mape(&actual, &est);
        assert!(m < 35.0, "THOR MAPE {m}% too high: actual {actual:?} est {est:?}");
    }

    #[test]
    fn profile_is_idempotent() {
        let reference = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let mut dev = Device::new(devices::tx2(), 1);
        let mut thor = Thor::new(ThorConfig::quick());
        let r1 = thor.profile_local(&mut dev, &reference);
        let r2 = thor.profile_local(&mut dev, &reference);
        assert!(!r1.families.is_empty());
        assert!(r2.families.is_empty(), "second profile should be a no-op");
    }

    #[test]
    fn measurer_driven_profile_with_per_job_backend_and_batch() {
        let reference = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let mut thor = Thor::new(ThorConfig { batch: Batch::Fixed(3), ..ThorConfig::quick() });
        let mut m = LocalMeasurer::per_job(devices::xavier(), 42, &reference);
        let report = thor.profile(&mut m, &reference).unwrap();
        assert_eq!(report.families.len(), 5);
        assert!(thor.estimate("xavier", &zoo::cnn5(&[4, 8, 16, 32], 16, 10)).is_ok());
    }

    #[test]
    fn per_job_profile_is_run_to_run_byte_identical() {
        // The store is a byte-stable artifact: no wall-clock inside, and
        // per-request seeding makes it a pure function of the config.
        let reference = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let run = || {
            let mut thor = Thor::new(ThorConfig { batch: Batch::Fixed(2), ..ThorConfig::quick() });
            let mut m = LocalMeasurer::per_job(devices::tx2(), 7, &reference);
            thor.profile(&mut m, &reference).unwrap();
            thor.store.to_json().to_string()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn multi_class_profile_equals_per_class_profiles_merged() {
        // The heterogeneous driver contract, at the in-process level:
        // one multi-class backend profiled in one pipeline run produces
        // the same store as per-class runs merged — interleaving classes
        // never perturbs a class's fit.  (The fleet-level version over
        // real sockets lives in rust/tests/backend_equiv.rs.)
        let reference = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let cfg = ThorConfig { batch: Batch::Fixed(2), ..ThorConfig::quick() };
        let mut hetero = Thor::new(cfg);
        let mut m = LocalMeasurer::per_job_fleet(
            vec![devices::xavier(), devices::tx2()],
            42,
            &reference,
        );
        let report = hetero.profile(&mut m, &reference).unwrap();
        assert_eq!(report.families.len(), 10, "5 families × 2 classes");

        let mut merged = crate::thor::store::GpStore::new();
        for profile in [devices::xavier(), devices::tx2()] {
            let seed = crate::thor::profiler::class_seed(42, profile.name);
            let mut solo = Thor::new(cfg);
            let mut sm = LocalMeasurer::per_job(profile, seed, &reference);
            solo.profile(&mut sm, &reference).unwrap();
            merged.merge(solo.store);
        }
        assert_eq!(
            hetero.store.to_json().to_string(),
            merged.to_json().to_string(),
            "multi-class store diverged from merged per-class stores"
        );
    }

    #[test]
    fn store_reusable_across_models_of_same_family() {
        // Profiling cnn5 once covers every narrower cnn5 variant.
        let reference = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let mut dev = Device::new(devices::server(), 5);
        let mut thor = Thor::new(ThorConfig::quick());
        thor.profile_local(&mut dev, &reference);
        let narrow = zoo::cnn5(&[2, 5, 9, 30], 16, 10);
        assert!(thor.estimate("server", &narrow).is_ok());
    }
}
