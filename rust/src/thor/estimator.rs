//! Additive estimation (paper §3.4, eq. 4): parse the target model, look
//! up each group's family GP, predict at the group's channel features,
//! and sum:
//!
//! Ê_model = Ê_input(C₁) + Σ Ê_hidden(C_{i−1}, C_i) + Ê_output(C_{n−1})
//!
//! §Perf: queries are grouped **by family** and answered with one
//! `predict_batch` per family (ResNet-56's 55 groups collapse to a
//! handful of batched GP calls), with an optional [`EstimateCache`]
//! memoizing `(family, features) → (mean, var)` across calls — the
//! pruning candidate sweep re-queries the same few families at
//! overlapping widths thousands of times.  Both paths are bit-identical
//! to the scalar per-group loop (asserted by tests): predictions are
//! scattered back and summed in group order, so even the float
//! accumulation order is unchanged.
//!
//! Caches validate against [`GpStore::generation`]: a re-profiled or
//! hot-reloaded store automatically invalidates memoized predictions, so
//! no caller has to remember to drop its cache.  The serving tier uses
//! [`SharedEstimateCache`] — the same memo sharded behind per-shard
//! `RwLock`s so daemon worker threads read concurrently — and
//! [`estimate_batch_shared`], which coalesces same-family GP queries
//! across an entire request batch into single `predict_raw_batch` calls
//! while keeping every individual answer bit-identical to [`estimate`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::model::ModelGraph;
use crate::thor::parse::{parse, Position};
use crate::thor::store::GpStore;
use crate::util::hash::Fnv1a;

#[derive(Debug, thiserror::Error)]
pub enum EstimateError {
    #[error("family '{0}' has no fitted GP for device '{1}' — profile it first")]
    MissingFamily(String, String),
}

/// An energy estimate with per-layer attribution.
#[derive(Clone, Debug)]
pub struct Estimate {
    /// Joules per training iteration.
    pub energy_per_iter: f64,
    /// Sum of per-layer predictive variances (independence assumption).
    pub variance: f64,
    /// (family id, raw features, layer estimate J) per group.
    pub per_layer: Vec<(String, Vec<f64>, f64)>,
}

impl Estimate {
    /// Total energy for `iterations` iterations.
    pub fn total(&self, iterations: usize) -> f64 {
        self.energy_per_iter * iterations as f64
    }
}

/// Raw channel features of a group, by position (paper §3.2: output
/// channels for input layers, input channels for output layers, both for
/// hidden layers).  Output layers are characterized by their *effective*
/// input width (flattened for conv producers).
fn features(g: &crate::thor::parse::Group) -> Vec<f64> {
    match g.key.position {
        Position::Input => vec![g.anchor.c_out as f64],
        Position::Output => vec![g.anchor.c_in as f64],
        Position::Hidden => vec![g.anchor.c_in as f64, g.anchor.c_out as f64],
    }
}

/// Memoized per-family predictions keyed by (device, family id) and
/// feature bits — device is part of the key, so one cache can safely
/// span a sweep that touches several devices.  Thread one cache through
/// a candidate sweep (`pruning`) so repeated queries of the same family
/// at the same widths skip the GP entirely; cached values are exactly
/// what `predict_raw` would return, so results are unchanged.
///
/// The cache is a memo of one [`GpStore`] snapshot, identified by its
/// generation stamp: [`estimate_cached`] compares the stamp on every
/// call and drops all entries when the store has mutated since they
/// were filled, so re-profiling a family (or handing the same cache a
/// different store) can never serve a stale hit.
#[derive(Default)]
pub struct EstimateCache {
    /// `"{device}|{family}"` (the [`GpStore`] key convention) → memo.
    map: HashMap<String, HashMap<Vec<u64>, (f64, f64)>>,
    /// [`GpStore::generation`] the entries were computed against
    /// (0 = empty, matches no store).
    generation: u64,
    pub hits: u64,
    pub misses: u64,
}

impl EstimateCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.map.values().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.map.values().all(|m| m.is_empty())
    }

    /// Drop every entry unless it was computed against exactly this
    /// store state.  Hit/miss counters survive (they are observability,
    /// not correctness).
    fn validate(&mut self, store: &GpStore) {
        if self.generation != store.generation() {
            self.map.clear();
            self.generation = store.generation();
        }
    }
}

/// Number of shards a [`SharedEstimateCache`] defaults to — enough to
/// keep writer collisions rare at daemon thread counts (a shard is
/// picked per (device, family), and reads take shared locks anyway).
const DEFAULT_SHARDS: usize = 16;

/// One memoized posterior plus its recency stamp.  `used` is atomic so
/// hits can refresh it under the shard's *shared* lock; it orders
/// evictions only, never values, so the cache stays write-idempotent
/// in everything that matters for correctness.
struct CacheEntry {
    mv: (f64, f64),
    used: AtomicU64,
}

impl CacheEntry {
    fn new(mv: (f64, f64), tick: u64) -> Self {
        Self { mv, used: AtomicU64::new(tick) }
    }
}

/// One lock's worth of [`SharedEstimateCache`] state.
#[derive(Default)]
struct CacheShard {
    /// [`GpStore::generation`] this shard's entries were computed
    /// against (0 = empty).  Checked under the lock on every access, so
    /// a hot-reloaded store lazily invalidates shard by shard.
    generation: u64,
    map: HashMap<String, HashMap<Vec<u64>, CacheEntry>>,
}

impl CacheShard {
    fn entries(&self) -> usize {
        self.map.values().map(|m| m.len()).sum()
    }
}

/// [`EstimateCache`] for the serving tier: the same
/// `(device|family, feature-bits) → (mean, var)` memo, sharded by
/// `(device|family)` hash behind per-shard `RwLock`s so many daemon
/// threads resolve hits concurrently and writers only contend within
/// one family's shard.  Generation-stamped per shard against the store,
/// exactly like [`EstimateCache::validate`].
///
/// Entries are pure functions of `(store generation, device, family,
/// features)`, so racing writers can only ever insert identical values
/// — the cache is write-idempotent, and lock poisoning is recovered
/// from (`into_inner`) rather than propagated: a thread that dies
/// mid-request cannot poison a shard for everyone else.
pub struct SharedEstimateCache {
    shards: Vec<RwLock<CacheShard>>,
    /// Max entries per shard; `0` = unbounded (the default).  Enforced
    /// after each write pass by evicting least-recently-used entries.
    per_shard_cap: usize,
    /// Monotonic recency clock shared by all shards.
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for SharedEstimateCache {
    fn default() -> Self {
        Self::new(DEFAULT_SHARDS)
    }
}

impl SharedEstimateCache {
    pub fn new(n_shards: usize) -> Self {
        Self {
            shards: (0..n_shards.max(1)).map(|_| RwLock::default()).collect(),
            per_shard_cap: 0,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// A cache bounded to roughly `total_cap` entries across all shards
    /// (each shard holds its even share; `0` = unbounded).  Eviction is
    /// LRU per shard and only ever forgets memoized values — a bounded
    /// cache re-misses where an unbounded one would hit, but every
    /// served estimate stays bit-identical.
    pub fn bounded(total_cap: usize) -> Self {
        let mut c = Self::new(DEFAULT_SHARDS);
        let n = c.shards.len();
        c.per_shard_cap = if total_cap == 0 { 0 } else { (total_cap + n - 1) / n };
        c
    }

    fn shard_for(&self, key: &str) -> &RwLock<CacheShard> {
        let mut h = Fnv1a::new();
        h.write(key.as_bytes());
        &self.shards[(h.finish() % self.shards.len() as u64) as usize]
    }

    /// Drop least-recently-used entries until `shard` is back under the
    /// cap (to 7/8 of it, so a hot shard doesn't evict on every single
    /// insert).  Called with the shard's write lock held.
    fn enforce_cap(&self, sh: &mut CacheShard) {
        if self.per_shard_cap == 0 || sh.entries() <= self.per_shard_cap {
            return;
        }
        let target = (self.per_shard_cap * 7 / 8).max(1);
        let mut by_age: Vec<(u64, String, Vec<u64>)> = sh
            .map
            .iter()
            .flat_map(|(fam, m)| {
                m.iter().map(|(k, e)| (e.used.load(Ordering::Relaxed), fam.clone(), k.clone()))
            })
            .collect();
        by_age.sort_unstable();
        let n_evict = by_age.len().saturating_sub(target);
        for (_, fam, k) in by_age.into_iter().take(n_evict) {
            if let Some(m) = sh.map.get_mut(&fam) {
                m.remove(&k);
                if m.is_empty() {
                    sh.map.remove(&fam);
                }
            }
        }
        self.evictions.fetch_add(n_evict as u64, Ordering::Relaxed);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries evicted to stay under the [`SharedEstimateCache::bounded`]
    /// cap (0 for an unbounded cache).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Total memoized entries across all shards (deterministic for a
    /// fixed query set: entries are keyed by content, not by timing).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap_or_else(|e| e.into_inner()).entries())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// f64 features as exact hash keys (bit patterns; the features are
/// channel counts, so NaN never appears).
fn feat_key(feats: &[f64]) -> Vec<u64> {
    feats.iter().map(|f| f.to_bits()).collect()
}

/// Per-query precomputation shared by every estimation path: parsed
/// groups flattened to features, family ids, and group indices per
/// family.
struct QueryPlan {
    n: usize,
    feats: Vec<Vec<f64>>,
    fam_ids: Vec<String>,
    assignment: Vec<usize>,
    /// Group indices per family (first-appearance order = group order of
    /// each family's first member, so the "first missing family" error
    /// is the same one the scalar loop would report).
    by_fam: Vec<Vec<usize>>,
}

fn plan(model: &ModelGraph) -> QueryPlan {
    let parsed = parse(model);
    let n = parsed.groups.len();
    let feats: Vec<Vec<f64>> = parsed.groups.iter().map(features).collect();
    let fam_ids: Vec<String> = parsed.families.iter().map(|f| f.id()).collect();
    let mut by_fam: Vec<Vec<usize>> = vec![Vec::new(); fam_ids.len()];
    for (gi, &fi) in parsed.assignment.iter().enumerate() {
        by_fam[fi].push(gi);
    }
    QueryPlan { n, feats, fam_ids, assignment: parsed.assignment, by_fam }
}

impl QueryPlan {
    /// The first family (in family order, counting only families with
    /// members) missing from the store — the error [`estimate`]'s scalar
    /// loop would report.
    fn first_missing(&self, store: &GpStore, device: &str) -> Option<EstimateError> {
        for (fi, gidx) in self.by_fam.iter().enumerate() {
            if !gidx.is_empty() && !store.contains(device, &self.fam_ids[fi]) {
                return Some(EstimateError::MissingFamily(
                    self.fam_ids[fi].clone(),
                    device.to_string(),
                ));
            }
        }
        None
    }

    /// Fold resolved per-group (mean, var) pairs in group order — the
    /// same float accumulation order as the scalar per-group loop.
    fn fold(self, per_layer_mv: &[(f64, f64)]) -> Estimate {
        let mut energy = 0.0;
        let mut variance = 0.0;
        let mut per_layer = Vec::with_capacity(self.n);
        for (gi, feat) in self.feats.into_iter().enumerate() {
            let (m, v) = per_layer_mv[gi];
            let m = m.max(0.0); // energies are physical
            energy += m;
            variance += v;
            per_layer.push((self.fam_ids[self.assignment[gi]].clone(), feat, m));
        }
        Estimate { energy_per_iter: energy, variance, per_layer }
    }
}

/// Estimate a model's per-iteration training energy on `device`.
pub fn estimate(store: &GpStore, device: &str, model: &ModelGraph) -> Result<Estimate, EstimateError> {
    estimate_cached(store, device, model, &mut EstimateCache::new())
}

/// [`estimate`] with a caller-owned memo cache.  Queries are batched per
/// family: misses of one family go through a single `predict_batch`
/// call, hits skip the GP.  Per-layer results are scattered back and
/// folded in group order, so the output is bit-identical to the scalar
/// per-group loop regardless of cache state.
pub fn estimate_cached(
    store: &GpStore,
    device: &str,
    model: &ModelGraph,
    cache: &mut EstimateCache,
) -> Result<Estimate, EstimateError> {
    cache.validate(store);
    let p = plan(model);
    let QueryPlan { n, ref feats, ref fam_ids, ref by_fam, .. } = p;

    let mut per_layer_mv: Vec<(f64, f64)> = vec![(0.0, 0.0); n];
    for (fi, gidx) in by_fam.iter().enumerate() {
        if gidx.is_empty() {
            continue;
        }
        let fam = &fam_ids[fi];
        let stored = store
            .get(device, fam)
            .ok_or_else(|| EstimateError::MissingFamily(fam.clone(), device.to_string()))?;
        let fam_cache = cache.map.entry(format!("{device}|{fam}")).or_default();
        // one feat_key per missed group, reused for dedup + insertion
        let mut misses: Vec<(usize, Vec<u64>)> = Vec::new();
        for &gi in gidx {
            let key = feat_key(&feats[gi]);
            match fam_cache.get(&key) {
                Some(&mv) => {
                    per_layer_mv[gi] = mv;
                    cache.hits += 1;
                }
                None => {
                    misses.push((gi, key));
                    cache.misses += 1;
                }
            }
        }
        if !misses.is_empty() {
            // dedup identical features within the call: ResNet repeats
            // the same (family, width) dozens of times, and each unique
            // query costs an O(n²) posterior
            let mut uniq: Vec<Vec<f64>> = Vec::new();
            let mut slot_of: HashMap<&[u64], usize> = HashMap::new();
            let slots: Vec<usize> = misses
                .iter()
                .map(|(gi, key)| {
                    *slot_of.entry(key.as_slice()).or_insert_with(|| {
                        uniq.push(feats[*gi].clone());
                        uniq.len() - 1
                    })
                })
                .collect();
            let mv = stored.predict_raw_batch(&uniq);
            drop(slot_of);
            for ((gi, key), &slot) in misses.into_iter().zip(&slots) {
                per_layer_mv[gi] = mv[slot];
                fam_cache.insert(key, mv[slot]);
            }
        }
    }

    Ok(p.fold(&per_layer_mv))
}

/// [`estimate`] against a [`SharedEstimateCache`] — the daemon's
/// single-request path.  Identical results to [`estimate`] (it is the
/// one-query case of [`estimate_batch_shared`]).
pub fn estimate_shared(
    store: &GpStore,
    device: &str,
    model: &ModelGraph,
    cache: &SharedEstimateCache,
) -> Result<Estimate, EstimateError> {
    estimate_batch_shared(store, &[(device, model)], cache)
        .pop()
        .expect("one query in, one result out")
}

/// Estimate a whole batch of `(device, model)` queries against a shared
/// concurrent cache, coalescing same-family GP queries **across the
/// batch**: all cache-missed features of one `(device, family)` — from
/// every query that touches it — go through one `predict_raw_batch`
/// call.  Safe because batched GP prediction computes each point
/// independently (pinned by `predict_raw_batch_matches_scalar_bitwise`),
/// so batch composition never changes any individual answer: every
/// returned estimate is bit-identical to a standalone [`estimate`] call,
/// and errors match per query (one unknown family fails only its own
/// query).  Results come back in query order.
pub fn estimate_batch_shared(
    store: &GpStore,
    queries: &[(&str, &ModelGraph)],
    cache: &SharedEstimateCache,
) -> Vec<Result<Estimate, EstimateError>> {
    let plans: Vec<QueryPlan> = queries.iter().map(|(_, m)| plan(m)).collect();
    let errs: Vec<Option<EstimateError>> = queries
        .iter()
        .zip(&plans)
        .map(|((device, _), p)| p.first_missing(store, device))
        .collect();

    // Gather wanted groups per "{device}|{family}" key across the whole
    // batch, in first-appearance order (query order, then family order,
    // then group order — deterministic, and within one query identical
    // to the per-family order of `estimate_cached`).
    struct Gather<'a> {
        stored: &'a crate::thor::store::StoredGp,
        /// (query index, group index) pairs wanting this family.
        wants: Vec<(usize, usize)>,
    }
    let mut order: Vec<String> = Vec::new();
    let mut gathers: HashMap<String, Gather<'_>> = HashMap::new();
    for (qi, ((device, _), p)) in queries.iter().zip(&plans).enumerate() {
        if errs[qi].is_some() {
            continue;
        }
        for (fi, gidx) in p.by_fam.iter().enumerate() {
            if gidx.is_empty() {
                continue;
            }
            let fam = &p.fam_ids[fi];
            let key = format!("{device}|{fam}");
            let g = gathers.entry(key.clone()).or_insert_with(|| {
                order.push(key);
                Gather {
                    stored: store.get(device, fam).expect("validated by first_missing"),
                    wants: Vec::new(),
                }
            });
            g.wants.extend(gidx.iter().map(|&gi| (qi, gi)));
        }
    }

    let generation = store.generation();
    let mut per_query_mv: Vec<Vec<(f64, f64)>> =
        plans.iter().map(|p| vec![(0.0, 0.0); p.n]).collect();
    for key in &order {
        let g = &gathers[key];
        let shard = cache.shard_for(key);
        let mut misses: Vec<((usize, usize), Vec<u64>)> = Vec::new();
        {
            // read pass: shared lock; a shard stamped by a different
            // store state yields no hits (it is cleared lazily below)
            let sh = shard.read().unwrap_or_else(|e| e.into_inner());
            let fam_map = if sh.generation == generation { sh.map.get(key) } else { None };
            for &(qi, gi) in &g.wants {
                let k = feat_key(&plans[qi].feats[gi]);
                match fam_map.and_then(|m| m.get(&k)) {
                    Some(e) => {
                        per_query_mv[qi][gi] = e.mv;
                        // Refresh recency under the shared lock (atomic
                        // stamp; ordering races are harmless — any
                        // recent tick keeps the entry hot).
                        e.used.store(cache.tick.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
                        cache.hits.fetch_add(1, Ordering::Relaxed);
                    }
                    None => {
                        misses.push(((qi, gi), k));
                        cache.misses.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        if misses.is_empty() {
            continue;
        }
        // dedup identical features across the whole batch, then one GP
        // call for this family
        let mut uniq: Vec<Vec<f64>> = Vec::new();
        let mut slot_of: HashMap<&[u64], usize> = HashMap::new();
        let slots: Vec<usize> = misses
            .iter()
            .map(|((qi, gi), k)| {
                *slot_of.entry(k.as_slice()).or_insert_with(|| {
                    uniq.push(plans[*qi].feats[*gi].clone());
                    uniq.len() - 1
                })
            })
            .collect();
        let mv = g.stored.predict_raw_batch(&uniq);
        drop(slot_of);
        // write pass: exclusive lock; restamp-and-clear if the shard was
        // filled against some other store state.  Values are pure
        // functions of (generation, key, features), so concurrent
        // writers can only insert identical entries.
        let mut sh = shard.write().unwrap_or_else(|e| e.into_inner());
        if sh.generation != generation {
            sh.map.clear();
            sh.generation = generation;
        }
        let fam_map = sh.map.entry(key.clone()).or_default();
        for (((qi, gi), k), &slot) in misses.into_iter().zip(&slots) {
            per_query_mv[qi][gi] = mv[slot];
            fam_map.insert(k, CacheEntry::new(mv[slot], cache.tick.fetch_add(1, Ordering::Relaxed)));
        }
        cache.enforce_cap(&mut sh);
    }

    plans
        .into_iter()
        .zip(errs)
        .zip(per_query_mv)
        .map(|((p, err), mv)| match err {
            Some(e) => Err(e),
            None => Ok(p.fold(&mv)),
        })
        .collect()
}

/// The reactor's micro-batch drain path: estimate several independent
/// *units* (one unit = the queries of one protocol request — a single
/// `est` is a one-query unit, an `est_batch` is a many-query unit)
/// coalesced through **one** [`estimate_batch_shared`] call, then split
/// back per unit.  This is what turns cross-connection coalescing on:
/// same-`(device, family)` queries from different clients drained in
/// one micro-batch share one `predict_raw_batch` call.
///
/// Bit-identity is inherited, not re-derived: `estimate_batch_shared`
/// pins every individual answer to a standalone [`estimate`] regardless
/// of batch composition, so flattening units together cannot perturb
/// any reply.  Results come back unit-by-unit in unit order, each
/// unit's answers in its own query order.
pub fn estimate_units_shared(
    store: &GpStore,
    units: &[Vec<(&str, &ModelGraph)>],
    cache: &SharedEstimateCache,
) -> Vec<Vec<Result<Estimate, EstimateError>>> {
    let flat: Vec<(&str, &ModelGraph)> = units.iter().flatten().copied().collect();
    let mut answers = estimate_batch_shared(store, &flat, cache).into_iter();
    units.iter().map(|u| answers.by_ref().take(u.len()).collect()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{GpModel, KernelKind};
    use crate::model::zoo;
    use crate::thor::store::StoredGp;

    /// A store whose GPs encode a known linear function of features so
    /// the additive sum is checkable in closed form.
    fn synthetic_store(model: &ModelGraph, device: &str, coef: f64) -> GpStore {
        let mut store = GpStore::new();
        add_synthetic(&mut store, model, device, coef);
        store
    }

    fn add_synthetic(store: &mut GpStore, model: &ModelGraph, device: &str, coef: f64) {
        let parsed = parse(model);
        for fam in &parsed.families {
            let tmpl = parsed.template(fam).unwrap();
            let dim = match fam.position {
                Position::Hidden => 2,
                _ => 1,
            };
            let x_max = match fam.position {
                Position::Input => vec![tmpl.anchor.c_out as f64 * 2.0],
                Position::Output => vec![tmpl.anchor.c_in as f64 * 2.0],
                Position::Hidden => vec![tmpl.anchor.c_in as f64 * 2.0, tmpl.anchor.c_out as f64 * 2.0],
            };
            // fit an (almost) linear GP: y = coef * sum(features_norm)
            let grid: Vec<Vec<f64>> = if dim == 1 {
                (0..9).map(|i| vec![i as f64 / 8.0]).collect()
            } else {
                let mut v = Vec::new();
                for i in 0..5 {
                    for j in 0..5 {
                        v.push(vec![i as f64 / 4.0, j as f64 / 4.0]);
                    }
                }
                v
            };
            let ys: Vec<f64> = grid.iter().map(|p| coef * p.iter().sum::<f64>()).collect();
            let gp = GpModel::fit(KernelKind::Matern52, grid, &ys).unwrap();
            store.insert(
                device,
                &fam.id(),
                StoredGp { gp, x_max, log_x: false, log_y: false, device_seconds: 1.0, fit_seconds: 0.1, converged: true },
            );
        }
    }

    #[test]
    fn estimate_sums_per_layer_terms() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let store = synthetic_store(&g, "xavier", 10.0);
        let est = estimate(&store, "xavier", &g).unwrap();
        let sum: f64 = est.per_layer.iter().map(|(_, _, e)| e).sum();
        assert!((est.energy_per_iter - sum).abs() < 1e-9);
        assert_eq!(est.per_layer.len(), 5);
        assert!(est.energy_per_iter > 0.0);
    }

    #[test]
    fn missing_family_is_reported() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let store = synthetic_store(&g, "xavier", 10.0);
        match estimate(&store, "oppo", &g) {
            Err(EstimateError::MissingFamily(_, dev)) => assert_eq!(dev, "oppo"),
            other => panic!("expected MissingFamily, got {other:?}"),
        }
    }

    #[test]
    fn repeated_families_reuse_one_gp() {
        // ResNet-56 has 55 conv groups but ~an order fewer families; every
        // group must still get a per-layer term.
        let g = zoo::resnet(20, 8, 10);
        let store = synthetic_store(&g, "server", 5.0);
        let est = estimate(&store, "server", &g).unwrap();
        let parsed = parse(&g);
        assert_eq!(est.per_layer.len(), parsed.groups.len());
        assert!(parsed.families.len() < parsed.groups.len());
    }

    #[test]
    fn batched_estimate_matches_scalar_loop_exactly() {
        // The per-family batched path must reproduce the naive per-group
        // scalar loop bit-for-bit (ResNet has many groups per family, so
        // this exercises real batching).
        let g = zoo::resnet(20, 8, 10);
        let store = synthetic_store(&g, "xavier", 7.0);
        let est = estimate(&store, "xavier", &g).unwrap();

        let parsed = parse(&g);
        let mut energy = 0.0;
        let mut variance = 0.0;
        for (i, grp) in parsed.groups.iter().enumerate() {
            let fam = grp.key.id();
            let stored = store.get("xavier", &fam).unwrap();
            let feats = features(grp);
            let (m, v) = stored.predict_raw(&feats);
            let m = m.max(0.0);
            energy += m;
            variance += v;
            let (got_fam, got_feats, got_m) = &est.per_layer[i];
            assert_eq!(*got_fam, fam);
            assert_eq!(*got_feats, feats);
            assert_eq!(got_m.to_bits(), m.to_bits(), "group {i} mean diverged");
        }
        assert_eq!(est.energy_per_iter.to_bits(), energy.to_bits());
        assert_eq!(est.variance.to_bits(), variance.to_bits());
    }

    #[test]
    fn sparse_backend_store_estimates_bit_identical_to_scalar_loop() {
        // The estimator is backend-agnostic: a store whose family GPs were
        // fitted sparse (inducing-point posterior) must flow through the
        // batched plan, the scalar loop, and the cache with the same
        // bit-identity contracts as exact stores.
        use crate::gp::{FitWorkspace, GpBackend};
        let g = zoo::resnet(20, 8, 10);
        let parsed = parse(&g);
        let mut store = GpStore::new();
        let mut ws = FitWorkspace::new();
        for fam in &parsed.families {
            let tmpl = parsed.template(fam).unwrap();
            let (dim, x_max) = match fam.position {
                Position::Input => (1, vec![tmpl.anchor.c_out as f64 * 2.0]),
                Position::Output => (1, vec![tmpl.anchor.c_in as f64 * 2.0]),
                Position::Hidden => {
                    (2, vec![tmpl.anchor.c_in as f64 * 2.0, tmpl.anchor.c_out as f64 * 2.0])
                }
            };
            let grid: Vec<Vec<f64>> = if dim == 1 {
                (0..25).map(|i| vec![i as f64 / 24.0]).collect()
            } else {
                let mut v = Vec::new();
                for i in 0..7 {
                    for j in 0..7 {
                        v.push(vec![i as f64 / 6.0, j as f64 / 6.0]);
                    }
                }
                v
            };
            let ys: Vec<f64> = grid.iter().map(|p| 4.0 * p.iter().sum::<f64>() + 1.0).collect();
            let gp = GpModel::fit_b(&mut ws, KernelKind::Matern52, grid, &ys, GpBackend::Sparse { m: 8 })
                .unwrap();
            assert_eq!(gp.inducing().len(), 8, "family {} must actually fit sparse", fam.id());
            store.insert(
                "xavier",
                &fam.id(),
                StoredGp { gp, x_max, log_x: false, log_y: false, device_seconds: 1.0, fit_seconds: 0.1, converged: true },
            );
        }
        let est = estimate(&store, "xavier", &g).unwrap();
        let mut energy = 0.0;
        for (i, grp) in parsed.groups.iter().enumerate() {
            let stored = store.get("xavier", &grp.key.id()).unwrap();
            let (m, _) = stored.predict_raw(&features(grp));
            energy += m.max(0.0);
            assert_eq!(est.per_layer[i].2.to_bits(), m.max(0.0).to_bits(), "group {i}");
        }
        assert_eq!(est.energy_per_iter.to_bits(), energy.to_bits());
        let mut cache = EstimateCache::new();
        let cached = estimate_cached(&store, "xavier", &g, &mut cache).unwrap();
        assert_eq!(cached.energy_per_iter.to_bits(), est.energy_per_iter.to_bits());
        assert_eq!(cached.variance.to_bits(), est.variance.to_bits());
    }

    #[test]
    fn cached_estimate_hits_and_matches() {
        let g = zoo::resnet(20, 8, 10);
        let store = synthetic_store(&g, "server", 3.0);
        let mut cache = EstimateCache::new();
        let a = estimate_cached(&store, "server", &g, &mut cache).unwrap();
        assert!(cache.misses > 0 && cache.len() > 0);
        // ResNet repeats families at identical widths: the dedup keeps
        // unique entries below the group count, and a second pass over
        // the same model is all hits.
        assert!(cache.len() < parse(&g).groups.len(), "dedup should collapse repeats");
        let misses_after_first = cache.misses;
        let b = estimate_cached(&store, "server", &g, &mut cache).unwrap();
        assert_eq!(cache.misses, misses_after_first, "second pass should not miss");
        assert!(cache.hits as usize >= parse(&g).groups.len());
        assert_eq!(a.energy_per_iter.to_bits(), b.energy_per_iter.to_bits());
        assert_eq!(a.variance.to_bits(), b.variance.to_bits());
        // and the cached result equals the uncached one
        let c = estimate(&store, "server", &g).unwrap();
        assert_eq!(a.energy_per_iter.to_bits(), c.energy_per_iter.to_bits());
    }

    #[test]
    fn cache_keys_by_device() {
        // One cache across two devices must not cross-contaminate: the
        // same family ids exist on both, with different fitted surfaces.
        let g = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let mut store = synthetic_store(&g, "xavier", 10.0);
        add_synthetic(&mut store, &g, "server", 3.0);
        let mut cache = EstimateCache::new();
        let a = estimate_cached(&store, "xavier", &g, &mut cache).unwrap();
        let b = estimate_cached(&store, "server", &g, &mut cache).unwrap();
        assert_eq!(
            a.energy_per_iter.to_bits(),
            estimate(&store, "xavier", &g).unwrap().energy_per_iter.to_bits()
        );
        assert_eq!(
            b.energy_per_iter.to_bits(),
            estimate(&store, "server", &g).unwrap().energy_per_iter.to_bits()
        );
        assert!((a.energy_per_iter - b.energy_per_iter).abs() > 1e-6, "devices must differ");
    }

    #[test]
    fn reprofiling_never_serves_a_stale_hit() {
        // The old contract ("drop the cache yourself on re-profile") is
        // unenforceable from a daemon; the generation stamp enforces it.
        let g = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let mut store = synthetic_store(&g, "xavier", 10.0);
        let mut cache = EstimateCache::new();
        let before = estimate_cached(&store, "xavier", &g, &mut cache).unwrap();
        assert!(cache.len() > 0);
        // re-profile the same families onto the same store: different GP
        add_synthetic(&mut store, &g, "xavier", 3.0);
        let after = estimate_cached(&store, "xavier", &g, &mut cache).unwrap();
        let fresh = estimate(&store, "xavier", &g).unwrap();
        assert_eq!(
            after.energy_per_iter.to_bits(),
            fresh.energy_per_iter.to_bits(),
            "cache served a stale pre-reprofile hit"
        );
        assert!((before.energy_per_iter - after.energy_per_iter).abs() > 1e-6);
        // and a cache filled from one store must not leak into another
        let other = synthetic_store(&g, "xavier", 20.0);
        let x = estimate_cached(&other, "xavier", &g, &mut cache).unwrap();
        let y = estimate(&other, "xavier", &g).unwrap();
        assert_eq!(x.energy_per_iter.to_bits(), y.energy_per_iter.to_bits());
    }

    #[test]
    fn shared_cache_matches_estimate_bitwise() {
        let g = zoo::resnet(20, 8, 10);
        let store = synthetic_store(&g, "server", 3.0);
        let cache = SharedEstimateCache::default();
        // cold pass (all misses), then warm pass (all hits): both must
        // equal the uncached scalar path bit-for-bit
        for _ in 0..2 {
            let est = estimate_shared(&store, "server", &g, &cache).unwrap();
            let direct = estimate(&store, "server", &g).unwrap();
            assert_eq!(est.energy_per_iter.to_bits(), direct.energy_per_iter.to_bits());
            assert_eq!(est.variance.to_bits(), direct.variance.to_bits());
        }
        assert!(cache.hits() > 0 && cache.misses() > 0);
        assert!(cache.len() < parse(&g).groups.len(), "dedup should collapse repeats");
    }

    #[test]
    fn batch_coalescing_is_bit_identical_per_query() {
        // Several models sharing families in one batch: coalesced GP
        // calls must not perturb any individual answer.
        let wide = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let narrow = zoo::cnn5(&[4, 8, 16, 32], 16, 10);
        let mut store = synthetic_store(&wide, "xavier", 10.0);
        add_synthetic(&mut store, &wide, "tx2", 4.0);
        let cache = SharedEstimateCache::new(4);
        let queries: Vec<(&str, &ModelGraph)> =
            vec![("xavier", &wide), ("xavier", &narrow), ("tx2", &wide), ("xavier", &wide)];
        let got = estimate_batch_shared(&store, &queries, &cache);
        assert_eq!(got.len(), 4);
        for ((device, model), r) in queries.iter().zip(&got) {
            let direct = estimate(&store, device, model).unwrap();
            let r = r.as_ref().unwrap();
            assert_eq!(r.energy_per_iter.to_bits(), direct.energy_per_iter.to_bits());
            assert_eq!(r.variance.to_bits(), direct.variance.to_bits());
        }
    }

    #[test]
    fn unit_drain_is_bit_identical_and_splits_per_unit() {
        // Three "connections" drained in one micro-batch: a single, a
        // batch sharing families with it, and a single on another
        // device.  Every answer must equal a standalone estimate()
        // bit-for-bit, and errors must stay inside their own unit.
        let wide = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let narrow = zoo::cnn5(&[4, 8, 16, 32], 16, 10);
        let mut store = synthetic_store(&wide, "xavier", 10.0);
        add_synthetic(&mut store, &wide, "tx2", 4.0);
        let cache = SharedEstimateCache::new(4);
        let units: Vec<Vec<(&str, &ModelGraph)>> = vec![
            vec![("xavier", &wide)],
            vec![("xavier", &narrow), ("oppo", &wide), ("tx2", &wide)],
            vec![("tx2", &narrow)],
        ];
        let got = estimate_units_shared(&store, &units, &cache);
        assert_eq!(got.len(), units.len());
        for (unit, answers) in units.iter().zip(&got) {
            assert_eq!(unit.len(), answers.len(), "unit arity preserved");
            for ((device, model), a) in unit.iter().zip(answers) {
                match estimate(&store, device, model) {
                    Ok(direct) => {
                        let a = a.as_ref().expect("unit answer");
                        assert_eq!(a.energy_per_iter.to_bits(), direct.energy_per_iter.to_bits());
                        assert_eq!(a.variance.to_bits(), direct.variance.to_bits());
                    }
                    Err(EstimateError::MissingFamily(_, dev)) => {
                        assert!(
                            matches!(a, Err(EstimateError::MissingFamily(_, ref d)) if *d == dev),
                            "error must stay per-query: {a:?}"
                        );
                    }
                }
            }
        }
        // Empty units are legal (a drained request with zero queries)
        // and must not shift the split.
        let units2: Vec<Vec<(&str, &ModelGraph)>> =
            vec![vec![], vec![("xavier", &wide)], vec![]];
        let got2 = estimate_units_shared(&store, &units2, &cache);
        assert_eq!(got2[0].len(), 0);
        assert_eq!(got2[1].len(), 1);
        assert_eq!(got2[2].len(), 0);
        let direct = estimate(&store, "xavier", &wide).unwrap();
        assert_eq!(
            got2[1][0].as_ref().unwrap().energy_per_iter.to_bits(),
            direct.energy_per_iter.to_bits()
        );
    }

    #[test]
    fn batch_errors_are_per_query() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let store = synthetic_store(&g, "xavier", 10.0);
        let cache = SharedEstimateCache::default();
        let got = estimate_batch_shared(&store, &[("oppo", &g), ("xavier", &g)], &cache);
        assert!(matches!(got[0], Err(EstimateError::MissingFamily(_, ref d)) if d == "oppo"));
        let ok = got[1].as_ref().unwrap();
        let direct = estimate(&store, "xavier", &g).unwrap();
        assert_eq!(ok.energy_per_iter.to_bits(), direct.energy_per_iter.to_bits());
    }

    #[test]
    fn shared_cache_invalidates_on_store_swap() {
        // Hot reload: the same shared cache handed a mutated store must
        // re-derive every value from the new GPs.
        let g = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let cache = SharedEstimateCache::default();
        let store_a = synthetic_store(&g, "xavier", 10.0);
        let a = estimate_shared(&store_a, "xavier", &g, &cache).unwrap();
        let store_b = synthetic_store(&g, "xavier", 3.0);
        let b = estimate_shared(&store_b, "xavier", &g, &cache).unwrap();
        let direct_b = estimate(&store_b, "xavier", &g).unwrap();
        assert_eq!(b.energy_per_iter.to_bits(), direct_b.energy_per_iter.to_bits());
        assert!((a.energy_per_iter - b.energy_per_iter).abs() > 1e-6);
        // swap back: generation differs again (global counter), no alias
        let a2 = estimate_shared(&store_a, "xavier", &g, &cache).unwrap();
        assert_eq!(a2.energy_per_iter.to_bits(), a.energy_per_iter.to_bits());
    }

    #[test]
    fn shared_cache_concurrent_readers_stay_bit_identical() {
        use std::sync::Arc;
        let g = zoo::resnet(20, 8, 10);
        let store = Arc::new(synthetic_store(&g, "server", 5.0));
        let g = Arc::new(g);
        let cache = Arc::new(SharedEstimateCache::new(4));
        let expect = estimate(&store, "server", &g).unwrap().energy_per_iter.to_bits();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let (store, g, cache) = (store.clone(), g.clone(), cache.clone());
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        let e = estimate_shared(&store, "server", &g, &cache).unwrap();
                        assert_eq!(e.energy_per_iter.to_bits(), expect);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.hits() + cache.misses(), 8 * 50 * parse(&g).groups.len() as u64);
    }

    #[test]
    fn bounded_shared_cache_evicts_and_stays_bit_identical() {
        // Same model structure at many widths piles entries into the
        // same few "{device}|{family}" shard keys, so a tiny cap must
        // evict — and a bounded cache may only ever re-miss, never
        // change an answer.
        let reference = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let store = synthetic_store(&reference, "xavier", 4.0);
        let unbounded = SharedEstimateCache::default();
        let bounded = SharedEstimateCache::bounded(16); // one entry per shard
        for i in 0..24usize {
            let m = zoo::cnn5(&[4 + i, 8 + i, 16 + i, 32 + i], 16, 10);
            let a = estimate_shared(&store, "xavier", &m, &unbounded).unwrap();
            let b = estimate_shared(&store, "xavier", &m, &bounded).unwrap();
            assert_eq!(a.energy_per_iter.to_bits(), b.energy_per_iter.to_bits());
            assert_eq!(a.variance.to_bits(), b.variance.to_bits());
        }
        assert!(bounded.evictions() > 0, "a 16-entry cap must evict under 24 width variants");
        assert_eq!(unbounded.evictions(), 0, "an unbounded cache never evicts");
        assert!(bounded.len() <= 16, "cap violated: {} entries", bounded.len());
        assert!(unbounded.len() > bounded.len());
    }

    #[test]
    fn lru_keeps_recently_used_entries_over_cold_ones() {
        let reference = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let store = synthetic_store(&reference, "xavier", 4.0);
        let cache = SharedEstimateCache::bounded(160); // 10 per shard
        let hot = reference.clone();
        estimate_shared(&store, "xavier", &hot, &cache).unwrap();
        for i in 0..20usize {
            // a stream of cold width-variants, with the hot model
            // re-touched after each — its recency stamps stay newest
            let m = zoo::cnn5(&[5 + i, 9 + i, 17 + i, 33 + i], 16, 10);
            estimate_shared(&store, "xavier", &m, &cache).unwrap();
            estimate_shared(&store, "xavier", &hot, &cache).unwrap();
        }
        assert!(cache.evictions() > 0, "the cold stream must overflow the cap");
        let misses_before = cache.misses();
        estimate_shared(&store, "xavier", &hot, &cache).unwrap();
        assert_eq!(cache.misses(), misses_before, "hot entries must survive LRU eviction");
    }

    #[test]
    fn wider_model_estimates_higher() {
        let narrow = zoo::cnn5(&[4, 8, 16, 32], 28, 10);
        let wide = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        // one store fitted on the wide parse covers both (same families)
        let store = synthetic_store(&wide, "tx2", 20.0);
        let e_n = estimate(&store, "tx2", &narrow).unwrap().energy_per_iter;
        let e_w = estimate(&store, "tx2", &wide).unwrap().energy_per_iter;
        assert!(e_w > e_n, "{e_w} vs {e_n}");
    }
}
